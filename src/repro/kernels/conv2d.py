"""Spatially-tiled direct convolution as a Pallas TPU kernel.

The paper's compute hot-spot is CNN convolution on the client device.  The
TPU-native formulation: a KxK conv is K^2 shifted (Cout x Cin) @ (Cin x HW)
matmuls -- pure MXU work with the image tile resident in VMEM, instead of a
GPU-style im2col gather.

Grid: ``(batch, cout_blocks, h_blocks)``.  Each grid step stages

  * a *row tile* of the padded input -- ``tile_in_h = (tile_h-1)*stride + K``
    rows, i.e. the ``tile_h`` output rows it produces plus the K-1 halo rows
    shared with the neighbouring tiles (expressed with
    ``pl.BlockSpec(..., indexing_mode=pl.unblocked)`` so consecutive input
    blocks may overlap),
  * one ``block_co``-channel slice of the weights, and
  * the fp32 accumulator / output tile.

VMEM budget model
-----------------
Per grid step the kernel holds (``B = dtype bytes``; Pallas double-buffers
every streamed block for the HBM->VMEM pipeline, hence the factor 2):

    2 * [ cin_block * tile_in_h * W_in * B      (input row tile)
        + block_co * cin_per_group * K^2 * B    (weight slice)
        + block_co * 4                          (bias column, fp32)
        + block_co * tile_h * W_out * B ]       (output tile)
    +   block_co * tile_h * W_out * 4           (fp32 accumulator)

``choose_tile_h`` picks the largest ``tile_h`` whose estimate fits the
budget (default 12 MiB, leaving headroom inside a v5e core's ~16 MiB VMEM
for Mosaic scratch), then shrinks it to ``ceil(h_out / n_blocks)`` so the
final grid wastes as few padded rows as possible.  ``h_out`` need not be a
multiple of ``tile_h``: the wrapper zero-pads input rows so the remainder
tile reads in-bounds and slices the padded output rows away.

The epilogue (bias add + relu/relu6) runs on the fp32 accumulator before
writeback, so a paper-layer conv+bias+relu pair is one kernel launch.
Grouped convolution (``feature_group_count``) is supported: pointwise
(groups=1), group-aligned channel blocks (1 < groups < Cin), and the
depthwise case (cin_per_group == 1) which runs an elementwise VPU path
instead of degenerate 1-deep matmuls.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

VMEM_LIMIT_BYTES = 16 * 1024 * 1024     # one v5e core
DEFAULT_VMEM_BUDGET = 12 * 1024 * 1024  # headroom for Mosaic scratch


def conv_vmem_bytes(*, cin_block: int, block_co: int, tile_h: int,
                    w_in: int, w_out: int, K: int, stride: int,
                    cin_per_group: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM bytes one grid step of the tiled kernel occupies."""
    tile_in_h = (tile_h - 1) * stride + K
    x_b = cin_block * tile_in_h * w_in * dtype_bytes
    w_b = block_co * cin_per_group * K * K * dtype_bytes
    b_b = block_co * 4
    o_b = block_co * tile_h * w_out * dtype_bytes
    acc = block_co * tile_h * w_out * 4
    return 2 * (x_b + w_b + b_b + o_b) + acc


def choose_tile_h(h_out: int, *, cin_block: int, block_co: int, w_in: int,
                  w_out: int, K: int, stride: int, cin_per_group: int,
                  dtype_bytes: int = 4,
                  budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Largest output-row tile whose VMEM estimate fits ``budget``, shrunk
    to the smallest tile with the same block count (minimal padded waste)."""
    if h_out < 1:
        raise ValueError(f"invalid conv geometry: h_out={h_out} "
                         f"(kernel/stride larger than padded input)")
    est = functools.partial(
        conv_vmem_bytes, cin_block=cin_block, block_co=block_co,
        w_in=w_in, w_out=w_out, K=K, stride=stride,
        cin_per_group=cin_per_group, dtype_bytes=dtype_bytes)
    tile_h = next((t for t in range(min(h_out, 512), 0, -1)
                   if est(tile_h=t) <= budget), 0)
    if tile_h == 0:
        raise ValueError(
            f"conv tile of a single output row exceeds VMEM budget "
            f"({est(tile_h=1)} > {budget}); W-axis tiling not implemented")
    n_blocks = -(-h_out // tile_h)
    return -(-h_out // n_blocks)


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Static tiling decision + derived geometry for one conv shape
    (exposed for tests; ``conv2d`` consumes it so the BlockSpec geometry
    and the VMEM estimate can never desynchronise)."""
    block_co: int
    cin_block: int
    tile_h: int
    tile_in_h: int
    n_h_blocks: int
    vmem_bytes: int
    h_out: int
    w_out: int
    g_out: int          # output channels per group
    depthwise: bool


def plan_conv(x_shape: tuple, w_shape: tuple, *, stride: int = 1,
              pad: int = 0, groups: int = 1, block_co: int = 0,
              tile_h: int = 0, dtype_bytes: int = 4,
              vmem_budget: int = DEFAULT_VMEM_BUDGET) -> ConvPlan:
    """Pick (block_co, tile_h) for the grid and estimate per-step VMEM."""
    N, Cin, H, W = x_shape
    Cout, cin_pg, K, _ = w_shape
    if Cin != cin_pg * groups or Cout % groups:
        raise ValueError(f"bad grouping: x Cin={Cin}, w Cin/g={cin_pg}, "
                         f"groups={groups}, Cout={Cout}")
    g_out = Cout // groups
    depthwise = cin_pg == 1 and groups > 1
    if depthwise and g_out != 1:
        raise ValueError("depthwise with channel multiplier > 1 unsupported")
    if not block_co:
        # largest channel block <= 128 that divides the group structure
        limit = Cout if groups == 1 or depthwise else g_out
        block_co = next(b for b in range(min(limit, 128), 0, -1)
                        if limit % b == 0)
    if groups == 1 or depthwise:
        if Cout % block_co:
            raise ValueError(f"block_co={block_co} must divide Cout={Cout}")
    elif g_out % block_co:
        raise ValueError(f"block_co={block_co} must divide the per-group "
                         f"output channels ({g_out}) when groups > 1")
    cin_block = cin_pg * (block_co if depthwise else 1)
    h_in, w_in = H + 2 * pad, W + 2 * pad
    h_out = (h_in - K) // stride + 1
    w_out = (w_in - K) // stride + 1
    kw = dict(cin_block=cin_block, block_co=block_co, w_in=w_in,
              w_out=w_out, K=K, stride=stride, cin_per_group=cin_pg,
              dtype_bytes=dtype_bytes)
    if not tile_h:
        tile_h = choose_tile_h(h_out, budget=vmem_budget, **kw)
    tile_h = min(tile_h, h_out)
    return ConvPlan(
        block_co=block_co, cin_block=cin_block, tile_h=tile_h,
        tile_in_h=(tile_h - 1) * stride + K,
        n_h_blocks=-(-h_out // tile_h),
        vmem_bytes=conv_vmem_bytes(tile_h=tile_h, **kw),
        h_out=h_out, w_out=w_out, g_out=g_out, depthwise=depthwise)


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, K: int, stride: int,
                 tile_h: int, w_out: int, depthwise: bool,
                 activation: str | None):
    x = x_ref[0].astype(jnp.float32)           # (cin_block, tile_in_h, w_in)
    wts = w_ref[...].astype(jnp.float32)       # (block_co, cin_pg, K, K)
    block_co = wts.shape[0]
    cin = x.shape[0]
    if depthwise:
        # channel-aligned elementwise path: output channel c reads input
        # channel c of the staged block -- no MXU, pure VPU multiplies
        acc = jnp.zeros((block_co, tile_h, w_out), jnp.float32)
        for kh in range(K):
            for kw in range(K):
                xs = jax.lax.slice(
                    x, (0, kh, kw),
                    (cin, kh + (tile_h - 1) * stride + 1,
                     kw + (w_out - 1) * stride + 1),
                    (1, stride, stride))       # (block_co, tile_h, w_out)
                acc += xs * wts[:, 0, kh, kw][:, None, None]
        acc = acc.reshape(block_co, tile_h * w_out)
    else:
        acc = jnp.zeros((block_co, tile_h * w_out), jnp.float32)
        for kh in range(K):
            for kw in range(K):
                xs = jax.lax.slice(
                    x, (0, kh, kw),
                    (cin, kh + (tile_h - 1) * stride + 1,
                     kw + (w_out - 1) * stride + 1),
                    (1, stride, stride))       # (cin, tile_h, w_out)
                xs = xs.reshape(cin, tile_h * w_out)
                wk = wts[:, :, kh, kw]         # (block_co, cin)
                acc += jax.lax.dot_general(
                    wk, xs, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)  # (block_co, 1) broadcast
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "relu6":
        acc = jnp.clip(acc, 0.0, 6.0)
    o_ref[0] = acc.reshape(block_co, tile_h, w_out).astype(o_ref.dtype)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1,
           pad: int = 0, bias: jnp.ndarray | None = None,
           activation: str | None = None, groups: int = 1,
           block_co: int = 0, tile_h: int = 0,
           vmem_budget: int = DEFAULT_VMEM_BUDGET,
           interpret: bool = True) -> jnp.ndarray:
    """x: (N, Cin, H, W); w: (Cout, Cin/groups, K, K) -> (N, Cout, Ho, Wo).

    ``bias`` (Cout,) and ``activation`` in {None, "relu", "relu6"} fuse into
    the kernel epilogue; ``groups`` follows lax ``feature_group_count``."""
    if activation not in (None, "relu", "relu6"):
        raise ValueError(f"unknown activation {activation!r}")
    N, Cin, H, W = x.shape
    Cout, cin_pg, K, _ = w.shape
    plan = plan_conv(x.shape, w.shape, stride=stride, pad=pad, groups=groups,
                     block_co=block_co, tile_h=tile_h,
                     dtype_bytes=x.dtype.itemsize, vmem_budget=vmem_budget)
    block_co, tile_h = plan.block_co, plan.tile_h
    h_out, w_out, g_out = plan.h_out, plan.w_out, plan.g_out
    h_in, w_in = H + 2 * pad, W + 2 * pad
    # pad rows so the remainder tile's halo read stays in-bounds
    h_out_pad = plan.n_h_blocks * tile_h
    rows_needed = (h_out_pad - 1) * stride + K
    x = jnp.pad(x, ((0, 0), (0, 0),
                    (pad, pad + max(0, rows_needed - h_in)), (pad, pad)))
    if bias is None:
        bias = jnp.zeros((Cout,), jnp.float32)
    bias2d = bias.reshape(Cout, 1).astype(jnp.float32)

    kernel = functools.partial(
        _conv_kernel, K=K, stride=stride, tile_h=tile_h, w_out=w_out,
        depthwise=plan.depthwise, activation=activation)
    out = pl.pallas_call(
        kernel,
        grid=(N, Cout // block_co, plan.n_h_blocks),
        in_specs=[
            # overlapping (haloed) row tiles: element offsets, not block ids
            pl.BlockSpec(
                (1, plan.cin_block, plan.tile_in_h, w_in),
                lambda n, c, h: (n, c * block_co // g_out * cin_pg,
                                 h * tile_h * stride, 0),
                indexing_mode=pl.unblocked),
            pl.BlockSpec((block_co, cin_pg, K, K),
                         lambda n, c, h: (c, 0, 0, 0)),
            pl.BlockSpec((block_co, 1), lambda n, c, h: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_co, tile_h, w_out),
                               lambda n, c, h: (n, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Cout, h_out_pad, w_out), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(x, w, bias2d)
    return out[:, :, :h_out, :] if h_out_pad != h_out else out
