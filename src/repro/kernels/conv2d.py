"""Spatially-tiled direct convolution as a Pallas TPU kernel.

The paper's compute hot-spot is CNN convolution on the client device.  The
TPU-native formulation: a KxK conv is K^2 shifted (Cout x Cin) @ (Cin x HW)
matmuls -- pure MXU work with the image tile resident in VMEM, instead of a
GPU-style im2col gather.

Grid: ``(batch, cout_blocks, h_blocks, w_blocks)``.  Each grid step stages

  * a *rectangular tile* of the padded input -- ``tile_in_h x tile_in_w``
    elements, i.e. the conv rows/cols it produces plus the K-1 halo shared
    with the neighbouring tiles (expressed with
    ``pl.BlockSpec(..., indexing_mode=pl.unblocked)`` so consecutive input
    blocks may overlap along both spatial axes),
  * one ``block_co``-channel slice of the weights, and
  * the fp32 accumulator / output tile.

VMEM budget model
-----------------
Per grid step the kernel holds (``B = dtype bytes``; Pallas double-buffers
every streamed block for the HBM->VMEM pipeline, hence the factor 2).
Without a fused pool, ``tile_conv_h == tile_h`` / ``tile_conv_w == tile_w``;
with ``maxpool(pool_k, pool_s)`` fused, ``tile_h`` / ``tile_w`` count
*pooled* output rows/cols, so the accumulator spans
``tile_conv_h = (tile_h-1)*pool_s + pool_k`` conv rows (same for cols)
while the streamed output block shrinks to the pooled ``tile_h x tile_w``
footprint:

    2 * [ cin_block * tile_in_h * tile_in_w * B   (input tile)
        + block_co * cin_per_group * K^2 * B      (weight slice)
        + block_co * 4                            (bias column, fp32)
        + block_co * tile_h * tile_w * B ]        (pooled output tile)
    +   block_co * tile_conv_h * tile_conv_w * 4  (fp32 conv accumulator)

The pooled-epilogue term is why fusion *shrinks* the client-side memory
footprint the paper optimises: the conv activation lives only as the fp32
accumulator inside VMEM and is never written to HBM -- the kernel streams
out the (pool_s^2-times smaller) pooled tile instead.

Tiling search
-------------
``plan_conv`` picks ``(block_co, tile_h, tile_w)`` *jointly* by minimising
an explicit per-shape cost model over every channel-block divisor and a
dedup'd ladder of column splits (``plan_cost``: total HBM traffic the grid
streams -- input tiles including halo re-reads, the weight slice re-staged
every grid step, padded output tiles -- plus a fixed per-grid-step overhead
of ``LAUNCH_COST_BYTES`` bytes-equivalent).  For each candidate the largest
``tile_h`` whose VMEM estimate fits the budget (default 12 MiB, leaving
headroom inside a v5e core's ~16 MiB VMEM for Mosaic scratch) is found by
bisection -- the estimate is monotone in ``tile_h`` -- then shrunk to
``ceil(p_out / n_blocks)`` so the final grid wastes as few padded rows as
possible (columns get the same shrink).  The search subsumes the legacy
greedy choice (largest ``block_co <= 128``, then largest ``tile_h``) as a
candidate, so it never costs more than greedy; ``REPRO_CONV_SEARCH=0``
falls back to greedy exactly, and ``REPRO_CONV_TILE_W`` pins the column
tile (0 = automatic).

Column tiles open the wide-input workloads (1080p camera frames,
panoramic strips) where a *single output row* overflows VMEM and the
row-only planner had to give up: the W axis splits with the same
``pl.unblocked`` halo trick as rows, and with a fused pool the column
tiles land on pool-window starts exactly as pooled rows do.  ``h_out`` /
``pw_out`` need not be multiples of the tile: the wrapper zero-pads input
rows/cols so remainder tiles read in-bounds and slices the padded outputs
away.

The epilogue (bias add + relu/relu6 + optional maxpool) runs on the fp32
accumulator before writeback, so a paper-layer conv+relu+maxpool *triple*
is one kernel launch with no intermediate activation round-tripping HBM.

Storage dtype: the kernel is dtype-polymorphic over the *streamed* blocks.
Input tiles, weights, and the output tile move in ``x.dtype`` (fp32 or
bf16 under the ``REPRO_CONV_DTYPE`` policy -- see ``kernels.ops.conv2d``)
and are upcast on load; the accumulator, bias column, and every epilogue
op are always fp32, and the result is cast back to ``x.dtype`` only at
writeback.  With 2-byte storage the ``B``-scaled terms of the VMEM model
halve, so the planner (fed ``dtype_bytes = x.dtype.itemsize``) roughly
doubles the tile and the grid needs fewer launches.
Grouped convolution (``feature_group_count``) is supported: pointwise
(groups=1), group-aligned channel blocks (1 < groups < Cin), and the
depthwise case (cin_per_group == 1) which runs an elementwise VPU path
instead of degenerate 1-deep matmuls.
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

VMEM_LIMIT_BYTES = 16 * 1024 * 1024     # one v5e core
DEFAULT_VMEM_BUDGET = 12 * 1024 * 1024  # headroom for Mosaic scratch

# Fixed bytes-equivalent charged per grid step by the tiling-search cost
# model (DMA descriptor setup + pipeline bubble; ~an HBM microsecond).
LAUNCH_COST_BYTES = 128 * 1024
# VMEM lane width: the cost model rounds streamed-block widths up to full
# lanes so it never prefers a narrow column tile over an equal-byte
# full-width one (narrow last dims waste lanes on real hardware).
LANE = 128
# Channel-block candidates the search may consider (the legacy greedy
# planner capped block_co at 128; the search goes wider when VMEM allows,
# trading a bigger weight slice for fewer grid steps).
MAX_BLOCK_CO = 512
# Column-split ladder: candidate n_w_blocks in 1..MAX_W_SPLITS (dedup'd by
# the tile width they imply), enough to shatter an 8K-wide panorama row.
MAX_W_SPLITS = 128

SEARCH_ENV = "REPRO_CONV_SEARCH"
TILE_W_ENV = "REPRO_CONV_TILE_W"


def search_enabled(search: bool | None = None) -> bool:
    """Resolve the tiling-search switch *now* (mirrors ``conv_backend``).

    Explicit argument wins, else ``REPRO_CONV_SEARCH`` (default on)."""
    if search is not None:
        return search
    v = os.environ.get(SEARCH_ENV, "1")
    if v not in ("0", "1"):
        raise ValueError(f"{SEARCH_ENV} must be '0' or '1', got {v!r}")
    return v == "1"


def tile_w_override(tile_w: int = 0) -> int:
    """Resolve the column-tile override: explicit argument wins, else
    ``REPRO_CONV_TILE_W`` (0 = let the planner decide)."""
    if tile_w:
        return tile_w
    v = os.environ.get(TILE_W_ENV, "0")
    try:
        n = int(v)
    except ValueError:
        raise ValueError(f"{TILE_W_ENV} must be an integer, got {v!r}") \
            from None
    if n < 0:
        raise ValueError(f"{TILE_W_ENV} must be >= 0, got {n}")
    return n


def _pool_out(n: int, pool_k: int, pool_s: int) -> int:
    """VALID-window pooled extent (matches lax.reduce_window)."""
    return (n - pool_k) // pool_s + 1


def conv_vmem_bytes(*, cin_block: int, block_co: int, tile_h: int,
                    w_in: int, w_out: int, K: int, stride: int,
                    cin_per_group: int, dtype_bytes: int = 4,
                    pool_k: int = 0, pool_s: int = 1,
                    tile_w: int = 0) -> int:
    """Estimated VMEM bytes one grid step of the tiled kernel occupies.

    With ``pool_k > 0`` (fused maxpool epilogue) ``tile_h`` / ``tile_w``
    count pooled output rows/cols; the fp32 accumulator still spans the
    conv rows/cols feeding those pool windows.  ``tile_w = 0`` means the
    tile spans the full output width (single column block): the staged
    input tile is then the full padded width ``w_in``, exactly the legacy
    row-tiled geometry."""
    if pool_k:
        tile_conv_h = (tile_h - 1) * pool_s + pool_k
        full_out_w = _pool_out(w_out, pool_k, pool_s)
    else:
        tile_conv_h, full_out_w = tile_h, w_out
    tile_in_h = (tile_conv_h - 1) * stride + K
    if tile_w and tile_w < full_out_w:
        out_w = tile_w
        conv_w = (tile_w - 1) * pool_s + pool_k if pool_k else tile_w
        in_w = (conv_w - 1) * stride + K
    else:
        out_w, conv_w, in_w = full_out_w, w_out, w_in
    x_b = cin_block * tile_in_h * in_w * dtype_bytes
    w_b = block_co * cin_per_group * K * K * dtype_bytes
    b_b = block_co * 4
    o_b = block_co * tile_h * out_w * dtype_bytes
    acc = block_co * tile_conv_h * conv_w * 4
    return 2 * (x_b + w_b + b_b + o_b) + acc


def _max_fit_tile_h(est, h_cap: int, budget: int) -> int:
    """Largest ``tile_h in [1, h_cap]`` with ``est(tile_h) <= budget``
    (0 if even one row overflows).  Bisection is valid because the VMEM
    estimate is strictly monotone in ``tile_h``."""
    if est(tile_h=1) > budget:
        return 0
    lo, hi = 1, h_cap
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if est(tile_h=mid) <= budget:
            lo = mid
        else:
            hi = mid - 1
    return lo


def choose_tile_h(h_out: int, *, cin_block: int, block_co: int, w_in: int,
                  w_out: int, K: int, stride: int, cin_per_group: int,
                  dtype_bytes: int = 4, pool_k: int = 0, pool_s: int = 1,
                  tile_w: int = 0,
                  budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Largest output-row tile whose VMEM estimate fits ``budget`` (found
    by bisection -- the estimate is monotone in ``tile_h``), shrunk to the
    smallest tile with the same block count (minimal padded waste).

    ``h_out`` and the returned tile are in *kernel output rows*: conv rows
    normally, pooled rows when a maxpool epilogue is fused (``pool_k > 0``)
    -- tile boundaries then land on pool-window starts, i.e. ``tile_h`` is
    aligned to the pool stride by construction.  ``tile_w`` narrows the
    estimate to a column tile (0 = full width)."""
    if h_out < 1:
        raise ValueError(f"invalid conv geometry: h_out={h_out} "
                         f"(kernel/stride larger than padded input)")
    est = functools.partial(
        conv_vmem_bytes, cin_block=cin_block, block_co=block_co,
        w_in=w_in, w_out=w_out, K=K, stride=stride,
        cin_per_group=cin_per_group, dtype_bytes=dtype_bytes,
        pool_k=pool_k, pool_s=pool_s, tile_w=tile_w)
    tile_h = _max_fit_tile_h(est, min(h_out, 512), budget)
    if tile_h == 0:
        raise ValueError(
            f"conv tile of a single output row exceeds VMEM budget "
            f"({est(tile_h=1)} > {budget}); split columns with tile_w "
            f"(the tiling search, on by default in plan_conv, does this "
            f"automatically)")
    n_blocks = -(-h_out // tile_h)
    return -(-h_out // n_blocks)


def plan_cost(*, n_batch: int, n_c_blocks: int, n_h_blocks: int,
              n_w_blocks: int, cin_block: int, block_co: int, tile_h: int,
              tile_w: int, tile_in_h: int, tile_in_w: int, K: int,
              cin_per_group: int, dtype_bytes: int, p_out: int,
              pw_out: int) -> dict:
    """The tiling-search cost model for one candidate grid.

    ``hbm_bytes`` is everything the grid streams between HBM and VMEM:
    the input tile (halo re-reads appear as overlapping ``tile_in_*``
    extents, and for groups == 1 every channel block re-reads the same
    tile), the weight slice re-staged by every grid step, the fp32 bias
    column, and the (possibly padded) output tile.  ``waste_frac`` is the
    padded-output overshoot the remainder tiles compute and throw away.
    ``cost`` adds ``LAUNCH_COST_BYTES`` bytes-equivalent of fixed
    per-grid-step overhead so ties break toward fewer launches.  Streamed
    spatial widths are rounded up to full ``LANE`` lanes: a narrow column
    tile occupies (and moves) whole VMEM lanes on hardware, so the model
    must not prefer it over an equal-byte full-width tile."""
    launches = n_batch * n_c_blocks * n_h_blocks * n_w_blocks
    in_w_eff = -(-tile_in_w // LANE) * LANE
    out_w_eff = -(-tile_w // LANE) * LANE
    x_tile = cin_block * tile_in_h * in_w_eff * dtype_bytes
    w_slice = block_co * cin_per_group * K * K * dtype_bytes
    b_col = block_co * 4
    o_tile = block_co * tile_h * out_w_eff * dtype_bytes
    hbm = launches * (x_tile + w_slice + b_col + o_tile)
    waste = (n_h_blocks * tile_h * n_w_blocks * tile_w) \
        / (p_out * pw_out) - 1.0
    return {"launches": launches, "hbm_bytes": hbm, "waste_frac": waste,
            "cost": float(hbm + LAUNCH_COST_BYTES * launches)}


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Static tiling decision + derived geometry for one conv shape
    (exposed for tests; ``conv2d`` consumes it so the BlockSpec geometry
    and the VMEM estimate can never desynchronise).

    With a fused maxpool epilogue (``pool_k > 0``) the kernel's output
    rows/cols are *pooled*: ``tile_h x tile_w`` tiles ``p_out x pw_out``,
    and each grid step internally computes ``tile_conv_h x tile_conv_w``
    conv elements."""
    block_co: int
    cin_block: int
    tile_h: int
    tile_in_h: int
    n_h_blocks: int
    vmem_bytes: int
    h_out: int
    w_out: int
    g_out: int          # output channels per group
    depthwise: bool
    pool_k: int = 0     # fused maxpool window (0 = no pool epilogue)
    pool_s: int = 1     # fused maxpool stride
    p_out: int = 0      # pooled output rows (== h_out when no pool)
    pw_out: int = 0     # pooled output cols (== w_out when no pool)
    tile_conv_h: int = 0  # conv rows computed per grid step
    tile_w: int = 0       # output cols per grid step (pooled when fused)
    tile_in_w: int = 0    # staged input cols per grid step (with halo)
    n_w_blocks: int = 1   # column tiles (1 = legacy full-width rows)
    tile_conv_w: int = 0  # conv cols computed per grid step
    launches: int = 0     # total grid steps (batch x channel x h x w)
    cost_bytes: float = 0.0   # plan_cost()["cost"] for this geometry
    searched: bool = False    # True when the joint search picked the plan


def plan_conv(x_shape: tuple, w_shape: tuple, *, stride: int = 1,
              pad: int = 0, groups: int = 1, block_co: int = 0,
              tile_h: int = 0, tile_w: int = 0, dtype_bytes: int = 4,
              pool_k: int = 0, pool_s: int = 0,
              vmem_budget: int = DEFAULT_VMEM_BUDGET,
              search: bool | None = None) -> ConvPlan:
    """Pick ``(block_co, tile_h, tile_w)`` for the grid and estimate
    per-step VMEM.

    By default the joint cost-model search runs (``plan_cost`` over every
    channel-block divisor and column-split candidate).  Explicit
    ``block_co`` / ``tile_h`` arguments pin those dimensions and bypass
    the search (test/debug overrides keep the legacy greedy semantics);
    ``tile_w`` (or ``REPRO_CONV_TILE_W``) pins the column tile while the
    search still picks ``block_co``/``tile_h``.  ``search=False`` (or
    ``REPRO_CONV_SEARCH=0``) is the legacy greedy planner: largest
    ``block_co <= 128``, then the largest row tile -- and a ValueError
    when a single output row overflows the budget."""
    N, Cin, H, W = x_shape
    Cout, cin_pg, K, _ = w_shape
    if Cin != cin_pg * groups or Cout % groups:
        raise ValueError(f"bad grouping: x Cin={Cin}, w Cin/g={cin_pg}, "
                         f"groups={groups}, Cout={Cout}")
    g_out = Cout // groups
    depthwise = cin_pg == 1 and groups > 1
    if depthwise and g_out != 1:
        raise ValueError("depthwise with channel multiplier > 1 unsupported")
    limit = Cout if groups == 1 or depthwise else g_out
    if block_co:
        if groups == 1 or depthwise:
            if Cout % block_co:
                raise ValueError(f"block_co={block_co} must divide "
                                 f"Cout={Cout}")
        elif g_out % block_co:
            raise ValueError(f"block_co={block_co} must divide the "
                             f"per-group output channels ({g_out}) when "
                             f"groups > 1")
    h_in, w_in = H + 2 * pad, W + 2 * pad
    h_out = (h_in - K) // stride + 1
    w_out = (w_in - K) // stride + 1
    if pool_k:
        pool_s = pool_s or pool_k
        if pool_s < 1:
            raise ValueError(f"pool_s={pool_s} must be >= 1")
        p_out = _pool_out(h_out, pool_k, pool_s)
        pw_out = _pool_out(w_out, pool_k, pool_s)
        if h_out < 1 or p_out < 1 or pw_out < 1:
            raise ValueError(
                f"invalid fused conv+pool geometry: conv out "
                f"{h_out}x{w_out}, pool(k={pool_k}, s={pool_s}) out "
                f"{p_out}x{pw_out}")
    else:
        pool_s = 1
        p_out, pw_out = h_out, w_out
    if h_out < 1 or w_out < 1:
        raise ValueError(f"invalid conv geometry: output {h_out}x{w_out} "
                         f"(kernel/stride larger than padded input)")
    tile_w = min(tile_w_override(tile_w), pw_out)

    def est_kw(bc):
        return dict(cin_block=cin_pg * (bc if depthwise else 1),
                    block_co=bc, w_in=w_in, w_out=w_out, K=K,
                    stride=stride, cin_per_group=cin_pg,
                    dtype_bytes=dtype_bytes, pool_k=pool_k, pool_s=pool_s)

    def finalize(bc, th, tw, searched):
        cin_block = cin_pg * (bc if depthwise else 1)
        th, tw = min(th, p_out), min(tw, pw_out)
        n_h, n_w = -(-p_out // th), -(-pw_out // tw)
        tile_conv_h = (th - 1) * pool_s + pool_k if pool_k else th
        if n_w == 1:
            # single column tile: legacy full-width geometry, staged at
            # the full padded input width
            tile_conv_w, tile_in_w, tw_est = w_out, w_in, 0
        else:
            tile_conv_w = (tw - 1) * pool_s + pool_k if pool_k else tw
            tile_in_w, tw_est = (tile_conv_w - 1) * stride + K, tw
        tile_in_h = (tile_conv_h - 1) * stride + K
        cost = plan_cost(
            n_batch=N, n_c_blocks=Cout // bc, n_h_blocks=n_h,
            n_w_blocks=n_w, cin_block=cin_block, block_co=bc, tile_h=th,
            tile_w=tw, tile_in_h=tile_in_h, tile_in_w=tile_in_w, K=K,
            cin_per_group=cin_pg, dtype_bytes=dtype_bytes, p_out=p_out,
            pw_out=pw_out)
        return ConvPlan(
            block_co=bc, cin_block=cin_block, tile_h=th,
            tile_in_h=tile_in_h, n_h_blocks=n_h,
            vmem_bytes=conv_vmem_bytes(tile_h=th, tile_w=tw_est,
                                       **est_kw(bc)),
            h_out=h_out, w_out=w_out, g_out=g_out, depthwise=depthwise,
            pool_k=pool_k, pool_s=pool_s, p_out=p_out, pw_out=pw_out,
            tile_conv_h=tile_conv_h, tile_w=tw, tile_in_w=tile_in_w,
            n_w_blocks=n_w, tile_conv_w=tile_conv_w,
            launches=cost["launches"], cost_bytes=cost["cost"],
            searched=searched)

    do_search = search_enabled(search) and not block_co and not tile_h
    if not do_search:
        # legacy greedy: largest channel block <= 128 dividing the group
        # structure, then the largest row tile that fits the budget
        if not block_co:
            block_co = next(b for b in range(min(limit, 128), 0, -1)
                            if limit % b == 0)
        if not tile_h:
            tile_h = choose_tile_h(p_out, budget=vmem_budget,
                                   tile_w=tile_w, **est_kw(block_co))
        return finalize(block_co, tile_h, tile_w or pw_out, False)

    # joint search: every channel-block divisor x column-split candidate,
    # row tile maximised by bisection, scored by plan_cost
    bcs = [d for d in range(1, min(limit, MAX_BLOCK_CO) + 1)
           if limit % d == 0]
    if tile_w:
        tws = [tile_w]
    else:
        tws = sorted({-(-pw_out // n)
                      for n in range(1, min(pw_out, MAX_W_SPLITS) + 1)},
                     reverse=True)
    best, best_key = None, None
    for bc in bcs:
        kw = est_kw(bc)
        for tw in tws:
            tw_est = 0 if tw >= pw_out else tw
            th = _max_fit_tile_h(
                functools.partial(conv_vmem_bytes, tile_w=tw_est, **kw),
                min(p_out, 512), vmem_budget)
            if th == 0:
                continue
            # shrink both tiles to the smallest with the same block count
            th = -(-p_out // -(-p_out // th))
            tw_s = -(-pw_out // -(-pw_out // tw))
            cand = finalize(bc, th, tw_s, True)
            key = (cand.cost_bytes, cand.launches, cand.n_w_blocks,
                   cand.n_h_blocks, -cand.block_co)
            if best is None or key < best_key:
                best, best_key = cand, key
    if best is None:
        one = conv_vmem_bytes(tile_h=1, tile_w=1, **est_kw(bcs[0]))
        raise ValueError(
            f"no feasible conv tiling: even a single-element output tile "
            f"at block_co={bcs[0]} needs {one} bytes > budget "
            f"{vmem_budget}")
    return best


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, K: int, stride: int,
                 tile_h: int, tile_conv_h: int, conv_w: int, out_w: int,
                 depthwise: bool, activation: str | None,
                 pool_k: int, pool_s: int):
    x = x_ref[0].astype(jnp.float32)       # (cin_block, tile_in_h, tile_in_w)
    wts = w_ref[...].astype(jnp.float32)   # (block_co, cin_pg, K, K)
    block_co = wts.shape[0]
    cin = x.shape[0]
    if depthwise:
        # channel-aligned elementwise path: output channel c reads input
        # channel c of the staged block -- no MXU, pure VPU multiplies
        acc = jnp.zeros((block_co, tile_conv_h, conv_w), jnp.float32)
        for kh in range(K):
            for kw in range(K):
                xs = jax.lax.slice(
                    x, (0, kh, kw),
                    (cin, kh + (tile_conv_h - 1) * stride + 1,
                     kw + (conv_w - 1) * stride + 1),
                    (1, stride, stride))    # (block_co, tile_conv_h, conv_w)
                acc += xs * wts[:, 0, kh, kw][:, None, None]
        acc = acc.reshape(block_co, tile_conv_h * conv_w)
    else:
        acc = jnp.zeros((block_co, tile_conv_h * conv_w), jnp.float32)
        for kh in range(K):
            for kw in range(K):
                xs = jax.lax.slice(
                    x, (0, kh, kw),
                    (cin, kh + (tile_conv_h - 1) * stride + 1,
                     kw + (conv_w - 1) * stride + 1),
                    (1, stride, stride))       # (cin, tile_conv_h, conv_w)
                xs = xs.reshape(cin, tile_conv_h * conv_w)
                wk = wts[:, :, kh, kw]         # (block_co, cin)
                acc += jax.lax.dot_general(
                    wk, xs, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)  # (block_co, 1) broadcast
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "relu6":
        acc = jnp.clip(acc, 0.0, 6.0)
    acc = acc.reshape(block_co, tile_conv_h, conv_w)
    if pool_k:
        # pooled epilogue: max over the pool_k x pool_k window, straight
        # from the fp32 accumulator -- the conv rows never leave VMEM
        pooled = None
        for ph in range(pool_k):
            for pw in range(pool_k):
                s = jax.lax.slice(
                    acc, (0, ph, pw),
                    (block_co, ph + (tile_h - 1) * pool_s + 1,
                     pw + (out_w - 1) * pool_s + 1),
                    (1, pool_s, pool_s))       # (block_co, tile_h, out_w)
                pooled = s if pooled is None else jnp.maximum(pooled, s)
        acc = pooled
    o_ref[0] = acc.astype(o_ref.dtype)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1,
           pad: int = 0, bias: jnp.ndarray | None = None,
           activation: str | None = None, groups: int = 1,
           pool_k: int = 0, pool_s: int = 0,
           block_co: int = 0, tile_h: int = 0, tile_w: int = 0,
           vmem_budget: int = DEFAULT_VMEM_BUDGET,
           search: bool | None = None,
           interpret: bool = True) -> jnp.ndarray:
    """x: (N, Cin, H, W); w: (Cout, Cin/groups, K, K) -> (N, Cout, Ho, Wo).

    ``bias`` (Cout,) and ``activation`` in {None, "relu", "relu6"} fuse into
    the kernel epilogue; ``groups`` follows lax ``feature_group_count``.
    ``pool_k > 0`` additionally fuses a VALID ``maxpool(pool_k, pool_s)``
    (``pool_s`` defaults to ``pool_k``) after the activation, returning the
    pooled (N, Cout, Po, Pw) tensor from the same launch.  Tiling comes
    from ``plan_conv`` (joint cost-model search by default; ``block_co`` /
    ``tile_h`` / ``tile_w`` / ``search`` are overrides)."""
    if activation not in (None, "relu", "relu6"):
        raise ValueError(f"unknown activation {activation!r}")
    N, Cin, H, W = x.shape
    Cout, cin_pg, K, _ = w.shape
    plan = plan_conv(x.shape, w.shape, stride=stride, pad=pad, groups=groups,
                     block_co=block_co, tile_h=tile_h, tile_w=tile_w,
                     pool_k=pool_k, pool_s=pool_s,
                     dtype_bytes=x.dtype.itemsize, vmem_budget=vmem_budget,
                     search=search)
    block_co, tile_h, tile_w = plan.block_co, plan.tile_h, plan.tile_w
    pool_k, pool_s = plan.pool_k, plan.pool_s
    p_out, pw_out = plan.p_out, plan.pw_out
    h_in, w_in = H + 2 * pad, W + 2 * pad
    # pad rows/cols so every remainder tile's halo read stays in-bounds
    # (the padded pooled rows/cols, and the conv elements feeding only
    # them, are sliced away)
    p_out_pad = plan.n_h_blocks * tile_h
    pw_out_pad = plan.n_w_blocks * tile_w
    conv_rows = ((p_out_pad - 1) * pool_s + pool_k) if pool_k \
        else p_out_pad
    rows_needed = (conv_rows - 1) * stride + K
    if plan.n_w_blocks == 1:
        cols_extra = 0
    else:
        conv_cols = ((pw_out_pad - 1) * pool_s + pool_k) if pool_k \
            else pw_out_pad
        cols_extra = max(0, (conv_cols - 1) * stride + K - w_in)
    x = jnp.pad(x, ((0, 0), (0, 0),
                    (pad, pad + max(0, rows_needed - h_in)),
                    (pad, pad + cols_extra)))
    if bias is None:
        bias = jnp.zeros((Cout,), jnp.float32)
    bias2d = bias.reshape(Cout, 1).astype(jnp.float32)

    g_out = plan.g_out
    # consecutive tiles advance by tile_h/tile_w kernel-output elements,
    # i.e. tile * pool_s conv elements, i.e. tile * pool_s * stride input
    # elements -- so pooled tiles land on pool-window starts on both axes
    row_step = tile_h * pool_s * stride
    col_step = tile_w * pool_s * stride
    kernel = functools.partial(
        _conv_kernel, K=K, stride=stride, tile_h=tile_h,
        tile_conv_h=plan.tile_conv_h, conv_w=plan.tile_conv_w,
        out_w=tile_w, depthwise=plan.depthwise, activation=activation,
        pool_k=pool_k, pool_s=pool_s)
    out = pl.pallas_call(
        kernel,
        grid=(N, Cout // block_co, plan.n_h_blocks, plan.n_w_blocks),
        in_specs=[
            # overlapping (haloed) tiles: element offsets, not block ids
            pl.BlockSpec(
                (1, plan.cin_block, plan.tile_in_h, plan.tile_in_w),
                lambda n, c, h, w: (n, c * block_co // g_out * cin_pg,
                                    h * row_step, w * col_step),
                indexing_mode=pl.unblocked),
            pl.BlockSpec((block_co, cin_pg, K, K),
                         lambda n, c, h, w: (c, 0, 0, 0)),
            pl.BlockSpec((block_co, 1), lambda n, c, h, w: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_co, tile_h, tile_w),
                               lambda n, c, h, w: (n, c, h, w)),
        out_shape=jax.ShapeDtypeStruct((N, Cout, p_out_pad, pw_out_pad),
                                       x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",) * 4),
        interpret=interpret,
    )(x, w, bias2d)
    if p_out_pad != p_out or pw_out_pad != pw_out:
        out = out[:, :, :p_out, :pw_out]
    return out
