"""Direct convolution as a Pallas TPU kernel.

The paper's compute hot-spot is CNN convolution on the client device.  The
TPU-native formulation: a KxK conv is K^2 shifted (Cout x Cin) @ (Cin x HW)
matmuls -- pure MXU work with the image tile resident in VMEM, instead of a
GPU-style im2col gather.  Grid: (batch, cout_blocks); weights for the block
and the whole (padded) input image tile live in VMEM; the K^2 loop is
unrolled (K is a static hyper-parameter)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _conv_kernel(x_ref, w_ref, o_ref, *, K: int, stride: int,
                 h_out: int, w_out: int):
    x = x_ref[0].astype(jnp.float32)              # (Cin, Hp, Wp)
    wts = w_ref[...].astype(jnp.float32)          # (block_co, Cin, K, K)
    block_co = wts.shape[0]
    cin = x.shape[0]
    acc = jnp.zeros((block_co, h_out * w_out), jnp.float32)
    for kh in range(K):
        for kw in range(K):
            xs = jax.lax.slice(
                x, (0, kh, kw),
                (cin, kh + (h_out - 1) * stride + 1,
                 kw + (w_out - 1) * stride + 1),
                (1, stride, stride))              # (Cin, h_out, w_out)
            xs = xs.reshape(cin, h_out * w_out)
            wk = wts[:, :, kh, kw]                # (block_co, Cin)
            acc += jax.lax.dot_general(
                wk, xs, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(block_co, h_out, w_out).astype(o_ref.dtype)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1,
           pad: int = 0, block_co: int = 0,
           interpret: bool = True) -> jnp.ndarray:
    """x: (N, Cin, H, W); w: (Cout, Cin, K, K) -> (N, Cout, Hout, Wout)."""
    N, Cin, H, W = x.shape
    Cout, _, K, _ = w.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
        H, W = H + 2 * pad, W + 2 * pad
    h_out = (H - K) // stride + 1
    w_out = (W - K) // stride + 1
    if not block_co:
        block_co = next(b for b in range(min(Cout, 128), 0, -1)
                        if Cout % b == 0)
    assert Cout % block_co == 0
    kernel = functools.partial(_conv_kernel, K=K, stride=stride,
                               h_out=h_out, w_out=w_out)
    return pl.pallas_call(
        kernel,
        grid=(N, Cout // block_co),
        in_specs=[
            pl.BlockSpec((1, Cin, H, W), lambda n, c: (n, 0, 0, 0)),
            pl.BlockSpec((block_co, Cin, K, K), lambda n, c: (c, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_co, h_out, w_out),
                               lambda n, c: (n, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Cout, h_out, w_out), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, w)
