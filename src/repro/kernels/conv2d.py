"""Spatially-tiled direct convolution as a Pallas TPU kernel.

The paper's compute hot-spot is CNN convolution on the client device.  The
TPU-native formulation: a KxK conv is K^2 shifted (Cout x Cin) @ (Cin x HW)
matmuls -- pure MXU work with the image tile resident in VMEM, instead of a
GPU-style im2col gather.

Grid: ``(batch, cout_blocks, h_blocks)``.  Each grid step stages

  * a *row tile* of the padded input -- ``tile_in_h = (tile_conv_h-1)*stride
    + K`` rows, i.e. the ``tile_conv_h`` conv rows it produces plus the K-1
    halo rows shared with the neighbouring tiles (expressed with
    ``pl.BlockSpec(..., indexing_mode=pl.unblocked)`` so consecutive input
    blocks may overlap),
  * one ``block_co``-channel slice of the weights, and
  * the fp32 accumulator / output tile.

VMEM budget model
-----------------
Per grid step the kernel holds (``B = dtype bytes``; Pallas double-buffers
every streamed block for the HBM->VMEM pipeline, hence the factor 2).
Without a fused pool, ``tile_conv_h == tile_h`` and ``out_w == w_out``;
with ``maxpool(pool_k, pool_s)`` fused, ``tile_h`` counts *pooled* output
rows, so the accumulator spans ``tile_conv_h = (tile_h-1)*pool_s + pool_k``
conv rows while the streamed output block shrinks to the pooled
``tile_h x pw_out`` footprint (``pw_out = (w_out - pool_k)//pool_s + 1``):

    2 * [ cin_block * tile_in_h * W_in * B        (input row tile)
        + block_co * cin_per_group * K^2 * B      (weight slice)
        + block_co * 4                            (bias column, fp32)
        + block_co * tile_h * out_w * B ]         (pooled output tile)
    +   block_co * tile_conv_h * W_out * 4        (fp32 conv accumulator)

The pooled-epilogue term is why fusion *shrinks* the client-side memory
footprint the paper optimises: the conv activation lives only as the fp32
accumulator inside VMEM and is never written to HBM -- the kernel streams
out the (pool_s^2-times smaller) pooled tile instead.

``choose_tile_h`` picks the largest ``tile_h`` whose estimate fits the
budget (default 12 MiB, leaving headroom inside a v5e core's ~16 MiB VMEM
for Mosaic scratch), then shrinks it to ``ceil(h_out / n_blocks)`` so the
final grid wastes as few padded rows as possible.  ``h_out`` need not be a
multiple of ``tile_h``: the wrapper zero-pads input rows so the remainder
tile reads in-bounds and slices the padded output rows away.

The epilogue (bias add + relu/relu6 + optional maxpool) runs on the fp32
accumulator before writeback, so a paper-layer conv+relu+maxpool *triple*
is one kernel launch with no intermediate activation round-tripping HBM.

Storage dtype: the kernel is dtype-polymorphic over the *streamed* blocks.
Input rows, weights, and the output tile move in ``x.dtype`` (fp32 or
bf16 under the ``REPRO_CONV_DTYPE`` policy -- see ``kernels.ops.conv2d``)
and are upcast on load; the accumulator, bias column, and every epilogue
op are always fp32, and the result is cast back to ``x.dtype`` only at
writeback.  With 2-byte storage the ``B``-scaled terms of the VMEM model
halve, so ``choose_tile_h`` (fed ``dtype_bytes = x.dtype.itemsize``)
roughly doubles the row tile and the grid needs fewer launches.
Grouped convolution (``feature_group_count``) is supported: pointwise
(groups=1), group-aligned channel blocks (1 < groups < Cin), and the
depthwise case (cin_per_group == 1) which runs an elementwise VPU path
instead of degenerate 1-deep matmuls.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

VMEM_LIMIT_BYTES = 16 * 1024 * 1024     # one v5e core
DEFAULT_VMEM_BUDGET = 12 * 1024 * 1024  # headroom for Mosaic scratch


def _pool_out(n: int, pool_k: int, pool_s: int) -> int:
    """VALID-window pooled extent (matches lax.reduce_window)."""
    return (n - pool_k) // pool_s + 1


def conv_vmem_bytes(*, cin_block: int, block_co: int, tile_h: int,
                    w_in: int, w_out: int, K: int, stride: int,
                    cin_per_group: int, dtype_bytes: int = 4,
                    pool_k: int = 0, pool_s: int = 1) -> int:
    """Estimated VMEM bytes one grid step of the tiled kernel occupies.

    With ``pool_k > 0`` (fused maxpool epilogue) ``tile_h`` counts pooled
    output rows; the fp32 accumulator still spans the conv rows feeding
    those pool windows."""
    if pool_k:
        tile_conv_h = (tile_h - 1) * pool_s + pool_k
        out_w = _pool_out(w_out, pool_k, pool_s)
    else:
        tile_conv_h, out_w = tile_h, w_out
    tile_in_h = (tile_conv_h - 1) * stride + K
    x_b = cin_block * tile_in_h * w_in * dtype_bytes
    w_b = block_co * cin_per_group * K * K * dtype_bytes
    b_b = block_co * 4
    o_b = block_co * tile_h * out_w * dtype_bytes
    acc = block_co * tile_conv_h * w_out * 4
    return 2 * (x_b + w_b + b_b + o_b) + acc


def choose_tile_h(h_out: int, *, cin_block: int, block_co: int, w_in: int,
                  w_out: int, K: int, stride: int, cin_per_group: int,
                  dtype_bytes: int = 4, pool_k: int = 0, pool_s: int = 1,
                  budget: int = DEFAULT_VMEM_BUDGET) -> int:
    """Largest output-row tile whose VMEM estimate fits ``budget``, shrunk
    to the smallest tile with the same block count (minimal padded waste).

    ``h_out`` and the returned tile are in *kernel output rows*: conv rows
    normally, pooled rows when a maxpool epilogue is fused (``pool_k > 0``)
    -- tile boundaries then land on pool-window starts, i.e. ``tile_h`` is
    aligned to the pool stride by construction."""
    if h_out < 1:
        raise ValueError(f"invalid conv geometry: h_out={h_out} "
                         f"(kernel/stride larger than padded input)")
    est = functools.partial(
        conv_vmem_bytes, cin_block=cin_block, block_co=block_co,
        w_in=w_in, w_out=w_out, K=K, stride=stride,
        cin_per_group=cin_per_group, dtype_bytes=dtype_bytes,
        pool_k=pool_k, pool_s=pool_s)
    tile_h = next((t for t in range(min(h_out, 512), 0, -1)
                   if est(tile_h=t) <= budget), 0)
    if tile_h == 0:
        raise ValueError(
            f"conv tile of a single output row exceeds VMEM budget "
            f"({est(tile_h=1)} > {budget}); W-axis tiling not implemented")
    n_blocks = -(-h_out // tile_h)
    return -(-h_out // n_blocks)


@dataclasses.dataclass(frozen=True)
class ConvPlan:
    """Static tiling decision + derived geometry for one conv shape
    (exposed for tests; ``conv2d`` consumes it so the BlockSpec geometry
    and the VMEM estimate can never desynchronise).

    With a fused maxpool epilogue (``pool_k > 0``) the kernel's output rows
    are *pooled* rows: ``tile_h`` / ``n_h_blocks`` tile ``p_out``, and each
    grid step internally computes ``tile_conv_h`` conv rows."""
    block_co: int
    cin_block: int
    tile_h: int
    tile_in_h: int
    n_h_blocks: int
    vmem_bytes: int
    h_out: int
    w_out: int
    g_out: int          # output channels per group
    depthwise: bool
    pool_k: int = 0     # fused maxpool window (0 = no pool epilogue)
    pool_s: int = 1     # fused maxpool stride
    p_out: int = 0      # pooled output rows (== h_out when no pool)
    pw_out: int = 0     # pooled output cols (== w_out when no pool)
    tile_conv_h: int = 0  # conv rows computed per grid step


def plan_conv(x_shape: tuple, w_shape: tuple, *, stride: int = 1,
              pad: int = 0, groups: int = 1, block_co: int = 0,
              tile_h: int = 0, dtype_bytes: int = 4,
              pool_k: int = 0, pool_s: int = 0,
              vmem_budget: int = DEFAULT_VMEM_BUDGET) -> ConvPlan:
    """Pick (block_co, tile_h) for the grid and estimate per-step VMEM."""
    N, Cin, H, W = x_shape
    Cout, cin_pg, K, _ = w_shape
    if Cin != cin_pg * groups or Cout % groups:
        raise ValueError(f"bad grouping: x Cin={Cin}, w Cin/g={cin_pg}, "
                         f"groups={groups}, Cout={Cout}")
    g_out = Cout // groups
    depthwise = cin_pg == 1 and groups > 1
    if depthwise and g_out != 1:
        raise ValueError("depthwise with channel multiplier > 1 unsupported")
    if not block_co:
        # largest channel block <= 128 that divides the group structure
        limit = Cout if groups == 1 or depthwise else g_out
        block_co = next(b for b in range(min(limit, 128), 0, -1)
                        if limit % b == 0)
    if groups == 1 or depthwise:
        if Cout % block_co:
            raise ValueError(f"block_co={block_co} must divide Cout={Cout}")
    elif g_out % block_co:
        raise ValueError(f"block_co={block_co} must divide the per-group "
                         f"output channels ({g_out}) when groups > 1")
    cin_block = cin_pg * (block_co if depthwise else 1)
    h_in, w_in = H + 2 * pad, W + 2 * pad
    h_out = (h_in - K) // stride + 1
    w_out = (w_in - K) // stride + 1
    if pool_k:
        pool_s = pool_s or pool_k
        if pool_s < 1:
            raise ValueError(f"pool_s={pool_s} must be >= 1")
        p_out = _pool_out(h_out, pool_k, pool_s)
        pw_out = _pool_out(w_out, pool_k, pool_s)
        if h_out < 1 or p_out < 1 or pw_out < 1:
            raise ValueError(
                f"invalid fused conv+pool geometry: conv out "
                f"{h_out}x{w_out}, pool(k={pool_k}, s={pool_s}) out "
                f"{p_out}x{pw_out}")
    else:
        pool_s = 1
        p_out, pw_out = h_out, w_out
    kw = dict(cin_block=cin_block, block_co=block_co, w_in=w_in,
              w_out=w_out, K=K, stride=stride, cin_per_group=cin_pg,
              dtype_bytes=dtype_bytes, pool_k=pool_k, pool_s=pool_s)
    if not tile_h:
        tile_h = choose_tile_h(p_out, budget=vmem_budget, **kw)
    tile_h = min(tile_h, p_out)
    tile_conv_h = (tile_h - 1) * pool_s + pool_k if pool_k else tile_h
    return ConvPlan(
        block_co=block_co, cin_block=cin_block, tile_h=tile_h,
        tile_in_h=(tile_conv_h - 1) * stride + K,
        n_h_blocks=-(-p_out // tile_h),
        vmem_bytes=conv_vmem_bytes(tile_h=tile_h, **kw),
        h_out=h_out, w_out=w_out, g_out=g_out, depthwise=depthwise,
        pool_k=pool_k, pool_s=pool_s, p_out=p_out, pw_out=pw_out,
        tile_conv_h=tile_conv_h)


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, K: int, stride: int,
                 tile_h: int, tile_conv_h: int, w_out: int, pw_out: int,
                 depthwise: bool, activation: str | None,
                 pool_k: int, pool_s: int):
    x = x_ref[0].astype(jnp.float32)           # (cin_block, tile_in_h, w_in)
    wts = w_ref[...].astype(jnp.float32)       # (block_co, cin_pg, K, K)
    block_co = wts.shape[0]
    cin = x.shape[0]
    if depthwise:
        # channel-aligned elementwise path: output channel c reads input
        # channel c of the staged block -- no MXU, pure VPU multiplies
        acc = jnp.zeros((block_co, tile_conv_h, w_out), jnp.float32)
        for kh in range(K):
            for kw in range(K):
                xs = jax.lax.slice(
                    x, (0, kh, kw),
                    (cin, kh + (tile_conv_h - 1) * stride + 1,
                     kw + (w_out - 1) * stride + 1),
                    (1, stride, stride))    # (block_co, tile_conv_h, w_out)
                acc += xs * wts[:, 0, kh, kw][:, None, None]
        acc = acc.reshape(block_co, tile_conv_h * w_out)
    else:
        acc = jnp.zeros((block_co, tile_conv_h * w_out), jnp.float32)
        for kh in range(K):
            for kw in range(K):
                xs = jax.lax.slice(
                    x, (0, kh, kw),
                    (cin, kh + (tile_conv_h - 1) * stride + 1,
                     kw + (w_out - 1) * stride + 1),
                    (1, stride, stride))       # (cin, tile_conv_h, w_out)
                xs = xs.reshape(cin, tile_conv_h * w_out)
                wk = wts[:, :, kh, kw]         # (block_co, cin)
                acc += jax.lax.dot_general(
                    wk, xs, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)  # (block_co, 1) broadcast
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "relu6":
        acc = jnp.clip(acc, 0.0, 6.0)
    acc = acc.reshape(block_co, tile_conv_h, w_out)
    if pool_k:
        # pooled epilogue: max over the pool_k x pool_k window, straight
        # from the fp32 accumulator -- the conv rows never leave VMEM
        pooled = None
        for ph in range(pool_k):
            for pw in range(pool_k):
                s = jax.lax.slice(
                    acc, (0, ph, pw),
                    (block_co, ph + (tile_h - 1) * pool_s + 1,
                     pw + (pw_out - 1) * pool_s + 1),
                    (1, pool_s, pool_s))       # (block_co, tile_h, pw_out)
                pooled = s if pooled is None else jnp.maximum(pooled, s)
        acc = pooled
    o_ref[0] = acc.astype(o_ref.dtype)


def conv2d(x: jnp.ndarray, w: jnp.ndarray, *, stride: int = 1,
           pad: int = 0, bias: jnp.ndarray | None = None,
           activation: str | None = None, groups: int = 1,
           pool_k: int = 0, pool_s: int = 0,
           block_co: int = 0, tile_h: int = 0,
           vmem_budget: int = DEFAULT_VMEM_BUDGET,
           interpret: bool = True) -> jnp.ndarray:
    """x: (N, Cin, H, W); w: (Cout, Cin/groups, K, K) -> (N, Cout, Ho, Wo).

    ``bias`` (Cout,) and ``activation`` in {None, "relu", "relu6"} fuse into
    the kernel epilogue; ``groups`` follows lax ``feature_group_count``.
    ``pool_k > 0`` additionally fuses a VALID ``maxpool(pool_k, pool_s)``
    (``pool_s`` defaults to ``pool_k``) after the activation, returning the
    pooled (N, Cout, Po, Pw) tensor from the same launch."""
    if activation not in (None, "relu", "relu6"):
        raise ValueError(f"unknown activation {activation!r}")
    N, Cin, H, W = x.shape
    Cout, cin_pg, K, _ = w.shape
    plan = plan_conv(x.shape, w.shape, stride=stride, pad=pad, groups=groups,
                     block_co=block_co, tile_h=tile_h,
                     pool_k=pool_k, pool_s=pool_s,
                     dtype_bytes=x.dtype.itemsize, vmem_budget=vmem_budget)
    block_co, tile_h = plan.block_co, plan.tile_h
    pool_k, pool_s = plan.pool_k, plan.pool_s
    p_out, pw_out = plan.p_out, plan.pw_out
    h_in, w_in = H + 2 * pad, W + 2 * pad
    # pad rows so the remainder tile's halo read stays in-bounds (the padded
    # pooled rows, and the conv rows feeding only them, are sliced away)
    p_out_pad = plan.n_h_blocks * tile_h
    conv_rows = ((p_out_pad - 1) * pool_s + pool_k) if pool_k \
        else p_out_pad
    rows_needed = (conv_rows - 1) * stride + K
    x = jnp.pad(x, ((0, 0), (0, 0),
                    (pad, pad + max(0, rows_needed - h_in)), (pad, pad)))
    if bias is None:
        bias = jnp.zeros((Cout,), jnp.float32)
    bias2d = bias.reshape(Cout, 1).astype(jnp.float32)

    g_out = plan.g_out
    # consecutive tiles advance by tile_h kernel-output rows, i.e.
    # tile_h * pool_s conv rows, i.e. tile_h * pool_s * stride input rows
    row_step = tile_h * pool_s * stride
    kernel = functools.partial(
        _conv_kernel, K=K, stride=stride, tile_h=tile_h,
        tile_conv_h=plan.tile_conv_h, w_out=plan.w_out, pw_out=pw_out,
        depthwise=plan.depthwise, activation=activation,
        pool_k=pool_k, pool_s=pool_s)
    out = pl.pallas_call(
        kernel,
        grid=(N, Cout // block_co, plan.n_h_blocks),
        in_specs=[
            # overlapping (haloed) row tiles: element offsets, not block ids
            pl.BlockSpec(
                (1, plan.cin_block, plan.tile_in_h, w_in),
                lambda n, c, h: (n, c * block_co // g_out * cin_pg,
                                 h * row_step, 0),
                indexing_mode=pl.unblocked),
            pl.BlockSpec((block_co, cin_pg, K, K),
                         lambda n, c, h: (c, 0, 0, 0)),
            pl.BlockSpec((block_co, 1), lambda n, c, h: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_co, tile_h, pw_out),
                               lambda n, c, h: (n, c, h, 0)),
        out_shape=jax.ShapeDtypeStruct((N, Cout, p_out_pad, pw_out),
                                       x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(x, w, bias2d)
    return out[:, :, :p_out, :] if p_out_pad != p_out else out
