"""Flash attention (online softmax) as a Pallas TPU kernel.

TPU adaptation of the memory-hierarchy insight behind FlashAttention: tile
Q/K/V into VMEM blocks sized for the MXU (multiples of 128 on the matmul
dims), keep the running (m, l, acc) statistics in VMEM scratch across the
K-block loop, and never materialise the (Sq, Sk) score matrix in HBM.

Grid: (batch*heads, q_blocks, k_blocks); the k dimension is sequential
("arbitrary"), q and batch are parallel.  GQA is handled by the ops.py
wrapper (K/V indexed at kv_head = head // group)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, scale: float, causal: bool,
                  block_q: int, block_k: int, num_k_blocks: int,
                  sk_minus_sq: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)              # (block_q, hd)
    k = k_ref[0].astype(jnp.float32)              # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        qi = pl.program_id(1)
        rows = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + sk_minus_sq
        cols = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)           # fully-masked rows -> 0
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, hd); k, v: (BH, Sk, hd) -- pre-broadcast for GQA.

    Sq/Sk must be multiples of the block sizes (ops.py pads); hd should be
    a multiple of 128 on real hardware for MXU alignment (any hd works in
    interpret mode)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0
    scale = scale if scale is not None else 1.0 / hd**0.5
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, num_k_blocks=nk, sk_minus_sq=Sk - Sq)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
