"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics contracts: slow, obviously-correct implementations
(token-level scans, direct convolution, dense softmax attention) that the
kernel sweep tests assert_allclose against."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (BH, Sq, hd); k, v: (BH, Sk, hd). Dense softmax attention, f32."""
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / hd**0.5
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        # align the ends: query i attends to keys <= i + (Sk - Sq)
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        ki = jnp.arange(Sk)[None, :]
        s = jnp.where(ki <= qi, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", w, v.astype(jnp.float32)).astype(q.dtype)


def conv2d_ref(x, w, *, stride: int = 1, pad: int = 0, bias=None,
               activation: str | None = None, groups: int = 1,
               accum_dtype=None):
    """x: (N, Cin, H, W); w: (Cout, Cin/groups, K, K). Direct lax conv,
    optionally grouped (``feature_group_count``) with the same fused
    epilogue the Pallas kernel offers (bias + relu/relu6).

    ``accum_dtype`` (e.g. fp32 for bf16 inputs) mirrors the Pallas
    kernel's storage/accumulate split: the conv accumulates -- and the
    epilogue runs -- in that dtype, and the result is cast back to the
    storage dtype at the end."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
        feature_group_count=groups,
        preferred_element_type=accum_dtype)
    if bias is not None:
        y = y + bias[None, :, None, None].astype(y.dtype)
    if activation == "relu":
        y = jax.nn.relu(y)
    elif activation == "relu6":
        y = jnp.clip(y, 0.0, 6.0)
    elif activation is not None:
        raise ValueError(f"unknown activation {activation!r}")
    return y if accum_dtype is None else y.astype(x.dtype)


def rwkv6_wkv_ref(r, k, v, w, u, s0=None):
    """Token-level RWKV6 WKV recurrence.

    r,k,v,w: (B, T, H, hd); w is the per-step decay in (0,1);
    u: (H, hd) current-token bonus. Returns (out (B,T,H,hd), s_fin)."""
    B, T, H, hd = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(s, ins):
        rt, kt, vt, wt = ins
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = s * wt[..., :, None] + kv
        return s_new, out

    ins = tuple(t.transpose(1, 0, 2, 3).astype(jnp.float32)
                for t in (r, k, v, w))
    s_fin, outs = jax.lax.scan(step, s0, ins)
    return outs.transpose(1, 0, 2, 3).astype(r.dtype), s_fin


def mamba2_ssd_ref(x, dt, A, B, C, D=None, h0=None):
    """Token-level Mamba2 SSD recurrence.

    x: (Bb, T, H, hp); dt: (Bb, T, H) (post-softplus); A: (H,) negative;
    B, C: (Bb, T, H, ds). h_t = exp(dt*A) h_{t-1} + dt * B_t x_t^T;
    y_t = C_t . h_t (+ D x).  Returns (y, h_fin)."""
    Bb, T, H, hp = x.shape
    ds = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bb, H, hp, ds), jnp.float32)

    def step(h, ins):
        xt, dtt, Bt, Ct = ins
        a = jnp.exp(dtt * A[None, :])                       # (Bb,H)
        h_new = h * a[..., None, None] \
            + jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt, Bt)
        y = jnp.einsum("bhn,bhpn->bhp", Ct, h_new)
        return h_new, y

    ins = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
           dt.transpose(1, 0, 2).astype(jnp.float32),
           B.transpose(1, 0, 2, 3).astype(jnp.float32),
           C.transpose(1, 0, 2, 3).astype(jnp.float32))
    h_fin, ys = jax.lax.scan(step, h0, ins)
    y = ys.transpose(1, 0, 2, 3)
    if D is not None:
        y = y + x.astype(jnp.float32) * D[None, None, :, None]
    return y.astype(x.dtype), h_fin
