"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, GQA head broadcasting, and the
CPU-vs-TPU switch: ``interpret=True`` (the default) executes the kernel
bodies in Python on CPU for validation; on a real TPU runtime set
REPRO_PALLAS_COMPILE=1 to compile via Mosaic.  The env var is resolved at
*call* time (mirroring ``models/cnn.py::conv_backend``) and threaded into
the jit'd inner functions as a static argument, so flipping it after import
-- or between calls -- retraces instead of silently reusing the old mode.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.dtype_policy import conv_dtype, policy_jnp_dtype
from repro.kernels import conv2d as _conv
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba2_ssd as _ssd
from repro.kernels import rwkv6_wkv as _wkv
# Wire-dtype boundary codec (fused int8 quantize/dequantize + jnp fallback);
# re-exported here so callers reach every kernel through one surface.
from repro.kernels.quant import (boundary_roundtrip,  # noqa: F401
                                 dequantize_boundary, quantize_boundary)


def interpret_mode() -> bool:
    """Resolve the Pallas execution mode from the environment *now*.

    True (default) = interpret on CPU; REPRO_PALLAS_COMPILE=1 = Mosaic."""
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _flash_attention_gqa(q, k, v, *, causal, block_q, block_k, interpret):
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    kb = jnp.repeat(k, g, axis=2)
    vb = jnp.repeat(v, g, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = kb.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    vf = vb.transpose(0, 2, 1, 3).reshape(B * H, -1, hd)
    # sequence lengths must be block multiples (padding keys would need an
    # extra mask; callers pick block sizes that divide their seq lens)
    assert Sq % block_q == 0 and kf.shape[1] % block_k == 0, \
        (Sq, kf.shape[1], block_q, block_k)
    out = _fa.flash_attention(qf, kf, vf, causal=causal,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def flash_attention_gqa(q, k, v, *, causal: bool = True,
                        block_q: int = 128, block_k: int = 128):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd) with H % KV == 0."""
    return _flash_attention_gqa(q, k, v, causal=causal, block_q=block_q,
                                block_k=block_k, interpret=interpret_mode())


@functools.partial(jax.jit,
                   static_argnames=("stride", "pad", "activation", "groups",
                                    "pool_k", "pool_s", "tile_w", "search",
                                    "interpret"))
def _conv2d(x, w, *, stride, pad, bias, activation, groups, pool_k, pool_s,
            tile_w, search, interpret):
    return _conv.conv2d(x, w, stride=stride, pad=pad, bias=bias,
                        activation=activation, groups=groups,
                        pool_k=pool_k, pool_s=pool_s, tile_w=tile_w,
                        search=search, interpret=interpret)


def conv2d(x, w, *, stride: int = 1, pad: int = 0, bias=None,
           activation: str | None = None, groups: int = 1,
           pool_k: int = 0, pool_s: int = 0, dtype: str | None = None,
           tile_w: int = 0, search: bool | None = None):
    """Fused conv(+bias)(+relu/relu6)(+maxpool): one tiled kernel launch.

    ``bias`` (Cout,) and ``activation`` run in the kernel epilogue on the
    fp32 accumulator; ``groups`` is lax's ``feature_group_count`` (set to
    Cin for depthwise).  ``pool_k > 0`` fuses a VALID
    ``maxpool(pool_k, pool_s)`` after the activation so a paper-layer
    conv->relu->maxpool triple is a single launch -- the conv activation
    never round-trips HBM.

    ``dtype`` is the storage policy (``fp32`` | ``bf16``; default resolves
    ``REPRO_CONV_DTYPE`` at call time).  Under ``bf16`` the input and
    weights are stored/staged as bfloat16 -- the planner sees 2-byte
    elements and doubles ``tile_h`` for the same VMEM budget -- while the
    accumulator, bias add, activation, and pool epilogue all stay fp32;
    the output tensor is returned in the storage dtype.  ``fp32`` is the
    no-downcast default: tensors keep whatever dtype they already have.

    Tiling comes from the joint ``plan_conv`` cost-model search by
    default; ``tile_w`` pins the column tile and ``search=False`` falls
    back to the legacy greedy planner.  Both resolve their env knobs
    (``REPRO_CONV_TILE_W`` / ``REPRO_CONV_SEARCH``) at *call* time and are
    threaded into the jit as static arguments, so flipping an env var
    between calls retraces with the new plan instead of silently reusing
    the old grid."""
    if conv_dtype(dtype) == "bf16":
        jdt = policy_jnp_dtype("bf16")
        x = x if x.dtype == jdt else x.astype(jdt)
        w = w if w.dtype == jdt else w.astype(jdt)
    return _conv2d(x, w, stride=stride, pad=pad, bias=bias,
                   activation=activation, groups=groups,
                   pool_k=pool_k, pool_s=pool_s,
                   tile_w=_conv.tile_w_override(tile_w),
                   search=_conv.search_enabled(search),
                   interpret=interpret_mode())


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def _rwkv6_wkv(r, k, v, w, u, *, block_t, interpret):
    r2, p = _pad_to(r, 1, block_t)
    k2, _ = _pad_to(k, 1, block_t)
    v2, _ = _pad_to(v, 1, block_t)
    w2, _ = _pad_to(w, 1, block_t)
    if p:
        # pad decay with ones (identity) so state evolution is unaffected
        w2 = w2.at[:, -p:].set(1.0)
    out = _wkv.rwkv6_wkv(r2, k2, v2, w2, u, block_t=block_t,
                         interpret=interpret)
    return out[:, :r.shape[1]]


def rwkv6_wkv(r, k, v, w, u, *, block_t: int = 64):
    return _rwkv6_wkv(r, k, v, w, u, block_t=block_t,
                      interpret=interpret_mode())


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _mamba2_ssd(x, dt, A, B, C, *, chunk, interpret):
    T = x.shape[1]
    (x2, p) = _pad_to(x, 1, chunk)
    dt2, _ = _pad_to(dt, 1, chunk)
    B2, _ = _pad_to(B, 1, chunk)
    C2, _ = _pad_to(C, 1, chunk)
    out = _ssd.mamba2_ssd(x2, dt2, A, B2, C2, chunk=chunk,
                          interpret=interpret)
    return out[:, :T]


def mamba2_ssd(x, dt, A, B, C, *, chunk: int = 64):
    return _mamba2_ssd(x, dt, A, B, C, chunk=chunk,
                       interpret=interpret_mode())
