"""RWKV6 WKV recurrence as a Pallas TPU kernel.

The WKV state S is an (hd, hd) matrix per (batch, head); the recurrence
  out_t = r_t . (S + diag(u) k_t v_t^T)
  S     = diag(w_t) S + k_t v_t^T
is strictly sequential in t, so the TPU adaptation keeps S resident in VMEM
scratch across a time-block loop (grid dim 2, "arbitrary") while (batch,
head) parallelise across cores.  Each grid step loads a (block_t, hd) tile
of r/k/v/w and walks it with a fori_loop -- HBM traffic is O(T*hd) per
head instead of O(T*hd^2) for a naive state-materialising implementation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr,
                *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                    # (hd,)

    def step(t, s):
        rt = r_ref[0, t, 0].astype(jnp.float32)         # (hd,)
        kt = k_ref[0, t, 0].astype(jnp.float32)
        vt = v_ref[0, t, 0].astype(jnp.float32)
        wt = w_ref[0, t, 0].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                  # (hd, hd)
        out = (rt[:, None] * (s + u[:, None] * kv)).sum(axis=0)
        o_ref[0, t, 0] = out.astype(o_ref.dtype)
        return s * wt[:, None] + kv

    s_scr[...] = jax.lax.fori_loop(0, block_t, step, s_scr[...])


def rwkv6_wkv(r, k, v, w, u, *, block_t: int = 64,
              interpret: bool = True):
    """r,k,v,w: (B, T, H, hd); u: (H, hd). Returns out (B, T, H, hd).

    T must be a multiple of block_t (ops.py pads)."""
    B, T, H, hd = r.shape
    assert T % block_t == 0
    nt = T // block_t
    kernel = functools.partial(_wkv_kernel, block_t=block_t)
    spec = pl.BlockSpec((1, block_t, 1, hd), lambda b, h, t: (b, t, h, 0))
    return pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda b, h, t: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
