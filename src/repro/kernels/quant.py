"""Per-channel symmetric int8 boundary quantization (wire-dtype tier).

The split-boundary upload is the term SmartSplit's objectives are most
sensitive to (``I|l1 / B`` dominates both Eq. 4 latency and Eq. 9 energy on
mobile uplinks).  Shipping the boundary activation as int8 -- one byte per
element plus one fp32 absmax scale per channel -- cuts the wire payload
~4x vs fp32 at a bounded, reported accuracy cost.

Scheme (deterministic, so fault-free runs are reproducible bit-for-bit):

    absmax_c = max(|x_c|)                    per channel c
    scale_c  = absmax_c / 127   (1.0 when the channel is all-zero)
    q        = clip(round(x / scale_c), -127, 127)  as int8
    dequant  = q * scale_c                   (error <= scale_c / 2)

The fused Pallas kernel does the absmax reduce, scale, and round/clip in
one pass over each channel block (the channel axis is moved to the front
and the rest flattened to lanes); ``quantize_jnp`` / ``dequantize_jnp``
are the plain-jnp fallback -- the same ops in the same order, so the two
backends agree bitwise and either side of a link may use either path.

Channel convention: feature maps (ndim >= 3, layout (B, C, H, W)) quantize
per channel axis 1; flat tensors (ndim <= 2) quantize per-tensor (a single
scale) -- per-feature scales on a (B, 4096) flatten boundary would cost
more wire bytes than they save.  ``default_channel_axis`` encodes this so
the runtime codec, ``apply_split``, and the cost model all agree.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dtype_policy import policy_jnp_dtype

# Per-tile VMEM budget for the quantize kernel (fp32 in + int8 out + scales).
_VMEM_BUDGET = 8 * 1024 * 1024
_LANE = 128


def _interpret_mode() -> bool:
    """Mirrors ``ops.interpret_mode`` (ops imports this module, not vice
    versa, so the env read is duplicated rather than creating a cycle)."""
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _use_pallas(backend: str | None = None) -> bool:
    """Quantize on the Pallas path iff the conv path does (same knob)."""
    b = backend or os.environ.get("REPRO_CONV_BACKEND", "xla")
    return b == "pallas"


def default_channel_axis(ndim: int) -> int | None:
    """Quantization-group axis: channels for feature maps, whole-tensor
    (None) for flat activations."""
    return 1 if ndim >= 3 else None


def scale_count(shape: tuple[int, ...], axis: int | None) -> int:
    """Number of fp32 scales shipped alongside an int8 payload."""
    return 1 if axis is None else int(shape[axis])


# ---------------------------------------------------------------------------
# Fused Pallas kernels (one pass per channel block)
# ---------------------------------------------------------------------------
def _quantize_kernel(x_ref, values_ref, scales_ref):
    x = x_ref[:].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    scales_ref[:] = scale
    q = jnp.round(x / scale)
    values_ref[:] = jnp.clip(q, -127.0, 127.0).astype(jnp.int8)


def _dequantize_kernel(values_ref, scales_ref, out_ref):
    out_ref[:] = values_ref[:].astype(jnp.float32) * scales_ref[:]


def _block_c(c: int, n: int) -> int:
    """Channel-block rows whose fp32+int8 tile fits the VMEM budget."""
    rows = max(1, _VMEM_BUDGET // max(1, n * 5))
    rows = min(rows, 128)
    if rows >= 8:
        rows -= rows % 8  # sublane-friendly when compiled
    return max(1, min(rows, c))


def _quantize_pallas_2d(x2d, interpret: bool):
    """x2d: (C, N) fp32 -> (values int8 (C, N), scales fp32 (C, 1))."""
    c, n = x2d.shape
    n_pad = (-n) % _LANE
    xp = jnp.pad(x2d, ((0, 0), (0, n_pad))) if n_pad else x2d
    bc = _block_c(c, xp.shape[1])
    c_pad = (-c) % bc
    if c_pad:
        xp = jnp.pad(xp, ((0, c_pad), (0, 0)))
    cp, np_ = xp.shape
    values, scales = pl.pallas_call(
        _quantize_kernel,
        grid=(cp // bc,),
        in_specs=[pl.BlockSpec((bc, np_), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bc, np_), lambda i: (i, 0)),
                   pl.BlockSpec((bc, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((cp, np_), jnp.int8),
                   jax.ShapeDtypeStruct((cp, 1), jnp.float32)],
        interpret=interpret,
    )(xp)
    return values[:c, :n], scales[:c]


def _dequantize_pallas_2d(v2d, s2d, interpret: bool):
    """(C, N) int8 + (C, 1) fp32 scales -> (C, N) fp32."""
    c, n = v2d.shape
    n_pad = (-n) % _LANE
    vp = jnp.pad(v2d, ((0, 0), (0, n_pad))) if n_pad else v2d
    bc = _block_c(c, vp.shape[1])
    c_pad = (-c) % bc
    sp = s2d
    if c_pad:
        vp = jnp.pad(vp, ((0, c_pad), (0, 0)))
        sp = jnp.pad(sp, ((0, c_pad), (0, 0)))
    cp, np_ = vp.shape
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(cp // bc,),
        in_specs=[pl.BlockSpec((bc, np_), lambda i: (i, 0)),
                  pl.BlockSpec((bc, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bc, np_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, np_), jnp.float32),
        interpret=interpret,
    )(vp, sp)
    return out[:c, :n]


# ---------------------------------------------------------------------------
# Plain-jnp fallback (usable inside shard_map; bitwise-equal to the kernel)
# ---------------------------------------------------------------------------
def quantize_jnp(x, axis: int | None = None):
    """Quantize ``x`` per channel ``axis`` (None = per-tensor).

    Returns ``(values int8 like x, scales fp32 (C,))`` with C = 1 when
    per-tensor."""
    x32 = x.astype(jnp.float32)
    if axis is None:
        absmax = jnp.max(jnp.abs(x32)).reshape(1)
        sb = absmax  # broadcasts over everything
    else:
        axis = axis % x.ndim
        red = tuple(a for a in range(x.ndim) if a != axis)
        absmax = jnp.max(jnp.abs(x32), axis=red)
        shape = [1] * x.ndim
        shape[axis] = x.shape[axis]
        sb = absmax.reshape(shape)
    scale = jnp.where(absmax > 0.0, absmax / 127.0, 1.0)
    sb = jnp.where(sb > 0.0, sb / 127.0, 1.0)
    q = jnp.clip(jnp.round(x32 / sb), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_jnp(values, scales, axis: int | None = None,
                   out_dtype=jnp.float32):
    """Invert ``quantize_jnp``: values * scale, cast to ``out_dtype``."""
    if axis is None:
        sb = scales.reshape(())
    else:
        axis = axis % values.ndim
        shape = [1] * values.ndim
        shape[axis] = values.shape[axis]
        sb = scales.reshape(shape)
    return (values.astype(jnp.float32) * sb).astype(out_dtype)


# ---------------------------------------------------------------------------
# Jit'd public wrappers (env knobs resolved at call time, ops.py idiom)
# ---------------------------------------------------------------------------
def _to_2d(x, axis: int):
    xm = jnp.moveaxis(x, axis, 0)
    return xm.reshape(x.shape[axis], -1), xm.shape


def _from_2d(x2d, moved_shape, axis: int, ndim: int):
    return jnp.moveaxis(x2d.reshape(moved_shape), 0, axis % ndim)


@functools.partial(jax.jit, static_argnames=("axis", "use_pallas",
                                             "interpret"))
def _quantize(x, *, axis, use_pallas, interpret):
    if not use_pallas:
        return quantize_jnp(x, axis)
    if axis is None:
        x2d = x.astype(jnp.float32).reshape(1, -1)
        v2d, s2d = _quantize_pallas_2d(x2d, interpret)
        return v2d.reshape(x.shape), s2d.reshape(1)
    x2d, moved = _to_2d(x.astype(jnp.float32), axis % x.ndim)
    v2d, s2d = _quantize_pallas_2d(x2d, interpret)
    return _from_2d(v2d, moved, axis, x.ndim), s2d.reshape(-1)


@functools.partial(jax.jit, static_argnames=("axis", "use_pallas",
                                             "interpret", "out_dtype"))
def _dequantize(values, scales, *, axis, use_pallas, interpret, out_dtype):
    if not use_pallas:
        return dequantize_jnp(values, scales, axis, out_dtype)
    if axis is None:
        v2d = values.reshape(1, -1)
        s2d = jnp.broadcast_to(scales.reshape(1, 1), (1, 1))
        out = _dequantize_pallas_2d(v2d, s2d, interpret)
        return out.reshape(values.shape).astype(out_dtype)
    v2d, moved = _to_2d(values, axis % values.ndim)
    out = _dequantize_pallas_2d(v2d, scales.reshape(-1, 1), interpret)
    return _from_2d(out, moved, axis, values.ndim).astype(out_dtype)


def quantize_boundary(x, axis: int | None = None, *,
                      backend: str | None = None):
    """Fused absmax+scale+round/clip quantize of a boundary activation.

    ``axis`` defaults to the channel convention for ``x.ndim``; ``backend``
    picks pallas-vs-jnp like the conv path (``REPRO_CONV_BACKEND``)."""
    if axis is None:
        axis = default_channel_axis(x.ndim)
    return _quantize(x, axis=axis, use_pallas=_use_pallas(backend),
                     interpret=_interpret_mode())


def dequantize_boundary(values, scales, axis: int | None = None, *,
                        out_dtype=None, backend: str | None = None):
    """Invert ``quantize_boundary`` (values must carry its dtype/shape)."""
    if axis is None:
        axis = default_channel_axis(values.ndim)
    return _dequantize(values, scales, axis=axis,
                       use_pallas=_use_pallas(backend),
                       interpret=_interpret_mode(),
                       out_dtype=out_dtype or jnp.float32)


def boundary_roundtrip(x, wire: str, *, axis: int | None = None,
                       backend: str | None = None):
    """What the receiver decodes when ``x`` ships under wire format
    ``wire``: quantize->dequantize for int8, downcast->upcast for a float
    wire format, back in ``x.dtype`` either way.  This is the exact math
    the runtime codec performs, so planners/tests/benches can model the
    end-to-end effect without a link."""
    if wire == "int8":
        if axis is None:
            axis = default_channel_axis(x.ndim)
        q, scales = quantize_boundary(x, axis, backend=backend)
        return dequantize_boundary(q, scales, axis, out_dtype=x.dtype,
                                   backend=backend)
    jdt = policy_jnp_dtype(wire)
    if x.dtype == jdt:
        return x
    return x.astype(jdt).astype(x.dtype)
