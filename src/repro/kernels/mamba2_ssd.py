"""Mamba2 SSD (state-space dual) chunked scan as a Pallas TPU kernel.

TPU adaptation of the SSD insight: within a chunk the recurrence
  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T,   y_t = C_t . h_t
collapses into attention-like matmuls (MXU work), while the cross-chunk
state (hp, ds) lives in VMEM scratch and is carried across the sequential
chunk grid dimension:

  y_intra = ((C B^T) o decay_mask) @ (dt * x)       -- (c,c)x(c,hp) matmuls
  y_inter = exp(cum) * (C @ h_prev^T)
  h_next  = chunk_decay * h_prev + sum_u w_u B_u (dt_u x_u)^T

Grid: (batch, heads, chunks) with chunks "arbitrary" (sequential)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, la_ref, b_ref, c_ref, o_ref, h_scr,
                *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (chunk, hp)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (chunk,)
    la = la_ref[0, :, 0].astype(jnp.float32)      # (chunk,) log decay
    B = b_ref[0, :, 0].astype(jnp.float32)        # (chunk, ds)
    C = c_ref[0, :, 0].astype(jnp.float32)        # (chunk, ds)

    cs = jnp.cumsum(la)                           # (chunk,)
    # intra-chunk attention-like term
    seg = cs[:, None] - cs[None, :]               # (t, u)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(cols <= rows, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * decay                              # (chunk, chunk)
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(att, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: read carried state
    h = h_scr[...]                                # (hp, ds)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        C, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: h_next = exp(cs_last) h + sum_u exp(cs_last-cs_u) dt_u x_u B_u^T
    w_u = jnp.exp(cs[-1] - cs) * dt               # (chunk,)
    new_contrib = jax.lax.dot_general(
        x * w_u[:, None], B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)       # (hp, ds)
    h_scr[...] = h * jnp.exp(cs[-1]) + new_contrib
    o_ref[0, :, 0] = y.astype(o_ref.dtype)


def mamba2_ssd(x, dt, A, B, C, *, chunk: int = 64, interpret: bool = True):
    """x: (Bb, T, H, hp); dt: (Bb, T, H); A: (H,); B, C: (Bb, T, H, ds).
    Returns y (Bb, T, H, hp) with h0 = 0.  T must be a chunk multiple."""
    Bb, T, H, hp = x.shape
    ds = B.shape[-1]
    assert T % chunk == 0
    la = dt * A[None, None, :]                     # (Bb, T, H) log decay
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    sx = pl.BlockSpec((1, chunk, 1, hp), lambda b, h, c: (b, c, h, 0))
    s1 = pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h))
    sb = pl.BlockSpec((1, chunk, 1, ds), lambda b, h, c: (b, c, h, 0))
    return pl.pallas_call(
        kernel,
        grid=(Bb, H, T // chunk),
        in_specs=[sx, s1, s1, sb, sb],
        out_specs=sx,
        out_shape=jax.ShapeDtypeStruct((Bb, T, H, hp), x.dtype),
        scratch_shapes=[pltpu.VMEM((hp, ds), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, la, B, C)
