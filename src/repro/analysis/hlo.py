"""Compiled-HLO text analysis: collective-bytes accounting.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled module text and sum the result-shape bytes of every collective op,
bucketed by op kind.  Methodology notes:

* result-shape bytes is the per-device payload of the op; wire traffic per
  device is ~(n-1)/n of that for all-gather/reduce-scatter and ~2(n-1)/n
  for ring all-reduce -- the roofline divides by per-chip link bandwidth,
  so result bytes is the right order-zero proxy and we report the raw sum
  (consistent across iterations, which is what the hillclimb compares).
* async pairs (-start/-done) are counted once (the -start carries the op).
"""
from __future__ import annotations

import re
from collections import defaultdict

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes per collective kind over the whole module.
    '-done' ops are skipped (their '-start' counterpart was counted)."""
    out: dict[str, float] = defaultdict(float)
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(type_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def collective_counts(hlo_text: str) -> dict[str, int]:
    out: dict[str, int] = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        out[m.group(2)] += 1
    return dict(out)


def count_op(hlo_text: str, opname: str) -> int:
    """Count occurrences of a given HLO op (e.g. 'fusion', 'transpose')."""
    return len(re.findall(rf"\s{re.escape(opname)}\(", hlo_text))


def cost_analysis_dict(compiled) -> dict[str, float]:
    """``Compiled.cost_analysis()`` returns a plain dict on newer jax and a
    per-partition list of dicts on older releases -- normalise to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
