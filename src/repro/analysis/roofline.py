"""Three-term roofline from dry-run artefacts (DESIGN.md section 7).

  compute    = total_FLOPs    / (chips x 197e12)
  memory     = total_HBM_bytes/ (chips x 819e9)
  collective = collective_bytes / (chips x 50e9)

``cost_analysis()`` on the SPMD-partitioned module reports *per-device*
numbers (verified experimentally in this repo), so totals = per_device x
chips and the chips cancel: the terms below use per-device numbers
directly.  MODEL_FLOPS uses the analytic 6*N_active*D (train) / 2*N_active*D
(inference) so the useful-work ratio exposes remat/dispatch overheads."""
from __future__ import annotations

import dataclasses

from repro.core.hardware import (ICI_LINK_BW, TPU_PJ_PER_FLOP,
                                 TPU_PJ_PER_HBM_BYTE, TPU_PJ_PER_ICI_BYTE,
                                 V5E_HBM_BW, V5E_PEAK_FLOPS_BF16)


@dataclasses.dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    bytes_per_device: float
    hbm_budget_ok: bool

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops_total \
            if self.hlo_flops_total else 0.0

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def energy_j(self) -> float:
        """Per-device energy per step from the TPU energy model -- the
        paper's f2 objective lifted to the fleet (DESIGN.md section 2):
        pJ/FLOP + pJ/HBM-byte + pJ/link-byte."""
        return (self.compute_s * V5E_PEAK_FLOPS_BF16 * TPU_PJ_PER_FLOP
                + self.memory_s * V5E_HBM_BW * TPU_PJ_PER_HBM_BYTE
                + self.collective_s * 50e9 * TPU_PJ_PER_ICI_BYTE) * 1e-12


def from_record(rec: dict) -> Roofline:
    """rec: one dry-run JSON record (see launch/dryrun.py)."""
    chips = rec["num_devices"]
    flops_dev = rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes accessed", 0.0)
    coll_dev = rec["collective_bytes"].get("total", 0.0)
    mem = rec["memory"]
    resident = mem.get("argument_size_in_bytes", 0) \
        + mem.get("output_size_in_bytes", 0) \
        + mem.get("temp_size_in_bytes", 0) \
        - mem.get("alias_size_in_bytes", 0)
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=flops_dev / V5E_PEAK_FLOPS_BF16,
        memory_s=bytes_dev / V5E_HBM_BW,
        collective_s=coll_dev / ICI_LINK_BW,
        model_flops=rec["model_flops"] / chips,
        hlo_flops_total=flops_dev,
        bytes_per_device=resident,
        hbm_budget_ok=resident <= 16 * 1024**3,
    )


def format_table(rows: list[Roofline]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':9s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'bound':>10s} {'useful':>7s} {'GB/dev':>8s} {'fits':>5s} "
           f"{'J/dev':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.mesh:9s} "
            f"{r.compute_s:10.4f} {r.memory_s:10.4f} {r.collective_s:10.4f} "
            f"{r.dominant:>10s} {r.useful_ratio:7.2f} "
            f"{r.bytes_per_device / 2**30:8.2f} "
            f"{'yes' if r.hbm_budget_ok else 'NO':>5s} "
            f"{r.energy_j:8.2f}")
    return "\n".join(lines)
