"""Training driver: data pipeline -> jit'd train step -> metrics +
checkpoints.  Used by examples/train_small.py on CPU and by
launch/train.py on a mesh."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.launch.partition import make_train_step
from repro.models import transformer as T
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    log_every: int = 10
    ckpt_every: int = 0              # 0 = only final
    ckpt_dir: str = ""
    seed: int = 0
    dtype: str = "float32"
    adamw: opt.AdamWConfig = dataclasses.field(
        default_factory=lambda: opt.AdamWConfig(lr=1e-3, warmup_steps=20,
                                                total_steps=200))


def train(cfg: ModelConfig, tcfg: TrainConfig,
          log: Callable[[str], None] = print) -> dict:
    dtype = jnp.dtype(tcfg.dtype)
    params = T.init_params(cfg, jax.random.PRNGKey(tcfg.seed), dtype)
    opt_state = opt.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg.adamw))

    data = Prefetcher(iter(SyntheticLM(cfg, tcfg.batch, tcfg.seq_len,
                                       seed=tcfg.seed)))
    losses = []
    t0 = time.time()
    for step in range(tcfg.steps):
        batch = next(data)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            log(f"step {step:5d} loss {loss:.4f} "
                f"ce {float(metrics['ce']):.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"lr {float(metrics['lr']):.2e} "
                f"({(time.time() - t0):.1f}s)")
        if tcfg.ckpt_every and tcfg.ckpt_dir \
                and step and step % tcfg.ckpt_every == 0:
            ckpt.save(tcfg.ckpt_dir, step, params, opt_state)
    data.close()
    if tcfg.ckpt_dir:
        ckpt.save(tcfg.ckpt_dir, tcfg.steps, params, opt_state)
    return {"losses": losses, "params": params, "opt_state": opt_state,
            "wall_s": time.time() - t0}
