"""AdamW in pure JAX (no optax dependency): decoupled weight decay, global
grad-norm clipping, linear-warmup + cosine schedule.  Optimiser state dtype
follows the canonical mixed-precision recipe: f32 moments regardless of
parameter dtype."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init_state(params) -> AdamWState:
    f32 = lambda t: jnp.zeros(t.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(f32, params),
                      nu=jax.tree.map(f32, params))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads,
                  state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else jnp.float32(1.0)
    step = state.step + 1
    b1, b2 = cfg.betas
    lr = schedule(cfg, state.step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), \
        {"grad_norm": gnorm, "lr": lr}
