"""Checkpointing: pytree -> directory of .npz shards + a JSON manifest.

Single-host implementation (arrays are gathered with jax.device_get); the
manifest records tree structure, shapes, dtypes and the training step so
restores are validated structurally before any array is touched."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree, prefix="") -> dict[str, Any]:
    flat = {}
    if isinstance(tree, dict):
        for k, v in sorted(tree.items()):
            flat.update(_flatten(v, f"{prefix}/{k}" if prefix else k))
    elif isinstance(tree, tuple) and hasattr(tree, "_fields"):
        for f, v in zip(tree._fields, tree):
            flat.update(_flatten(v, f"{prefix}/{f}"))
        flat[f"{prefix}/__namedtuple__"] = type(tree).__name__
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            flat.update(_flatten(v, f"{prefix}/{i}"))
        flat[f"{prefix}/__seq__"] = type(tree).__name__
    elif tree is None:
        flat[f"{prefix}/__none__"] = True
    else:
        flat[prefix] = tree
    return flat


def save(path: str, step: int, params, opt_state=None,
         extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    tree = {"params": params}
    if opt_state is not None:
        tree["opt_state"] = opt_state
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()
              if hasattr(v, "shape")}
    meta = {k: v for k, v in flat.items() if not hasattr(v, "shape")}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "meta": meta,
        "arrays": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like) -> tuple[int, Any]:
    """Restore into the structure of ``like`` (a pytree template, e.g.
    freshly-initialised params or {'params':..., 'opt_state':...}).
    Returns (step, tree)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    # structural restore: walk `like`, pull arrays by path
    def rebuild(node, prefix):
        if isinstance(node, dict):
            return {k: rebuild(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            return type(node)(*[rebuild(v, f"{prefix}/{f}")
                                for f, v in zip(node._fields, node)])
        if isinstance(node, (tuple, list)):
            return type(node)(rebuild(v, f"{prefix}/{i}")
                              for i, v in enumerate(node))
        if node is None:
            return None
        if prefix not in data:
            raise KeyError(f"checkpoint missing array {prefix!r}")
        arr = data[prefix]
        want = tuple(node.shape)
        if tuple(arr.shape) != want:
            raise ValueError(
                f"checkpoint shape mismatch at {prefix!r}: "
                f"{arr.shape} vs {want}")
        return jax.numpy.asarray(arr).astype(node.dtype)

    return manifest["step"], rebuild(like, "")
