"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

DeepSeek-V3-style MoE: 64 routed experts, top-6, per-expert ff=1408,
48L, d=2048, 16H (kv=16, MHA), vocab 163840."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="moonshot-v1-16b-a3b", arch_type="dense",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    num_experts=64, experts_per_token=6, moe_d_ff=1408,
    pattern="attn_moe",
    source="hf:moonshotai/Moonlight-16B-A3B",
))
