"""HuBERT X-Large [arXiv:2106.07447]: encoder-only audio transformer
(wav2vec2 architecture), 48L, d=1280, 16H, ff=5120; 504 masked-unit
classes.  Audio carve-out: the conv feature extractor is a STUB --
``input_specs`` provides precomputed frame embeddings (batch, frames, d).
Encoder => decode_32k / long_500k are skipped (DESIGN.md section 5)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hubert-xlarge", arch_type="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504, pattern="enc_attn", is_encoder=True,
    frontend="audio",
    source="arXiv:2106.07447 (HuBERT X-Large)",
))
