"""Config registry: importing this package registers every assigned
architecture (plus the paper's CNNs live in configs/paper_cnns.py)."""
from repro.configs import (granite_moe_3b_a800m, hubert_xlarge,
                           internvl2_76b, kimi_k2_1t_a32b,
                           moonshot_v1_16b_a3b, phi3_mini_3_8b, qwen3_4b,
                           rwkv6_7b, starcoder2_15b, zamba2_7b)
from repro.configs.base import (INPUT_SHAPES, InputShape, ModelConfig,
                                all_configs, get_config, shape_skips)

__all__ = ["INPUT_SHAPES", "InputShape", "ModelConfig", "all_configs",
           "get_config", "shape_skips"]
