"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base
family]: 32L, d=1536, 24H GQA kv=8, 40 routed experts top-8, per-expert
ff=512, vocab 49155 (padded to the model-axis multiple; DESIGN.md sec 6)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-moe-3b-a800m", arch_type="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    num_experts=40, experts_per_token=8, moe_d_ff=512,
    pattern="attn_moe",
    source="hf:ibm-granite/granite-3.0 MoE family",
))
