"""InternVL2-Llama3-76B language backbone [arXiv:2404.16821].

VLM carve-out: the InternViT-6B vision encoder + MLP projector are a STUB --
``input_specs`` provides precomputed patch embeddings of shape
(batch, n_patches, d_model); this config is the Llama-3-70B-class LM that
consumes them (80L, d=8192, 64H GQA kv=8, ff=28672, vocab 128256)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-76b", arch_type="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=28672, vocab_size=128256, head_dim=128,
    pattern="attn_mlp", rope_theta=5e5, frontend="vision",
    source="arXiv:2404.16821 (InternVL2; LM = Llama-3-70B class)",
))
