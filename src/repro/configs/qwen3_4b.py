"""Qwen3-4B [hf:Qwen/Qwen3-8B family]: 36L, d=2560, 32H GQA kv=8,
head_dim=128, qk-norm, SwiGLU ff=9728, vocab 151936."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-4b", arch_type="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=9728, vocab_size=151936, head_dim=128, qk_norm=True,
    pattern="attn_mlp", rope_theta=1e6,
    source="hf:Qwen/Qwen3 family",
))
