"""Architecture configs: the schema every assigned architecture fills in,
plus the analytic per-block cost methods the SmartSplit profiler uses and
the ShapeDtypeStruct input specs the dry-run lowers against.

Block kinds:
  attn_mlp   -- GQA attention + dense (SwiGLU) MLP         (dense archs)
  attn_moe   -- GQA attention + top-k MoE                   (MoE archs)
  rwkv       -- RWKV6 time-mix + channel-mix                (attn-free)
  mamba      -- Mamba2 block                                (SSM)
  mamba_attn -- Mamba2 block + zamba2 shared attention+MLP  (hybrid)
  enc_attn   -- bidirectional attention + MLP               (encoder-only)
"""
from __future__ import annotations

import dataclasses

VOCAB_PAD_MULTIPLE = 2048  # lcm-friendly with a 16-way model axis


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str            # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str              # dense / moe / ssm / hybrid / audio / vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                   # dense MLP hidden (or attn-block MLP hidden)
    vocab_size: int
    head_dim: int = 0           # 0 => d_model // num_heads
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0           # per-expert hidden (0 => d_ff)
    moe_capacity_factor: float = 1.25
    # SSM / RWKV
    ssm_state: int = 0
    ssm_heads: int = 0          # mamba2 value heads (0 => 2*d_model // 64)
    ssm_groups: int = 8         # mamba2 B/C groups (GQA-style)
    ssm_expand: int = 2
    # layer pattern
    pattern: str = "attn_mlp"   # attn_mlp | attn_moe | rwkv | mamba | enc_attn
    attn_every: int = 0         # zamba2: shared attn after every k mamba
    # attention details
    qk_norm: bool = False
    sliding_window: int = 0     # 0 = full causal attention
    rope_theta: float = 1e4
    is_encoder: bool = False
    frontend: str = "none"      # none | audio | vision (stub embeddings)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    source: str = ""            # citation for the config numbers
    # Activation-checkpoint policy for train_step: "none" | "block"
    remat: str = "block"

    # ------------------------------------------------------------------
    def __post_init__(self):
        assert self.d_model % 2 == 0
        if self.pattern in ("attn_mlp", "attn_moe", "enc_attn"):
            assert self.num_heads > 0 and self.num_kv_heads > 0
            assert self.num_heads % self.num_kv_heads == 0

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def e_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def n_mamba_heads(self) -> int:
        return self.ssm_heads or max(1, (self.ssm_expand * self.d_model) // 64)

    # ------------------------------------------------------------------
    def block_kinds(self) -> list[str]:
        if self.pattern == "mamba" and self.attn_every:
            return ["mamba_attn" if (i + 1) % self.attn_every == 0
                    else "mamba" for i in range(self.num_layers)]
        return [self.pattern] * self.num_layers

    # -- parameter counts (per block, in parameter *elements*) ----------
    def _attn_params(self) -> float:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.hd
        return d * h * hd + 2 * d * kv * hd + h * hd * d \
            + (2 * hd if self.qk_norm else 0) + 2 * d  # norms

    def _mlp_params(self, ff: int) -> float:
        return 3 * self.d_model * ff  # SwiGLU: gate, up, down

    def _moe_params(self) -> float:
        return self.num_experts * self._mlp_params(self.e_ff) \
            + self.d_model * self.num_experts  # router

    def _mamba_params(self) -> float:
        d = self.d_model
        inner = self.ssm_expand * d
        nh = self.n_mamba_heads
        # in_proj: x -> (z, x, B, C, dt); B/C are per-GROUP (Mamba2's
        # GQA-style sharing), dt per head; out_proj: inner -> d.
        bc = 2 * self.ssm_state * self.ssm_groups
        return d * (2 * inner + bc + nh) + inner * d + 2 * d

    def _rwkv_params(self) -> float:
        d = self.d_model
        # time-mix: r,k,v,w,g projections + output; channel-mix: 2 mats
        tm = 5 * d * d + d * d
        cm = d * self.d_ff + self.d_ff * d
        return tm + cm + 4 * d

    def block_params(self, kind: str) -> float:
        if kind in ("attn_mlp", "enc_attn"):
            return self._attn_params() + self._mlp_params(self.d_ff)
        if kind == "attn_moe":
            return self._attn_params() + self._moe_params()
        if kind == "mamba":
            return self._mamba_params()
        if kind == "mamba_attn":
            # shared attn+MLP params are charged once in the profile of the
            # first mamba_attn block; duplication-on-split is handled by the
            # planner's state accounting.  Here: amortised share.
            n_attn = max(1, sum(k == "mamba_attn" for k in self.block_kinds()))
            shared = self._attn_params() + self._mlp_params(self.d_ff)
            return self._mamba_params() + shared / n_attn
        if kind == "rwkv":
            return self._rwkv_params()
        raise ValueError(kind)

    def total_params(self) -> float:
        blocks = sum(self.block_params(k) for k in self.block_kinds())
        embed = self.padded_vocab * self.d_model
        unembed = 0 if self.tie_embeddings else self.padded_vocab * self.d_model
        return blocks + embed + unembed

    def active_params(self) -> float:
        """Parameters touched per token (MoE: top-k experts only)."""
        total = self.padded_vocab * self.d_model * \
            (1 if self.tie_embeddings else 2)
        for k in self.block_kinds():
            if k == "attn_moe":
                total += self._attn_params() \
                    + self.experts_per_token * self._mlp_params(self.e_ff) \
                    + self.d_model * self.num_experts
            else:
                total += self.block_params(k)
        return total

    # -- FLOPs per block for a given workload ---------------------------
    def block_flops(self, kind: str, *, seq_len: int, batch: int,
                    mode: str) -> float:
        """Forward FLOPs (multiply-adds x2). mode: prefill|decode|train;
        train = 3x forward (fwd + 2x bwd)."""
        q_tokens = batch * (1 if mode == "decode" else seq_len)
        kv_len = seq_len
        if self.sliding_window and mode == "decode":
            kv_len = min(seq_len, self.sliding_window)
        d, hd = self.d_model, self.hd
        h, kv = self.num_heads, self.num_kv_heads

        def attn_flops(causal: bool) -> float:
            proj = 2 * q_tokens * d * (h * hd + 2 * kv * hd + h * hd)
            if mode == "decode":
                av = 2 * q_tokens * h * hd * kv_len * 2
            else:
                ctx = kv_len if not causal else kv_len / 2
                if self.sliding_window:
                    ctx = min(ctx, self.sliding_window)
                av = 2 * q_tokens * h * hd * ctx * 2
            return proj + av

        def mlp_flops(ff: int, per_tok: int = 1) -> float:
            return 2 * q_tokens * d * ff * 3 * per_tok

        if kind in ("attn_mlp", "enc_attn"):
            f = attn_flops(causal=not self.is_encoder) + mlp_flops(self.d_ff)
        elif kind == "attn_moe":
            f = attn_flops(True) + mlp_flops(self.e_ff,
                                             self.experts_per_token) \
                + 2 * q_tokens * d * self.num_experts
        elif kind in ("mamba", "mamba_attn"):
            inner = self.ssm_expand * d
            nh, ds = self.n_mamba_heads, self.ssm_state
            proj = 2 * q_tokens * d * (2 * inner + 2 * self.ssm_groups * ds
                                       + nh) + 2 * q_tokens * inner * d
            scan = 2 * q_tokens * inner * ds * 3
            f = proj + scan
            if kind == "mamba_attn":
                f += attn_flops(True) + mlp_flops(self.d_ff)
        elif kind == "rwkv":
            tm = 2 * q_tokens * d * d * 6
            wkv = 2 * q_tokens * d * 64 * 3   # per-head hd=64 state update
            cm = 2 * q_tokens * d * self.d_ff * 2
            f = tm + wkv + cm
        else:
            raise ValueError(kind)
        return 3 * f if mode == "train" else f

    def block_state_bytes(self, kind: str, *, batch: int,
                          dtype_bytes: int = 2) -> float:
        """Recurrent state that must migrate if the split cuts here."""
        if kind in ("mamba", "mamba_attn"):
            nh, ds = self.n_mamba_heads, self.ssm_state
            inner = self.ssm_expand * self.d_model
            return batch * (inner // max(nh, 1)) * nh * ds * dtype_bytes
        if kind == "rwkv":
            nh = self.d_model // 64
            return batch * nh * 64 * 64 * dtype_bytes
        return 0.0

    def model_flops(self, *, seq_len: int, batch: int, mode: str) -> float:
        """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train;
        2*N*D for inference -- the roofline's useful-work numerator."""
        tokens = batch * (1 if mode == "decode" else seq_len)
        mult = 6 if mode == "train" else 2
        return mult * self.active_params() * tokens

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family, toy size."""
        return dataclasses.replace(
            self, name=self.name + "-smoke", num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=64 if self.head_dim else 0,
            d_ff=min(self.d_ff, 512),
            moe_d_ff=min(self.e_ff, 256) if self.num_experts else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.num_experts else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=4 if self.pattern == "mamba" else 0,
            ssm_groups=2,
            attn_every=2 if self.attn_every else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
        )


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (loads all config modules)
    return _REGISTRY[name]


def all_configs() -> dict[str, ModelConfig]:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)


def shape_skips(cfg: ModelConfig, shape: InputShape) -> str | None:
    """Return a skip reason, or None if the (arch, shape) cell runs."""
    if cfg.is_encoder and shape.mode == "decode":
        return "encoder-only: no autoregressive decode"
    return None
