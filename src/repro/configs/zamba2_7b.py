"""Zamba2-7B [arXiv:2411.15242]: Mamba2 backbone with a SHARED
attention+MLP block applied every 6th layer (weights shared across all
applications).  81 Mamba2 layers, d=3584, ssm_state=64; the shared block
uses 32 heads (kv=32) and ff=14336."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b", arch_type="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, pattern="mamba", attn_every=6,
    source="arXiv:2411.15242 (Zamba2)",
))
