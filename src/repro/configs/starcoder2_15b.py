"""StarCoder2-15B [arXiv:2402.19173]: 40L, d=6144, 48H GQA kv=4,
ff=24576, RoPE, vocab 49152."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-15b", arch_type="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    d_ff=24576, vocab_size=49152, head_dim=128,
    pattern="attn_mlp",
    source="arXiv:2402.19173 (StarCoder2)",
))
