"""RWKV-6 'Finch' 7B [arXiv:2404.05892]: attention-free RNN with
data-dependent decay. 32L, d=4096 (64 heads x 64), channel-mix ff=14336,
vocab 65536."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b", arch_type="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536, head_dim=64, pattern="rwkv",
    source="arXiv:2404.05892 (RWKV-6 Finch)",
))
