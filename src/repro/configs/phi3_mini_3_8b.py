"""Phi-3-mini 3.8B [arXiv:2404.14219]: 32L, d=3072, 32H MHA (kv=32),
SwiGLU ff=8192, RoPE, vocab 32064."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="phi3-mini-3.8b", arch_type="dense",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, pattern="attn_mlp",
    source="arXiv:2404.14219 (Phi-3)",
))
