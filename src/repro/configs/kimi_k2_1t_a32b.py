"""Kimi K2 [arXiv:2501.kimi2 per assignment]: trillion-parameter MoE.
61L, d=7168, 64H GQA kv=8 (hd=128), 384 routed experts top-8,
per-expert ff=2048, vocab 163840."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840, head_dim=128,
    num_experts=384, experts_per_token=8, moe_d_ff=2048,
    pattern="attn_moe",
    source="arXiv:2501.kimi2 (Kimi K2, paper-table config)",
))
