"""Batched serving engine (the paper's kind: inference).

Bucketed batch-synchronous serving: requests queue up, the scheduler packs
same-length prompts into batches (bucketing keeps the shared-position KV
cache design exact -- see DESIGN.md), one jit'd prefill fills the cache,
then a jit'd decode loop emits tokens greedily (or by temperature sampling)
until every row hit its stop condition.  Optionally executes under a
SmartSplit plan: the engine asks the planner for the split and reports the
boundary-transfer bytes the plan predicted vs the runtime's actual payload.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    done: bool = False
    output: list[int] = dataclasses.field(default_factory=list)
    enqueue_t: float = 0.0
    finish_t: float = 0.0


class BucketScheduler:
    """Groups pending requests by exact prompt length; emits batches of at
    most ``max_batch``."""

    def __init__(self, max_batch: int = 8):
        self.max_batch = max_batch
        self.pending: dict[int, list[Request]] = defaultdict(list)

    def add(self, req: Request) -> None:
        # perf_counter, not time.time(): queue/latency deltas must be
        # monotonic (wall clock can step backwards under NTP adjustment)
        req.enqueue_t = time.perf_counter()
        self.pending[len(req.prompt)].append(req)

    def next_batch(self) -> list[Request] | None:
        if not self.pending:
            return None
        # largest bucket first (throughput), FIFO within bucket
        length = max(self.pending, key=lambda k: len(self.pending[k]))
        bucket = self.pending[length]
        batch, self.pending[length] = bucket[:self.max_batch], \
            bucket[self.max_batch:]
        if not self.pending[length]:
            del self.pending[length]
        return batch or None

    @property
    def n_pending(self) -> int:
        return sum(len(v) for v in self.pending.values())


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 max_batch: int = 8, dtype=jnp.float32):
        assert not cfg.is_encoder, "serving engine drives decoder archs"
        self.cfg, self.params = cfg, params
        self.max_len, self.dtype = max_len, dtype
        self.scheduler = BucketScheduler(max_batch)
        self._rid = 0
        self.stats: dict[str, float] = {"batches": 0, "tokens": 0,
                                        "prefill_tokens": 0,
                                        "latency_p50_s": 0.0,
                                        "latency_p99_s": 0.0}
        self._latencies: list[float] = []

        def prefill(params, tokens, cache):
            logits, cache, _ = T.forward(cfg, params, {"tokens": tokens},
                                         mode="prefill", cache=cache)
            return logits[:, -1, :], cache

        def decode(params, tok, cache):
            logits, cache = T.decode_step(cfg, params, tok, cache)
            return logits[:, -1, :], cache

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    def submit(self, prompt: list[int], max_new_tokens: int = 16,
               temperature: float = 0.0) -> Request:
        self._rid += 1
        req = Request(rid=self._rid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens,
                      temperature=temperature)
        self.scheduler.add(req)
        return req

    def _sample(self, logits: np.ndarray, reqs: list[Request],
                key) -> np.ndarray:
        if all(r.temperature == 0.0 for r in reqs):
            return np.argmax(logits, axis=-1)
        out = np.empty(len(reqs), np.int64)
        for i, r in enumerate(reqs):
            if r.temperature == 0.0:
                out[i] = int(np.argmax(logits[i]))
            else:
                p = jax.nn.softmax(jnp.asarray(logits[i])
                                   / r.temperature)
                out[i] = int(jax.random.categorical(
                    jax.random.fold_in(key, r.rid), jnp.log(p)))
        return out

    def run_batch(self, reqs: list[Request]) -> None:
        B = len(reqs)
        plen = len(reqs[0].prompt)
        toks = jnp.asarray([r.prompt for r in reqs], jnp.int32)
        cache = T.init_cache(self.cfg, B, self.max_len, self.dtype)
        logits, cache = self._prefill(self.params, toks, cache)
        self.stats["prefill_tokens"] += B * plen
        key = jax.random.PRNGKey(0)
        max_new = max(r.max_new_tokens for r in reqs)
        active = np.ones(B, bool)
        cur = self._sample(np.asarray(logits), reqs, key)
        for i, r in enumerate(reqs):
            r.output.append(int(cur[i]))
            # the prefill-sampled token is output too -- without this the
            # reported tok/s drifts from sum(len(r.output)) by one per
            # request per batch
            self.stats["tokens"] += 1
        for step in range(1, max_new):
            active = np.array([len(r.output) < r.max_new_tokens
                               for r in reqs])
            if not active.any() or plen + step >= self.max_len:
                break
            logits, cache = self._decode(
                self.params, jnp.asarray(cur, jnp.int32)[:, None], cache)
            cur = self._sample(np.asarray(logits), reqs, key)
            for i, r in enumerate(reqs):
                if active[i]:
                    r.output.append(int(cur[i]))
                    self.stats["tokens"] += 1
        now = time.perf_counter()
        for r in reqs:
            r.done = True
            r.finish_t = now
            self._latencies.append(now - r.enqueue_t)
        self.stats["batches"] += 1
        self.stats["latency_p50_s"] = float(
            np.percentile(self._latencies, 50))
        self.stats["latency_p99_s"] = float(
            np.percentile(self._latencies, 99))

    def run_until_idle(self) -> None:
        while (batch := self.scheduler.next_batch()) is not None:
            self.run_batch(batch)
