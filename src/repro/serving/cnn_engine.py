"""Batched CNN split-serving engine (the paper's workload, under load).

``serving.engine.Engine`` batches transformer decode; this engine serves
the paper's actual workload -- split CNN inference between a phone-class
client and one or more server tiers -- from a *stream* of requests
instead of one synchronous call at a time:

* **Bounded queue with backpressure.**  ``submit`` rejects with a named
  ``QueueFullError`` (and counts the shed) once the pending depth hits
  ``max_queue`` (``REPRO_SERVE_QUEUE_DEPTH``) -- queue-based load
  leveling with an explicit shed policy rather than unbounded growth.
* **Bucketed batch packing.**  Compatible requests -- same
  ``(model, resolution, storage dtype, wire formats)`` -- pack into
  batches of up to ``max_batch`` (``REPRO_SERVE_MAX_BATCH``).
  Heterogeneous input resolutions are fine: each resolution is its own
  bucket with its own chain plan (the W-axis tiling handles arbitrary
  geometry on the pallas backend).  A batch only packs requests that
  have *arrived* by its launch time -- no clairvoyant batching.
* **Cross-request pipelining.**  Each request rides its own microbatch
  through ``runtime.ChainRuntime`` against a **shared**
  ``ChainResources`` (per-tier / per-link next-free times on the
  virtual clock), so while batch i's boundary payload is in flight on
  the ``FaultyLink``, batch i+1 is running its client stage -- the
  PR-6 within-request microbatch pipeline generalised across requests.
  ``pipelined=False`` (``REPRO_SERVE_PIPELINED=0``) serialises
  everything: the sequential baseline the serving bench compares
  against.
* **Deadlines.**  ``submit(..., deadline_s=...)`` bounds a request's
  end-to-end virtual latency: requests that cannot start in time are
  expired before wasting compute, and requests that finish late are
  flagged (``status == "expired"``) -- both land in the shared
  ``EventLog`` as ``deadline_expired`` events.
* **Fault tolerance for free.**  Execution goes through
  ``ChainRuntime``, so retries, stage merges, and Pareto-front re-picks
  all work mid-stream; a re-pick triggered by one batch never corrupts
  later queued batches (each request's samples still walk every layer).
* **Breaker-aware dispatch.**  Pass ``tier_faults`` (and optionally
  ``breakers``) and every bucket runtime shares ONE ``FaultyTier`` list
  and ONE ``CircuitBreaker`` per tier: a tier that trips while serving
  bucket A is already open when bucket B dispatches, so B fails over
  proactively instead of burning a doomed attempt.  A standby-tier
  failover in one bucket resets the shared breaker and heals the shared
  fault model -- later batches from *any* bucket ride the spare.

Numerics: in pipelined mode (the default) one request = one microbatch,
so every request's logits are computed at its own batch size and are
**bit-identical** to ``apply_split`` / a direct ``SplitRuntime`` run on
that request alone, whatever else is in flight around it.  The
sequential baseline fuses each batch into one stage call (XLA convs are
not batch-size-invariant, so fused logits can differ in the last ulp --
it is a throughput baseline, not the serving path).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.costs import ModelProfile, resolve_chain_wire
from repro.core.dtype_policy import conv_dtype
from repro.core.hardware import ChainHardware, TwoTierHardware, chain_of, \
    paper_chain
from repro.core.multicut import smartsplit_chain
from repro.models import cnn as cnn_lib
from repro.models.profiles import cnn_profile
from repro.runtime import events as ev
from repro.runtime.breakers import CircuitBreaker, tier_breakers
from repro.runtime.events import EventLog
from repro.runtime.faults import FaultyLink, VirtualClock
from repro.runtime.tier_faults import FaultyTier
from repro.runtime.link_estimator import chain_estimators
from repro.runtime.runtime import (ChainInferenceResult, ChainResources,
                                   ChainRuntime, SplitUnrecoverable)
from repro.runtime.transfer import RetryPolicy

MAX_BATCH_ENV = "REPRO_SERVE_MAX_BATCH"
QUEUE_DEPTH_ENV = "REPRO_SERVE_QUEUE_DEPTH"
PIPELINED_ENV = "REPRO_SERVE_PIPELINED"


class QueueFullError(RuntimeError):
    """Request rejected: the bounded queue is at ``max_queue`` depth.

    Backpressure is explicit -- the caller sheds or retries later; the
    engine never buffers unboundedly.  The rejected ``CnnRequest`` (with
    ``status == "shed"``) is attached as ``request``."""

    def __init__(self, msg: str, request: "CnnRequest"):
        super().__init__(msg)
        self.request = request


class DeadlineExceeded(RuntimeError):
    """Named marker for deadline misses (recorded, never raised by the
    engine itself: a late result is flagged, not destroyed)."""


@dataclasses.dataclass
class CnnRequest:
    """One inference request: a single sample plus its SLO bookkeeping.

    status walks ``queued`` -> ``served`` | ``expired`` | ``failed``;
    ``shed`` requests were never queued.  All times are virtual-clock
    seconds; ``latency_s`` is end-to-end (arrival -> own microbatch
    finish, queueing included)."""

    rid: int
    model: str
    x: Any                          # one sample, e.g. (C, H, W)
    arrival_s: float
    deadline_s: float | None
    bucket: tuple
    status: str = "queued"
    logits: Any = None              # this sample's output row
    start_s: float = 0.0
    finish_s: float = 0.0
    latency_s: float = 0.0
    result: ChainInferenceResult | None = None

    @property
    def done(self) -> bool:
        return self.status in ("served", "expired", "failed")


class _Bucket:
    """Per-(model, resolution, dtype, wire) serving state: the chain
    plan for that geometry and the runtime that executes it (sharing the
    engine's links, resources, estimators, and event log)."""

    def __init__(self, key: tuple, prof: ModelProfile, rt: ChainRuntime):
        self.key = key
        self.prof = prof
        self.rt = rt
        self.pending: list[CnnRequest] = []
        self.served = 0
        self.batches = 0


class CnnServingEngine:
    """Batched, pipelined, fault-tolerant CNN split serving.

    models: ``{name: params}`` (layers looked up in ``cnn.CNN_MODELS``)
      or ``{name: (layers, params)}`` for explicit layer lists.
    hw / tiers: the serving chain -- an explicit ``ChainHardware`` (or
      ``TwoTierHardware``), else ``paper_chain(tiers)`` with ``tiers``
      defaulting to ``REPRO_CHAIN_TIERS`` (2 = the paper's phone/cloud).
    max_batch: batch packing limit per bucket (``REPRO_SERVE_MAX_BATCH``,
      default 4).
    max_queue: bounded queue depth across all buckets
      (``REPRO_SERVE_QUEUE_DEPTH``, default 64); beyond it ``submit``
      sheds with ``QueueFullError``.
    pipelined: cross-request pipelining via a shared ``ChainResources``
      + one microbatch per request (``REPRO_SERVE_PIPELINED``, default
      on).  ``False`` is the sequential synchronous-RPC baseline:
      whole-batch fused stages, no microbatching, and every batch waits
      out the previous one's full makespan.
    dtype / wire / backend / policy: as in ``ChainRuntime`` (engine-wide;
      dtype and wire are part of the bucket key).
    links: per-hop ``FaultyLink``s on one shared clock (default: fault
      free at the chain's nominal bandwidths) -- inject faults here.
    tier_faults: one ``FaultyTier`` per tier (compute-side faults),
      shared by every bucket runtime -- one health model per physical
      tier, not per bucket.
    breakers: one ``CircuitBreaker`` per tier, likewise shared; default
      when ``tier_faults`` is given: ``tier_breakers`` on this engine's
      event log.
    standby: allow standby-tier failover inside the bucket runtimes
      (see ``ChainRuntime``); the swap heals the shared fault model so
      all buckets benefit.
    """

    def __init__(self, models, *,
                 hw: ChainHardware | TwoTierHardware | None = None,
                 tiers: int | None = None,
                 max_batch: int | None = None,
                 max_queue: int | None = None,
                 pipelined: bool | None = None,
                 dtype: str | None = None, wire=None,
                 backend: str | None = None,
                 policy: RetryPolicy = RetryPolicy(),
                 links: list[FaultyLink] | None = None,
                 tier_faults: list[FaultyTier] | None = None,
                 breakers: list[CircuitBreaker] | None = None,
                 standby: bool = True,
                 merge_fallback: bool | None = None,
                 estimator_alpha: float = 0.3,
                 jitter_seed: int = 0,
                 log: EventLog | None = None):
        self._models: dict[str, tuple[list, Any]] = {}
        for name, val in dict(models).items():
            if isinstance(val, tuple) and len(val) == 2 \
                    and isinstance(val[0], list):
                self._models[name] = val
            else:
                self._models[name] = (cnn_lib.CNN_MODELS[name], val)
        if hw is None:
            if tiers is None:
                tiers = int(os.environ.get("REPRO_CHAIN_TIERS", 2))
            hw = paper_chain(tiers)
        elif isinstance(hw, TwoTierHardware):
            hw = chain_of(hw)
        self.hw = hw
        if max_batch is None:
            max_batch = int(os.environ.get(MAX_BATCH_ENV, 4))
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        if max_queue is None:
            max_queue = int(os.environ.get(QUEUE_DEPTH_ENV, 64))
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        if pipelined is None:
            pipelined = os.environ.get(PIPELINED_ENV, "1") != "0"
        self.pipelined = bool(pipelined)
        self.backend = backend
        self.policy = policy
        self._storage = conv_dtype(dtype)
        self._wire = wire
        self._wire_key = resolve_chain_wire(wire, len(hw.links),
                                            self._storage)
        if links is None:
            clock = VirtualClock()
            links = [FaultyLink(link.bandwidth, clock=clock)
                     for link in hw.links]
        else:
            links = list(links)
            clock = links[0]._clock if links else VirtualClock()
        if len(links) != hw.num_tiers - 1:
            raise ValueError(
                f"{hw.num_tiers} tiers need {hw.num_tiers - 1} links, "
                f"got {len(links)}")
        self.links = links
        self.clock = clock
        self.resources = ChainResources(hw.num_tiers, len(links)) \
            if self.pipelined else None
        self.estimators = chain_estimators(
            [link.bandwidth for link in hw.links], alpha=estimator_alpha)
        self.merge_fallback = merge_fallback
        self.estimator_alpha = estimator_alpha
        self.jitter_seed = int(jitter_seed)
        self.log = log if log is not None else EventLog()
        if tier_faults is not None and len(tier_faults) != hw.num_tiers:
            raise ValueError(
                f"{hw.num_tiers} tiers need {hw.num_tiers} tier_faults, "
                f"got {len(tier_faults)}")
        if breakers is not None and len(breakers) != hw.num_tiers:
            raise ValueError(
                f"{hw.num_tiers} tiers need {hw.num_tiers} breakers, "
                f"got {len(breakers)}")
        # One FaultyTier + one breaker per *physical* tier, shared across
        # every bucket runtime (built here so per-bucket ChainRuntimes
        # don't each auto-build their own disconnected set).
        self.tier_faults = list(tier_faults) if tier_faults is not None \
            else None
        if breakers is None and tier_faults is not None:
            breakers = tier_breakers([t.name for t in hw.tiers],
                                     log=self.log)
        self.breakers = list(breakers) if breakers is not None else None
        self.standby = bool(standby)
        self._buckets: dict[tuple, _Bucket] = {}
        self._seq_free = 0.0    # sequential mode: prior batch's makespan
        self._rid = 0
        # engine counters (stats() reads these)
        self.n_submitted = 0
        self.n_served = 0
        self.n_shed = 0
        self.n_expired = 0
        self.n_expired_queued = 0   # expired before dispatch (phase=queued)
        self.n_expired_mid = 0      # finished past deadline (in_flight)
        self.n_failed = 0
        self.n_batches = 0
        self._batch_sizes: list[int] = []
        self._latencies: list[float] = []
        self._t_first_arrival = float("inf")
        self._t_last_finish = 0.0

    # -- admission ------------------------------------------------------
    @property
    def n_pending(self) -> int:
        return sum(len(b.pending) for b in self._buckets.values())

    def submit(self, x, model: str | None = None, *,
               deadline_s: float | None = None,
               at: float | None = None) -> CnnRequest:
        """Enqueue one sample (shape = the model's input shape, no batch
        dim; a leading batch dim of 1 is squeezed).  ``at`` stamps the
        arrival on the virtual clock (default: now); ``deadline_s`` is a
        relative end-to-end SLO.  Raises ``QueueFullError`` when the
        bounded queue is at depth -- the shed is counted either way."""
        if model is None:
            if len(self._models) != 1:
                raise ValueError(
                    f"engine serves {sorted(self._models)}: pass model=")
            model = next(iter(self._models))
        if model not in self._models:
            raise ValueError(f"unknown model {model!r}; registered: "
                             f"{sorted(self._models)}")
        x = jnp.asarray(x)
        if x.ndim == 4 and x.shape[0] == 1:
            x = x[0]
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, "
                             f"got {deadline_s}")
        arrival = self.clock.now if at is None else float(at)
        self._rid += 1
        self.n_submitted += 1
        key = (model, tuple(int(s) for s in x.shape), self._storage,
               self._wire_key)
        req = CnnRequest(rid=self._rid, model=model, x=x,
                         arrival_s=arrival, deadline_s=deadline_s,
                         bucket=key)
        if self.n_pending >= self.max_queue:
            req.status = "shed"
            self.n_shed += 1
            self.log.emit(ev.QUEUE_SHED, arrival, rid=req.rid,
                          depth=self.n_pending, max_queue=self.max_queue)
            raise QueueFullError(
                f"queue depth {self.n_pending} >= max_queue "
                f"{self.max_queue}: request {req.rid} shed", req)
        self._bucket_for(key).pending.append(req)
        return req

    def _bucket_for(self, key: tuple) -> _Bucket:
        bucket = self._buckets.get(key)
        if bucket is not None:
            return bucket
        model, shape, _, _ = key
        layers, params = self._models[model]
        prof = cnn_profile(model, batch=1, in_shape=shape,
                           dtype=self._storage, layers=layers)
        # Pipelined: one microbatch per request, so each request's convs
        # run at batch 1 -- bit-identical to apply_split of that sample
        # alone.  Sequential is the synchronous-RPC baseline: the whole
        # batch is one fused stage call, no pipelining anywhere.
        n_micro = self.max_batch if self.pipelined else 1
        plan = smartsplit_chain(prof, self.hw, microbatches=n_micro,
                                wire=self._wire)
        rt = ChainRuntime(
            layers, params, plan, prof, self.hw, links=self.links,
            policy=self.policy, backend=self.backend, dtype=self._storage,
            wire=self._wire, microbatches=n_micro,
            tier_faults=self.tier_faults, breakers=self.breakers,
            standby=self.standby,
            merge_fallback=self.merge_fallback,
            estimator_alpha=self.estimator_alpha,
            jitter_seed=self.jitter_seed + len(self._buckets),
            resources=self.resources, estimators=self.estimators,
            profile_batch=1, log=self.log)
        bucket = _Bucket(key, prof, rt)
        self._buckets[key] = bucket
        return bucket

    # -- scheduling -----------------------------------------------------
    def _earliest_start(self, arrival: float) -> float:
        free0 = self.resources.tier_free[0] if self.pipelined \
            else self._seq_free
        return max(arrival, free0)

    def _expire(self, req: CnnRequest, t: float, phase: str) -> None:
        req.status = "expired"
        self.n_expired += 1
        if phase == "queued":
            self.n_expired_queued += 1
        else:
            self.n_expired_mid += 1
        self.log.emit(ev.DEADLINE_EXPIRED, t, rid=req.rid, phase=phase,
                      arrival_s=req.arrival_s, deadline_s=req.deadline_s)

    def step(self) -> bool:
        """Dispatch one batch (FIFO across buckets by head arrival).
        Returns False when nothing is pending."""
        live = [b for b in self._buckets.values() if b.pending]
        if not live:
            return False
        bucket = min(live, key=lambda b: b.pending[0].arrival_s)
        batch: list[CnnRequest] = []
        start: float | None = None
        while bucket.pending and len(batch) < self.max_batch:
            req = bucket.pending[0]
            est = self._earliest_start(req.arrival_s) if start is None \
                else start
            if req.deadline_s is not None \
                    and est > req.arrival_s + req.deadline_s:
                # cannot possibly meet its SLO: expire before computing
                bucket.pending.pop(0)
                self._expire(req, est, phase="queued")
                if start is None:
                    return True      # head changed; re-pick the bucket
                continue
            if start is None:
                start = est
            elif req.arrival_s > start:
                break                # not arrived by launch time
            bucket.pending.pop(0)
            batch.append(req)
        if not batch:
            return True              # expired the head(s); queue shrank
        xb = jnp.stack([r.x for r in batch])
        try:
            res = bucket.rt.infer(xb, at=start)
        except SplitUnrecoverable:
            for r in batch:
                r.status = "failed"
                r.start_s = start
            self.n_failed += len(batch)
            self.n_batches += 1
            self._batch_sizes.append(len(batch))
            return True
        finish = start + res.chain_elapsed_s
        if not self.pipelined:
            self._seq_free = max(self._seq_free, finish)
        per_request = len(res.microbatch_finish_s) == len(batch)
        for i, req in enumerate(batch):
            req.logits = res.logits[i]
            req.result = res
            req.start_s = start
            req.finish_s = res.microbatch_finish_s[i] if per_request \
                else finish
            req.latency_s = req.finish_s - req.arrival_s
            if req.deadline_s is not None \
                    and req.latency_s > req.deadline_s:
                self._expire(req, req.finish_s, phase="in_flight")
            else:
                req.status = "served"
                self.n_served += 1
                bucket.served += 1
                self._latencies.append(req.latency_s)
            self._t_first_arrival = min(self._t_first_arrival,
                                        req.arrival_s)
            self._t_last_finish = max(self._t_last_finish, req.finish_s)
        self.n_batches += 1
        bucket.batches += 1
        self._batch_sizes.append(len(batch))
        return True

    def run_until_idle(self) -> None:
        while self.step():
            pass

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        """Engine counters + latency percentiles + per-hop link stats
        (same per-hop schema as ``ChainRuntime.stats()["hops"]``)."""
        runtimes = [b.rt for b in self._buckets.values()]
        span = max(self._t_last_finish - self._t_first_arrival, 0.0) \
            if self.n_served else 0.0
        hops = []
        for k in range(len(self.links)):
            wire_bytes = sum(rt.hop_wire_bytes[k] for rt in runtimes)
            goodput = sum(rt.hop_goodput_bytes[k] for rt in runtimes)
            hops.append({
                "hop": k,
                "wire_dtype": self._wire_key[k],
                "attempts": sum(rt.hop_attempts[k] for rt in runtimes),
                "wire_bytes": wire_bytes,
                "goodput_bytes": goodput,
                "raw_bytes": sum(rt.hop_raw_bytes[k] for rt in runtimes),
                "retransmitted_bytes": wire_bytes - goodput,
                "merges": sum(rt.hop_merges[k] for rt in runtimes),
                "est_bandwidth": self.estimators[k].bandwidth,
                "degradation": self.estimators[k].degradation(),
                "goodput_Bps": goodput / span if span > 0 else 0.0,
                "link": self.links[k].counters(),
            })
        lat = np.asarray(self._latencies) if self._latencies else \
            np.zeros(1)
        return {
            "submitted": self.n_submitted,
            "queued": self.n_pending,
            "served": self.n_served,
            "shed": self.n_shed,
            "queue_shed": self.n_shed,
            "deadline_expired": self.n_expired,
            "deadline_pre_dispatch": self.n_expired_queued,
            "deadline_mid_flight": self.n_expired_mid,
            "failed": self.n_failed,
            "batches": self.n_batches,
            "avg_batch_size": float(np.mean(self._batch_sizes))
            if self._batch_sizes else 0.0,
            "pipelined": self.pipelined,
            "max_batch": self.max_batch,
            "max_queue": self.max_queue,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "virtual_span_s": span,
            "requests_per_s": self.n_served / span if span > 0 else 0.0,
            "recovered": sum(rt.n_recovered for rt in runtimes),
            "merges": sum(rt.n_merges for rt in runtimes),
            "repicks": sum(rt.n_repicks for rt in runtimes),
            "proactive_resplits": sum(rt.n_proactive for rt in runtimes),
            "failovers": sum(rt.n_failovers for rt in runtimes),
            "fallback_device": sum(rt.n_fallback_device
                                   for rt in runtimes),
            "tiers": None if self.tier_faults is None else
                [ft.counters() for ft in self.tier_faults],
            "breakers": None if self.breakers is None else
                [br.counters() for br in self.breakers],
            "buckets": [{
                "model": b.key[0], "in_shape": list(b.key[1]),
                "dtype": b.key[2], "wire": list(b.key[3]),
                "cuts": list(b.rt.plan.cuts),
                "tiers": [t.name for t in b.rt.hw.tiers],
                "pending": len(b.pending), "served": b.served,
                "batches": b.batches,
            } for b in self._buckets.values()],
            "hops": hops,
            "events": self.log.counts(),
        }
