"""Transformer block library covering every assigned architecture family:

* GQA attention (RoPE, optional qk-norm, causal / bidirectional / sliding
  window, KV-cache decode with ring buffer for windowed caches),
* SwiGLU dense MLP,
* top-k MoE with sort-based capacity dispatch (scalable: no (T,E,C) one-hot
  -- FLOPs stay ~= active FLOPs, the property the roofline depends on),
* Mamba2 (SSD) block with chunked parallel scan + single-step decode,
* RWKV6 time-mix / channel-mix with recurrent state + single-step decode.

All functions are pure (params as pytrees); layer stacking/scan lives in
``models/transformer.py``.  Simplifications vs the reference repos are
documented in DESIGN.md section 9: RWKV6 uses static token-shift lerp
(not ddlerp LoRA), Mamba2's short conv covers the x stream only."""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# Dry-run cost-extrapolation knob (see launch/dryrun.py): lax.scan unroll
# factor for the sequential inner scans (mamba2 chunks, rwkv6 tokens).
SCAN_UNROLL = 1

# Tensor-parallel sharding-hint mesh (set by launch/partition.py during
# lowering; None = no hints).  Used where GSPMD propagation picks a
# replicated layout for scan inputs (measured in section-Perf P3).
HINT_AXIS = None
HINT_MESH = None


def _hint(x, spec):
    """with_sharding_constraint against HINT_MESH; no-op when disabled
    or when a named dim does not divide the axis size."""
    if HINT_AXIS is None or HINT_MESH is None:
        return x
    resolved = tuple(HINT_AXIS if a == "model" else a for a in spec)
    for dim, name in zip(x.shape, resolved):
        if name is not None and dim % HINT_MESH.shape[name] != 0:
            return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(
            HINT_MESH, jax.sharding.PartitionSpec(*resolved)))


# ---------------------------------------------------------------------------
# Norms and RoPE
# ---------------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    # fold the scale into the f32 math and downcast ONCE: consumers (and
    # the partitioner's resharding, section-Perf P3) then move bf16, not f32
    return (x32 * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 1e4) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) absolute token positions."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin],
        axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA)
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, M, KV, hd)
    v: jnp.ndarray          # (B, M, KV, hd)
    slot_pos: jnp.ndarray   # (M,) absolute position stored in each slot, -1 empty


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> KVCache:
    kv, hd = cfg.num_kv_heads, cfg.hd
    return KVCache(
        k=jnp.zeros((batch, max_len, kv, hd), dtype),
        v=jnp.zeros((batch, max_len, kv, hd), dtype),
        slot_pos=jnp.full((max_len,), -1, jnp.int32))


def init_attn_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, h * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kv * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h * hd, d), dtype) * (s / cfg.num_layers),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention(cfg: ModelConfig, p, x: jnp.ndarray, *,
              positions: jnp.ndarray,
              cache: KVCache | None = None,
              causal: bool = True) -> tuple[jnp.ndarray, KVCache | None]:
    """x: (B, S, d). positions: (B, S). If cache is given, new K/V are
    written at slot ``pos % M`` (a ring buffer: exact for both full caches
    M >= total length and sliding-window caches M == window)."""
    B, S, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    g = h // kv
    q = (x @ p["wq"]).reshape(B, S, h, hd)
    k = (x @ p["wk"]).reshape(B, S, kv, hd)
    v = (x @ p["wv"]).reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is not None:
        M = cache.k.shape[1]
        slots = positions[0] % M       # (S,) same slot layout for all rows
        ck = cache.k.at[:, slots].set(k)
        cv = cache.v.at[:, slots].set(v)
        spos = cache.slot_pos.at[slots].set(positions[0])
        keys, vals = ck, cv
        key_pos = spos[None, :]                          # (1, M)
        cache = KVCache(ck, cv, spos)
    else:
        keys, vals = k, v
        key_pos = positions                              # (B, S)
    T = keys.shape[1]

    qg = q.reshape(B, S, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, keys,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    qp = positions[:, None, None, :, None].astype(jnp.int32)   # (B,1,1,S,1)
    kp = key_pos[:, None, None, None, :].astype(jnp.int32)     # (.,1,1,1,T)
    valid = kp >= 0
    if causal:
        valid &= kp <= qp
    if cfg.sliding_window:
        valid &= kp > qp - cfg.sliding_window
    scores = jnp.where(valid, scores, -1e30)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    y = jnp.einsum("bkgst,btkh->bskgh", w, vals)
    y = y.reshape(B, S, h * hd) @ p["wo"]
    return y, cache


# ---------------------------------------------------------------------------
# Dense SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp_params(d: int, ff: int, key, dtype=jnp.bfloat16, n_layers=32):
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {"wg": jax.random.normal(ks[0], (d, ff), dtype) * s,
            "wu": jax.random.normal(ks[1], (d, ff), dtype) * s,
            "wd": jax.random.normal(ks[2], (ff, d), dtype)
            * (1.0 / math.sqrt(ff) / n_layers)}


def swiglu(p, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


# ---------------------------------------------------------------------------
# Mixture of Experts: sort-based capacity dispatch
# ---------------------------------------------------------------------------
def init_moe_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.e_ff
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s,
        "wg": jax.random.normal(ks[1], (e, d, ff), dtype) * s,
        "wu": jax.random.normal(ks[2], (e, d, ff), dtype) * s,
        "wd": jax.random.normal(ks[3], (e, ff, d), dtype)
        * (1.0 / math.sqrt(ff) / cfg.num_layers),
    }


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.experts_per_token
                      * cfg.moe_capacity_factor / cfg.num_experts))
    return max(8, -(-c // 8) * 8)   # round up to 8 for lane alignment


def moe(cfg: ModelConfig, p, x: jnp.ndarray):
    """x: (B, S, d) -> (y, aux) with sort-based top-k capacity dispatch.

    When expert parallelism is configured (launch/partition.py sets
    moe_ep.EP_MESH and E divides the model axis), dispatch goes through
    the shard_map all-to-all path instead -- see models/moe_ep.py.

    No (T, E, C) one-hot: tokens are argsorted by expert id and scattered
    into an (E*C) slot table, so compiled FLOPs stay proportional to
    *active* parameters -- the property the roofline report depends on.
    Overflowing tokens beyond capacity are dropped (their combine weight
    never lands in a slot); aux carries the router load-balance loss."""
    from repro.models import moe_ep
    if moe_ep.ep_enabled(cfg, x.shape):
        return moe_ep.moe_expert_parallel(cfg, p, x)
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = moe_capacity(cfg, T)
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    # Flatten the T*K (token, expert) pairs, group by expert via argsort.
    flat_e = eidx.reshape(-1)                                # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank of each entry within its expert group
    counts = jnp.bincount(se, length=E)                      # (E,)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[se]
    keep = rank < C
    # dropped assignments land in a trash slot past the buffer (a slot-0
    # write would clobber a kept token: duplicate-index scatter order is
    # unspecified)
    slot = jnp.where(keep, se * C + rank, E * C)             # (T*K,)

    # slot tables: token index and gate per (E*C) slot (+1 trash)
    slot_tok = jnp.zeros((E * C + 1,), jnp.int32).at[slot].set(
        st.astype(jnp.int32))[:-1]
    slot_gate = jnp.zeros((E * C + 1,), flat_g.dtype).at[slot].set(
        sg)[:-1]

    xe = xt[slot_tok].reshape(E, C, d)                       # gather
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"])) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wu"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"])              # (E, C, d)
    ye = ye.reshape(E * C, d) * slot_gate[:, None].astype(ye.dtype)
    y = jnp.zeros((T, d), ye.dtype).at[slot_tok].add(ye)

    # Switch-style load-balance aux loss.
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.bincount(eidx.reshape(-1), length=E) / (T * K)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------
class MambaState(NamedTuple):
    h: jnp.ndarray       # (B, nh, hp, ds) SSD state
    conv: jnp.ndarray    # (B, k-1, inner) short-conv tail


CONV_K = 4


def init_mamba_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    nh, ds, G = cfg.n_mamba_heads, cfg.ssm_state, cfg.ssm_groups
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    proj_out = 2 * inner + 2 * G * ds + nh
    return {
        "in_proj": jax.random.normal(ks[0], (d, proj_out), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (CONV_K, inner), dtype) * 0.5,
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": jax.random.normal(ks[2], (inner, d), dtype)
        * (1.0 / math.sqrt(inner) / cfg.num_layers),
        "gate_norm": jnp.ones((inner,), dtype),
    }


def _mamba_split(cfg: ModelConfig, z_all: jnp.ndarray):
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    nh, ds, G = cfg.n_mamba_heads, cfg.ssm_state, cfg.ssm_groups
    z, xs, B, C, dt = jnp.split(
        z_all, [inner, 2 * inner, 2 * inner + G * ds,
                2 * inner + 2 * G * ds], axis=-1)
    return z, xs, B, C, dt


def _causal_conv(xs: jnp.ndarray, w: jnp.ndarray,
                 tail: jnp.ndarray | None = None):
    """Depthwise causal conv, k = CONV_K. xs: (B, S, inner); tail: the
    previous k-1 inputs for streaming decode."""
    B, S, inner = xs.shape
    if tail is None:
        tail = jnp.zeros((B, CONV_K - 1, inner), xs.dtype)
    full = jnp.concatenate([tail, xs], axis=1)           # (B, S+k-1, inner)
    out = sum(full[:, i:i + S, :] * w[i] for i in range(CONV_K))
    new_tail = full[:, -(CONV_K - 1):, :]
    return jax.nn.silu(out), new_tail


def mamba2(cfg: ModelConfig, p, x: jnp.ndarray,
           state: MambaState | None = None, chunk: int = 64):
    """Full-sequence (chunked SSD) form. x: (B, S, d) -> (y, new_state)."""
    B, S, d = x.shape
    inner = cfg.ssm_expand * d
    nh, ds, G = cfg.n_mamba_heads, cfg.ssm_state, cfg.ssm_groups
    hp = inner // nh
    z, xs, Bm, Cm, dt = _mamba_split(cfg, x @ p["in_proj"])
    xs, new_tail = _causal_conv(
        xs, p["conv_w"], None if state is None else state.conv)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                     # (nh,)
    # heads
    xh = xs.reshape(B, S, nh, hp).astype(jnp.float32)
    rep = nh // G
    Bh = jnp.repeat(Bm.reshape(B, S, G, ds), rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B, S, G, ds), rep, axis=2).astype(jnp.float32)
    la = dt * A[None, None, :]                                   # log decay

    # pad to chunk multiple
    nC = -(-S // chunk)
    pad = nC * chunk - S
    def padc(t):
        return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
    xh, Bh, Ch = padc(xh), padc(Bh), padc(Ch)
    la_p, dt_p = padc(la), padc(dt)
    xh = xh.reshape(B, nC, chunk, nh, hp)
    Bh = Bh.reshape(B, nC, chunk, nh, ds)
    Ch = Ch.reshape(B, nC, chunk, nh, ds)
    la_c = la_p.reshape(B, nC, chunk, nh)
    dt_c = dt_p.reshape(B, nC, chunk, nh)

    cs = jnp.cumsum(la_c, axis=2)                        # within-chunk cumsum
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]    # (B,nC,t,u,nh)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: y[t] = sum_u (C_t.B_u) decay[t,u] dt_u x_u
    cb = jnp.einsum("bcthn,bcuhn->bctuh", Ch, Bh)
    att = cb * decay
    y_intra = jnp.einsum("bctuh,bcuh,bcuhp->bcthp", att, dt_c, xh)

    # inter-chunk: scan carried state
    chunk_decay = jnp.exp(cs[:, :, -1, :])               # (B,nC,nh)
    # state contribution of each chunk: sum_u exp(cs_last - cs_u) dt_u B_u x_u^T
    w_u = jnp.exp(cs[:, :, -1:, :] - cs) * dt_c          # (B,nC,chunk,nh)
    chunk_state = jnp.einsum("bcuh,bcuhn,bcuhp->bchpn", w_u, Bh, xh)

    h0 = jnp.zeros((B, nh, hp, ds), jnp.float32) if state is None \
        else state.h.astype(jnp.float32)

    def step(h, ins):
        cdec, cstate, C_c, cs_c = ins
        # y_inter[t] = C_t . (h * exp(cs_t))
        y_int = jnp.einsum("bthn,bhpn,bth->bthp", C_c, h, jnp.exp(cs_c))
        h_new = h * cdec[:, :, None, None] + cstate
        return h_new, y_int

    xs_scan = (chunk_decay.transpose(1, 0, 2),
               chunk_state.transpose(1, 0, 2, 3, 4),
               Ch.transpose(1, 0, 2, 3, 4),
               cs.transpose(1, 0, 2, 3))
    h_fin, y_inter = jax.lax.scan(step, h0, xs_scan, unroll=SCAN_UNROLL)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)           # (B,nC,chunk,nh,hp)

    y = (y_intra + y_inter).reshape(B, nC * chunk, nh, hp)[:, :S]
    y = y + xh.reshape(B, nC * chunk, nh, hp)[:, :S] * p["D"][None, None, :, None]
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], MambaState(h=h_fin.astype(jnp.float32),
                                         conv=new_tail)


def mamba2_step(cfg: ModelConfig, p, x: jnp.ndarray, state: MambaState):
    """Single-token decode. x: (B, 1, d)."""
    B, S, d = x.shape
    assert S == 1
    inner = cfg.ssm_expand * d
    nh, ds, G = cfg.n_mamba_heads, cfg.ssm_state, cfg.ssm_groups
    hp = inner // nh
    z, xs, Bm, Cm, dt = _mamba_split(cfg, x @ p["in_proj"])
    xs, new_tail = _causal_conv(xs, p["conv_w"], state.conv)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None, :])                                 # (B,nh)
    xh = xs.reshape(B, nh, hp).astype(jnp.float32)
    rep = nh // G
    Bh = jnp.repeat(Bm.reshape(B, G, ds), rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Cm.reshape(B, G, ds), rep, axis=1).astype(jnp.float32)
    h = state.h * a[:, :, None, None] \
        + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhn,bhpn->bhp", Ch, h) + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], MambaState(h=h, conv=new_tail)


# ---------------------------------------------------------------------------
# RWKV6 block (time-mix + channel-mix)
# ---------------------------------------------------------------------------
class RWKVState(NamedTuple):
    wkv: jnp.ndarray      # (B, nh, hd, hd)
    x_tm: jnp.ndarray     # (B, d) last input seen by time-mix
    x_cm: jnp.ndarray     # (B, d) last input seen by channel-mix


RWKV_HD = 64


def init_rwkv_params(cfg: ModelConfig, key, dtype=jnp.bfloat16):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),     # r,k,v,w,g token-shift mix
        "wr": jax.random.normal(ks[0], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, d), dtype) * s,
        "ww": jax.random.normal(ks[3], (d, d), dtype) * 0.1 * s,
        "w_bias": jnp.full((d,), -6.0, jnp.float32),
        "wg": jax.random.normal(ks[4], (d, d), dtype) * s,
        "u": jnp.zeros((d,), jnp.float32),       # bonus for current token
        "wo": jax.random.normal(ks[5], (d, d), dtype)
        * (s / cfg.num_layers),
        "ln_x": jnp.ones((d,), dtype),
        "mu_cm": 0.5 * jnp.ones((2, d), dtype),
        "ck": jax.random.normal(ks[6], (d, ff), dtype) * s,
        "cv": jax.random.normal(ks[7], (ff, d), dtype)
        * (1.0 / math.sqrt(ff) / cfg.num_layers),
        "cr": jax.random.normal(jax.random.fold_in(key, 9), (d, d), dtype) * s,
    }


def _token_shift(x: jnp.ndarray, last: jnp.ndarray):
    """x: (B,S,d); last: (B,d) -> x_{t-1} sequence and new last."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev, x[:, -1, :]


def rwkv6(cfg: ModelConfig, p, x: jnp.ndarray,
          state: RWKVState | None = None):
    """Full-sequence RWKV6. x: (B,S,d) -> (y, new_state).  Data-dependent
    per-channel decay w_t = exp(-exp(ww x + b)); static token-shift lerp."""
    B, S, d = x.shape
    nh, hd = d // RWKV_HD, RWKV_HD
    if state is None:
        state = RWKVState(wkv=jnp.zeros((B, nh, hd, hd), jnp.float32),
                          x_tm=jnp.zeros((B, d), x.dtype),
                          x_cm=jnp.zeros((B, d), x.dtype))
    prev, new_last = _token_shift(x, state.x_tm)
    mix = lambda i: x * p["mu"][i] + prev * (1 - p["mu"][i])
    r = (mix(0) @ p["wr"]).reshape(B, S, nh, hd)
    k = (mix(1) @ p["wk"]).reshape(B, S, nh, hd)
    v = (mix(2) @ p["wv"]).reshape(B, S, nh, hd)
    wlog = -jnp.exp((mix(3) @ p["ww"]).astype(jnp.float32)
                    + p["w_bias"])                       # (B,S,d) log decay
    w = jnp.exp(wlog).reshape(B, S, nh, hd)              # decay in (0,1)
    g = jax.nn.silu(mix(4) @ p["wg"])
    u = p["u"].reshape(nh, hd)

    def step(s_wkv, ins):
        rt, kt, vt, wt = ins                             # (B,nh,hd) each
        rt = rt.astype(jnp.float32)                      # stream stays bf16;
        kt = kt.astype(jnp.float32)                      # state math in f32
        vt = vt.astype(jnp.float32)
        wt = wt.astype(jnp.float32)
        kv = kt[:, :, :, None] * vt[:, :, None, :]       # (B,nh,hd,hd)
        out = jnp.einsum("bhk,bhkv->bhv", rt,
                         s_wkv + u[None, :, :, None] * kv)
        s_new = s_wkv * wt[:, :, :, None] + kv
        return s_new, out

    # r/k/v stream in model dtype (halves the HBM/collective traffic of
    # the scan inputs -- section-Perf P3); decay w streams f32 so decays
    # near 1.0 keep their precision over long contexts.
    xs = (r.transpose(1, 0, 2, 3),
          k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3),
          w.transpose(1, 0, 2, 3).astype(jnp.float32))
    s_fin, outs = jax.lax.scan(step, state.wkv, xs, unroll=SCAN_UNROLL)
    y = outs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    y = rmsnorm(y, p["ln_x"], cfg.norm_eps) * g
    y = y @ p["wo"]

    # channel-mix
    prev_c, new_last_c = _token_shift(x + y, state.x_cm)
    xc = x + y
    mixc = lambda i: xc * p["mu_cm"][i] + prev_c * (1 - p["mu_cm"][i])
    kk = jnp.square(jax.nn.relu(mixc(0) @ p["ck"]))
    out_c = (kk @ p["cv"]) * jax.nn.sigmoid(mixc(1) @ p["cr"])
    return y + out_c, RWKVState(wkv=s_fin, x_tm=new_last, x_cm=new_last_c)
