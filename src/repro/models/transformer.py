"""Composable transformer LM covering all 10 assigned architectures.

One parameter pytree, one ``forward`` for train/prefill, one ``decode_step``
for serving.  Homogeneous layer stacks are *scanned* (stacked weights,
``jax.lax.scan``) so HLO size and compile time are depth-independent --
required for the 80-layer dry-runs.  Zamba2's pattern (shared attention
block every k Mamba2 layers, weights shared across applications) is an
outer scan over segments with the shared block's weights as a closure
constant.

Batch conventions:
  batch = {"tokens": (B,S) int32, "labels": (B,S) int32 (train),
           "loss_mask": (B,S) f32 (train),
           "prefix_embeds": (B,P,d) (vlm/audio stub frontends)}
For frontend archs the embeddings REPLACE token embedding for the first P
positions (vision patches / audio frames) -- the stub carve-out."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(cfg: ModelConfig, kind: str, key, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("attn_mlp", "enc_attn"):
        return {"ln1": jnp.ones((d,), dtype),
                "attn": L.init_attn_params(cfg, ks[0], dtype),
                "ln2": jnp.ones((d,), dtype),
                "mlp": L.init_mlp_params(d, cfg.d_ff, ks[1], dtype,
                                         cfg.num_layers)}
    if kind == "attn_moe":
        return {"ln1": jnp.ones((d,), dtype),
                "attn": L.init_attn_params(cfg, ks[0], dtype),
                "ln2": jnp.ones((d,), dtype),
                "moe": L.init_moe_params(cfg, ks[1], dtype)}
    if kind == "mamba":
        return {"ln1": jnp.ones((d,), dtype),
                "mamba": L.init_mamba_params(cfg, ks[0], dtype)}
    if kind == "rwkv":
        return {"ln1": jnp.ones((d,), dtype),
                "rwkv": L.init_rwkv_params(cfg, ks[0], dtype)}
    raise ValueError(kind)


def _zamba_segments(cfg: ModelConfig) -> tuple[int, int]:
    """(n_seg, n_slots): layers padded to full segments of ``attn_every``.

    Uniform segments keep the whole stack one doubly-nested scan (no tail
    special case): padded slots run masked (their output is discarded via
    jnp.where) -- the same SPMD-uniformity idiom the SmartSplit two-stage
    executor uses for arbitrary split indices."""
    n_seg = -(-cfg.num_layers // cfg.attn_every)
    return n_seg, n_seg * cfg.attn_every


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    keys = jax.random.split(key, 8)
    d, V = cfg.d_model, cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (V, d), dtype) * 0.02,
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(keys[1], (d, V), dtype) \
            * (1.0 / d ** 0.5)

    if cfg.pattern == "mamba" and cfg.attn_every:
        n_seg, n_slots = _zamba_segments(cfg)
        bkeys = jax.random.split(keys[2], n_slots)
        params["blocks"] = jax.vmap(
            lambda k: _init_block(cfg, "mamba", k, dtype))(bkeys)
        params["shared"] = _init_block(cfg, "attn_mlp", keys[4], dtype)
    else:
        bkeys = jax.random.split(keys[2], cfg.num_layers)
        kind = cfg.pattern
        params["blocks"] = jax.vmap(
            lambda k: _init_block(cfg, kind, k, dtype))(bkeys)
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
class Cache(NamedTuple):
    pos: jnp.ndarray                 # () int32: number of tokens consumed
    kv: Any = None                   # stacked L.KVCache, leading axis = layer
    ssm: Any = None                  # stacked L.MambaState
    rwkv: Any = None                 # stacked L.RWKVState
    shared_kv: Any = None            # zamba: (n_seg,) stacked KVCache


def cache_max_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Cache:
    M = cache_max_len(cfg, max_len)

    def stack(n, fn):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape),
                            fn())

    pos = jnp.zeros((), jnp.int32)
    if cfg.pattern in ("attn_mlp", "attn_moe"):
        kv = stack(cfg.num_layers,
                   lambda: L.init_kv_cache(cfg, batch, M, dtype))
        return Cache(pos=pos, kv=kv)
    if cfg.pattern == "rwkv":
        d = cfg.d_model
        nh = d // L.RWKV_HD
        st = stack(cfg.num_layers, lambda: L.RWKVState(
            wkv=jnp.zeros((batch, nh, L.RWKV_HD, L.RWKV_HD), jnp.float32),
            x_tm=jnp.zeros((batch, d), dtype),
            x_cm=jnp.zeros((batch, d), dtype)))
        return Cache(pos=pos, rwkv=st)
    if cfg.pattern == "mamba":
        inner = cfg.ssm_expand * cfg.d_model
        nh, hp = cfg.n_mamba_heads, inner // cfg.n_mamba_heads

        def one():
            return L.MambaState(
                h=jnp.zeros((batch, nh, hp, cfg.ssm_state), jnp.float32),
                conv=jnp.zeros((batch, L.CONV_K - 1, inner), dtype))
        shared_kv = None
        n_states = cfg.num_layers
        if cfg.attn_every:
            n_seg, n_slots = _zamba_segments(cfg)
            n_states = n_slots          # padded slots carry (unused) state
            shared_kv = stack(n_seg,
                              lambda: L.init_kv_cache(cfg, batch, M, dtype))
        ssm = stack(n_states, one)
        return Cache(pos=pos, ssm=ssm, shared_kv=shared_kv)
    raise ValueError(cfg.pattern)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------
def _apply_block(cfg: ModelConfig, kind: str, p, x, *, positions,
                 kv_cache=None, ssm_state=None, rwkv_state=None,
                 decode: bool = False):
    """Returns (x, (new_kv, new_ssm, new_rwkv), aux_loss).

    The cache slots may carry dummy zero arrays (scan xs cannot hold None);
    a slot participates only when it is the right state NamedTuple."""
    aux = jnp.zeros((), jnp.float32)
    kv_real = kv_cache if isinstance(kv_cache, L.KVCache) else None
    ssm_real = ssm_state if isinstance(ssm_state, L.MambaState) else None
    rwkv_real = rwkv_state if isinstance(rwkv_state, L.RWKVState) else None
    if kind in ("attn_mlp", "attn_moe", "enc_attn"):
        h, kv_new = L.attention(cfg, p["attn"],
                                L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                                positions=positions, cache=kv_real,
                                causal=not cfg.is_encoder)
        kv_out = kv_new if kv_new is not None else kv_cache
        x = x + h
        z = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if kind == "attn_moe":
            h, aux = L.moe(cfg, p["moe"], z)
        else:
            h = L.swiglu(p["mlp"], z)
        return x + h, (kv_out, ssm_state, rwkv_state), aux
    if kind == "mamba":
        z = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if decode:
            h, ssm_out = L.mamba2_step(cfg, p["mamba"], z, ssm_real)
        else:
            h, ssm_out = L.mamba2(cfg, p["mamba"], z, ssm_real)
        return x + h, (kv_cache, ssm_out, rwkv_state), aux
    if kind == "rwkv":
        z = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        h, rwkv_out = L.rwkv6(cfg, p["rwkv"], z, rwkv_real)
        return x + h, (kv_cache, ssm_state, rwkv_out), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Forward (train / prefill) and decode
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    """Token embeddings, optionally prefixed by stub-frontend embeddings
    (vision patches / audio frames).  Encoder-only audio archs may have no
    tokens at all (pure frame input)."""
    tok = batch.get("tokens")
    x = params["embed"][tok] if tok is not None and tok.shape[-1] > 0 \
        else None
    if cfg.frontend != "none" and "prefix_embeds" in batch:
        pe = batch["prefix_embeds"]
        pe = pe.astype(x.dtype if x is not None
                       else params["embed"].dtype)
        x = pe if x is None else jnp.concatenate([pe, x], axis=1)
    assert x is not None, "batch must contain tokens or prefix_embeds"
    return x


def forward(cfg: ModelConfig, params, batch, *, mode: str = "train",
            cache: Cache | None = None, unroll_layers: bool = False):
    """mode 'train'/'prefill'. Returns (logits, new_cache, aux_loss).

    cache is only consumed/produced in prefill mode (SSM initial states /
    KV-cache fill for subsequent decode).  unroll_layers replaces the layer
    scans with python loops -- used only by the dry-run cost extrapolation."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    pos0 = jnp.zeros((), jnp.int32) if cache is None else cache.pos
    positions = pos0 + jnp.arange(S, dtype=jnp.int32)[None, :] \
        + jnp.zeros((B, 1), jnp.int32)

    want_cache = cache is not None
    use_remat = (mode == "train" and cfg.remat == "block")
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.pattern == "mamba" and cfg.attn_every:
        x, new_cache, aux_total = _zamba_forward(
            cfg, params, x, positions, cache, use_remat, unroll_layers)
    else:
        kind = cfg.pattern

        def body(carry, inp):
            h, auxc = carry
            p_i, kv_i, ssm_i, rwkv_i = inp
            h, (kv_o, ssm_o, rwkv_o), aux = _apply_block(
                cfg, kind, p_i, h, positions=positions,
                kv_cache=kv_i, ssm_state=ssm_i, rwkv_state=rwkv_i)
            return (h, auxc + aux), (kv_o, ssm_o, rwkv_o)

        if use_remat:
            body = jax.checkpoint(body)
        n = cfg.num_layers
        kv_in = cache.kv if want_cache else None
        ssm_in = cache.ssm if want_cache else None
        rwkv_in = cache.rwkv if want_cache else None
        fill = lambda t: t if t is not None else jnp.zeros((n,), jnp.float32)
        (x, aux_total), outs = _scan(
            body, (x, aux_total),
            (params["blocks"], fill(kv_in), fill(ssm_in), fill(rwkv_in)),
            unroll_layers)
        kv_o, ssm_o, rwkv_o = outs
        new_cache = None
        if want_cache:
            new_cache = Cache(pos=pos0 + S,
                              kv=kv_o if kv_in is not None else None,
                              ssm=ssm_o if ssm_in is not None else None,
                              rwkv=rwkv_o if rwkv_in is not None else None)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ unembed).astype(jnp.float32)
    return logits, new_cache, aux_total


def _zamba_masks(cfg):
    """(layer_active (n_seg, k), attn_active (n_seg,)) as static arrays."""
    import numpy as np
    n_seg, n_slots = _zamba_segments(cfg)
    k = cfg.attn_every
    slot = np.arange(n_slots).reshape(n_seg, k)
    layer_active = slot < cfg.num_layers
    attn_active = (np.arange(n_seg) + 1) * k <= cfg.num_layers
    return jnp.asarray(layer_active), jnp.asarray(attn_active)


def _zamba_forward(cfg, params, x, positions, cache, use_remat,
                   unroll_layers: bool = False):
    """Zamba2: doubly-nested scan over uniform padded segments of
    (attn_every mamba slots + shared attention block); shared weights are
    closure constants, padded slots masked with jnp.where."""
    n_seg, n_slots = _zamba_segments(cfg)
    k = cfg.attn_every
    want_cache = cache is not None
    shared = params["shared"]
    layer_active, attn_active = _zamba_masks(cfg)

    def seg_body(carry, inp):
        h, aux = carry
        p_seg, ssm_seg, skv, act_seg, attn_act = inp

        def inner(c, i):
            hh, auxc = c
            p_i, ssm_i, m = i
            out, (_, ssm_o, _), a = _apply_block(
                cfg, "mamba", p_i, hh, positions=positions, ssm_state=ssm_i)
            hh = jnp.where(m, out, hh)
            ssm_o = jax.tree.map(
                lambda new, old: jnp.where(m, new, old) if
                isinstance(old, jnp.ndarray) and old.shape == new.shape
                else new, ssm_o, ssm_i) if isinstance(ssm_i, L.MambaState) \
                else ssm_o
            return (hh, auxc + a), ssm_o

        (h, aux), ssm_out = _scan(inner, (h, aux),
                                  (p_seg, ssm_seg, act_seg), unroll_layers)
        out, (skv_o, _, _), a2 = _apply_block(
            cfg, "attn_mlp", shared, h, positions=positions, kv_cache=skv)
        h = jnp.where(attn_act, out, h)
        return (h, aux + a2), (ssm_out, skv_o)

    if use_remat:
        seg_body = jax.checkpoint(seg_body)

    # reshape stacked blocks (n_slots, ...) -> (n_seg, k, ...)
    blocks = jax.tree.map(
        lambda t: t.reshape((n_seg, k) + t.shape[1:]), params["blocks"])
    if want_cache:
        ssm_in = jax.tree.map(
            lambda t: t.reshape((n_seg, k) + t.shape[1:]), cache.ssm)
        skv_in = cache.shared_kv
    else:
        ssm_in = jnp.zeros((n_seg, k), jnp.float32)
        skv_in = jnp.zeros((n_seg,), jnp.float32)
    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), (ssm_out, skv_out) = _scan(
        seg_body, (x, aux0),
        (blocks, ssm_in, skv_in, layer_active, attn_active), unroll_layers)

    new_cache = None
    if want_cache:
        flat = jax.tree.map(
            lambda t: t.reshape((n_slots,) + t.shape[2:]), ssm_out)
        new_cache = Cache(pos=cache.pos + positions.shape[1], ssm=flat,
                          shared_kv=skv_out)
    return x, new_cache, aux


def _scan(body, carry, xs, unroll_layers: bool):
    """jax.lax.scan, or an equivalent python loop when the dry-run needs
    loop-free HLO for exact cost extrapolation (see launch/dryrun.py)."""
    if not unroll_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda t: t[i], xs))
        ys.append(y)
    stacked = jax.tree.map(lambda *ts: jnp.stack(ts), *ys)
    return carry, stacked


def decode_step(cfg: ModelConfig, params, tokens: jnp.ndarray,
                cache: Cache, unroll_layers: bool = False):
    """One-token serve step. tokens: (B, 1). Returns (logits, new_cache)."""
    assert not cfg.is_encoder, "encoder-only archs have no decode step"
    x = params["embed"][tokens]
    B, S, _ = x.shape
    positions = cache.pos + jnp.zeros((B, 1), jnp.int32)

    if cfg.pattern == "mamba" and cfg.attn_every:
        x, new_cache = _zamba_decode(cfg, params, x, positions, cache,
                                     unroll_layers)
    else:
        kind = cfg.pattern

        def body(h, inp):
            p_i, kv_i, ssm_i, rwkv_i = inp
            h, (kv_o, ssm_o, rwkv_o), _ = _apply_block(
                cfg, kind, p_i, h, positions=positions, kv_cache=kv_i,
                ssm_state=ssm_i, rwkv_state=rwkv_i, decode=True)
            return h, (kv_o, ssm_o, rwkv_o)

        n = cfg.num_layers
        fill = lambda t: t if t is not None else jnp.zeros((n,), jnp.float32)
        x, (kv_o, ssm_o, rwkv_o) = _scan(
            body, x, (params["blocks"], fill(cache.kv), fill(cache.ssm),
                      fill(cache.rwkv)), unroll_layers)
        new_cache = Cache(pos=cache.pos + 1,
                          kv=kv_o if cache.kv is not None else None,
                          ssm=ssm_o if cache.ssm is not None else None,
                          rwkv=rwkv_o if cache.rwkv is not None else None)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ unembed).astype(jnp.float32)
    return logits, new_cache


def _zamba_decode(cfg, params, x, positions, cache: Cache,
                  unroll_layers: bool = False):
    n_seg, n_slots = _zamba_segments(cfg)
    k = cfg.attn_every
    shared = params["shared"]
    layer_active, attn_active = _zamba_masks(cfg)

    def seg_body(h, inp):
        p_seg, ssm_seg, skv, act_seg, attn_act = inp

        def inner(hh, i):
            p_i, ssm_i, m = i
            out, (_, ssm_o, _), _ = _apply_block(
                cfg, "mamba", p_i, hh, positions=positions,
                ssm_state=ssm_i, decode=True)
            hh = jnp.where(m, out, hh)
            ssm_o = jax.tree.map(lambda new, old: jnp.where(m, new, old),
                                 ssm_o, ssm_i)
            return hh, ssm_o

        h, ssm_out = _scan(inner, h, (p_seg, ssm_seg, act_seg),
                           unroll_layers)
        out, (skv_o, _, _), _ = _apply_block(
            cfg, "attn_mlp", shared, h, positions=positions, kv_cache=skv)
        h = jnp.where(attn_act, out, h)
        return h, (ssm_out, skv_o)

    blocks = jax.tree.map(
        lambda t: t.reshape((n_seg, k) + t.shape[1:]), params["blocks"])
    ssm_in = jax.tree.map(
        lambda t: t.reshape((n_seg, k) + t.shape[1:]), cache.ssm)
    x, (ssm_out, skv_out) = _scan(
        seg_body, x,
        (blocks, ssm_in, cache.shared_kv, layer_active, attn_active),
        unroll_layers)

    flat = jax.tree.map(lambda t: t.reshape((n_slots,) + t.shape[2:]),
                        ssm_out)
    return x, Cache(pos=cache.pos + 1, ssm=flat, shared_kv=skv_out)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def loss_fn(cfg: ModelConfig, params, batch, aux_weight: float = 0.01,
            unroll_layers: bool = False):
    """Next-token CE (decoder) or per-frame classification CE (encoder).
    Returns (loss, metrics)."""
    logits, _, aux = forward(cfg, params, batch, mode="train",
                             unroll_layers=unroll_layers)
    labels = batch["labels"]
    if cfg.frontend != "none" and logits.shape[1] != labels.shape[1]:
        # frontend prefix positions carry no labels
        logits = logits[:, -labels.shape[1]:]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    ce = -(ll * mask).sum() / jnp.clip(mask.sum(), 1.0)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}
