"""Expert-parallel MoE via shard_map + all-to-all (§Perf P1).

The baseline `layers.moe` is written for GSPMD propagation: a global
gather ``xt[slot_tok]`` from data-sharded activations into expert-sharded
slots.  The compiler's only legal plan for that is an all-gather of the
full activation tensor per MoE layer (~T*d bytes broadcast to every model
shard) -- measured at 728 s of collective time for kimi-k2 train_4k.

This module is the explicit-communication version: tokens travel to their
experts (and back) with ``jax.lax.all_to_all`` over the ``model`` axis, so
per-device traffic is O(T_loc * topk * d) -- the information-theoretic
minimum for token routing -- instead of O(T * d).

Enabled per-config by ``launch/partition.py`` (module global EP_MESH) when
num_experts divides the model-axis size; the sort-based capacity dispatch
is reused *locally* on each shard.  Differentiable end-to-end (all_to_all,
sort, gather, scatter all have transposes)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig

# Set by launch/partition.py (and tests) before tracing; None = disabled.
EP_MESH = None
EP_AXIS = "model"


def ep_enabled(cfg: ModelConfig, x_shape: tuple | None = None) -> bool:
    if (EP_MESH is None or EP_AXIS not in EP_MESH.axis_names
            or cfg.num_experts % EP_MESH.shape[EP_AXIS] != 0
            or cfg.num_experts < EP_MESH.shape[EP_AXIS]):
        return False
    if x_shape is not None:
        B, S = x_shape[0], x_shape[1]
        dsize = 1
        for a in EP_MESH.axis_names:
            if a in ("pod", "data"):
                dsize *= EP_MESH.shape[a]
        if B % dsize != 0:
            return False
        t_loc = (B // dsize) * S
        if t_loc % EP_MESH.shape[EP_AXIS] != 0:
            return False                  # decode with tiny local batches
    return True


def _send_capacity(cfg: ModelConfig, t_loc: int, n_shards: int) -> int:
    c = math.ceil(t_loc * cfg.experts_per_token
                  * cfg.moe_capacity_factor / n_shards)
    return max(8, -(-c // 8) * 8)


def _expert_capacity(cfg: ModelConfig, n_recv: int, e_loc: int) -> int:
    c = math.ceil(n_recv * cfg.moe_capacity_factor / e_loc)
    return max(8, -(-c // 8) * 8)


def moe_expert_parallel(cfg: ModelConfig, p, x: jnp.ndarray):
    """x: (B, S, d) -> (y, aux). Must be called under jit with EP_MESH set."""
    mesh = EP_MESH
    n_shards = mesh.shape[EP_AXIS]
    daxes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    B = x.shape[0]
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    bspec = (daxes if len(daxes) > 1 else daxes[0]) \
        if daxes and B % dsize == 0 else None

    def body(xl, router, wg, wu, wd):
        # xl: (B_loc, S, d) -- replicated over the model axis; wg/wu/wd:
        # (E_loc, d, f) local experts.  Each model shard routes a DISJOINT
        # 1/n_shards slice of the local tokens (otherwise all shards send
        # identical copies and expert compute inflates n_shards-fold); a
        # final psum over the model axis reassembles the full output.
        Bl, S, d = xl.shape
        T_all = Bl * S
        E, K = cfg.num_experts, cfg.experts_per_token
        E_loc = E // n_shards
        assert T_all % n_shards == 0     # guarded by ep_enabled()
        T = T_all // n_shards
        midx = jax.lax.axis_index(EP_AXIS)
        xt_all = xl.reshape(T_all, d)
        xt = jax.lax.dynamic_slice_in_dim(xt_all, midx * T, T)

        logits = xt.astype(jnp.float32) @ router            # (T, E) global E
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eidx = jax.lax.top_k(probs, K)
        gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

        # ---- first hop: group the T*K assignments by destination shard
        flat_e = eidx.reshape(-1)                            # (T*K,)
        flat_t = jnp.repeat(jnp.arange(T), K)
        flat_g = gate.reshape(-1)
        dest = flat_e // E_loc                               # owning shard
        order = jnp.argsort(dest, stable=True)
        s_dest, s_e = dest[order], flat_e[order]
        s_t, s_g = flat_t[order], flat_g[order]
        Cs = _send_capacity(cfg, T, n_shards)
        counts = jnp.bincount(s_dest, length=n_shards)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(T * K) - starts[s_dest]
        keep = rank < Cs
        # dropped assignments scatter into a trash slot past the buffer
        # (never into slot 0 of a real bucket -- that would clobber)
        slot = jnp.where(keep, s_dest * Cs + rank, n_shards * Cs)

        def fill(src, init):
            buf = jnp.zeros((n_shards * Cs + 1,) + src.shape[1:],
                            src.dtype) + init
            return buf.at[slot].set(src)[:-1]

        send_x = fill(xt[s_t], 0).reshape(n_shards, Cs, d)
        send_e = fill((s_e % E_loc).astype(jnp.int32), E_loc)  # E_loc = pad
        send_g = fill(s_g, 0.0)
        send_e = send_e.reshape(n_shards, Cs)
        send_g = send_g.reshape(n_shards, Cs)

        # all-to-all: shard i's block j goes to shard j
        recv_x = jax.lax.all_to_all(send_x, EP_AXIS, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, EP_AXIS, 0, 0, tiled=False)
        # recv_*: (n_shards, Cs, ...) -- tokens from every source shard
        R = n_shards * Cs
        rx = recv_x.reshape(R, d)
        re = recv_e.reshape(R)                               # local expert id
        valid = re < E_loc

        # ---- local dispatch to E_loc experts (sort-based; R already
        # carries the capacity-factor headroom from the send hop)
        Cl = max(8, -(-R // E_loc // 8) * 8)
        order2 = jnp.argsort(jnp.where(valid, re, E_loc), stable=True)
        r_e, r_i = re[order2], order2
        counts2 = jnp.bincount(jnp.where(valid, re, E_loc)[order2],
                               length=E_loc + 1)[:E_loc]
        starts2 = jnp.cumsum(counts2) - counts2
        rank2 = jnp.arange(R) - starts2[jnp.clip(r_e, 0, E_loc - 1)]
        keep2 = (r_e < E_loc) & (rank2 < Cl)
        slot2 = jnp.where(keep2, jnp.clip(r_e, 0, E_loc - 1) * Cl + rank2,
                          E_loc * Cl)            # trash slot for drops/pads
        slot_src = jnp.zeros((E_loc * Cl + 1,), jnp.int32).at[slot2].set(
            r_i.astype(jnp.int32))[:-1]
        slot_ok = jnp.zeros((E_loc * Cl + 1,), bool).at[slot2].set(
            keep2)[:-1]

        xe = rx[slot_src].reshape(E_loc, Cl, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) \
            * jnp.einsum("ecd,edf->ecf", xe, wu)
        ye = jnp.einsum("ecf,efd->ecd", h, wd).reshape(E_loc * Cl, d)
        ye = jnp.where(slot_ok[:, None], ye, 0)

        # undo local dispatch: back to recv layout
        back = jnp.zeros((R, d), ye.dtype).at[slot_src].add(
            jnp.where(slot_ok[:, None], ye, 0))
        back = back.reshape(n_shards, Cs, d)

        # ---- second hop: return to source shards
        ret = jax.lax.all_to_all(back, EP_AXIS, 0, 0, tiled=False)
        ret = ret.reshape(n_shards * Cs, d)

        # combine at source: weighted scatter-add by GLOBAL token id into
        # the full local buffer (other shards' slices stay zero), then
        # psum over the model axis reassembles every slice exactly once.
        # (send_g was zero-filled for dropped assignments already.)
        w = send_g.reshape(-1).astype(ret.dtype)
        tok_of_slot = fill(s_t.astype(jnp.int32), 0).reshape(-1) \
            + midx * T
        y = jnp.zeros((T_all, d), ret.dtype).at[tok_of_slot].add(
            ret * w[:, None])
        y = jax.lax.psum(y, EP_AXIS)

        # router load-balance aux (local estimate, averaged over shards)
        me = probs.mean(axis=0)
        ce = jnp.bincount(eidx.reshape(-1), length=E) / (T * K)
        aux = E * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, daxes + (EP_AXIS,)) if daxes \
            else jax.lax.pmean(aux, EP_AXIS)
        return y.reshape(Bl, S, d), aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P(EP_AXIS, None, None), P(EP_AXIS, None, None),
                  P(EP_AXIS, None, None)),
        out_specs=(P(bspec, None, None), P()),
        check_rep=False)
    return fn(x, p["router"], p["wg"], p["wu"], p["wd"])
