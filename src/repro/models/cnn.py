"""The paper's CNN models as splittable JAX networks.

Layer granularity matches the paper: one entry per *PyTorch module*, which is
how the paper counts layers (AlexNet 21, VGG11 29, VGG13 33, VGG16 39,
MobileNetV2 21 -- verified against torchvision's module lists).  Each layer
knows how to (a) infer its output shape, (b) init parameters, (c) apply, and
(d) report analytic FLOPs/params so `models/profiles.py` can build the
``ModelProfile`` the optimiser consumes.

Tensors are NCHW, fp32 (PyTorch-for-Android runs fp32; the paper stresses it
does not quantise).  ``apply_split`` executes the network with an explicit
client/server handoff, returning the boundary payload -- the runtime used by
the split-execution tests and the serving example."""
from __future__ import annotations

import dataclasses
import math
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# Storage-dtype policy (re-exported: models-layer callers resolve the
# policy through cnn.* like they resolve conv_backend).
from repro.core.dtype_policy import CONV_DTYPES as CONV_DTYPES
from repro.core.dtype_policy import conv_dtype as conv_dtype
from repro.core.dtype_policy import dtype_bytes as dtype_bytes
from repro.core.dtype_policy import policy_jnp_dtype as policy_jnp_dtype

CONV_BACKENDS = ("xla", "pallas")


def conv_backend(backend: str | None = None) -> str:
    """Resolve the conv execution backend.

    ``xla`` (default) keeps the seed's bit-exact ``lax.conv_general_dilated``
    path; ``pallas`` routes conv(+bias)(+relu/relu6) pairs through the fused
    spatially-tiled kernel in ``repro.kernels.conv2d``.  Overridable per
    call, else by env ``REPRO_CONV_BACKEND``."""
    b = backend or os.environ.get("REPRO_CONV_BACKEND", "xla")
    if b not in CONV_BACKENDS:
        source = "backend argument" if backend else "REPRO_CONV_BACKEND"
        raise ValueError(f"{source} must be one of {CONV_BACKENDS}, "
                         f"got {b!r}")
    return b


@dataclasses.dataclass(frozen=True)
class Layer:
    """One paper-granularity layer."""

    kind: str                    # conv/relu/relu6/maxpool/avgpool/dropout/
                                 # linear/invres
    name: str = ""
    # conv / linear / invres hyper-params (unused fields stay 0)
    cout: int = 0
    ksize: int = 0
    stride: int = 1
    pad: int = 0
    features: int = 0            # linear out features
    expand: int = 0              # invres expansion ratio
    out_hw: int = 0              # adaptive avgpool target


def conv(cout, k, s=1, p=0):
    return Layer(kind="conv", cout=cout, ksize=k, stride=s, pad=p)


def relu():
    return Layer(kind="relu")


def relu6():
    return Layer(kind="relu6")


def maxpool(k, s):
    return Layer(kind="maxpool", ksize=k, stride=s)


def avgpool(out_hw):
    return Layer(kind="avgpool", out_hw=out_hw)


def dropout():
    return Layer(kind="dropout")


def linear(features):
    return Layer(kind="linear", features=features)


def invres(cout, stride, expand):
    return Layer(kind="invres", cout=cout, stride=stride, expand=expand)


def gap_linear(features):
    """Global-average-pool + linear (MobileNetV2 classifier head: the pool
    is functional in torchvision's forward(), not a module, so it shares a
    paper-layer with the Linear)."""
    return Layer(kind="gap_linear", features=features)


# ---------------------------------------------------------------------------
# Shape / cost inference
# ---------------------------------------------------------------------------
def _conv_out(h, k, s, p):
    return (h + 2 * p - k) // s + 1


def _check_spatial(layer: Layer, in_shape: tuple, oh: int, ow: int) -> None:
    """Reject degenerate geometry with a layer-naming error instead of an
    opaque lax shape failure deep inside the conv/reduce_window lowering."""
    if oh < 1 or ow < 1:
        label = layer.name or layer.kind
        raise ValueError(
            f"layer {label!r} (ksize={layer.ksize}, stride={layer.stride}, "
            f"pad={layer.pad}) produces empty output {oh}x{ow} from input "
            f"(H, W)=({in_shape[1]}, {in_shape[2]}): input too small for "
            f"this kernel/stride")


def layer_out_shape(layer: Layer, in_shape: tuple) -> tuple:
    """in_shape: (C, H, W) or (F,) -- batch handled outside."""
    if layer.kind == "conv":
        c, h, w = in_shape
        oh = _conv_out(h, layer.ksize, layer.stride, layer.pad)
        ow = _conv_out(w, layer.ksize, layer.stride, layer.pad)
        _check_spatial(layer, in_shape, oh, ow)
        return (layer.cout, oh, ow)
    if layer.kind in ("relu", "relu6", "dropout"):
        return in_shape
    if layer.kind == "maxpool":
        c, h, w = in_shape
        oh = _conv_out(h, layer.ksize, layer.stride, 0)
        ow = _conv_out(w, layer.ksize, layer.stride, 0)
        _check_spatial(layer, in_shape, oh, ow)
        return (c, oh, ow)
    if layer.kind == "avgpool":
        c, h, w = in_shape
        if layer.out_hw < 1 or h < 1 or w < 1:
            raise ValueError(
                f"layer {layer.name or layer.kind!r}: adaptive avgpool "
                f"needs out_hw >= 1 and a non-empty input, got "
                f"out_hw={layer.out_hw}, (H, W)=({h}, {w})")
        return (c, layer.out_hw, layer.out_hw)
    if layer.kind in ("linear", "gap_linear"):
        return (layer.features,)
    if layer.kind == "invres":
        c, h, w = in_shape
        oh = -(-h // layer.stride)  # stride with SAME padding
        ow = -(-w // layer.stride)
        return (layer.cout, oh, ow)
    raise ValueError(layer.kind)


def layer_flops_params(layer: Layer, in_shape: tuple) -> tuple[float, float]:
    """(FLOPs, param count) for one inference at batch 1."""
    out = layer_out_shape(layer, in_shape)
    n_out = float(np.prod(out))
    if layer.kind == "conv":
        cin = in_shape[0]
        macs = layer.ksize**2 * cin * n_out
        params = layer.ksize**2 * cin * layer.cout + layer.cout
        return 2 * macs, params
    if layer.kind in ("relu", "relu6"):
        return n_out, 0.0
    if layer.kind == "dropout":
        return 0.0, 0.0
    if layer.kind == "maxpool":
        return layer.ksize**2 * n_out, 0.0
    if layer.kind == "avgpool":
        n_in = float(np.prod(in_shape))
        return n_in, 0.0
    if layer.kind == "linear":
        fin = float(np.prod(in_shape))
        return 2 * fin * layer.features, fin * layer.features + layer.features
    if layer.kind == "gap_linear":
        fin = float(in_shape[0])
        pool = float(np.prod(in_shape))
        return pool + 2 * fin * layer.features, \
            fin * layer.features + layer.features
    if layer.kind == "invres":
        cin, h, w = in_shape
        hidden = cin * layer.expand
        oh, ow = out[1], out[2]
        f = p = 0.0
        if layer.expand != 1:                       # expand 1x1
            f += 2 * cin * hidden * h * w
            p += cin * hidden + 2 * hidden          # conv + bn
            f += hidden * h * w                     # relu6
        f += 2 * 9 * hidden * oh * ow               # depthwise 3x3
        p += 9 * hidden + 2 * hidden
        f += hidden * oh * ow                       # relu6
        f += 2 * hidden * layer.cout * oh * ow      # project 1x1
        p += hidden * layer.cout + 2 * layer.cout
        if layer.stride == 1 and cin == layer.cout:
            f += layer.cout * oh * ow               # residual add
        return f, p
    raise ValueError(layer.kind)


# ---------------------------------------------------------------------------
# Parameter init + apply
# ---------------------------------------------------------------------------
def _init_conv(key, cin, cout, k):
    fan_in = cin * k * k
    w = jax.random.normal(key, (cout, cin, k, k)) * math.sqrt(2 / fan_in)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((cout,), jnp.float32)}


def _init_linear(key, fin, fout):
    w = jax.random.normal(key, (fin, fout)) * math.sqrt(2 / fin)
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((fout,), jnp.float32)}


def init_layer(key, layer: Layer, in_shape: tuple) -> Any:
    if layer.kind == "conv":
        return _init_conv(key, in_shape[0], layer.cout, layer.ksize)
    if layer.kind == "linear":
        return _init_linear(key, int(np.prod(in_shape)), layer.features)
    if layer.kind == "gap_linear":
        return _init_linear(key, int(in_shape[0]), layer.features)
    if layer.kind == "invres":
        cin = in_shape[0]
        hidden = cin * layer.expand
        keys = jax.random.split(key, 3)
        p = {}
        if layer.expand != 1:
            p["expand"] = _init_conv(keys[0], cin, hidden, 1)
        p["dw"] = {"w": jax.random.normal(keys[1], (hidden, 1, 3, 3))
                   * math.sqrt(2 / 9), "b": jnp.zeros((hidden,))}
        p["project"] = _init_conv(keys[2], hidden, layer.cout, 1)
        return p
    return {}


def _maxpool(x, k, s):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, k, k), (1, 1, s, s), "VALID")


def _conv2d(x, w, b, stride, pad, groups=1, activation=None,
            pool_k=0, pool_s=0, backend=None, dtype=None):
    """Backend-dispatched conv(+bias)(+act)(+maxpool).

    On pallas the whole chain is one kernel launch; on xla the pool (if
    any) runs as a separate reduce_window so both backends share the same
    call signature and semantics.  ``dtype`` is the storage policy
    (``conv_dtype``): under bf16 both backends store inputs/weights and
    the returned activation in bfloat16 while accumulating in fp32.

    Tiling on the pallas path comes from the ``plan_conv`` joint search
    (``REPRO_CONV_SEARCH`` / ``REPRO_CONV_TILE_W`` knobs): with column
    tiles the kernel also handles high-resolution client inputs (1080p
    frames, panoramic strips) whose single output row overflows VMEM --
    ``INPUT_SHAPE`` is just the paper default, not a limit."""
    policy = conv_dtype(dtype)
    if conv_backend(backend) == "pallas":
        from repro.kernels import ops
        return ops.conv2d(x, w, stride=stride, pad=pad, bias=b,
                          activation=activation, groups=groups,
                          pool_k=pool_k, pool_s=pool_s, dtype=policy)
    from repro.kernels import ref
    accum = None
    if policy == "bf16":
        jdt = policy_jnp_dtype(policy)
        x = x if x.dtype == jdt else x.astype(jdt)
        w = w if w.dtype == jdt else w.astype(jdt)
        accum = jnp.float32
    y = ref.conv2d_ref(x, w, stride=stride, pad=pad, bias=b,
                       activation=activation, groups=groups,
                       accum_dtype=accum)
    return _maxpool(y, pool_k, pool_s or pool_k) if pool_k else y


def _adaptive_avgpool_1d(x: jnp.ndarray, axis: int, out: int) -> jnp.ndarray:
    """torchvision AdaptiveAvgPool semantics along one axis: output index i
    averages input [floor(i*n/out), ceil((i+1)*n/out)) -- variable windows,
    every input element covered (no truncation when ``n % out != 0``)."""
    n = x.shape[axis]
    if n % out == 0:                  # uniform windows: one cheap reshape
        k = n // out
        shape = x.shape[:axis] + (out, k) + x.shape[axis + 1:]
        return x.reshape(shape).mean(axis=axis + 1)
    pieces = []
    for i in range(out):
        s, e = (i * n) // out, -(-((i + 1) * n) // out)
        pieces.append(jax.lax.slice_in_dim(x, s, e, axis=axis)
                      .mean(axis=axis, keepdims=True))
    return jnp.concatenate(pieces, axis=axis)


def apply_layer(layer: Layer, params: Any, x: jnp.ndarray,
                train: bool = False, backend: str | None = None,
                dtype: str | None = None) -> jnp.ndarray:
    if layer.kind in ("conv", "maxpool", "avgpool"):
        layer_out_shape(layer, x.shape[1:])   # fail with a named layer
    if layer.kind == "conv":
        return _conv2d(x, params["w"], params["b"], layer.stride, layer.pad,
                       backend=backend, dtype=dtype)
    if layer.kind == "relu":
        return jax.nn.relu(x)
    if layer.kind == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if layer.kind == "dropout":
        return x                      # inference: identity (paper: inference)
    if layer.kind == "maxpool":
        return _maxpool(x, layer.ksize, layer.stride)
    if layer.kind == "avgpool":
        # Adaptive average pool to (out_hw, out_hw), variable-window like
        # torch's AdaptiveAvgPool2d (the old reshape path truncated
        # trailing rows/cols whenever H % out_hw != 0, e.g. 227-px AlexNet)
        x = _adaptive_avgpool_1d(x, 2, layer.out_hw)
        return _adaptive_avgpool_1d(x, 3, layer.out_hw)
    if layer.kind in ("linear", "gap_linear"):
        if layer.kind == "linear" and x.ndim > 2:
            x = x.reshape(x.shape[0], -1)
        if layer.kind == "gap_linear" and x.ndim == 4:
            x = x.mean(axis=(2, 3))
        # same storage/accumulate split as the conv kernel: weights and
        # activations stored in the policy dtype, matmul in fp32 (so the
        # analytic profile's per-layer weight bytes match the runtime)
        jdt = policy_jnp_dtype(conv_dtype(dtype))
        w = params["w"].astype(jdt).astype(jnp.float32)
        y = x.astype(jnp.float32) @ w + params["b"]
        return y.astype(jdt)
    if layer.kind == "invres":
        # conv+relu6 pairs fuse into one kernel launch on the pallas backend
        y = x
        hidden_in = x
        if "expand" in params:
            y = _conv2d(y, params["expand"]["w"], params["expand"]["b"], 1, 0,
                        activation="relu6", backend=backend, dtype=dtype)
        y = _conv2d(y, params["dw"]["w"], params["dw"]["b"], layer.stride, 1,
                    groups=y.shape[1], activation="relu6", backend=backend,
                    dtype=dtype)
        y = _conv2d(y, params["project"]["w"], params["project"]["b"], 1, 0,
                    backend=backend, dtype=dtype)
        if layer.stride == 1 and hidden_in.shape == y.shape:
            y = y + hidden_in.astype(y.dtype)
        return y
    raise ValueError(layer.kind)


# ---------------------------------------------------------------------------
# Model definitions (module lists match torchvision; counts match the paper)
# ---------------------------------------------------------------------------
def _vgg_features(cfg: list) -> list[Layer]:
    layers = []
    for v in cfg:
        if v == "M":
            layers.append(maxpool(2, 2))
        else:
            layers += [conv(v, 3, 1, 1), relu()]
    return layers


_CLASSIFIER_VGG = [linear(4096), relu(), dropout(),
                   linear(4096), relu(), dropout(), linear(1000)]

ALEXNET = [
    conv(64, 11, 4, 2), relu(), maxpool(3, 2),
    conv(192, 5, 1, 2), relu(), maxpool(3, 2),
    conv(384, 3, 1, 1), relu(),
    conv(256, 3, 1, 1), relu(),
    conv(256, 3, 1, 1), relu(), maxpool(3, 2),
    avgpool(6),
    dropout(), linear(4096), relu(),
    dropout(), linear(4096), relu(), linear(1000),
]                                                     # 21 layers

VGG11 = _vgg_features([64, "M", 128, "M", 256, 256, "M",
                       512, 512, "M", 512, 512, "M"]) \
    + [avgpool(7)] + _CLASSIFIER_VGG                  # 29 layers

VGG13 = _vgg_features([64, 64, "M", 128, 128, "M", 256, 256, "M",
                       512, 512, "M", 512, 512, "M"]) \
    + [avgpool(7)] + _CLASSIFIER_VGG                  # 33 layers

VGG16 = _vgg_features([64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                       512, 512, 512, "M", 512, 512, 512, "M"]) \
    + [avgpool(7)] + _CLASSIFIER_VGG                  # 39 layers

_MBV2_SETTING = [  # (expand, cout, repeats, stride)
    (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
    (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]


def _mobilenet_v2() -> list[Layer]:
    layers: list[Layer] = [conv(32, 3, 2, 1)]         # ConvBNReLU stem
    cin = 32
    for t, c, n, s in _MBV2_SETTING:
        for i in range(n):
            layers.append(invres(c, s if i == 0 else 1, t))
            cin = c
    layers.append(conv(1280, 1, 1, 0))                # last ConvBNReLU
    layers.append(dropout())
    layers.append(gap_linear(1000))
    return layers                                     # 21 layers


MOBILENET_V2 = _mobilenet_v2()

CNN_MODELS: dict[str, list[Layer]] = {
    "alexnet": ALEXNET,        # 21
    "vgg11": VGG11,            # 29
    "vgg13": VGG13,            # 33
    "vgg16": VGG16,            # 39
    "mobilenetv2": MOBILENET_V2,  # 21
}

INPUT_SHAPE = (3, 224, 224)


# ---------------------------------------------------------------------------
# Whole-network helpers
# ---------------------------------------------------------------------------
def shapes_through(layers: list[Layer],
                   in_shape: tuple = INPUT_SHAPE) -> list[tuple]:
    """Per-layer output shapes (len == len(layers))."""
    out = []
    shape = in_shape
    for l in layers:
        shape = layer_out_shape(l, shape)
        out.append(shape)
    return out


def conv_pool_triples(layers: list[Layer],
                      in_shape: tuple = INPUT_SHAPE) -> list[tuple]:
    """(layer_index, cin, hw, cout, ksize, stride, pad, act, pool_k, pool_s)
    for every conv->relu/relu6->maxpool triple ``apply_cnn`` fuses on the
    pallas backend when wholly on one side of the split.  Single source of
    truth for the fusion benchmarks and tests -- the condition here mirrors
    the walk in ``apply_cnn`` exactly."""
    shape = in_shape
    out = []
    for i, l in enumerate(layers):
        if (l.kind == "conv" and i + 2 < len(layers)
                and layers[i + 1].kind in ("relu", "relu6")
                and layers[i + 2].kind == "maxpool"):
            mp = layers[i + 2]
            out.append((i, shape[0], shape[1], l.cout, l.ksize, l.stride,
                        l.pad, layers[i + 1].kind, mp.ksize, mp.stride))
        shape = layer_out_shape(l, shape)
    return out


def conv_plans(layers: list[Layer], in_shape: tuple = INPUT_SHAPE, *,
               batch: int = 1, dtype: str | None = None,
               search: bool | None = None) -> list[tuple]:
    """``(layer_index, ConvPlan)`` for every conv paper-layer, planned
    exactly as the pallas fusion walk will launch it: a conv heading a
    conv->relu->maxpool triple is planned *with* its fused pool geometry
    (``conv_pool_triples`` supplies the window -- the same source
    ``apply_cnn`` mirrors), and the planner sees the storage policy's
    element size, so the plan/BlockSpec geometry the runtime executes and
    the launch/VMEM numbers benches and tests reason about can never
    desynchronise.  ``search`` forwards to ``plan_conv`` (None = resolve
    ``REPRO_CONV_SEARCH``)."""
    from repro.kernels.conv2d import plan_conv
    nbytes = dtype_bytes(conv_dtype(dtype))
    triples = {t[0]: t for t in conv_pool_triples(layers, in_shape)}
    shape = in_shape
    out = []
    for i, l in enumerate(layers):
        if l.kind == "conv":
            pk, ps = (triples[i][-2], triples[i][-1]) if i in triples \
                else (0, 0)
            out.append((i, plan_conv(
                (batch,) + shape, (l.cout, shape[0], l.ksize, l.ksize),
                stride=l.stride, pad=l.pad, pool_k=pk, pool_s=ps,
                dtype_bytes=nbytes, search=search)))
        shape = layer_out_shape(l, shape)
    return out


def init_cnn(key, layers: list[Layer], in_shape: tuple = INPUT_SHAPE):
    params = []
    shape = in_shape
    for l in layers:
        key, sub = jax.random.split(key)
        params.append(init_layer(sub, l, shape))
        shape = layer_out_shape(l, shape)
    return params


def apply_cnn(layers: list[Layer], params, x, *, start: int = 0,
              stop: int | None = None, backend: str | None = None,
              dtype: str | None = None):
    """Run layers [start, stop) -- the split runtime building block.

    On the pallas backend the walk peeks up to two layers ahead: a conv
    paper-layer immediately followed by relu/relu6 collapses into a single
    fused kernel launch (conv + bias + activation in the epilogue), and if
    a maxpool follows the activation the whole conv->relu->maxpool *triple*
    becomes one launch with the pool running on the fp32 accumulator (no
    intermediate activation ever written to HBM).  All layers are still
    *counted* -- split indices keep paper-layer semantics -- and fusion
    only happens when every member sits wholly on one side of the split
    ([start, stop)), so the boundary payload is bit-identical to the
    unfused walk.

    ``dtype`` is the storage policy (``conv_dtype``; env
    ``REPRO_CONV_DTYPE``): under ``bf16`` every conv stores its weights /
    activations / pooled outputs in bfloat16 (fp32 accumulate), so the
    activation stream -- including any split-boundary payload -- flows at
    half the bytes.  Linear/gap_linear heads follow the same rule (bf16
    weight/activation storage, fp32 matmul), so the analytic profile's
    per-layer weight and activation bytes match the runtime everywhere."""
    stop = len(layers) if stop is None else stop
    if not 0 <= start <= stop <= len(layers):
        raise ValueError(
            f"apply_cnn: need 0 <= start <= stop <= {len(layers)} "
            f"(L), got start={start}, stop={stop}")
    bk = conv_backend(backend)
    dt = conv_dtype(dtype)
    if dt != "fp32":
        # the storage invariant starts at the input: even a degenerate
        # l1=0 split (COC) uploads the policy-dtype tensor the profile's
        # input_bytes term charges
        jdt = policy_jnp_dtype(dt)
        x = x if x.dtype == jdt else x.astype(jdt)
    i = start
    while i < stop:
        layer = layers[i]
        if (bk == "pallas" and layer.kind == "conv" and i + 1 < stop
                and layers[i + 1].kind in ("relu", "relu6")):
            pool_k = pool_s = 0
            step = 2
            conv_out = layer_out_shape(layer, x.shape[1:])
            if i + 2 < stop and layers[i + 2].kind == "maxpool":
                layer_out_shape(layers[i + 2], conv_out)  # named geom check
                pool_k = layers[i + 2].ksize
                pool_s = layers[i + 2].stride
                step = 3
            x = _conv2d(x, params[i]["w"], params[i]["b"], layer.stride,
                        layer.pad, activation=layers[i + 1].kind,
                        pool_k=pool_k, pool_s=pool_s, backend=bk, dtype=dt)
            i += step
            continue
        x = apply_layer(layer, params[i], x, backend=bk, dtype=dt)
        i += 1
    return x


def apply_split(layers: list[Layer], params, x, split_index: int,
                backend: str | None = None, dtype: str | None = None,
                wire: str | None = None):
    """Client runs [0, l1), payload crosses the link, server runs [l1, L).

    Returns (logits, boundary_payload) so callers can account the transfer.
    Under the bf16 storage policy the boundary tensor is serialized in
    bfloat16 -- exactly the halved I|l1 the dtype-aware cost model feeds
    the optimiser.

    ``wire`` (``fp32``/``bf16``/``int8``/``follow``; None resolves
    ``REPRO_WIRE_DTYPE``) applies the wire-format round-trip to the
    boundary the server stage consumes -- ``kernels.quant.
    boundary_roundtrip``, the same math the runtime codec performs -- so
    this is the bit-exact fault-free reference for a quantized-wire
    runtime run.  The returned boundary is the client's (pre-encode)
    activation either way.

    ``split_index`` must lie in [0, L]: the degenerate ends are the
    paper's COC (l1=0, boundary = the input upload) and COS-like
    all-on-device placement (l1=L, nothing crosses the link)."""
    from repro.core.dtype_policy import resolve_wire_dtype
    from repro.kernels.quant import boundary_roundtrip
    if not 0 <= split_index <= len(layers):
        raise ValueError(
            f"apply_split: split_index must be in [0, {len(layers)}] "
            f"(L={len(layers)} layers), got {split_index}")
    boundary = apply_cnn(layers, params, x, start=0, stop=split_index,
                         backend=backend, dtype=dtype)
    w = resolve_wire_dtype(wire, storage=conv_dtype(dtype))
    received = boundary if w == conv_dtype(dtype) \
        else boundary_roundtrip(boundary, w, backend=backend)
    logits = apply_cnn(layers, params, received, start=split_index,
                       backend=backend, dtype=dtype)
    return logits, boundary
