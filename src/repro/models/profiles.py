"""Analytic per-layer cost profiles.

Builds the ``ModelProfile`` the SmartSplit optimiser consumes from (a) the
paper's CNNs (layer granularity = PyTorch module, exactly as the paper
counts) and (b) the assigned transformer architectures (layer granularity =
transformer block; boundary payload = hidden state (+ recurrent state for
SSM/RWKV blocks downstream of the cut, + KV cache handoff when serving).

Analytic FLOPs are cross-checked against compiled-HLO ``cost_analysis`` in
``tests/test_costs_vs_hlo.py``."""
from __future__ import annotations

import numpy as np

from repro.core.costs import LayerProfile, ModelProfile
from repro.core.dtype_policy import conv_dtype
from repro.core.dtype_policy import dtype_bytes as policy_bytes
from repro.models import cnn as cnn_lib


# ---------------------------------------------------------------------------
# Paper CNNs
# ---------------------------------------------------------------------------
def cnn_profile(name: str, batch: int = 1,
                dtype_bytes: int | None = None,
                in_shape: tuple = cnn_lib.INPUT_SHAPE,
                dtype: str | None = None,
                layers: list | None = None) -> ModelProfile:
    """Analytic profile under a storage-dtype policy.

    ``dtype`` (``fp32`` | ``bf16``; default resolves ``REPRO_CONV_DTYPE``)
    scales every byte term -- weights, activations, boundary payloads, the
    input upload -- so NSGA-II/TOPSIS sees the memory and transfer costs
    the bf16 execution path actually incurs.  ``dtype_bytes`` overrides
    the per-element size directly (back-compat escape hatch).  ``layers``
    profiles an explicit layer list under ``name`` instead of looking the
    name up in ``CNN_MODELS`` -- the split runtime's tests plan against
    tiny synthetic CNNs through exactly this path."""
    policy = conv_dtype(dtype)
    if dtype_bytes is None:
        dtype_bytes = policy_bytes(policy)
    else:
        policy = {4: "fp32", 2: "bf16"}.get(dtype_bytes, policy)
    if layers is None:
        layers = cnn_lib.CNN_MODELS[name]
    shapes = cnn_lib.shapes_through(layers, in_shape)
    profs = []
    shape = in_shape
    for layer, out_shape in zip(layers, shapes):
        flops, params = cnn_lib.layer_flops_params(layer, shape)
        act = float(np.prod(out_shape)) * dtype_bytes * batch
        profs.append(LayerProfile(
            name=f"{name}.{len(profs)}.{layer.kind}", kind=layer.kind,
            flops=flops * batch, param_bytes=params * dtype_bytes,
            act_bytes=act, boundary_bytes=act,
            # int8-wire scale groups: channel axis for (C, H, W) feature
            # maps, per-tensor for flat activations (runtime convention in
            # kernels.quant.default_channel_axis)
            boundary_channels=float(out_shape[0])
            if len(out_shape) >= 3 else 1.0))
        shape = out_shape
    return ModelProfile(
        name=name, layers=tuple(profs),
        input_bytes=float(np.prod(in_shape)) * dtype_bytes * batch,
        dtype=policy,
        input_channels=float(in_shape[0]) if len(in_shape) >= 3 else 1.0)


# ---------------------------------------------------------------------------
# Transformer architectures (assigned pool)
# ---------------------------------------------------------------------------
def transformer_profile(cfg, *, seq_len: int, batch: int,
                        mode: str = "prefill",
                        dtype_bytes: int = 2) -> ModelProfile:
    """Per-block profile for a ``configs.base.ModelConfig``.

    mode: 'prefill' (process seq_len tokens) or 'decode' (one token against
    a cache of seq_len).  The boundary payload if split after block i is the
    hidden state (batch, tokens, d_model) plus, for decode, nothing extra --
    recurrent/KV state lives on whichever side owns the layer; state that
    must *migrate* at plan time is charged via ``state_bytes`` so the
    optimiser sees the cost of cutting inside a recurrent stack."""
    from repro.configs.base import ModelConfig  # local import, no cycle
    assert isinstance(cfg, ModelConfig)
    tokens = batch * (seq_len if mode == "prefill" else 1)
    d = cfg.d_model
    hidden_bytes = float(tokens * d) * dtype_bytes
    profs = []
    for i, block in enumerate(cfg.block_kinds()):
        flops = cfg.block_flops(block, seq_len=seq_len, batch=batch,
                                mode=mode)
        params = cfg.block_params(block)
        state = cfg.block_state_bytes(block, batch=batch,
                                      dtype_bytes=dtype_bytes)
        profs.append(LayerProfile(
            name=f"{cfg.name}.{i}.{block}", kind=block,
            flops=flops, param_bytes=params * dtype_bytes,
            act_bytes=hidden_bytes, boundary_bytes=hidden_bytes,
            state_bytes=state,
            boundary_channels=float(d)))  # per-feature int8 scales
    # Embedding + unembedding bracket the stack; fold them into first/last.
    embed_flops = 0.0
    unembed_flops = 2.0 * tokens * d * cfg.padded_vocab
    profs[0] = LayerProfile(
        name=profs[0].name, kind=profs[0].kind,
        flops=profs[0].flops + embed_flops,
        param_bytes=profs[0].param_bytes + cfg.padded_vocab * d * dtype_bytes,
        act_bytes=profs[0].act_bytes, boundary_bytes=profs[0].boundary_bytes,
        state_bytes=profs[0].state_bytes,
        boundary_channels=profs[0].boundary_channels)
    last = profs[-1]
    profs[-1] = LayerProfile(
        name=last.name, kind=last.kind, flops=last.flops + unembed_flops,
        param_bytes=last.param_bytes
        + (0 if cfg.tie_embeddings else cfg.padded_vocab * d * dtype_bytes),
        act_bytes=last.act_bytes, boundary_bytes=last.boundary_bytes,
        state_bytes=last.state_bytes,
        boundary_channels=last.boundary_channels)
    input_bytes = float(batch * (seq_len if mode == "prefill" else 1)) * 4
    return ModelProfile(name=f"{cfg.name}:{mode}", layers=tuple(profs),
                        input_bytes=max(input_bytes, 1.0),
                        dtype={4: "fp32", 2: "bf16"}.get(dtype_bytes,
                                                         "fp32"),
                        input_follows_dtype=False)   # int32 token ids
