"""Structured event log for the fault-tolerant split runtime.

Every recovery action -- retries, timeouts, checksum failures, backoff
waits, device fallbacks, Pareto-front re-picks, proactive re-splits -- is
recorded as an ``Event`` stamped with the link's virtual clock, so tests
can assert "no silent wrong answer" (a faulty run either matches the
fault-free logits bit-exactly or carries the recovery that explains why)
and the chaos harness can aggregate counts/bytes without parsing stdout.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Any

# Canonical event kinds (the log accepts any string; these are the ones
# the runtime emits -- tests and the chaos harness key on them).
ATTEMPT = "attempt"                  # one wire attempt started
TRANSFER_OK = "transfer_ok"          # attempt delivered + checksum passed
DROP = "drop"                        # attempt failed: payload dropped
TIMEOUT = "timeout"                  # attempt failed: timeout
OUTAGE = "outage"                    # attempt failed: outage window
CHECKSUM_FAIL = "checksum_fail"      # delivered but corrupt (crc32)
BACKOFF = "backoff"                  # retry wait added to the clock
WIRE_ENCODE = "wire_encode"          # boundary re-encoded to a wire dtype
GIVE_UP = "give_up"                  # retries exhausted for one transfer
FALLBACK_DEVICE = "fallback_device"  # degraded to full on-device run
STAGE_MERGE = "stage_merge"          # collapsed a cut onto the upstream tier
REPICK = "repick"                    # re-picked split from Pareto front
PROACTIVE_RESPLIT = "proactive_resplit"  # EWMA-triggered re-split
UNRECOVERABLE = "unrecoverable"      # no fallback or re-pick remained
QUEUE_SHED = "queue_shed"            # serving engine rejected: queue full
DEADLINE_EXPIRED = "deadline_expired"  # request missed its deadline
TIER_CRASH = "tier_crash"            # stage died on its tier (crash/window)
TIER_SHED = "tier_shed"              # stage rejected: tier memory pressure
TIER_SLOW = "tier_slow"              # straggler stretched a stage's compute
BREAKER_OPEN = "breaker_open"        # consecutive tier failures tripped it
BREAKER_HALF_OPEN = "breaker_half_open"  # cooldown elapsed; probe admitted
BREAKER_CLOSE = "breaker_close"      # probe succeeded; tier back in rotation
TIER_FAILOVER = "tier_failover"      # re-picked onto a standby-tier chain


@dataclasses.dataclass(frozen=True)
class Event:
    t: float                         # link virtual-clock seconds
    kind: str
    detail: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict[str, Any]:
        return {"t": round(self.t, 9), "kind": self.kind, **self.detail}


class EventLog:
    """Append-only event sink shared by the transfer layer and runtime."""

    def __init__(self):
        self.events: list[Event] = []

    def emit(self, kind: str, t: float, **detail: Any) -> Event:
        ev = Event(t=float(t), kind=kind, detail=detail)
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e.kind == kind)

    def counts(self) -> dict[str, int]:
        return dict(Counter(e.kind for e in self.events))

    def since(self, mark: int) -> list[Event]:
        """Events appended after ``mark`` (= an earlier ``len(log)``)."""
        return self.events[mark:]

    def to_json(self) -> list[dict[str, Any]]:
        return [e.to_json() for e in self.events]
