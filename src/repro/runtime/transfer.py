"""Reliable transfer over a ``FaultyLink``: checksum, timeout, retries,
exponential backoff with seeded jitter.

One call = one logical boundary-payload upload.  Each wire attempt carries
the payload plus a small framing header (crc32 + length); a delivered-but-
corrupt payload fails checksum verification and retries exactly like a
drop -- the caller NEVER sees corrupted bytes, which is what makes the
runtime's "bit-identical or recorded fallback" guarantee possible.
Backoff waits are spent on the link's virtual clock (seeded jitter keeps
the schedule deterministic), so retry storms interact correctly with
outage windows and time-varying bandwidth profiles."""
from __future__ import annotations

import dataclasses
import os
import struct
import zlib

import numpy as np

from repro.core.costs import (FRAME_HEADER_BYTES, MULTIPART_BASE_BYTES,
                              PART_HEADER_BYTES)
from repro.runtime import events as ev
from repro.runtime.events import EventLog
from repro.runtime.faults import (ENV_PREFIX, FaultyLink, LinkDropped,
                                  LinkError, LinkOutage, LinkTimeout)

# Framing overhead per wire attempt: crc32 (4B) + payload length (4B).
# The cost model prices the same constant (costs.FRAME_HEADER_BYTES) in
# the microbatch pipeline terms -- one source of truth.
HEADER_BYTES = FRAME_HEADER_BYTES


class ChecksumError(LinkError):
    """Payload delivered but its crc32 did not match the header's.

    ``part`` names the multipart frame the mismatch hit ("scales" /
    "data" / "header") when the transfer was framed, else None -- the
    chaos harness uses it to attribute quantized-frame corruption."""

    part: str | None = None


class FrameError(ValueError):
    """A multipart buffer failed structural or per-part crc validation."""

    def __init__(self, msg: str, part: str):
        super().__init__(msg)
        self.part = part


def pack_frames(*parts: bytes) -> bytes:
    """Frame N byte-strings as one payload, each with its own crc32.

    Layout: ``u32 part-count | [u32 length, u32 crc32, bytes] * N``.
    The int8 boundary codec sends (scales, data) through this, so a
    single flipped byte anywhere is caught -- and attributed -- by
    ``unpack_frames``.  The overhead constants (``MULTIPART_BASE_BYTES``
    + ``PART_HEADER_BYTES`` per part) live in ``core.costs`` so the
    optimiser prices exactly these bytes."""
    buf = [struct.pack("<I", len(parts))]
    for p in parts:
        buf.append(struct.pack("<II", len(p), zlib.crc32(p)))
        buf.append(p)
    return b"".join(buf)


def unpack_frames(buf: bytes, labels: tuple[str, ...] = ()
                  ) -> tuple[bytes, ...]:
    """Split and verify a ``pack_frames`` buffer.

    Raises ``FrameError`` naming the corrupted part (``labels[i]`` when
    given, else ``part{i}``; structural damage = "header")."""
    base = MULTIPART_BASE_BYTES
    if len(buf) < base:
        raise FrameError("multipart buffer shorter than its header",
                         "header")
    (count,) = struct.unpack_from("<I", buf, 0)
    if labels and count != len(labels):
        raise FrameError(
            f"expected {len(labels)} parts, header says {count}", "header")
    off = base
    parts = []
    for i in range(count):
        if off + PART_HEADER_BYTES > len(buf):
            raise FrameError(f"part {i} header out of bounds", "header")
        length, crc = struct.unpack_from("<II", buf, off)
        off += PART_HEADER_BYTES
        if off + length > len(buf):
            raise FrameError(f"part {i} length out of bounds", "header")
        part = buf[off:off + length]
        off += length
        label = labels[i] if i < len(labels) else f"part{i}"
        if zlib.crc32(part) != crc:
            raise FrameError(f"crc32 mismatch in part {label!r}", label)
        parts.append(part)
    if off != len(buf):
        raise FrameError("trailing bytes after last part", "header")
    return tuple(parts)


class TransferFailed(RuntimeError):
    """Retries exhausted for one logical transfer (stats attached)."""

    def __init__(self, msg: str, *, attempts: int, elapsed_s: float,
                 wire_bytes: int):
        super().__init__(msg)
        self.attempts = attempts
        self.elapsed_s = elapsed_s
        self.wire_bytes = wire_bytes


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Per-transfer reliability knobs (env: REPRO_LINK_RETRIES /
    REPRO_LINK_TIMEOUT / REPRO_LINK_BACKOFF / REPRO_LINK_BACKOFF_FACTOR /
    REPRO_LINK_JITTER via ``RetryPolicy.from_env``).

    Attempt i (1-based) waits ``backoff_base_s * backoff_factor**(i-1)``
    -- scaled by ``1 + jitter * U[0,1)`` from the caller's seeded rng --
    before attempt i+1."""

    max_attempts: int = 4
    timeout_s: float = 5.0
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    jitter: float = 0.25

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"max_attempts must be >= 1, got {self.max_attempts}")
        if self.timeout_s <= 0:
            raise ValueError(
                f"timeout_s must be positive, got {self.timeout_s}")
        if self.backoff_base_s < 0 or self.backoff_factor < 1 \
                or self.jitter < 0:
            raise ValueError("backoff must be non-negative and "
                             "non-shrinking")

    def backoff_s(self, attempt: int, u: float = 0.0) -> float:
        """Wait after failed attempt ``attempt`` (1-based); ``u`` in
        [0, 1) supplies the jitter draw."""
        base = self.backoff_base_s * self.backoff_factor ** (attempt - 1)
        return base * (1.0 + self.jitter * u)

    @classmethod
    def from_env(cls) -> "RetryPolicy":
        get = os.environ.get
        return cls(
            max_attempts=int(get(ENV_PREFIX + "RETRIES", 4)),
            timeout_s=float(get(ENV_PREFIX + "TIMEOUT", 5.0)),
            backoff_base_s=float(get(ENV_PREFIX + "BACKOFF", 0.05)),
            backoff_factor=float(get(ENV_PREFIX + "BACKOFF_FACTOR", 2.0)),
            jitter=float(get(ENV_PREFIX + "JITTER", 0.25)))


@dataclasses.dataclass(frozen=True)
class TransferOutcome:
    """A successful logical transfer and what it cost."""

    payload: bytes               # verified, bit-identical to what was sent
    attempts: int                # wire attempts used (1 = clean)
    elapsed_s: float             # total virtual time incl. failures+backoff
    success_elapsed_s: float     # the winning attempt's own wire time
    wire_bytes: int              # all bytes put on the wire (retransmits)
    goodput_bytes: int           # payload + one header (the useful bytes)

    @property
    def retransmitted_bytes(self) -> int:
        return self.wire_bytes - self.goodput_bytes

    # A zero-virtual-time win (e.g. a mocked or infinitely fast link)
    # must not hand callers an infinite bandwidth: one `inf` folded into
    # an EWMA poisons every later `degradation()` ratio (1/inf -> 0 ->
    # permanent "degraded" verdict).  Clamp to a finite ceiling instead.
    BANDWIDTH_CLAMP = 1e18          # bytes/s; ~8 exabit/s, safely absurd

    @property
    def observed_bandwidth(self) -> float:
        """Goodput of the winning attempt -- the EWMA estimator's input.
        Finite by construction (see ``BANDWIDTH_CLAMP``)."""
        if self.success_elapsed_s <= 0:
            return self.BANDWIDTH_CLAMP
        return min(self.goodput_bytes / self.success_elapsed_s,
                   self.BANDWIDTH_CLAMP)


_FAIL_KINDS = {LinkDropped: ev.DROP, LinkTimeout: ev.TIMEOUT,
               LinkOutage: ev.OUTAGE, ChecksumError: ev.CHECKSUM_FAIL}


def send_with_retry(link: FaultyLink, payload: bytes,
                    policy: RetryPolicy = RetryPolicy(), *,
                    rng: np.random.Generator | None = None,
                    log: EventLog | None = None,
                    what: str = "boundary",
                    at: float | None = None,
                    framed: tuple[str, ...] | None = None) -> TransferOutcome:
    """Deliver ``payload`` over ``link`` or raise ``TransferFailed``.

    rng: seeded generator for backoff jitter (None = no jitter).
    log: optional ``EventLog``; every attempt/failure/backoff is emitted.
    what: label carried on the events (e.g. "boundary", "logits").
    at: explicit virtual start time for the transfer.  ``None`` (the
      two-tier path) starts at the link clock and spends backoff waits on
      it directly -- exactly the historical behaviour.  The chain runtime
      passes its pipeline-scheduled send time: the retry loop then keeps
      a local time cursor (the shared clock only ratchets forward via
      ``send_at``), so concurrent hops don't steal each other's time.
    framed: part labels when ``payload`` is a ``pack_frames`` buffer
      (e.g. ``("scales", "data")`` for int8 boundaries).  Integrity then
      comes from the embedded per-part crc32s instead of the outer
      checksum, so a corruption event names the part it hit."""
    log = log if log is not None else EventLog()
    crc = zlib.crc32(payload)
    size = len(payload) + HEADER_BYTES
    scheduled = at is not None
    t = float(at) if scheduled else link.clock
    t_start = t
    wire_bytes = 0
    for attempt in range(1, policy.max_attempts + 1):
        log.emit(ev.ATTEMPT, t, what=what, attempt=attempt, nbytes=size)
        wire_bytes += size
        try:
            if scheduled:
                delivered, elapsed = link.send_at(t, payload,
                                                  policy.timeout_s)
            else:
                delivered, elapsed = link.send(payload, policy.timeout_s)
            if framed is not None:
                try:
                    unpack_frames(delivered, framed)
                except FrameError as fe:
                    err = ChecksumError(
                        f"{fe} on attempt {attempt}", elapsed)
                    err.part = fe.part
                    raise err from fe
            elif zlib.crc32(delivered) != crc:
                raise ChecksumError(
                    f"crc32 mismatch on attempt {attempt}", elapsed)
            t += elapsed
            log.emit(ev.TRANSFER_OK, t, what=what,
                     attempt=attempt, elapsed_s=elapsed)
            return TransferOutcome(
                payload=delivered, attempts=attempt,
                elapsed_s=t - t_start, success_elapsed_s=elapsed,
                wire_bytes=wire_bytes, goodput_bytes=size)
        except LinkError as e:
            t += e.elapsed_s
            part = getattr(e, "part", None)
            log.emit(_FAIL_KINDS[type(e)], t, what=what,
                     attempt=attempt, elapsed_s=e.elapsed_s,
                     **({"part": part} if part else {}))
            if attempt == policy.max_attempts:
                log.emit(ev.GIVE_UP, t, what=what, attempts=attempt)
                raise TransferFailed(
                    f"{what}: {attempt} attempts exhausted ({e})",
                    attempts=attempt, elapsed_s=t - t_start,
                    wire_bytes=wire_bytes) from e
            u = float(rng.uniform()) if rng is not None else 0.0
            wait = policy.backoff_s(attempt, u)
            if not scheduled:
                link.advance(wait)
            t += wait
            log.emit(ev.BACKOFF, t, what=what, attempt=attempt,
                     wait_s=wait)
    raise AssertionError("unreachable")  # pragma: no cover
