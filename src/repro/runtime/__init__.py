"""Fault-tolerant split-execution runtime: flaky-link channel model,
reliable transfer (checksum/retry/timeout/backoff), EWMA link estimation,
structured recovery events, and the degradation loops -- ``SplitRuntime``
for the paper's two-tier case, ``ChainRuntime`` for N-tier chains with
microbatch pipelining (device fallback / stage merges / cached-Pareto-
front TOPSIS re-picks).  The tier-side mirror of the link stack --
``FaultyTier`` compute-fault models, per-tier circuit breakers, and
standby-tier failover -- lives in ``tier_faults`` / ``breakers``."""
from repro.runtime.breakers import CircuitBreaker, tier_breakers
from repro.runtime.events import Event, EventLog
from repro.runtime.faults import (FaultSpec, FaultyLink, LinkDropped,
                                  LinkError, LinkOutage, LinkTimeout,
                                  VirtualClock, chain_links_from_env,
                                  link_from_env, parse_outages)
from repro.runtime.link_estimator import EwmaLinkEstimator, chain_estimators
from repro.runtime.runtime import (ChainInferenceResult, ChainResources,
                                   ChainRuntime, InferenceResult,
                                   SplitRuntime, SplitUnrecoverable,
                                   microbatch_slices)
from repro.runtime.tier_faults import (FaultyTier, TierCrash, TierError,
                                       TierFaultSpec, TierShed,
                                       parse_mem_profile, tier_faults_from_env,
                                       tier_from_env)
from repro.runtime.transfer import (ChecksumError, FrameError, RetryPolicy,
                                    TransferFailed, TransferOutcome,
                                    pack_frames, send_with_retry,
                                    unpack_frames)
from repro.runtime.wire import (BoundaryMeta, decode_boundary,
                                encode_boundary)

__all__ = [
    "Event", "EventLog",
    "FaultSpec", "FaultyLink", "LinkDropped", "LinkError", "LinkOutage",
    "LinkTimeout", "VirtualClock", "chain_links_from_env", "link_from_env",
    "parse_outages",
    "EwmaLinkEstimator", "chain_estimators",
    "ChainInferenceResult", "ChainResources", "ChainRuntime",
    "InferenceResult", "SplitRuntime", "SplitUnrecoverable",
    "microbatch_slices",
    "CircuitBreaker", "tier_breakers",
    "FaultyTier", "TierCrash", "TierError", "TierFaultSpec", "TierShed",
    "parse_mem_profile", "tier_faults_from_env", "tier_from_env",
    "ChecksumError", "FrameError", "RetryPolicy", "TransferFailed",
    "TransferOutcome", "pack_frames", "send_with_retry", "unpack_frames",
    "BoundaryMeta", "decode_boundary", "encode_boundary",
]
