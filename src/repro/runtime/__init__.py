"""Fault-tolerant split-execution runtime: flaky-link channel model,
reliable transfer (checksum/retry/timeout/backoff), EWMA link estimation,
structured recovery events, and the ``SplitRuntime`` degradation loop
(device fallback / cached-Pareto-front TOPSIS re-picks)."""
from repro.runtime.events import Event, EventLog
from repro.runtime.faults import (FaultSpec, FaultyLink, LinkDropped,
                                  LinkError, LinkOutage, LinkTimeout,
                                  link_from_env, parse_outages)
from repro.runtime.link_estimator import EwmaLinkEstimator
from repro.runtime.runtime import (InferenceResult, SplitRuntime,
                                   SplitUnrecoverable)
from repro.runtime.transfer import (ChecksumError, RetryPolicy,
                                    TransferFailed, TransferOutcome,
                                    send_with_retry)

__all__ = [
    "Event", "EventLog",
    "FaultSpec", "FaultyLink", "LinkDropped", "LinkError", "LinkOutage",
    "LinkTimeout", "link_from_env", "parse_outages",
    "EwmaLinkEstimator",
    "InferenceResult", "SplitRuntime", "SplitUnrecoverable",
    "ChecksumError", "RetryPolicy", "TransferFailed", "TransferOutcome",
    "send_with_retry",
]
