"""Fault-tolerant split-execution runtime: flaky-link channel model,
reliable transfer (checksum/retry/timeout/backoff), EWMA link estimation,
structured recovery events, and the degradation loops -- ``SplitRuntime``
for the paper's two-tier case, ``ChainRuntime`` for N-tier chains with
microbatch pipelining (device fallback / stage merges / cached-Pareto-
front TOPSIS re-picks)."""
from repro.runtime.events import Event, EventLog
from repro.runtime.faults import (FaultSpec, FaultyLink, LinkDropped,
                                  LinkError, LinkOutage, LinkTimeout,
                                  VirtualClock, chain_links_from_env,
                                  link_from_env, parse_outages)
from repro.runtime.link_estimator import EwmaLinkEstimator, chain_estimators
from repro.runtime.runtime import (ChainInferenceResult, ChainResources,
                                   ChainRuntime, InferenceResult,
                                   SplitRuntime, SplitUnrecoverable,
                                   microbatch_slices)
from repro.runtime.transfer import (ChecksumError, FrameError, RetryPolicy,
                                    TransferFailed, TransferOutcome,
                                    pack_frames, send_with_retry,
                                    unpack_frames)
from repro.runtime.wire import (BoundaryMeta, decode_boundary,
                                encode_boundary)

__all__ = [
    "Event", "EventLog",
    "FaultSpec", "FaultyLink", "LinkDropped", "LinkError", "LinkOutage",
    "LinkTimeout", "VirtualClock", "chain_links_from_env", "link_from_env",
    "parse_outages",
    "EwmaLinkEstimator", "chain_estimators",
    "ChainInferenceResult", "ChainResources", "ChainRuntime",
    "InferenceResult", "SplitRuntime", "SplitUnrecoverable",
    "microbatch_slices",
    "ChecksumError", "FrameError", "RetryPolicy", "TransferFailed",
    "TransferOutcome", "pack_frames", "send_with_retry", "unpack_frames",
    "BoundaryMeta", "decode_boundary", "encode_boundary",
]
