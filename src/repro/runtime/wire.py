"""Boundary wire codec: (de)serialize split-boundary activations in a
wire format decoupled from the storage dtype.

``encode_boundary`` turns a device array into the bytes a hop actually
ships: the raw storage bytes when the wire format equals the array's
dtype (bit-identical to the legacy serialization, so default runs don't
change), a cast payload for a narrower float wire, or -- for ``int8`` --
a two-part ``pack_frames`` buffer of (fp32 per-channel scales, int8
values) whose per-part crc32s let the transfer layer attribute corruption
to the scales frame vs the data frame.  ``decode_boundary`` inverts it
back to the storage dtype; a fault-free encode/decode is bit-identical to
``kernels.quant.boundary_roundtrip`` of the same array, which is what
makes ``apply_split(wire=...)`` the exact reference for a quantized
runtime run.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.dtype_policy import policy_jnp_dtype
from repro.kernels.quant import (default_channel_axis, dequantize_boundary,
                                 quantize_boundary)
from repro.runtime.transfer import pack_frames, unpack_frames

# Part labels for framed int8 payloads -- the chaos harness keys on these
# to count scales-frame vs data-frame corruption hits.
INT8_FRAME_LABELS = ("scales", "data")


@dataclasses.dataclass(frozen=True)
class BoundaryMeta:
    """Receiver-side description of one encoded boundary payload.

    Travels out of band: shape/dtype/axis are plan facts both endpoints
    already agree on, exactly like the legacy ``_serialize`` host-array
    handoff -- only the payload crosses the (faulty) link."""

    wire: str                    # concrete wire format of the payload
    storage: np.dtype            # dtype decode restores
    shape: tuple[int, ...]
    axis: int | None = None      # int8 scale-group axis (None = per-tensor)
    framed: tuple[str, ...] | None = None  # pack_frames labels (int8 only)
    raw_bytes: int = 0           # storage-dtype serialized size (stats)


def encode_boundary(arr, wire: str, *, backend: str | None = None
                    ) -> tuple[bytes, BoundaryMeta]:
    """Encode ``arr`` for the wire; returns ``(payload, meta)``.

    ``wire`` must be concrete (``fp32``/``bf16``/``int8``) -- resolve
    ``follow`` with ``core.dtype_policy.resolve_wire_dtype`` first.  When
    the wire format equals the array's dtype the payload is bit-identical
    to ``np.asarray(arr).tobytes()`` (the legacy raw path)."""
    storage = np.dtype(arr.dtype)
    shape = tuple(int(d) for d in arr.shape)
    raw_bytes = int(arr.size) * storage.itemsize
    if wire == "int8":
        axis = default_channel_axis(arr.ndim)
        q, scales = quantize_boundary(arr, axis, backend=backend)
        q_host = np.ascontiguousarray(np.asarray(q))
        s_host = np.ascontiguousarray(np.asarray(scales, dtype=np.float32))
        payload = pack_frames(s_host.tobytes(), q_host.tobytes())
        return payload, BoundaryMeta(
            wire=wire, storage=storage, shape=shape, axis=axis,
            framed=INT8_FRAME_LABELS, raw_bytes=raw_bytes)
    jdt = policy_jnp_dtype(wire)
    sent = arr if arr.dtype == jdt else arr.astype(jdt)
    host = np.ascontiguousarray(np.asarray(sent))
    return host.tobytes(), BoundaryMeta(
        wire=wire, storage=storage, shape=shape, raw_bytes=raw_bytes)


def decode_boundary(payload: bytes, meta: BoundaryMeta, *,
                    backend: str | None = None) -> jnp.ndarray:
    """Invert ``encode_boundary`` back to a device array in the storage
    dtype.  Decoding an uncorrupted payload reproduces
    ``boundary_roundtrip(arr, meta.wire)`` bit-for-bit."""
    if meta.wire == "int8":
        s_b, q_b = unpack_frames(payload, meta.framed or INT8_FRAME_LABELS)
        q = jnp.asarray(np.frombuffer(q_b, np.int8).reshape(meta.shape))
        scales = jnp.asarray(np.frombuffer(s_b, np.float32))
        return dequantize_boundary(q, scales, meta.axis,
                                   out_dtype=meta.storage, backend=backend)
    wdt = policy_jnp_dtype(meta.wire)
    x = jnp.asarray(np.frombuffer(payload, dtype=wdt).reshape(meta.shape))
    return x if x.dtype == meta.storage else x.astype(meta.storage)
