"""EWMA effective-bandwidth estimator (NeuPart-style runtime link model).

The planner's Eq. 4 upload term assumes a nominal bandwidth B; the runtime
observes what each transfer *actually* achieved (goodput bytes over the
successful attempt's wire time, zero for a failed transfer) and folds it
into an exponentially-weighted moving average.  Sustained degradation then
shows up as ``degradation() >> 1`` and triggers a *proactive* Pareto-front
re-pick before the next request burns its retries against a link the
estimator already knows is bad."""
from __future__ import annotations


class EwmaLinkEstimator:
    """bw_est <- (1 - alpha) * bw_est + alpha * observed.

    Seeded with the planning bandwidth so the first requests trust the
    plan; ``alpha`` trades reaction speed against noise (0.3 reacts within
    ~3 observations, the transfer layer feeds one per request)."""

    def __init__(self, planned_bandwidth: float, alpha: float = 0.3,
                 floor: float = 1.0):
        if planned_bandwidth <= 0:
            raise ValueError(
                f"planned_bandwidth must be positive, got "
                f"{planned_bandwidth}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.planned = float(planned_bandwidth)
        self.alpha = float(alpha)
        self.floor = float(floor)    # bytes/s; keeps 1/bw finite
        self.bandwidth = float(planned_bandwidth)
        self.n_obs = 0

    def observe(self, nbytes: float, seconds: float) -> float:
        """Fold one observed transfer in; failed transfers pass nbytes=0
        (the time was spent, nothing arrived).  Returns the new estimate."""
        if seconds <= 0:
            return self.bandwidth
        observed = max(nbytes / seconds, self.floor)
        self.bandwidth = ((1.0 - self.alpha) * self.bandwidth
                          + self.alpha * observed)
        self.bandwidth = max(self.bandwidth, self.floor)
        self.n_obs += 1
        return self.bandwidth

    def degradation(self) -> float:
        """planned/estimated bandwidth: 1 = nominal, >1 = degraded (the
        ratio ``core.topsis.link_weights`` and the re-pick consume)."""
        return self.planned / self.bandwidth


def chain_estimators(planned_bandwidths, alpha: float = 0.3,
                     floor: float = 1.0) -> list[EwmaLinkEstimator]:
    """One independent EWMA estimator per hop of a chain, each seeded
    with that hop's planning bandwidth (``core.topsis.chain_link_weights``
    consumes the resulting per-hop degradation ratios)."""
    return [EwmaLinkEstimator(bw, alpha=alpha, floor=floor)
            for bw in planned_bandwidths]
