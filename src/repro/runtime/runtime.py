"""Fault-tolerant split-execution runtime.

``models.cnn.apply_split`` assumes the client->server link never fails;
``SplitRuntime`` wraps the same client/boundary/server walk in a recovery
loop so one link hiccup no longer hangs the "optimal" split:

1. client stage runs layers [0, l1) exactly as ``apply_split`` would;
2. the boundary payload crosses a ``FaultyLink`` through the reliable
   transfer layer (crc32 + per-attempt timeout + bounded retries with
   exponential backoff, see runtime/transfer.py);
3. on success the server stage runs [l1, L) on the delivered (verified,
   bit-identical) payload;
4. on retry exhaustion the runtime degrades *gracefully*: if the client
   memory budget admits the whole model it continues from the boundary
   activation on-device (bit-identical logits, latency paid instead of an
   error); otherwise it re-picks the next-best feasible split from the
   plan's cached Pareto front via TOPSIS with link-weight re-weighting
   (``core.smartsplit.repick_split`` -- microseconds, no GA re-run) and
   tries again, never repeating a failed split index.

An EWMA estimator (runtime/link_estimator.py) folds every observed
transfer into an effective-bandwidth estimate; sustained degradation
triggers a *proactive* re-split at the next request instead of burning
retries against a link the runtime already knows is bad.  Every recovery
action lands in the structured ``EventLog`` -- the invariant tests and
the chaos harness (benchmarks/robustness_bench.py) both key on it.
"""
from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp
import numpy as np

from repro.core.chainplan import ChainPlan
from repro.core.costs import (ModelProfile, _tier_compute_time,
                              resolve_chain_wire)
from repro.core.dtype_policy import conv_dtype, resolve_wire_dtype
from repro.core.hardware import (ChainHardware, NetworkState,
                                 TwoTierHardware, chain_of, standby_chain,
                                 standby_for)
from repro.core.multicut import repick_chain
from repro.core.smartsplit import (SplitPlan, cached_chain_plan,
                                   repick_split)
from repro.models import cnn as cnn_lib
from repro.runtime import events as ev
from repro.runtime.breakers import OPEN, CircuitBreaker, tier_breakers
from repro.runtime.events import Event, EventLog
from repro.runtime.faults import FaultyLink, VirtualClock
from repro.runtime.link_estimator import EwmaLinkEstimator, chain_estimators
from repro.runtime.tier_faults import (FaultyTier, TierCrash, TierError,
                                       TierShed)
from repro.runtime.transfer import (RetryPolicy, TransferFailed,
                                    send_with_retry)
from repro.runtime.wire import decode_boundary, encode_boundary


class SplitUnrecoverable(RuntimeError):
    """Transfer failed, on-device fallback infeasible, Pareto front
    exhausted: the request cannot complete."""


@dataclasses.dataclass(frozen=True)
class InferenceResult:
    """One request's outcome + the recovery evidence behind it."""

    logits: jnp.ndarray
    split_index: int             # split that actually produced the logits
    planned_split: int           # active plan's split when the request began
    degraded: bool               # any fallback / re-pick happened
    on_device: bool              # completed without the server stage
    attempts: int                # wire attempts across all splits tried
    link_elapsed_s: float        # virtual link time (transfers + backoff)
    wire_bytes: int              # bytes put on the wire (incl. retransmits)
    goodput_bytes: int           # useful bytes delivered
    events: tuple[Event, ...]    # this request's slice of the event log

    @property
    def retransmitted_bytes(self) -> int:
        return self.wire_bytes - self.goodput_bytes


class SplitRuntime:
    """Executes a ``SplitPlan`` for one CNN over a (possibly faulty) link.

    model: a name from ``cnn.CNN_MODELS`` or an explicit layer list.
    params: the layer parameters (``cnn.init_cnn``).
    plan: the optimiser's pick, with its cached Pareto front.
    profile: the ``ModelProfile`` the plan was computed from (same dtype
      policy and input shape -- re-pick feasibility is judged against it).
    hw: the planning environment (client memory budget, nominal link).
    link: the channel to execute against (default: a fault-free
      ``FaultyLink`` at the plan's nominal bandwidth).
    policy: transfer-layer retry/timeout/backoff knobs.
    device_fallback: None (default) = allowed iff the whole model fits the
      client memory budget; True/False forces the decision (benches use
      False to exercise the re-pick path on roomy clients).
    resplit_ratio: proactive re-split trigger -- re-pick before the next
      request once planned/estimated bandwidth exceeds this.
    wire: boundary wire format (``fp32``/``bf16``/``int8``/``follow``).
      None resolves plan.wire_dtypes[0] if the plan carries one, else the
      ``REPRO_LINK0_WIRE_DTYPE`` / ``REPRO_WIRE_DTYPE`` env; ``follow``
      (the default everywhere) ships the storage dtype -- the legacy
      bit-identical path.
    """

    def __init__(self, model: str | list, params, plan: SplitPlan,
                 profile: ModelProfile, hw: TwoTierHardware, *,
                 link: FaultyLink | None = None,
                 policy: RetryPolicy = RetryPolicy(),
                 backend: str | None = None, dtype: str | None = None,
                 wire: str | None = None,
                 device_fallback: bool | None = None,
                 estimator_alpha: float = 0.3,
                 resplit_ratio: float = 2.0,
                 jitter_seed: int = 0,
                 tier_faults: list[FaultyTier] | None = None,
                 breakers: list[CircuitBreaker] | None = None,
                 standby: bool = True,
                 log: EventLog | None = None):
        self.layers = cnn_lib.CNN_MODELS[model] if isinstance(model, str) \
            else model
        if profile.num_layers != len(self.layers):
            raise ValueError(
                f"profile has {profile.num_layers} layers, model has "
                f"{len(self.layers)}: plan and runtime would disagree")
        self.params = params
        self.plan = plan                     # active (may be re-picked)
        self.profile = profile
        self.hw = hw
        self.link = link if link is not None \
            else FaultyLink(hw.link.bandwidth)
        self.policy = policy
        self.backend = backend
        self.dtype = dtype
        self._storage = conv_dtype(dtype)
        if wire is None and plan.wire_dtypes:
            wire = plan.wire_dtypes[0]
        self.wire = resolve_wire_dtype(wire, storage=self._storage, hop=0)
        self.device_fallback = device_fallback
        self.resplit_ratio = float(resplit_ratio)
        self.estimator = EwmaLinkEstimator(hw.link.bandwidth,
                                           alpha=estimator_alpha)
        self.net = NetworkState(hw.link)
        self.log = log if log is not None else EventLog()
        self._jitter_rng = np.random.default_rng(jitter_seed)
        if tier_faults is not None and len(tier_faults) != 2:
            raise ValueError(
                f"SplitRuntime takes 2 tier-fault models (client, "
                f"server), got {len(tier_faults)}")
        self.tier_faults = tier_faults
        if breakers is None and tier_faults is not None:
            breakers = tier_breakers([hw.client.name, hw.server.name],
                                     log=self.log)
        if breakers is not None and len(breakers) != 2:
            raise ValueError(
                f"SplitRuntime takes 2 breakers, got {len(breakers)}")
        self.breakers = breakers
        self.standby = bool(standby)
        self._cm = profile.cum_mem()
        # aggregate counters (the chaos harness reads these)
        self.n_requests = 0
        self.n_recovered = 0        # completed despite >= 1 failed attempt
        self.n_fallback_device = 0
        self.n_repicks = 0
        self.n_proactive = 0
        self.n_failovers = 0
        # per-hop transfer counters (one hop here; the chain runtime has
        # K-1 -- same stats schema so the chaos artifact can always say
        # *which* hop degraded)
        self.hop_attempts = 0
        self.hop_wire_bytes = 0
        self.hop_goodput_bytes = 0
        self.hop_raw_bytes = 0      # storage-dtype size of sent boundaries

    # -- stages --------------------------------------------------------
    def _run(self, x, start: int, stop: int):
        return cnn_lib.apply_cnn(self.layers, self.params, x, start=start,
                                 stop=stop, backend=self.backend,
                                 dtype=self.dtype)

    @staticmethod
    def _serialize(arr) -> tuple[bytes, np.ndarray]:
        host = np.ascontiguousarray(np.asarray(arr))
        return host.tobytes(), host

    @staticmethod
    def _deserialize(data: bytes, like: np.ndarray) -> jnp.ndarray:
        host = np.frombuffer(data, dtype=like.dtype).reshape(like.shape)
        return jnp.asarray(host)

    # -- degradation helpers -------------------------------------------
    def _device_ok(self) -> bool:
        if self.device_fallback is not None:
            return self.device_fallback
        full_mem = float(self.profile.cum_mem()[-1])
        return full_mem <= self.hw.client.memory_budget

    def _repick(self, exclude: tuple[int, ...],
                kind: str) -> SplitPlan | None:
        """Next-best feasible split under the current bandwidth estimate;
        None when the front is exhausted."""
        try:
            new = repick_split(self.plan, self.profile, self.hw,
                               bandwidth=self.estimator.bandwidth,
                               exclude=exclude)
        except ValueError:
            return None
        if kind == ev.PROACTIVE_RESPLIT and \
                new.split_index == self.plan.split_index:
            return None                      # estimate agrees with plan
        self.log.emit(kind, self.link.clock,
                      old_split=self.plan.split_index,
                      new_split=new.split_index,
                      est_bandwidth=self.estimator.bandwidth,
                      degradation=self.estimator.degradation())
        return new

    def _maybe_proactive_resplit(self) -> None:
        if self.estimator.degradation() < self.resplit_ratio:
            return
        new = self._repick(exclude=(), kind=ev.PROACTIVE_RESPLIT)
        if new is not None:
            self.plan = new
            self.n_proactive += 1

    def _vet_server(self, l1: int):
        """Breaker-gate + fault-vet the server stage for one request.

        None = healthy (dispatch).  Otherwise ``(transient, cause)`` for
        the degradation ladder: ``transient`` False means the tier is
        known-down (open breaker, active crash window) and a cut re-pick
        onto the same box would be futile."""
        t = self.link.clock
        if self.breakers is not None and not self.breakers[1].allow(t):
            return False, "breaker_open"
        if self.tier_faults is None:
            return None
        ft = self.tier_faults[1]
        mem = float(self._cm[-1] - self._cm[l1])
        try:
            # compute_s=0: SplitRuntime's clock accounts link time only,
            # so the model vets (crash / shed) without stretching time.
            ft.execute(t, 0.0, mem_bytes=mem)
        except TierError as fail:
            kind = ev.TIER_SHED if isinstance(fail, TierShed) \
                else ev.TIER_CRASH
            self.log.emit(kind, t, tier=1, split=l1, error=str(fail))
            if self.breakers is not None:
                self.breakers[1].record_failure(t)
            transient = not (isinstance(fail, TierCrash)
                             and ft.in_crash_window(t))
            return transient, kind
        if self.breakers is not None:
            self.breakers[1].record_success(t)
        return None

    def _tier_failover(self) -> SplitPlan | None:
        """Swap the server for its warm standby and TOPSIS re-pick over
        the plan's cached front (never a GA re-run); None when disabled
        or no standby is registered for the current server."""
        if not self.standby:
            return None
        spare = standby_for(self.hw.server)
        if spare is None:
            return None
        old = self.hw.server.name
        hw = dataclasses.replace(self.hw, server=spare)
        try:
            new = repick_split(self.plan, self.profile, hw,
                               bandwidth=self.estimator.bandwidth)
        except ValueError:
            return None
        self.hw = hw
        if self.tier_faults is not None:
            self.tier_faults[1] = FaultyTier(spare.name)
        if self.breakers is not None:
            self.breakers[1].reset()
        self.n_failovers += 1
        self.log.emit(ev.TIER_FAILOVER, self.link.clock, tier=1,
                      old_tier=old, new_tier=spare.name,
                      new_split=new.split_index)
        return new

    # -- the request loop ----------------------------------------------
    def infer(self, x) -> InferenceResult:
        """Run one request to completion (or raise SplitUnrecoverable).

        The returned logits are bit-identical to the fault-free
        ``apply_split`` run whenever the executed split equals the planned
        one (clean transfer after any retries, or on-device continuation);
        a re-picked split is a *different* placement of the same exact
        computation -- still the fault-free logits of that split."""
        self.n_requests += 1
        mark = len(self.log)
        self._maybe_proactive_resplit()
        planned = self.plan.split_index
        L = len(self.layers)
        attempts = 0
        wire = goodput = 0
        t0 = self.link.clock
        tried: tuple[int, ...] = ()
        tier_degraded = False
        l1 = planned
        while True:
            boundary = self._run(x, 0, l1)
            if l1 == L:                      # everything on the client
                logits = boundary
                on_device = True
                break
            data, meta = encode_boundary(boundary, self.wire,
                                         backend=self.backend)
            if self.wire != self._storage:
                self.log.emit(ev.WIRE_ENCODE, self.link.clock,
                              what=f"boundary@l1={l1}", wire=self.wire,
                              raw_bytes=meta.raw_bytes,
                              payload_bytes=len(data))
            try:
                out = send_with_retry(self.link, data, self.policy,
                                      rng=self._jitter_rng, log=self.log,
                                      what=f"boundary@l1={l1}",
                                      framed=meta.framed)
                attempts += out.attempts
                wire += out.wire_bytes
                goodput += out.goodput_bytes
                self.hop_attempts += out.attempts
                self.hop_wire_bytes += out.wire_bytes
                self.hop_goodput_bytes += out.goodput_bytes
                self.hop_raw_bytes += meta.raw_bytes
                self.estimator.observe(out.goodput_bytes,
                                       out.success_elapsed_s)
                self.net.update(self.estimator.bandwidth)
                verdict = self._vet_server(l1)
                if verdict is None:
                    logits = self._run(
                        decode_boundary(out.payload, meta,
                                        backend=self.backend), l1, L)
                    on_device = False
                    break
                # Server-tier degradation ladder: re-pick (transient
                # failures only) -> standby failover -> on-device
                # fallback -> give up.
                tier_degraded = True
                tried = tried + (l1,)
                transient, cause = verdict
                if transient:
                    new = self._repick(exclude=tried, kind=ev.REPICK)
                    if new is not None:
                        self.plan = new
                        self.n_repicks += 1
                        l1 = new.split_index
                        continue
                new = self._tier_failover()
                if new is not None:
                    self.plan = new
                    l1 = new.split_index
                    tried = ()
                    continue
                if self._device_ok():
                    self.log.emit(ev.FALLBACK_DEVICE, self.link.clock,
                                  split=l1, cause=cause)
                    self.n_fallback_device += 1
                    logits = self._run(boundary, l1, L)
                    on_device = True
                    break
                self.log.emit(ev.UNRECOVERABLE, self.link.clock,
                              tried=list(tried), cause=cause)
                raise SplitUnrecoverable(
                    f"server tier failed ({cause}); no standby, "
                    f"on-device fallback infeasible and Pareto front "
                    f"exhausted")
            except TransferFailed as fail:
                attempts += fail.attempts
                wire += fail.wire_bytes
                self.hop_attempts += fail.attempts
                self.hop_wire_bytes += fail.wire_bytes
                self.hop_raw_bytes += meta.raw_bytes
                # the link burned fail.elapsed_s and delivered nothing
                self.estimator.observe(0.0, fail.elapsed_s)
                self.net.update(self.estimator.bandwidth, outage=True)
                tried = tried + (l1,)
                if self._device_ok():
                    self.log.emit(ev.FALLBACK_DEVICE, self.link.clock,
                                  split=l1, attempts=fail.attempts)
                    self.n_fallback_device += 1
                    logits = self._run(boundary, l1, L)
                    on_device = True
                    break
                new = self._repick(exclude=tried, kind=ev.REPICK)
                if new is None:
                    self.log.emit(ev.UNRECOVERABLE, self.link.clock,
                                  tried=list(tried))
                    raise SplitUnrecoverable(
                        f"transfer failed at splits {list(tried)}; "
                        f"on-device fallback infeasible and Pareto front "
                        f"exhausted") from fail
                self.plan = new
                self.n_repicks += 1
                l1 = new.split_index
        self.net.update(self.estimator.bandwidth, outage=False)
        degraded = bool(tried) or l1 != planned or tier_degraded
        if degraded or attempts > 1:
            self.n_recovered += 1
        return InferenceResult(
            logits=logits, split_index=l1, planned_split=planned,
            degraded=degraded, on_device=on_device, attempts=attempts,
            link_elapsed_s=self.link.clock - t0, wire_bytes=wire,
            goodput_bytes=goodput,
            events=tuple(self.log.since(mark)))

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate counters + link counters + event-kind histogram."""
        return {
            "requests": self.n_requests,
            "recovered": self.n_recovered,
            "fallback_device": self.n_fallback_device,
            "repicks": self.n_repicks,
            "proactive_resplits": self.n_proactive,
            "failovers": self.n_failovers,
            "active_split": self.plan.split_index,
            "est_bandwidth": self.estimator.bandwidth,
            "degradation": self.estimator.degradation(),
            "link": self.link.counters(),
            "tiers": None if self.tier_faults is None else
                [ft.counters() for ft in self.tier_faults],
            "breakers": None if self.breakers is None else
                [br.counters() for br in self.breakers],
            "hops": [{
                "hop": 0,
                "wire_dtype": self.wire,
                "attempts": self.hop_attempts,
                "wire_bytes": self.hop_wire_bytes,
                "goodput_bytes": self.hop_goodput_bytes,
                "raw_bytes": self.hop_raw_bytes,
                "retransmitted_bytes": (self.hop_wire_bytes
                                        - self.hop_goodput_bytes),
                "est_bandwidth": self.estimator.bandwidth,
                "degradation": self.estimator.degradation(),
                "link": self.link.counters(),
            }],
            "events": self.log.counts(),
        }


# ---------------------------------------------------------------------------
# N-tier chain execution
# ---------------------------------------------------------------------------
def microbatch_slices(batch: int, microbatches: int
                      ) -> list[tuple[int, int]]:
    """Contiguous [start, stop) microbatch slices of a batch: an even
    split with the remainder spread over the leading microbatches.

    Exposed so references can be computed at the same granularity --
    XLA convs are NOT bitwise batch-size-invariant, so an M-microbatch
    chain run is bit-identical to a single-device run *sliced the same
    way* (and to the plain batched run only at M=1)."""
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    M = max(1, min(int(microbatches), batch))
    sizes = [batch // M + (1 if i < batch % M else 0) for i in range(M)]
    offsets = np.concatenate([[0], np.cumsum(sizes)]).astype(int)
    return [(int(offsets[i]), int(offsets[i + 1])) for i in range(M)]


class ChainResources:
    """Persistent per-tier / per-link next-free times on the virtual
    clock, shared across requests (and across the per-bucket runtimes of
    a serving engine).

    ``ChainRuntime.infer`` normally resets its resource model per
    request, so consecutive requests serialise completely: request i+1's
    client stage cannot start before request i's makespan.  Passing one
    ``ChainResources`` instance to the runtime makes tier/link
    availability *outlive* the request: while request i's boundary
    payload is in flight on hop k, request i+1's client stage runs on
    tier 0 -- the cross-request generalisation of the microbatch
    pipeline, priced on the same virtual clock.  Indexed by ORIGINAL
    tier/hop ids (merges never renumber)."""

    def __init__(self, num_tiers: int, num_links: int, start: float = 0.0):
        if num_links != num_tiers - 1:
            raise ValueError(
                f"{num_tiers} tiers need {num_tiers - 1} links, "
                f"got {num_links}")
        self.tier_free = [float(start)] * num_tiers
        self.link_free = [float(start)] * num_links

    @property
    def busy_until(self) -> float:
        """Latest committed claim on any tier or link."""
        return max(self.tier_free + self.link_free)


@dataclasses.dataclass(frozen=True)
class ChainInferenceResult:
    """One request's outcome through the N-stage pipeline."""

    logits: jnp.ndarray
    cuts: tuple[int, ...]          # cut vector the request finished under
    planned_cuts: tuple[int, ...]  # active plan's cuts when it began
    degraded: bool                 # any merge / re-pick happened
    merged_hops: tuple[int, ...]   # original hop ids collapsed this request
    attempts: int                  # wire attempts across all hops
    chain_elapsed_s: float         # virtual makespan (pipeline schedule)
    wire_bytes: int
    goodput_bytes: int
    microbatches: int              # M actually used (<= batch size)
    events: tuple[Event, ...]
    # per-microbatch completion times on the virtual clock; the serving
    # engine maps one request to one microbatch, so request i's own
    # end-to-end latency is microbatch_finish_s[i], not the batch makespan
    microbatch_finish_s: tuple[float, ...] = ()

    @property
    def retransmitted_bytes(self) -> int:
        return self.wire_bytes - self.goodput_bytes


class ChainRuntime:
    """Executes a ``ChainPlan`` over K tiers and K-1 (possibly faulty)
    links with microbatch pipelining.

    The generalisation of ``SplitRuntime``: every hop gets its own
    ``FaultyLink`` (all on one shared ``VirtualClock``) and its own EWMA
    bandwidth estimator.  The input batch is split into M microbatches;
    hop transfers are scheduled against a per-tier / per-link resource
    model, so microbatch m+1's stage-k compute overlaps microbatch m's
    downstream hops exactly as ``core.costs.pipeline_latency`` prices it.
    Numerics are schedule-independent: each microbatch's samples walk the
    same layers whatever the timing, so concatenated logits stay
    bit-identical to the single-device reference.

    Degradation ladder (six rungs) when a hop exhausts its retries or a
    tier fails a stage (``tier_faults`` crash/shed, open breaker):

    1. **retry** -- the transfer layer's bounded retries with backoff
       (link failures only; a crashed tier is not retried in place).
    2. **stage merge** -- fold the stage across the dead resource onto
       the upstream tier (collapse the cut) if the merged stage fits
       that tier's memory budget; the dead hop/tier drops out of the
       chain for the rest of the request and later microbatches.  For
       K=2 this is exactly the on-device fallback.
    3. **chain re-pick** -- TOPSIS over the plan's cached Pareto front
       under the current per-hop bandwidth estimates
       (``core.multicut.repick_chain``), never repeating a failed cut
       vector; the request restarts its current microbatch from tier 0.
       Skipped for *persistent* tier failures (open breaker, active
       crash window): every cut vector routes through every tier, so a
       re-pick onto the same dead box would be futile.
    4. **tier failover** -- swap the failed tier for its registered
       warm standby (``core.hardware.standby_for``) and re-pick from
       the standby chain's memoised Pareto front
       (``core.smartsplit.cached_chain_plan``) in one TOPSIS pass --
       never an NSGA-II re-run on the recovery path.
    5. **full on-device fallback** -- run the whole model on tier 0
       when it fits the device memory budget.
    6. ``SplitUnrecoverable`` when nothing remains.

    Rungs 4-5 extend the link-failure ladder only when the tier-fault
    layer is active (``tier_faults``/``breakers`` passed); unprotected
    runtimes keep the legacy merge -> re-pick -> unrecoverable contract.

    microbatches: pipeline depth M (default: REPRO_CHAIN_MICROBATCH env,
      else the plan's own ``microbatches`` field); clamped to the batch.
    merge_fallback: None (default) = merge allowed iff the merged stage
      fits the tier's memory budget; True/False forces the decision.
    wire: per-hop boundary wire formats -- one policy string for every
      hop or a K-1 sequence.  None resolves plan.wire_dtypes if the plan
      carries them, else ``REPRO_LINK{k}_WIRE_DTYPE`` / ``REPRO_WIRE_
      DTYPE`` per hop; ``follow`` ships the storage dtype (legacy path).
      Indexed by ORIGINAL hop id, so merges keep surviving hops' formats.
    resources: optional shared ``ChainResources``.  Default None keeps
      the legacy per-request resource model (every request starts from a
      fresh chain).  With an instance, tier/link next-free times persist
      across requests -- and across every runtime holding the same
      instance -- so back-to-back requests overlap on the pipeline
      exactly like microbatches of one request do (the serving engine's
      cross-request pipelining; pass ``infer(x, at=arrival)``).
    estimators: optional shared per-hop EWMA estimator list (the serving
      engine shares one set across its per-bucket runtimes: the hops are
      the same physical links, so bandwidth evidence should pool).
    profile_batch: how many samples ``profile``'s byte/flop terms
      describe.  Default None keeps the legacy rule (the profile covers
      the whole request batch; each of M microbatches costs 1/M of it);
      an explicit value makes microbatch compute time proportional to
      the slice's own sample count -- a per-sample profile
      (``profile_batch=1``) then prices variable-size batches correctly.
    tier_faults: optional per-tier ``FaultyTier`` models (length K,
      shared virtual clock) vetting every stage execution -- crash
      windows, stragglers, memory-pressure shedding.
    breakers: optional per-tier ``CircuitBreaker`` list gating dispatch;
      auto-built (threshold 3, cooldown 1s) when ``tier_faults`` is
      given.  An open breaker at request start triggers a *proactive*
      failover next to the EWMA-driven proactive re-pick.
    standby: allow rung-4 standby-tier failover (default True).  The
      standby chains' Pareto fronts are prewarmed at construction so the
      failover itself is cache-hit + TOPSIS only.
    """

    def __init__(self, model: str | list, params, plan: ChainPlan,
                 profile: ModelProfile,
                 hw: ChainHardware | TwoTierHardware, *,
                 links: list[FaultyLink] | None = None,
                 policy: RetryPolicy = RetryPolicy(),
                 backend: str | None = None, dtype: str | None = None,
                 wire=None,
                 microbatches: int | None = None,
                 merge_fallback: bool | None = None,
                 estimator_alpha: float = 0.3,
                 resplit_ratio: float = 2.0,
                 jitter_seed: int = 0,
                 resources: ChainResources | None = None,
                 estimators: list[EwmaLinkEstimator] | None = None,
                 profile_batch: int | None = None,
                 tier_faults: list[FaultyTier] | None = None,
                 breakers: list[CircuitBreaker] | None = None,
                 standby: bool = True,
                 log: EventLog | None = None):
        if isinstance(hw, TwoTierHardware):
            hw = chain_of(hw)
        self.layers = cnn_lib.CNN_MODELS[model] if isinstance(model, str) \
            else model
        if profile.num_layers != len(self.layers):
            raise ValueError(
                f"profile has {profile.num_layers} layers, model has "
                f"{len(self.layers)}: plan and runtime would disagree")
        if plan.num_tiers != hw.num_tiers:
            raise ValueError(
                f"plan has {plan.num_tiers} tiers, hardware has "
                f"{hw.num_tiers}")
        self.params = params
        self.plan = plan                     # active (may be re-picked)
        self.profile = profile
        self.hw = hw
        if links is None:
            clock = VirtualClock()
            links = [FaultyLink(link.bandwidth, clock=clock)
                     for link in hw.links]
        else:
            links = list(links)
            clock = links[0]._clock if links else VirtualClock()
        if len(links) != hw.num_tiers - 1:
            raise ValueError(
                f"{hw.num_tiers} tiers need {hw.num_tiers - 1} links, "
                f"got {len(links)}")
        self.links = links
        self.clock = clock
        self.policy = policy
        self.backend = backend
        self.dtype = dtype
        self._storage = conv_dtype(dtype)
        if wire is None and plan.wire_dtypes:
            wire = plan.wire_dtypes
        self.wire_dtypes = resolve_chain_wire(wire, len(links),
                                              self._storage)
        if microbatches is None:
            microbatches = int(os.environ.get("REPRO_CHAIN_MICROBATCH",
                                              plan.microbatches))
        if microbatches < 1:
            raise ValueError(
                f"microbatches must be >= 1, got {microbatches}")
        self.microbatches = microbatches
        self.merge_fallback = merge_fallback
        self.resplit_ratio = float(resplit_ratio)
        if resources is not None and \
                len(resources.link_free) != len(self.links):
            raise ValueError(
                f"resources model {len(resources.link_free)} links, "
                f"chain has {len(self.links)}")
        self.resources = resources
        if profile_batch is not None and profile_batch < 1:
            raise ValueError(
                f"profile_batch must be >= 1, got {profile_batch}")
        self.profile_batch = profile_batch
        if estimators is not None and len(estimators) != len(self.links):
            raise ValueError(
                f"{len(estimators)} estimators for {len(self.links)} links")
        self.estimators = estimators if estimators is not None \
            else chain_estimators(
                [link.bandwidth for link in hw.links], alpha=estimator_alpha)
        self.log = log if log is not None else EventLog()
        self._jitter_rng = np.random.default_rng(jitter_seed)
        self._cm = profile.cum_mem()
        self._cf = profile.cum_flops()
        if tier_faults is not None and len(tier_faults) != hw.num_tiers:
            raise ValueError(
                f"{hw.num_tiers} tiers need {hw.num_tiers} tier-fault "
                f"models, got {len(tier_faults)}")
        self.tier_faults = tier_faults
        if breakers is None and tier_faults is not None:
            breakers = tier_breakers([t.name for t in hw.tiers],
                                     log=self.log)
        if breakers is not None and len(breakers) != hw.num_tiers:
            raise ValueError(
                f"{hw.num_tiers} tiers need {hw.num_tiers} breakers, "
                f"got {len(breakers)}")
        self.breakers = breakers
        self.standby = bool(standby)
        # The failover / on-device rungs extend the LINK-failure ladder
        # only when the tier-fault layer is active: an unprotected
        # runtime keeps the legacy merge -> re-pick -> unrecoverable
        # contract.
        self._protected = tier_faults is not None or breakers is not None
        if self.standby and self._protected:
            # Prewarm the standby chains' Pareto fronts now (the one
            # place the full planner may run) so a breaker-open failover
            # later is a pure cached-front TOPSIS pass.
            for k in range(hw.num_tiers):
                self._standby_plan(k)
        # aggregate counters (the chaos harness reads these)
        self.n_requests = 0
        self.n_recovered = 0
        self.n_merges = 0
        self.n_repicks = 0
        self.n_proactive = 0
        self.n_failovers = 0
        self.n_fallback_device = 0
        n_hops = len(self.links)
        self.hop_attempts = [0] * n_hops
        self.hop_wire_bytes = [0] * n_hops
        self.hop_goodput_bytes = [0] * n_hops
        self.hop_raw_bytes = [0] * n_hops
        self.hop_merges = [0] * n_hops

    # -- stages --------------------------------------------------------
    def _run(self, x, start: int, stop: int):
        return cnn_lib.apply_cnn(self.layers, self.params, x, start=start,
                                 stop=stop, backend=self.backend,
                                 dtype=self.dtype)

    def _stage_seconds(self, tier_id: int, start: int, stop: int) -> float:
        """Whole-batch compute seconds for layers [start, stop) on a tier
        (the same cost model the planner priced the chain with)."""
        tier = self.hw.tiers[tier_id]
        mem = float(self._cm[stop] - self._cm[start])
        fl = float(self._cf[stop] - self._cf[start])
        return float(_tier_compute_time(tier, mem, fl, mem))

    # -- degradation helpers -------------------------------------------
    def _merge_ok(self, tier_id: int, start: int, merged_stop: int) -> bool:
        if self.merge_fallback is not None:
            return self.merge_fallback
        mem = float(self._cm[merged_stop] - self._cm[start])
        return mem <= self.hw.tiers[tier_id].memory_budget

    def _bandwidths(self) -> list[float]:
        return [est.bandwidth for est in self.estimators]

    def _repick(self, exclude: tuple[tuple[int, ...], ...],
                kind: str) -> ChainPlan | None:
        try:
            new = repick_chain(self.plan, self.profile, self.hw,
                               bandwidths=self._bandwidths(),
                               exclude=exclude)
        except ValueError:
            return None
        if kind == ev.PROACTIVE_RESPLIT and new.cuts == self.plan.cuts:
            return None                      # estimate agrees with plan
        self.log.emit(kind, self.clock.now,
                      old_cuts=list(self.plan.cuts),
                      new_cuts=list(new.cuts),
                      est_bandwidths=self._bandwidths(),
                      degradation=max(est.degradation()
                                      for est in self.estimators))
        return new

    def _maybe_proactive_repick(self) -> None:
        if max(est.degradation() for est in self.estimators) \
                < self.resplit_ratio:
            return
        new = self._repick(exclude=(), kind=ev.PROACTIVE_RESPLIT)
        if new is not None:
            self.plan = new
            self.n_proactive += 1

    def _standby_plan(self, tier_id: int):
        """(standby hardware, memoised base plan) for replacing tier
        ``tier_id``, or (None, None) when it has no registered standby.
        First call per chain runs the planner; later calls (the failover
        path) hit ``core.smartsplit``'s plan cache."""
        new_hw = standby_chain(self.hw, tier_id)
        if new_hw is None:
            return None, None
        base = cached_chain_plan(self.profile, new_hw,
                                 microbatches=self.plan.microbatches,
                                 wire=self.wire_dtypes)
        return new_hw, base

    def _failover(self, tier_id: int, t: float) -> ChainPlan | None:
        """Swap tier ``tier_id`` for its warm standby: one TOPSIS pass
        over the standby chain's cached front under the current per-hop
        bandwidth estimates -- never an NSGA-II re-run.  Mutates the
        runtime's hardware/plan/fault state on success; None when no
        standby exists (or standby failover is disabled)."""
        if not self.standby:
            return None
        old = self.hw.tiers[tier_id].name
        new_hw, base = self._standby_plan(tier_id)
        if new_hw is None:
            return None
        try:
            new = repick_chain(base, self.profile, new_hw,
                               bandwidths=self._bandwidths())
        except ValueError:
            return None
        self.hw = new_hw
        self.plan = new
        if self.tier_faults is not None:
            # the standby starts healthy: fault-free model, same clock
            self.tier_faults[tier_id] = FaultyTier(
                new_hw.tiers[tier_id].name, clock=self.clock)
        if self.breakers is not None:
            self.breakers[tier_id].reset()
        self.n_failovers += 1
        self.log.emit(ev.TIER_FAILOVER, t, tier=tier_id, old_tier=old,
                      new_tier=new_hw.tiers[tier_id].name,
                      cuts=list(new.cuts))
        return new

    def _device_fallback_ok(self) -> bool:
        """May the whole model run on the device tier (ladder rung 5)?"""
        return float(self._cm[-1]) <= self.hw.tiers[0].memory_budget

    def _maybe_proactive_failover(self) -> None:
        """An open breaker at request start triggers failover *before*
        dispatch -- the tier-side analogue of the EWMA-driven proactive
        re-pick (don't burn a request against a box known to be down)."""
        if self.breakers is None:
            return
        t = self.clock.now
        for tier_id, br in enumerate(self.breakers):
            if br.state == OPEN and t < br.opened_at + br.cooldown_s:
                if self._failover(tier_id, t) is not None:
                    self.n_proactive += 1

    # -- the request loop ----------------------------------------------
    def infer(self, x, *, at: float | None = None) -> ChainInferenceResult:
        """Run one request through the chain (or raise
        SplitUnrecoverable).

        Microbatches are processed in order against the per-tier /
        per-link resource model -- valid because each microbatch only
        waits on its own upstream ops and on earlier microbatches'
        claims of the same resource (FIFO per tier/link), so m-major
        traversal reproduces the chronological schedule.  Fault draws
        happen per hop in microbatch order (deterministic per seed).

        ``at`` schedules the request's arrival on the virtual clock
        (default: now).  With a shared ``ChainResources``, an arrival
        earlier than the previous request's makespan overlaps it --
        the serving engine's cross-request pipelining; stages still
        start no earlier than both the arrival and the tier's previous
        claim, so the schedule stays FIFO-valid per resource."""
        self.n_requests += 1
        mark = len(self.log)
        self._maybe_proactive_repick()
        self._maybe_proactive_failover()
        planned_cuts = self.plan.cuts
        L = len(self.layers)
        t0 = self.clock.now if at is None else float(at)
        batch = int(x.shape[0])
        slices = microbatch_slices(batch, self.microbatches)
        M = len(slices)

        # Active chain structure, keyed to ORIGINAL tier/hop ids so the
        # resource model and counters survive merges.
        edges = list(self.plan.edges)
        tiers = list(range(len(edges) - 1))
        hops = list(range(len(edges) - 2))
        if self.resources is None:           # per-request resource model
            tier_free = [t0] * self.hw.num_tiers
            link_free = [t0] * len(self.links)
        else:                                # persists across requests
            tier_free = self.resources.tier_free
            link_free = self.resources.link_free

        attempts = 0
        retries = 0
        wire = goodput = 0
        merged: tuple[int, ...] = ()
        tried: tuple[tuple[int, ...], ...] = ()
        repicked = False
        fell_back = False
        outs = []
        mb_finish: list[float] = []
        finish = t0
        for m in range(M):
            x_m = x[slices[m][0]:slices[m][1]]
            cur = x_m
            layer = 0
            s = 0
            ready = t0
            while True:
                tier_id = tiers[s]
                stop = edges[s + 1]
                t_start = max(tier_free[tier_id], ready)
                # Legacy: the profile describes the WHOLE batch, so each
                # of the M microbatches costs 1/M of it.  A serving
                # engine plans per sample (profile_batch=1) and then
                # dispatches variable-size batches, so its microbatch
                # cost scales with the slice's own sample count instead.
                if self.profile_batch is None:
                    dt = self._stage_seconds(tier_id, layer, stop) / M
                else:
                    size = slices[m][1] - slices[m][0]
                    dt = self._stage_seconds(tier_id, layer, stop) \
                        * (size / self.profile_batch)
                # Breaker gate + tier-fault vetting before the stage runs.
                tier_fail: TierError | None = None
                rejected = False
                if stop > layer and self.breakers is not None \
                        and not self.breakers[tier_id].allow(t_start):
                    rejected = True
                    t_fail = t_start
                elif stop > layer and self.tier_faults is not None:
                    try:
                        actual = self.tier_faults[tier_id].execute(
                            t_start, dt,
                            mem_bytes=float(self._cm[stop]
                                            - self._cm[layer]))
                        if actual > dt:
                            self.log.emit(ev.TIER_SLOW, t_start,
                                          tier=tier_id, stage=s,
                                          modelled_s=dt, actual_s=actual)
                            dt = actual
                        if self.breakers is not None:
                            self.breakers[tier_id].record_success(
                                t_start + dt)
                    except TierError as fail:
                        tier_fail = fail
                        t_fail = t_start + fail.elapsed_s
                if rejected or tier_fail is not None:
                    # Tier-failure ladder: upstream stage merge ->
                    # cached-front re-pick (transient failures only) ->
                    # standby failover -> on-device fallback -> give up.
                    tier_free[tier_id] = t_fail
                    ready = t_fail
                    persistent = rejected
                    if tier_fail is not None:
                        kind = ev.TIER_SHED \
                            if isinstance(tier_fail, TierShed) \
                            else ev.TIER_CRASH
                        self.log.emit(kind, t_fail, tier=tier_id,
                                      stage=s, error=str(tier_fail))
                        if self.breakers is not None:
                            self.breakers[tier_id].record_failure(t_fail)
                        persistent = isinstance(tier_fail, TierCrash) \
                            and self.tier_faults[tier_id] \
                            .in_crash_window(t_fail)
                    if not rejected and s > 0 and \
                            self._merge_ok(tiers[s - 1], edges[s - 1],
                                           edges[s + 1]):
                        # Fold the failed stage back onto the upstream
                        # tier: it recomputes [layer, stop) from the
                        # boundary it already holds (the transfer was
                        # bit-exact), and the dead tier drops out of
                        # the chain for the rest of the request.
                        dead_hop = hops[s - 1]
                        self.log.emit(ev.STAGE_MERGE, t_fail,
                                      hop=dead_hop, tier=tiers[s - 1],
                                      cut=edges[s],
                                      merged_stop=edges[s + 1])
                        self.n_merges += 1
                        self.hop_merges[dead_hop] += 1
                        merged = merged + (dead_hop,)
                        del edges[s]
                        del tiers[s]
                        del hops[s - 1]
                        s -= 1
                        continue
                    if not persistent:
                        tried = tried + (tuple(self.plan.cuts),)
                        new = self._repick(exclude=tried, kind=ev.REPICK)
                        if new is not None:
                            self.plan = new
                            self.n_repicks += 1
                            repicked = True
                            edges = list(new.edges)
                            tiers = list(range(len(edges) - 1))
                            hops = list(range(len(edges) - 2))
                            cur = x_m
                            layer = 0
                            s = 0
                            ready = t_fail
                            continue
                    new = self._failover(tier_id, t_fail)
                    if new is not None:
                        repicked = True
                        tried = ()
                        edges = list(new.edges)
                        tiers = list(range(len(edges) - 1))
                        hops = list(range(len(edges) - 2))
                        cur = x_m
                        layer = 0
                        s = 0
                        ready = t_fail
                        continue
                    if not fell_back and self._device_fallback_ok():
                        self.log.emit(ev.FALLBACK_DEVICE, t_fail,
                                      tier=tier_id, stage=s)
                        self.n_fallback_device += 1
                        fell_back = True
                        edges = [0, L]
                        tiers = [0]
                        hops = []
                        cur = x_m
                        layer = 0
                        s = 0
                        ready = t_fail
                        continue
                    self.log.emit(ev.UNRECOVERABLE, t_fail, tier=tier_id,
                                  tried=[list(c) for c in tried])
                    raise SplitUnrecoverable(
                        f"tier {tier_id} failed; merge, re-pick, "
                        f"failover and on-device fallback all "
                        f"unavailable") from tier_fail
                if stop > layer:
                    cur = self._run(cur, layer, stop)
                tier_free[tier_id] = t_start + dt
                ready = t_start + dt
                layer = stop
                if layer == L:
                    break
                hop_id = hops[s]
                w = self.wire_dtypes[hop_id]
                data, meta = encode_boundary(cur, w, backend=self.backend)
                tx = max(link_free[hop_id], ready)
                if w != self._storage:
                    self.log.emit(ev.WIRE_ENCODE, tx,
                                  what=f"hop{hop_id}@l={layer}", wire=w,
                                  raw_bytes=meta.raw_bytes,
                                  payload_bytes=len(data))
                try:
                    out = send_with_retry(
                        self.links[hop_id], data, self.policy,
                        rng=self._jitter_rng, log=self.log,
                        what=f"hop{hop_id}@l={layer}", at=tx,
                        framed=meta.framed)
                    link_free[hop_id] = tx + out.elapsed_s
                    ready = tx + out.elapsed_s
                    attempts += out.attempts
                    retries += out.attempts - 1
                    wire += out.wire_bytes
                    goodput += out.goodput_bytes
                    self.hop_attempts[hop_id] += out.attempts
                    self.hop_wire_bytes[hop_id] += out.wire_bytes
                    self.hop_goodput_bytes[hop_id] += out.goodput_bytes
                    self.hop_raw_bytes[hop_id] += meta.raw_bytes
                    self.estimators[hop_id].observe(out.goodput_bytes,
                                                    out.success_elapsed_s)
                    cur = decode_boundary(out.payload, meta,
                                          backend=self.backend)
                    s += 1
                except TransferFailed as fail:
                    t_fail = tx + fail.elapsed_s
                    link_free[hop_id] = t_fail
                    ready = t_fail
                    attempts += fail.attempts
                    retries += fail.attempts
                    wire += fail.wire_bytes
                    self.hop_attempts[hop_id] += fail.attempts
                    self.hop_wire_bytes[hop_id] += fail.wire_bytes
                    self.hop_raw_bytes[hop_id] += meta.raw_bytes
                    self.estimators[hop_id].observe(0.0, fail.elapsed_s)
                    if self._merge_ok(tier_id, edges[s], edges[s + 2]):
                        self.log.emit(ev.STAGE_MERGE, t_fail,
                                      hop=hop_id, tier=tier_id,
                                      cut=edges[s + 1],
                                      merged_stop=edges[s + 2],
                                      attempts=fail.attempts)
                        self.n_merges += 1
                        self.hop_merges[hop_id] += 1
                        merged = merged + (hop_id,)
                        del edges[s + 1]
                        del tiers[s + 1]
                        del hops[s]
                        # stay on stage s: the loop's next pass computes
                        # the folded layers [layer, new stop) on this tier
                        continue
                    tried = tried + (tuple(self.plan.cuts),)
                    new = self._repick(exclude=tried, kind=ev.REPICK)
                    if new is None and self._protected:
                        # ladder rungs 4/5 (tier-fault deployments):
                        # fail the dead hop's downstream tier over to
                        # its standby, else run fully on the device
                        new = self._failover(tiers[s + 1], t_fail)
                        if new is not None:
                            tried = ()
                        elif not fell_back and self._device_fallback_ok():
                            self.log.emit(ev.FALLBACK_DEVICE, t_fail,
                                          hop=hop_id)
                            self.n_fallback_device += 1
                            fell_back = True
                            edges = [0, L]
                            tiers = [0]
                            hops = []
                            cur = x_m
                            layer = 0
                            s = 0
                            ready = t_fail
                            continue
                    elif new is not None:
                        self.plan = new
                        self.n_repicks += 1
                    if new is None:
                        self.log.emit(ev.UNRECOVERABLE, t_fail,
                                      tried=[list(c) for c in tried],
                                      merged=list(merged))
                        raise SplitUnrecoverable(
                            f"hop {hop_id} failed; stage merge infeasible "
                            f"and chain Pareto front exhausted "
                            f"(tried {list(tried)})") from fail
                    repicked = True
                    # restart this microbatch from tier 0 on the new cuts
                    edges = list(new.edges)
                    tiers = list(range(len(edges) - 1))
                    hops = list(range(len(edges) - 2))
                    cur = x_m
                    layer = 0
                    s = 0
                    ready = t_fail
            outs.append(cur)
            mb_finish.append(ready)
            finish = max(finish, ready)
        self.clock.advance_to(finish)
        logits = outs[0] if M == 1 else jnp.concatenate(outs, axis=0)
        degraded = bool(merged) or repicked or fell_back
        if degraded or retries:
            self.n_recovered += 1
        return ChainInferenceResult(
            logits=logits, cuts=tuple(edges[1:-1]),
            planned_cuts=planned_cuts, degraded=degraded,
            merged_hops=merged, attempts=attempts,
            chain_elapsed_s=finish - t0, wire_bytes=wire,
            goodput_bytes=goodput, microbatches=M,
            events=tuple(self.log.since(mark)),
            microbatch_finish_s=tuple(mb_finish))

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate counters + per-hop counters + event histogram."""
        return {
            "requests": self.n_requests,
            "recovered": self.n_recovered,
            "merges": self.n_merges,
            "repicks": self.n_repicks,
            "proactive_resplits": self.n_proactive,
            "failovers": self.n_failovers,
            "fallback_device": self.n_fallback_device,
            "active_cuts": list(self.plan.cuts),
            "active_tiers": [t.name for t in self.hw.tiers],
            "microbatches": self.microbatches,
            "tiers": None if self.tier_faults is None else
                [ft.counters() for ft in self.tier_faults],
            "breakers": None if self.breakers is None else
                [br.counters() for br in self.breakers],
            "hops": [{
                "hop": k,
                "wire_dtype": self.wire_dtypes[k],
                "attempts": self.hop_attempts[k],
                "wire_bytes": self.hop_wire_bytes[k],
                "goodput_bytes": self.hop_goodput_bytes[k],
                "raw_bytes": self.hop_raw_bytes[k],
                "retransmitted_bytes": (self.hop_wire_bytes[k]
                                        - self.hop_goodput_bytes[k]),
                "merges": self.hop_merges[k],
                "est_bandwidth": self.estimators[k].bandwidth,
                "degradation": self.estimators[k].degradation(),
                "link": self.links[k].counters(),
            } for k in range(len(self.links))],
            "events": self.log.counts(),
        }
