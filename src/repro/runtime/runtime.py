"""Fault-tolerant split-execution runtime.

``models.cnn.apply_split`` assumes the client->server link never fails;
``SplitRuntime`` wraps the same client/boundary/server walk in a recovery
loop so one link hiccup no longer hangs the "optimal" split:

1. client stage runs layers [0, l1) exactly as ``apply_split`` would;
2. the boundary payload crosses a ``FaultyLink`` through the reliable
   transfer layer (crc32 + per-attempt timeout + bounded retries with
   exponential backoff, see runtime/transfer.py);
3. on success the server stage runs [l1, L) on the delivered (verified,
   bit-identical) payload;
4. on retry exhaustion the runtime degrades *gracefully*: if the client
   memory budget admits the whole model it continues from the boundary
   activation on-device (bit-identical logits, latency paid instead of an
   error); otherwise it re-picks the next-best feasible split from the
   plan's cached Pareto front via TOPSIS with link-weight re-weighting
   (``core.smartsplit.repick_split`` -- microseconds, no GA re-run) and
   tries again, never repeating a failed split index.

An EWMA estimator (runtime/link_estimator.py) folds every observed
transfer into an effective-bandwidth estimate; sustained degradation
triggers a *proactive* re-split at the next request instead of burning
retries against a link the runtime already knows is bad.  Every recovery
action lands in the structured ``EventLog`` -- the invariant tests and
the chaos harness (benchmarks/robustness_bench.py) both key on it.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.costs import ModelProfile
from repro.core.hardware import NetworkState, TwoTierHardware
from repro.core.smartsplit import SplitPlan, repick_split
from repro.models import cnn as cnn_lib
from repro.runtime import events as ev
from repro.runtime.events import Event, EventLog
from repro.runtime.faults import FaultyLink
from repro.runtime.link_estimator import EwmaLinkEstimator
from repro.runtime.transfer import (RetryPolicy, TransferFailed,
                                    send_with_retry)


class SplitUnrecoverable(RuntimeError):
    """Transfer failed, on-device fallback infeasible, Pareto front
    exhausted: the request cannot complete."""


@dataclasses.dataclass(frozen=True)
class InferenceResult:
    """One request's outcome + the recovery evidence behind it."""

    logits: jnp.ndarray
    split_index: int             # split that actually produced the logits
    planned_split: int           # active plan's split when the request began
    degraded: bool               # any fallback / re-pick happened
    on_device: bool              # completed without the server stage
    attempts: int                # wire attempts across all splits tried
    link_elapsed_s: float        # virtual link time (transfers + backoff)
    wire_bytes: int              # bytes put on the wire (incl. retransmits)
    goodput_bytes: int           # useful bytes delivered
    events: tuple[Event, ...]    # this request's slice of the event log

    @property
    def retransmitted_bytes(self) -> int:
        return self.wire_bytes - self.goodput_bytes


class SplitRuntime:
    """Executes a ``SplitPlan`` for one CNN over a (possibly faulty) link.

    model: a name from ``cnn.CNN_MODELS`` or an explicit layer list.
    params: the layer parameters (``cnn.init_cnn``).
    plan: the optimiser's pick, with its cached Pareto front.
    profile: the ``ModelProfile`` the plan was computed from (same dtype
      policy and input shape -- re-pick feasibility is judged against it).
    hw: the planning environment (client memory budget, nominal link).
    link: the channel to execute against (default: a fault-free
      ``FaultyLink`` at the plan's nominal bandwidth).
    policy: transfer-layer retry/timeout/backoff knobs.
    device_fallback: None (default) = allowed iff the whole model fits the
      client memory budget; True/False forces the decision (benches use
      False to exercise the re-pick path on roomy clients).
    resplit_ratio: proactive re-split trigger -- re-pick before the next
      request once planned/estimated bandwidth exceeds this.
    """

    def __init__(self, model: str | list, params, plan: SplitPlan,
                 profile: ModelProfile, hw: TwoTierHardware, *,
                 link: FaultyLink | None = None,
                 policy: RetryPolicy = RetryPolicy(),
                 backend: str | None = None, dtype: str | None = None,
                 device_fallback: bool | None = None,
                 estimator_alpha: float = 0.3,
                 resplit_ratio: float = 2.0,
                 jitter_seed: int = 0,
                 log: EventLog | None = None):
        self.layers = cnn_lib.CNN_MODELS[model] if isinstance(model, str) \
            else model
        if profile.num_layers != len(self.layers):
            raise ValueError(
                f"profile has {profile.num_layers} layers, model has "
                f"{len(self.layers)}: plan and runtime would disagree")
        self.params = params
        self.plan = plan                     # active (may be re-picked)
        self.profile = profile
        self.hw = hw
        self.link = link if link is not None \
            else FaultyLink(hw.link.bandwidth)
        self.policy = policy
        self.backend = backend
        self.dtype = dtype
        self.device_fallback = device_fallback
        self.resplit_ratio = float(resplit_ratio)
        self.estimator = EwmaLinkEstimator(hw.link.bandwidth,
                                           alpha=estimator_alpha)
        self.net = NetworkState(hw.link)
        self.log = log if log is not None else EventLog()
        self._jitter_rng = np.random.default_rng(jitter_seed)
        # aggregate counters (the chaos harness reads these)
        self.n_requests = 0
        self.n_recovered = 0        # completed despite >= 1 failed attempt
        self.n_fallback_device = 0
        self.n_repicks = 0
        self.n_proactive = 0

    # -- stages --------------------------------------------------------
    def _run(self, x, start: int, stop: int):
        return cnn_lib.apply_cnn(self.layers, self.params, x, start=start,
                                 stop=stop, backend=self.backend,
                                 dtype=self.dtype)

    @staticmethod
    def _serialize(arr) -> tuple[bytes, np.ndarray]:
        host = np.ascontiguousarray(np.asarray(arr))
        return host.tobytes(), host

    @staticmethod
    def _deserialize(data: bytes, like: np.ndarray) -> jnp.ndarray:
        host = np.frombuffer(data, dtype=like.dtype).reshape(like.shape)
        return jnp.asarray(host)

    # -- degradation helpers -------------------------------------------
    def _device_ok(self) -> bool:
        if self.device_fallback is not None:
            return self.device_fallback
        full_mem = float(self.profile.cum_mem()[-1])
        return full_mem <= self.hw.client.memory_budget

    def _repick(self, exclude: tuple[int, ...],
                kind: str) -> SplitPlan | None:
        """Next-best feasible split under the current bandwidth estimate;
        None when the front is exhausted."""
        try:
            new = repick_split(self.plan, self.profile, self.hw,
                               bandwidth=self.estimator.bandwidth,
                               exclude=exclude)
        except ValueError:
            return None
        if kind == ev.PROACTIVE_RESPLIT and \
                new.split_index == self.plan.split_index:
            return None                      # estimate agrees with plan
        self.log.emit(kind, self.link.clock,
                      old_split=self.plan.split_index,
                      new_split=new.split_index,
                      est_bandwidth=self.estimator.bandwidth,
                      degradation=self.estimator.degradation())
        return new

    def _maybe_proactive_resplit(self) -> None:
        if self.estimator.degradation() < self.resplit_ratio:
            return
        new = self._repick(exclude=(), kind=ev.PROACTIVE_RESPLIT)
        if new is not None:
            self.plan = new
            self.n_proactive += 1

    # -- the request loop ----------------------------------------------
    def infer(self, x) -> InferenceResult:
        """Run one request to completion (or raise SplitUnrecoverable).

        The returned logits are bit-identical to the fault-free
        ``apply_split`` run whenever the executed split equals the planned
        one (clean transfer after any retries, or on-device continuation);
        a re-picked split is a *different* placement of the same exact
        computation -- still the fault-free logits of that split."""
        self.n_requests += 1
        mark = len(self.log)
        self._maybe_proactive_resplit()
        planned = self.plan.split_index
        L = len(self.layers)
        attempts = 0
        wire = goodput = 0
        t0 = self.link.clock
        tried: tuple[int, ...] = ()
        l1 = planned
        while True:
            boundary = self._run(x, 0, l1)
            if l1 == L:                      # everything on the client
                logits = boundary
                on_device = True
                break
            data, host = self._serialize(boundary)
            try:
                out = send_with_retry(self.link, data, self.policy,
                                      rng=self._jitter_rng, log=self.log,
                                      what=f"boundary@l1={l1}")
                attempts += out.attempts
                wire += out.wire_bytes
                goodput += out.goodput_bytes
                self.estimator.observe(out.goodput_bytes,
                                       out.success_elapsed_s)
                self.net.update(self.estimator.bandwidth)
                logits = self._run(self._deserialize(out.payload, host),
                                   l1, L)
                on_device = False
                break
            except TransferFailed as fail:
                attempts += fail.attempts
                wire += fail.wire_bytes
                # the link burned fail.elapsed_s and delivered nothing
                self.estimator.observe(0.0, fail.elapsed_s)
                self.net.update(self.estimator.bandwidth, outage=True)
                tried = tried + (l1,)
                if self._device_ok():
                    self.log.emit(ev.FALLBACK_DEVICE, self.link.clock,
                                  split=l1, attempts=fail.attempts)
                    self.n_fallback_device += 1
                    logits = self._run(boundary, l1, L)
                    on_device = True
                    break
                new = self._repick(exclude=tried, kind=ev.REPICK)
                if new is None:
                    self.log.emit(ev.UNRECOVERABLE, self.link.clock,
                                  tried=list(tried))
                    raise SplitUnrecoverable(
                        f"transfer failed at splits {list(tried)}; "
                        f"on-device fallback infeasible and Pareto front "
                        f"exhausted") from fail
                self.plan = new
                self.n_repicks += 1
                l1 = new.split_index
        self.net.update(self.estimator.bandwidth, outage=False)
        degraded = bool(tried) or l1 != planned
        if degraded or attempts > 1:
            self.n_recovered += 1
        return InferenceResult(
            logits=logits, split_index=l1, planned_split=planned,
            degraded=degraded, on_device=on_device, attempts=attempts,
            link_elapsed_s=self.link.clock - t0, wire_bytes=wire,
            goodput_bytes=goodput,
            events=tuple(self.log.since(mark)))

    # -- reporting ------------------------------------------------------
    def stats(self) -> dict:
        """Aggregate counters + link counters + event-kind histogram."""
        return {
            "requests": self.n_requests,
            "recovered": self.n_recovered,
            "fallback_device": self.n_fallback_device,
            "repicks": self.n_repicks,
            "proactive_resplits": self.n_proactive,
            "active_split": self.plan.split_index,
            "est_bandwidth": self.estimator.bandwidth,
            "degradation": self.estimator.degradation(),
            "link": self.link.counters(),
            "events": self.log.counts(),
        }
