"""Per-tier circuit breakers on the virtual clock.

A breaker sits in front of a tier and gates stage dispatch so the
runtime stops burning compute (and pipeline slots) against a box it
already knows is down:

* **closed** -- dispatch flows; consecutive failures count up.
* **open** -- after ``failure_threshold`` consecutive failures the
  breaker trips: ``allow()`` rejects every dispatch until
  ``cooldown_s`` of virtual time has passed.  An open breaker is the
  standby-failover trigger (``runtime.ChainRuntime``) and feeds the
  proactive re-pick path next to the EWMA link estimators.
* **half-open** -- after the cooldown one probe execution is admitted:
  success closes the breaker (the tier restarted), failure re-opens it
  and restarts the cooldown.

State transitions are driven purely by the caller's virtual timestamps
-- no wall clock, no threads -- so breaker schedules are as reproducible
as the fault schedules that trip them.  Transitions land in the shared
``EventLog`` (``breaker_open`` / ``breaker_half_open`` /
``breaker_close``) when one is attached.
"""
from __future__ import annotations

from repro.runtime import events as ev
from repro.runtime.events import EventLog

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Closed -> open on consecutive failures -> half-open probe."""

    def __init__(self, name: str = "tier", *,
                 failure_threshold: int = 3, cooldown_s: float = 1.0,
                 log: EventLog | None = None):
        if failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got {failure_threshold}")
        if cooldown_s <= 0:
            raise ValueError(
                f"cooldown_s must be positive, got {cooldown_s}")
        self.name = name
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self.log = log
        self.state = CLOSED
        self.failures = 0            # consecutive
        self.opened_at = 0.0
        # counters
        self.n_opens = 0
        self.n_probes = 0
        self.n_closes = 0
        self.n_rejected = 0

    def _emit(self, kind: str, t: float, **detail) -> None:
        if self.log is not None:
            self.log.emit(kind, t, breaker=self.name, **detail)

    def allow(self, t: float) -> bool:
        """May a stage dispatch to this tier at virtual time ``t``?
        Open breakers reject until the cooldown elapses, then admit one
        half-open probe (and keep admitting until its verdict arrives:
        recording the probe's outcome is what resolves the state)."""
        if self.state == CLOSED or self.state == HALF_OPEN:
            return True
        if t >= self.opened_at + self.cooldown_s:
            self.state = HALF_OPEN
            self.n_probes += 1
            self._emit(ev.BREAKER_HALF_OPEN, t, failures=self.failures)
            return True
        self.n_rejected += 1
        return False

    def record_success(self, t: float) -> None:
        """A stage completed on the tier: reset the failure streak and
        close a half-open breaker (the probe succeeded)."""
        self.failures = 0
        if self.state == HALF_OPEN:
            self.state = CLOSED
            self.n_closes += 1
            self._emit(ev.BREAKER_CLOSE, t)
        elif self.state == OPEN:        # defensive: forced execution
            self.state = CLOSED
            self.n_closes += 1
            self._emit(ev.BREAKER_CLOSE, t)

    def record_failure(self, t: float) -> bool:
        """A stage failed on the tier.  Returns True when this failure
        tripped (or re-tripped) the breaker open."""
        self.failures += 1
        if self.state == HALF_OPEN or \
                (self.state == CLOSED
                 and self.failures >= self.failure_threshold):
            self.state = OPEN
            self.opened_at = float(t)
            self.n_opens += 1
            self._emit(ev.BREAKER_OPEN, t, failures=self.failures,
                       cooldown_s=self.cooldown_s)
            return True
        return False

    def reset(self) -> None:
        """Forget all state (e.g. after the tier was failed over)."""
        self.state = CLOSED
        self.failures = 0
        self.opened_at = 0.0

    def counters(self) -> dict[str, int | str]:
        return {"state": self.state, "failures": self.failures,
                "opens": self.n_opens, "probes": self.n_probes,
                "closes": self.n_closes, "rejected": self.n_rejected}


def tier_breakers(names, *, failure_threshold: int = 3,
                  cooldown_s: float = 1.0,
                  log: EventLog | None = None) -> list[CircuitBreaker]:
    """One breaker per chain tier (``names`` = the tier names)."""
    return [CircuitBreaker(name, failure_threshold=failure_threshold,
                           cooldown_s=cooldown_s, log=log)
            for name in names]
