"""Seeded, deterministic flaky-link channel model.

The planner (core/smartsplit.py) chooses a split against a *nominal*
client->server link; the runtime executes against this one, which can
degrade, drop, corrupt, delay, or black out entirely.  Everything is
simulated on a **virtual clock** driven only by link activity (transfer
time, timeouts, backoff waits), so fault schedules are bit-reproducible
from a seed and a send sequence -- no real sleeps, no wall-clock in the
loop -- and a whole chaos sweep runs in milliseconds of host time.

Fault taxonomy (one uniform draw per category per send, so the fault
schedule for a given seed is independent of payload sizes and outcomes):

* **drop**     -- the payload vanishes in flight; the sender learns
                  nothing until its per-attempt timeout expires.
* **corrupt**  -- the payload arrives with a flipped byte.  The link
                  itself stays silent: detection is the transfer layer's
                  job (crc32, see runtime/transfer.py), which is exactly
                  why the checksum exists.
* **delay**    -- the transfer takes ``delay_s`` longer; if that pushes
                  it past the timeout the sender sees a timeout.
* **outage**   -- wall of silence during configured virtual-time windows;
                  every send inside one burns its full timeout.

Bandwidth/latency come from either a constant or a piecewise-constant
profile over virtual time, so sustained degradation (the EWMA estimator's
trigger) is expressible without any fault randomness at all.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

ENV_PREFIX = "REPRO_LINK_"


class VirtualClock:
    """A monotone virtual-time source shared by every hop of a chain.

    The two-tier runtime had one link and therefore one clock; an N-hop
    chain needs its hops to agree on *when* things happen (an outage
    window on hop 2 is a window in chain time, not hop-2-activity time).
    ``advance_to`` is a max -- concurrent activity on different hops can
    report out of order without ever moving time backwards."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance(self, seconds: float) -> None:
        if seconds < 0:
            raise ValueError(f"cannot advance by {seconds}")
        self.now += seconds

    def advance_to(self, t: float) -> None:
        self.now = max(self.now, float(t))


class LinkError(RuntimeError):
    """One failed transfer attempt; ``elapsed_s`` is the virtual time the
    attempt consumed (the link clock has already advanced by it)."""

    def __init__(self, msg: str, elapsed_s: float):
        super().__init__(msg)
        self.elapsed_s = elapsed_s


class LinkDropped(LinkError):
    """Payload lost in flight (sender observed a timeout)."""


class LinkTimeout(LinkError):
    """Transfer could not complete within the per-attempt timeout."""


class LinkOutage(LinkError):
    """Send fell inside a configured outage window."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Injectable fault rates + outage windows (virtual-time seconds)."""

    drop_rate: float = 0.0
    corrupt_rate: float = 0.0
    delay_rate: float = 0.0
    delay_s: float = 0.0
    outages: tuple[tuple[float, float], ...] = ()

    def __post_init__(self):
        for field in ("drop_rate", "corrupt_rate", "delay_rate"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field} must be in [0, 1], got {v}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")
        for start, end in self.outages:
            if end <= start:
                raise ValueError(f"outage window ({start}, {end}) is empty")

    @property
    def fault_free(self) -> bool:
        return (self.drop_rate == 0.0 and self.corrupt_rate == 0.0
                and self.delay_rate == 0.0 and not self.outages)


class FaultyLink:
    """A client->server channel with seeded, injectable faults.

    bandwidth: nominal bytes/s (e.g. ``hw.link.bandwidth``).
    latency_s: fixed per-transfer propagation latency.
    faults: the ``FaultSpec`` to inject.
    seed: PRNG seed; same seed + same send sequence => same fault schedule.
    bandwidth_profile: optional piecewise-constant schedule
      ``((start_s, bytes_per_s), ...)`` overriding ``bandwidth`` from each
      start time onward -- models sustained degradation (walking out of
      Wi-Fi range) as opposed to point faults.
    """

    def __init__(self, bandwidth: float, *, latency_s: float = 0.0,
                 faults: FaultSpec = FaultSpec(), seed: int = 0,
                 bandwidth_profile: tuple[tuple[float, float], ...] = (),
                 clock: VirtualClock | None = None):
        if bandwidth <= 0:
            raise ValueError(f"bandwidth must be positive, got {bandwidth}")
        self.bandwidth = float(bandwidth)
        self.latency_s = float(latency_s)
        self.faults = faults
        self.seed = int(seed)
        self.bandwidth_profile = tuple(sorted(bandwidth_profile))
        self._rng = np.random.default_rng(self.seed)
        # virtual seconds of link activity; a chain passes one shared
        # VirtualClock to all its hops so their timelines agree
        self._clock = clock if clock is not None else VirtualClock()
        # counters (all attempts, successful or not)
        self.sends = 0
        self.delivered = 0
        self.dropped = 0
        self.timeouts = 0
        self.outage_hits = 0
        self.corrupted = 0
        self.bytes_delivered = 0
        self.bytes_lost = 0

    # -- clock ---------------------------------------------------------
    @property
    def clock(self) -> float:
        return self._clock.now

    @clock.setter
    def clock(self, value: float) -> None:
        self._clock.now = float(value)

    def advance(self, seconds: float) -> None:
        """Spend non-transfer virtual time on the clock (backoff waits)."""
        self._clock.advance(seconds)

    def bandwidth_at(self, t: float) -> float:
        """Effective bytes/s at virtual time ``t``."""
        bw = self.bandwidth
        for start, seg_bw in self.bandwidth_profile:
            if t >= start:
                bw = seg_bw
        return bw

    def in_outage(self, t: float) -> bool:
        return any(start <= t < end for start, end in self.faults.outages)

    def outage_overlaps(self, t0: float, t1: float) -> bool:
        """True when [t0, t1) intersects any outage window: a transfer in
        flight when the link blacks out dies too, not just one that
        *starts* during the window."""
        return any(start < t1 and t0 < end
                   for start, end in self.faults.outages)

    # -- transfer ------------------------------------------------------
    def send(self, data: bytes, timeout_s: float) -> tuple[bytes, float]:
        """Attempt one transfer starting now.  Returns
        ``(delivered, elapsed_s)`` and advances the clock; raises
        ``LinkDropped`` / ``LinkTimeout`` / ``LinkOutage`` on failure
        (clock advanced by the timeout either way -- a failed attempt is
        never free).  A *corrupted* delivery returns normally with a
        flipped byte: callers must checksum."""
        return self.send_at(self.clock, data, timeout_s)

    def send_at(self, t0: float, data: bytes,
                timeout_s: float) -> tuple[bytes, float]:
        """Attempt one transfer starting at virtual time ``t0``.

        The chain runtime schedules hop sends from its pipeline model, so
        a send's start time comes from the schedule (compute finish /
        link free), not from "whenever the shared clock happens to be".
        Fault draws happen in call order (deterministic per seed); the
        shared clock only ever moves forward (``advance_to``), so
        ``send()`` -- where ``t0 == clock`` -- behaves exactly as
        before."""
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be positive, got {timeout_s}")
        self.sends += 1
        n = len(data)
        t0 = float(t0)
        # Draw every category each send so the schedule is size-invariant
        # (a scaled uniform, not integers(0, n): bounded-int draws consume
        # a size-dependent amount of the stream via rejection sampling).
        u_drop, u_corrupt, u_delay, u_pos = self._rng.uniform(size=4)
        corrupt_at = min(int(u_pos * n), n - 1) if n else 0
        xfer = self.latency_s + n / self.bandwidth_at(t0)
        if u_delay < self.faults.delay_rate:
            xfer += self.faults.delay_s
        if self.outage_overlaps(t0, t0 + min(xfer, timeout_s)):
            self.outage_hits += 1
            self.bytes_lost += n
            self._clock.advance_to(t0 + timeout_s)
            raise LinkOutage(f"outage window at t={t0:.3f}s", timeout_s)
        if u_drop < self.faults.drop_rate:
            self.dropped += 1
            self.bytes_lost += n
            self._clock.advance_to(t0 + timeout_s)
            raise LinkDropped(f"payload dropped at t={t0:.3f}s", timeout_s)
        if xfer > timeout_s:
            self.timeouts += 1
            self.bytes_lost += n
            self._clock.advance_to(t0 + timeout_s)
            raise LinkTimeout(
                f"transfer needs {xfer:.3f}s > timeout {timeout_s:.3f}s",
                timeout_s)
        self._clock.advance_to(t0 + xfer)
        self.delivered += 1
        self.bytes_delivered += n
        if u_corrupt < self.faults.corrupt_rate and n:
            self.corrupted += 1
            out = bytearray(data)
            out[corrupt_at] ^= 0xFF
            return bytes(out), xfer
        return bytes(data), xfer

    def counters(self) -> dict[str, int | float]:
        return {"sends": self.sends, "delivered": self.delivered,
                "dropped": self.dropped, "timeouts": self.timeouts,
                "outage_hits": self.outage_hits,
                "corrupted": self.corrupted,
                "bytes_delivered": self.bytes_delivered,
                "bytes_lost": self.bytes_lost, "clock_s": self.clock}


def _env_raw(name: str, hop: int | None = None) -> str | None:
    """Env lookup with per-hop override: ``REPRO_LINK{hop}_X`` wins over
    the chain-wide ``REPRO_LINK_X``."""
    if hop is not None:
        raw = os.environ.get(f"REPRO_LINK{hop}_{name}")
        if raw is not None:
            return raw
    return os.environ.get(ENV_PREFIX + name)


def _env_float(name: str, default: float, hop: int | None = None) -> float:
    raw = _env_raw(name, hop)
    return default if raw is None else float(raw)


def parse_outages(raw: str) -> tuple[tuple[float, float], ...]:
    """Parse ``"start:end[,start:end...]"`` (seconds) outage windows."""
    windows = []
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        start, _, end = part.partition(":")
        windows.append((float(start), float(end)))
    return tuple(windows)


def link_from_env(bandwidth: float, *, seed: int | None = None,
                  faults: FaultSpec | None = None,
                  hop: int | None = None,
                  clock: VirtualClock | None = None) -> FaultyLink:
    """Build a ``FaultyLink`` from ``REPRO_LINK_*`` env knobs.

    REPRO_LINK_BW        bytes/s (default: the ``bandwidth`` argument,
                         normally the plan's nominal link)
    REPRO_LINK_LATENCY   fixed per-transfer latency, seconds (default 0)
    REPRO_LINK_DROP      drop probability per attempt      (default 0)
    REPRO_LINK_CORRUPT   corruption probability per attempt (default 0)
    REPRO_LINK_DELAY     delay-fault probability per attempt (default 0)
    REPRO_LINK_DELAY_S   extra seconds when a delay fires  (default 0.5)
    REPRO_LINK_OUTAGES   "start:end[,start:end]" virtual-time windows
    REPRO_LINK_SEED      fault-schedule seed (default 0)

    With ``hop`` given, ``REPRO_LINK{hop}_X`` (e.g. ``REPRO_LINK1_DROP``)
    overrides the chain-wide knob for that hop only -- how the chaos
    harness aims a fault at one specific link of a chain.

    Explicit ``faults``/``seed`` arguments win over the environment."""
    if faults is None:
        faults = FaultSpec(
            drop_rate=_env_float("DROP", 0.0, hop),
            corrupt_rate=_env_float("CORRUPT", 0.0, hop),
            delay_rate=_env_float("DELAY", 0.0, hop),
            delay_s=_env_float("DELAY_S", 0.5, hop),
            outages=parse_outages(_env_raw("OUTAGES", hop) or ""),
        )
    if seed is None:
        seed = int(_env_float("SEED", 0, hop))
    return FaultyLink(_env_float("BW", bandwidth, hop),
                      latency_s=_env_float("LATENCY", 0.0, hop),
                      faults=faults, seed=seed, clock=clock)


def chain_links_from_env(bandwidths, *, seed: int | None = None,
                         clock: VirtualClock | None = None
                         ) -> list[FaultyLink]:
    """One env-configured ``FaultyLink`` per hop, all on a shared clock.

    bandwidths: nominal bytes/s per hop (e.g. from the plan's links).
    seed: base fault-schedule seed; hop k draws from ``seed + k`` so the
      hops' fault streams are independent (REPRO_LINK{k}_SEED overrides
      per hop, REPRO_LINK_SEED overrides the base)."""
    clock = clock if clock is not None else VirtualClock()
    links = []
    for k, bw in enumerate(bandwidths):
        if os.environ.get(f"REPRO_LINK{k}_SEED") is not None:
            hop_seed = None      # per-hop env knob wins verbatim
        else:
            env_base = os.environ.get(ENV_PREFIX + "SEED")
            base = int(env_base) if env_base is not None else \
                (int(seed) if seed is not None else 0)
            hop_seed = base + k
        links.append(link_from_env(bw, seed=hop_seed, hop=k, clock=clock))
    return links

