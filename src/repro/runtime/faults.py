"""Seeded, deterministic flaky-link channel model.

The planner (core/smartsplit.py) chooses a split against a *nominal*
client->server link; the runtime executes against this one, which can
degrade, drop, corrupt, delay, or black out entirely.  Everything is
simulated on a **virtual clock** driven only by link activity (transfer
time, timeouts, backoff waits), so fault schedules are bit-reproducible
from a seed and a send sequence -- no real sleeps, no wall-clock in the
loop -- and a whole chaos sweep runs in milliseconds of host time.

Fault taxonomy (one uniform draw per category per send, so the fault
schedule for a given seed is independent of payload sizes and outcomes):

* **drop**     -- the payload vanishes in flight; the sender learns
                  nothing until its per-attempt timeout expires.
* **corrupt**  -- the payload arrives with a flipped byte.  The link
                  itself stays silent: detection is the transfer layer's
                  job (crc32, see runtime/transfer.py), which is exactly
                  why the checksum exists.
* **delay**    -- the transfer takes ``delay_s`` longer; if that pushes
                  it past the timeout the sender sees a timeout.
* **outage**   -- wall of silence during configured virtual-time windows;
                  every send inside one burns its full timeout.

Bandwidth/latency come from either a constant or a piecewise-constant
profile over virtual time, so sustained degradation (the EWMA estimator's
trigger) is expressible without any fault randomness at all.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

ENV_PREFIX = "REPRO_LINK_"


class LinkError(RuntimeError):
    """One failed transfer attempt; ``elapsed_s`` is the virtual time the
    attempt consumed (the link clock has already advanced by it)."""

    def __init__(self, msg: str, elapsed_s: float):
        super().__init__(msg)
        self.elapsed_s = elapsed_s


class LinkDropped(LinkError):
    """Payload lost in flight (sender observed a timeout)."""


class LinkTimeout(LinkError):
    """Transfer could not complete within the per-attempt timeout."""


class LinkOutage(LinkError):
    """Send fell inside a configured outage window."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Injectable fault rates + outage windows (virtual-time seconds)."""

    drop_rate: float = 0.0
    corrupt_rate: float = 0.0
    delay_rate: float = 0.0
    delay_s: float = 0.0
    outages: tuple[tuple[float, float], ...] = ()

    def __post_init__(self):
        for field in ("drop_rate", "corrupt_rate", "delay_rate"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field} must be in [0, 1], got {v}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")
        for start, end in self.outages:
            if end <= start:
                raise ValueError(f"outage window ({start}, {end}) is empty")

    @property
    def fault_free(self) -> bool:
        return (self.drop_rate == 0.0 and self.corrupt_rate == 0.0
                and self.delay_rate == 0.0 and not self.outages)


class FaultyLink:
    """A client->server channel with seeded, injectable faults.

    bandwidth: nominal bytes/s (e.g. ``hw.link.bandwidth``).
    latency_s: fixed per-transfer propagation latency.
    faults: the ``FaultSpec`` to inject.
    seed: PRNG seed; same seed + same send sequence => same fault schedule.
    bandwidth_profile: optional piecewise-constant schedule
      ``((start_s, bytes_per_s), ...)`` overriding ``bandwidth`` from each
      start time onward -- models sustained degradation (walking out of
      Wi-Fi range) as opposed to point faults.
    """

    def __init__(self, bandwidth: float, *, latency_s: float = 0.0,
                 faults: FaultSpec = FaultSpec(), seed: int = 0,
                 bandwidth_profile: tuple[tuple[float, float], ...] = ()):
        if bandwidth <= 0:
            raise ValueError(f"bandwidth must be positive, got {bandwidth}")
        self.bandwidth = float(bandwidth)
        self.latency_s = float(latency_s)
        self.faults = faults
        self.seed = int(seed)
        self.bandwidth_profile = tuple(sorted(bandwidth_profile))
        self._rng = np.random.default_rng(self.seed)
        self.clock = 0.0          # virtual seconds of link activity
        # counters (all attempts, successful or not)
        self.sends = 0
        self.delivered = 0
        self.dropped = 0
        self.timeouts = 0
        self.outage_hits = 0
        self.corrupted = 0
        self.bytes_delivered = 0
        self.bytes_lost = 0

    # -- clock ---------------------------------------------------------
    def advance(self, seconds: float) -> None:
        """Spend non-transfer virtual time on the clock (backoff waits)."""
        if seconds < 0:
            raise ValueError(f"cannot advance by {seconds}")
        self.clock += seconds

    def bandwidth_at(self, t: float) -> float:
        """Effective bytes/s at virtual time ``t``."""
        bw = self.bandwidth
        for start, seg_bw in self.bandwidth_profile:
            if t >= start:
                bw = seg_bw
        return bw

    def in_outage(self, t: float) -> bool:
        return any(start <= t < end for start, end in self.faults.outages)

    def outage_overlaps(self, t0: float, t1: float) -> bool:
        """True when [t0, t1) intersects any outage window: a transfer in
        flight when the link blacks out dies too, not just one that
        *starts* during the window."""
        return any(start < t1 and t0 < end
                   for start, end in self.faults.outages)

    # -- transfer ------------------------------------------------------
    def send(self, data: bytes, timeout_s: float) -> tuple[bytes, float]:
        """Attempt one transfer.  Returns ``(delivered, elapsed_s)`` and
        advances the clock; raises ``LinkDropped`` / ``LinkTimeout`` /
        ``LinkOutage`` on failure (clock advanced by the timeout either
        way -- a failed attempt is never free).  A *corrupted* delivery
        returns normally with a flipped byte: callers must checksum."""
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be positive, got {timeout_s}")
        self.sends += 1
        n = len(data)
        t0 = self.clock
        # Draw every category each send so the schedule is size-invariant
        # (a scaled uniform, not integers(0, n): bounded-int draws consume
        # a size-dependent amount of the stream via rejection sampling).
        u_drop, u_corrupt, u_delay, u_pos = self._rng.uniform(size=4)
        corrupt_at = min(int(u_pos * n), n - 1) if n else 0
        xfer = self.latency_s + n / self.bandwidth_at(t0)
        if u_delay < self.faults.delay_rate:
            xfer += self.faults.delay_s
        if self.outage_overlaps(t0, t0 + min(xfer, timeout_s)):
            self.outage_hits += 1
            self.bytes_lost += n
            self.clock = t0 + timeout_s
            raise LinkOutage(f"outage window at t={t0:.3f}s", timeout_s)
        if u_drop < self.faults.drop_rate:
            self.dropped += 1
            self.bytes_lost += n
            self.clock = t0 + timeout_s
            raise LinkDropped(f"payload dropped at t={t0:.3f}s", timeout_s)
        if xfer > timeout_s:
            self.timeouts += 1
            self.bytes_lost += n
            self.clock = t0 + timeout_s
            raise LinkTimeout(
                f"transfer needs {xfer:.3f}s > timeout {timeout_s:.3f}s",
                timeout_s)
        self.clock = t0 + xfer
        self.delivered += 1
        self.bytes_delivered += n
        if u_corrupt < self.faults.corrupt_rate and n:
            self.corrupted += 1
            out = bytearray(data)
            out[corrupt_at] ^= 0xFF
            return bytes(out), xfer
        return bytes(data), xfer

    def counters(self) -> dict[str, int | float]:
        return {"sends": self.sends, "delivered": self.delivered,
                "dropped": self.dropped, "timeouts": self.timeouts,
                "outage_hits": self.outage_hits,
                "corrupted": self.corrupted,
                "bytes_delivered": self.bytes_delivered,
                "bytes_lost": self.bytes_lost, "clock_s": self.clock}


def _env_float(name: str, default: float) -> float:
    raw = os.environ.get(ENV_PREFIX + name)
    return default if raw is None else float(raw)


def parse_outages(raw: str) -> tuple[tuple[float, float], ...]:
    """Parse ``"start:end[,start:end...]"`` (seconds) outage windows."""
    windows = []
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        start, _, end = part.partition(":")
        windows.append((float(start), float(end)))
    return tuple(windows)


def link_from_env(bandwidth: float, *, seed: int | None = None,
                  faults: FaultSpec | None = None) -> FaultyLink:
    """Build a ``FaultyLink`` from ``REPRO_LINK_*`` env knobs.

    REPRO_LINK_BW        bytes/s (default: the ``bandwidth`` argument,
                         normally the plan's nominal link)
    REPRO_LINK_LATENCY   fixed per-transfer latency, seconds (default 0)
    REPRO_LINK_DROP      drop probability per attempt      (default 0)
    REPRO_LINK_CORRUPT   corruption probability per attempt (default 0)
    REPRO_LINK_DELAY     delay-fault probability per attempt (default 0)
    REPRO_LINK_DELAY_S   extra seconds when a delay fires  (default 0.5)
    REPRO_LINK_OUTAGES   "start:end[,start:end]" virtual-time windows
    REPRO_LINK_SEED      fault-schedule seed (default 0)

    Explicit ``faults``/``seed`` arguments win over the environment."""
    if faults is None:
        faults = FaultSpec(
            drop_rate=_env_float("DROP", 0.0),
            corrupt_rate=_env_float("CORRUPT", 0.0),
            delay_rate=_env_float("DELAY", 0.0),
            delay_s=_env_float("DELAY_S", 0.5),
            outages=parse_outages(os.environ.get(ENV_PREFIX + "OUTAGES",
                                                 "")),
        )
    if seed is None:
        seed = int(_env_float("SEED", 0))
    return FaultyLink(_env_float("BW", bandwidth),
                      latency_s=_env_float("LATENCY", 0.0),
                      faults=faults, seed=seed)
