"""Seeded, deterministic faulty-TIER compute model.

``runtime/faults.py`` makes the *wire* unreliable; this module does the
same for the compute tiers themselves (phone NPU, edge box, core
server).  A ``FaultyTier`` sits between the chain runtime's schedule and
a tier's stage execution and can

* **crash** -- the stage dies.  Either probabilistically per execution
  (``crash_rate``) or deterministically inside configured virtual-time
  ``crash_windows`` (a tier that is down is down for *everyone* whose
  stage overlaps the window -- restarts are just the window ending).
* **straggle** -- the stage completes but takes ``slow_factor`` x its
  modelled compute time (probability ``slow_rate`` per execution).
  Stragglers are not failures: they never trip circuit breakers, they
  just stretch the pipeline schedule.
* **shed** -- memory-pressure admission control: a stage whose activation
  footprint exceeds the tier's *current* memory budget is rejected
  before it runs.  The budget is time-varying (``mem_profile``,
  piecewise-constant over virtual time) so "the edge box is busy between
  t=2 and t=5" is expressible without randomness.

Everything draws from one seeded generator in call order (one uniform
vector per execution, size-invariant), so a chaos schedule is
bit-reproducible from a seed and an execution sequence -- exactly the
contract ``FaultyLink`` established for links.

Env surface mirrors the link stack: ``REPRO_TIER_*`` knobs configure
every tier of a chain, ``REPRO_TIER{k}_*`` overrides one tier (k =
0-based tier id), and ``tier_faults_from_env`` builds the per-tier
models with tier k seeded from ``seed + k``.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.runtime.faults import VirtualClock, parse_outages

ENV_TIER_PREFIX = "REPRO_TIER_"


class TierError(RuntimeError):
    """One failed stage execution; ``elapsed_s`` is the virtual time the
    tier consumed before the failure surfaced."""

    def __init__(self, msg: str, elapsed_s: float):
        super().__init__(msg)
        self.elapsed_s = elapsed_s


class TierCrash(TierError):
    """The tier died mid-stage (random crash or crash window)."""


class TierShed(TierError):
    """Stage rejected: activation footprint exceeds the tier's current
    memory budget (admission control, never mid-flight)."""


@dataclasses.dataclass(frozen=True)
class TierFaultSpec:
    """Injectable tier-fault rates, crash windows, and memory pressure.

    crash_rate: per-execution crash probability.
    crash_windows: ``((start, end), ...)`` virtual-time windows during
      which every overlapping stage execution dies.
    slow_rate / slow_factor: straggler probability and the compute-time
      multiplier applied when one fires (factor 1 = no-op).
    mem_budget: admission budget in bytes (0 = unlimited) -- a stage
      whose activation footprint exceeds it is shed.
    mem_profile: piecewise-constant ``((start_s, budget_bytes), ...)``
      overriding ``mem_budget`` from each start time onward (0 entries
      mean unlimited from then on)."""

    crash_rate: float = 0.0
    crash_windows: tuple[tuple[float, float], ...] = ()
    slow_rate: float = 0.0
    slow_factor: float = 1.0
    mem_budget: float = 0.0
    mem_profile: tuple[tuple[float, float], ...] = ()

    def __post_init__(self):
        for field in ("crash_rate", "slow_rate"):
            v = getattr(self, field)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{field} must be in [0, 1], got {v}")
        if self.slow_factor < 1.0:
            raise ValueError(
                f"slow_factor must be >= 1, got {self.slow_factor}")
        if self.mem_budget < 0:
            raise ValueError(
                f"mem_budget must be >= 0, got {self.mem_budget}")
        for start, end in self.crash_windows:
            if end <= start:
                raise ValueError(
                    f"crash window ({start}, {end}) is empty")

    @property
    def fault_free(self) -> bool:
        return (self.crash_rate == 0.0 and not self.crash_windows
                and self.slow_rate == 0.0 and self.mem_budget == 0.0
                and not self.mem_profile)


class FaultyTier:
    """One tier's compute health model on the shared virtual clock.

    The runtime asks it to *vet and price* each stage execution:
    ``execute(t_start, compute_s, mem_bytes)`` returns the actual compute
    seconds (possibly stretched by a straggler fault) or raises
    ``TierCrash`` / ``TierShed``.  The tier never touches the clock --
    the caller owns scheduling (resource free-times, ``advance_to``) --
    so ``SplitRuntime`` can consult the same model without perturbing its
    link-only time accounting."""

    def __init__(self, name: str = "tier", *,
                 faults: TierFaultSpec = TierFaultSpec(), seed: int = 0,
                 clock: VirtualClock | None = None):
        self.name = name
        self.faults = faults
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self._clock = clock if clock is not None else VirtualClock()
        # counters (the chaos harness reads these)
        self.executions = 0
        self.completed = 0
        self.crashes = 0
        self.window_hits = 0
        self.sheds = 0
        self.slowdowns = 0
        self.compute_s = 0.0        # virtual compute seconds delivered

    def in_crash_window(self, t: float) -> bool:
        return any(start <= t < end
                   for start, end in self.faults.crash_windows)

    def crash_overlaps(self, t0: float, t1: float) -> bool:
        """True when [t0, t1) intersects any crash window: a stage in
        flight when the tier dies dies with it."""
        return any(start < t1 and t0 < end
                   for start, end in self.faults.crash_windows)

    def budget_at(self, t: float) -> float:
        """Effective admission budget (bytes) at virtual time ``t``;
        0 = unlimited."""
        budget = self.faults.mem_budget
        for start, b in sorted(self.faults.mem_profile):
            if t >= start:
                budget = b
        return budget

    def execute(self, t_start: float, compute_s: float,
                mem_bytes: float = 0.0) -> float:
        """Vet one stage execution starting at ``t_start`` that would
        take ``compute_s`` seconds and hold ``mem_bytes`` of activations.

        Returns the actual compute seconds (>= ``compute_s`` when a
        straggler fault fires); raises ``TierShed`` (before any time is
        spent) or ``TierCrash`` (``elapsed_s`` = the partial compute the
        crash wasted).  Draws every fault category each call so the
        schedule is invariant to payload sizes and outcomes."""
        if compute_s < 0:
            raise ValueError(f"compute_s must be >= 0, got {compute_s}")
        self.executions += 1
        t_start = float(t_start)
        u_crash, u_slow, u_frac = self._rng.uniform(size=3)
        budget = self.budget_at(t_start)
        if budget > 0 and mem_bytes > budget:
            self.sheds += 1
            raise TierShed(
                f"{self.name}: stage needs {mem_bytes:.0f}B > budget "
                f"{budget:.0f}B at t={t_start:.3f}s", 0.0)
        dt = float(compute_s)
        slowed = u_slow < self.faults.slow_rate \
            and self.faults.slow_factor > 1.0
        if slowed:
            dt *= self.faults.slow_factor
        if self.crash_overlaps(t_start, t_start + dt):
            self.window_hits += 1
            self.crashes += 1
            # the crash lands where the window first intersects the stage
            hit = min((max(start, t_start)
                       for start, end in self.faults.crash_windows
                       if start < t_start + dt and t_start < end),
                      default=t_start)
            raise TierCrash(
                f"{self.name}: crash window hit at t={hit:.3f}s",
                hit - t_start)
        if u_crash < self.faults.crash_rate:
            self.crashes += 1
            wasted = u_frac * dt
            raise TierCrash(
                f"{self.name}: crashed {wasted:.3f}s into a "
                f"{dt:.3f}s stage at t={t_start:.3f}s", wasted)
        if slowed:
            self.slowdowns += 1
        self.completed += 1
        self.compute_s += dt
        return dt

    def counters(self) -> dict[str, int | float]:
        return {"executions": self.executions, "completed": self.completed,
                "crashes": self.crashes, "window_hits": self.window_hits,
                "sheds": self.sheds, "slowdowns": self.slowdowns,
                "compute_s": self.compute_s}


def _tier_env_raw(name: str, tier: int | None = None) -> str | None:
    """Env lookup with per-tier override: ``REPRO_TIER{tier}_X`` wins
    over the chain-wide ``REPRO_TIER_X``."""
    if tier is not None:
        raw = os.environ.get(f"REPRO_TIER{tier}_{name}")
        if raw is not None:
            return raw
    return os.environ.get(ENV_TIER_PREFIX + name)


def _tier_env_float(name: str, default: float,
                    tier: int | None = None) -> float:
    raw = _tier_env_raw(name, tier)
    return default if raw is None else float(raw)


def parse_mem_profile(raw: str) -> tuple[tuple[float, float], ...]:
    """Parse ``"start:budget[,start:budget...]"`` (seconds : bytes)."""
    return parse_outages(raw)


def tier_from_env(name: str, *, tier: int | None = None,
                  seed: int | None = None,
                  faults: TierFaultSpec | None = None,
                  clock: VirtualClock | None = None) -> FaultyTier:
    """Build a ``FaultyTier`` from ``REPRO_TIER_*`` env knobs.

    REPRO_TIER_CRASH          crash probability per stage      (default 0)
    REPRO_TIER_CRASH_WINDOWS  "start:end[,start:end]" dead windows
    REPRO_TIER_SLOW           straggler probability per stage  (default 0)
    REPRO_TIER_SLOW_FACTOR    compute multiplier when one fires (default 4)
    REPRO_TIER_MEM_BUDGET     admission budget, bytes (0 = unlimited)
    REPRO_TIER_MEM_PROFILE    "start:budget[,...]" time-varying budget
    REPRO_TIER_SEED           fault-schedule seed (default 0)

    With ``tier`` given, ``REPRO_TIER{tier}_X`` (e.g.
    ``REPRO_TIER1_CRASH_WINDOWS``) overrides the chain-wide knob for that
    tier only -- how the chaos harness kills one specific box.  Explicit
    ``faults``/``seed`` arguments win over the environment."""
    if faults is None:
        faults = TierFaultSpec(
            crash_rate=_tier_env_float("CRASH", 0.0, tier),
            crash_windows=parse_outages(
                _tier_env_raw("CRASH_WINDOWS", tier) or ""),
            slow_rate=_tier_env_float("SLOW", 0.0, tier),
            slow_factor=_tier_env_float("SLOW_FACTOR", 4.0, tier),
            mem_budget=_tier_env_float("MEM_BUDGET", 0.0, tier),
            mem_profile=parse_mem_profile(
                _tier_env_raw("MEM_PROFILE", tier) or ""),
        )
    if seed is None:
        seed = int(_tier_env_float("SEED", 0, tier))
    return FaultyTier(name, faults=faults, seed=seed, clock=clock)


def tier_faults_from_env(names, *, seed: int | None = None,
                         clock: VirtualClock | None = None
                         ) -> list[FaultyTier]:
    """One env-configured ``FaultyTier`` per chain tier, shared clock.

    names: per-tier display names (e.g. the chain's tier names).
    seed: base fault-schedule seed; tier k draws from ``seed + k`` so
      the tiers' fault streams are independent (``REPRO_TIER{k}_SEED``
      overrides per tier, ``REPRO_TIER_SEED`` overrides the base)."""
    clock = clock if clock is not None else VirtualClock()
    tiers = []
    for k, name in enumerate(names):
        if os.environ.get(f"REPRO_TIER{k}_SEED") is not None:
            tier_seed = None     # per-tier env knob wins verbatim
        else:
            env_base = os.environ.get(ENV_TIER_PREFIX + "SEED")
            base = int(env_base) if env_base is not None else \
                (int(seed) if seed is not None else 0)
            tier_seed = base + k
        tiers.append(tier_from_env(name, tier=k, seed=tier_seed,
                                   clock=clock))
    return tiers
