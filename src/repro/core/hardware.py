"""Hardware profiles for the SmartSplit cost models.

Two families of profiles live behind one abstraction:

* paper-faithful smartphone/cloud profiles (Samsung J6, Redmi Note 8,
  the paper's Windows i5 server, 10 Mbps Wi-Fi) with the paper's energy
  constants (k = 1.172, Huang et al. radio model), used to reproduce
  Tables I/II and Figures 6-10;
* TPU pod-tier profiles (v5e edge pod / cloud pod, inter-pod DCN link),
  used by the beyond-paper two-tier TPU partitioner.

Energy constants for the TPU tier are documented estimates (per-chip wall
power at peak divided by peak throughput; HBM/ICI energy from published
pJ/bit figures) -- they parameterise the f2 objective, and every benchmark
records which profile produced its numbers.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# TPU v5e roofline constants (the assignment's hardware targets).
# ---------------------------------------------------------------------------
V5E_PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
V5E_HBM_BW = 819e9                  # bytes/s per chip
V5E_HBM_BYTES = 16 * 1024**3        # 16 GiB HBM per chip
ICI_LINK_BW = 50e9                  # bytes/s per link (assignment constant)
DCN_POD_BW = 25e9                   # bytes/s inter-pod (DCN, conservative)

# TPU energy model (documented estimates, see module docstring):
#   ~200 W chip at peak compute -> 200/197e12 ~ 1.0 pJ/FLOP.
#   HBM2e access energy ~ 3.5 pJ/bit -> ~28 pJ/byte; we use 15 pJ/byte to
#   reflect on-chip reuse (not every HLO byte is a DRAM transaction).
#   ICI serdes ~ 10 pJ/byte; DCN (optical + NIC) ~ 40 pJ/byte.
TPU_PJ_PER_FLOP = 1.0
TPU_PJ_PER_HBM_BYTE = 15.0
TPU_PJ_PER_ICI_BYTE = 10.0
TPU_PJ_PER_DCN_BYTE = 40.0


@dataclasses.dataclass(frozen=True)
class DeviceTier:
    """One side of the split (paper: smartphone or cloud server).

    The paper's compute model is latency = M|l / (cores * speed): a
    memory-as-work proxy over cores x clock.  ``compute_scale`` is the
    (cores * speed) denominator in *bytes per second* equivalents for the
    paper profile; the TPU profile instead fills peak_flops/hbm_bw and the
    cost model uses a per-layer roofline (see core/costs.py).
    """

    name: str
    cores: int
    speed_hz: float                 # per-core clock (paper model)
    memory_budget: float            # bytes available to the app (constraint M)
    # Roofline terms (TPU tiers; 0 => use the paper cores*speed model).
    chips: int = 0
    peak_flops: float = 0.0
    hbm_bw: float = 0.0
    # Energy model. Paper client: P = k * cores * nu^3 (nu in GHz, P in W).
    energy_k: float = 0.0
    # TPU tier energy.
    pj_per_flop: float = 0.0
    pj_per_hbm_byte: float = 0.0

    @property
    def compute_scale(self) -> float:
        """cores * speed -- denominator of Eq. 2/3 (paper model)."""
        return self.cores * self.speed_hz

    @property
    def is_roofline(self) -> bool:
        return self.peak_flops > 0.0

    def compute_power_w(self) -> float:
        """Paper Eq. 6: P_client = k * C * nu^3 with nu in GHz."""
        nu_ghz = self.speed_hz / 1e9
        return self.energy_k * self.cores * nu_ghz**3


@dataclasses.dataclass(frozen=True)
class LinkProfile:
    """The client->server transport (paper: Wi-Fi; TPU: ICI/DCN)."""

    name: str
    bandwidth: float                # bytes/s (paper B, converted from Mbps)
    # Paper radio power model (Huang et al.): P = alpha * tau + beta, with
    # tau the throughput in Mbps and P in mW.
    alpha_up_mw_per_mbps: float = 0.0
    alpha_down_mw_per_mbps: float = 0.0
    beta_mw: float = 0.0
    # TPU link energy.
    pj_per_byte: float = 0.0

    def upload_power_w(self, throughput_bytes_s: float) -> float:
        mbps = throughput_bytes_s * 8 / 1e6
        return (self.alpha_up_mw_per_mbps * mbps + self.beta_mw) / 1e3

    def download_power_w(self, throughput_bytes_s: float) -> float:
        mbps = throughput_bytes_s * 8 / 1e6
        return (self.alpha_down_mw_per_mbps * mbps + self.beta_mw) / 1e3


@dataclasses.dataclass(frozen=True)
class TwoTierHardware:
    """Full client/link/server environment the optimiser plans against."""

    client: DeviceTier
    server: DeviceTier
    link: LinkProfile
    download_bytes: float = 4096.0  # result payload d (paper Eq. 11)

    def with_link_bandwidth(self, bandwidth: float) -> "TwoTierHardware":
        """The same environment under a different link bandwidth (bytes/s)
        -- the runtime re-pick path re-evaluates the cached Pareto front
        against this instead of mutating the planning profile."""
        if bandwidth <= 0:
            raise ValueError(f"bandwidth must be positive, got {bandwidth}")
        link = dataclasses.replace(self.link, bandwidth=float(bandwidth))
        return dataclasses.replace(self, link=link)


@dataclasses.dataclass(frozen=True)
class ChainHardware:
    """K tiers connected by K-1 links -- the N-tier deployment shape
    (device -> edge -> regional -> core).  ``TwoTierHardware`` is the
    K=2 degenerate instance (see ``chain_of``); the chain planner
    (``core.multicut.smartsplit_chain``) and the chain runtime
    (``runtime.ChainRuntime``) both consume this."""

    tiers: tuple[DeviceTier, ...]
    links: tuple[LinkProfile, ...]
    download_bytes: float = 4096.0  # result payload d (paper Eq. 11)

    def __post_init__(self):
        if len(self.tiers) < 2:
            raise ValueError(
                f"ChainHardware needs >= 2 tiers, got {len(self.tiers)}")
        if len(self.links) != len(self.tiers) - 1:
            raise ValueError(
                f"ChainHardware tier/link mismatch: {len(self.tiers)} "
                f"tiers need {len(self.tiers) - 1} links, got "
                f"{len(self.links)}")

    @property
    def num_tiers(self) -> int:
        return len(self.tiers)

    def with_link_bandwidths(
            self, bandwidths: "tuple[float | None, ...]"
    ) -> "ChainHardware":
        """The same chain under per-hop effective bandwidths (bytes/s);
        ``None`` entries keep that hop's nominal bandwidth.  The runtime
        re-pick path evaluates the cached Pareto front against this."""
        if len(bandwidths) != len(self.links):
            raise ValueError(
                f"need {len(self.links)} per-hop bandwidths, got "
                f"{len(bandwidths)}")
        links = []
        for link, bw in zip(self.links, bandwidths):
            if bw is None:
                links.append(link)
                continue
            if bw <= 0:
                raise ValueError(
                    f"bandwidth must be positive, got {bw} for {link.name}")
            links.append(dataclasses.replace(link, bandwidth=float(bw)))
        return dataclasses.replace(self, links=tuple(links))


def chain_of(hw: TwoTierHardware) -> ChainHardware:
    """The K=2 chain view of a two-tier environment (same tiers, same
    link, same download payload) -- the paper case as a degenerate
    chain instead of a separate code path."""
    return ChainHardware(tiers=(hw.client, hw.server), links=(hw.link,),
                         download_bytes=hw.download_bytes)


@dataclasses.dataclass
class NetworkState:
    """Mutable runtime view of a link (deliberately NOT frozen).

    Every planning-side profile above is immutable; what *changes* at run
    time is the network.  ``NetworkState`` carries the current effective
    bandwidth estimate (fed by the runtime's EWMA link estimator) next to
    the nominal ``LinkProfile`` the plan assumed, so degradation is always
    a ratio against the planning assumption."""

    base: LinkProfile
    effective_bandwidth: float = 0.0   # bytes/s; 0 -> base.bandwidth
    outage: bool = False               # link currently unusable

    def __post_init__(self):
        if self.effective_bandwidth <= 0.0:
            self.effective_bandwidth = self.base.bandwidth

    @property
    def degradation(self) -> float:
        """planned/effective bandwidth: 1 = nominal, >1 = degraded --
        exactly the ratio ``topsis.link_weights`` consumes."""
        return self.base.bandwidth / self.effective_bandwidth

    def update(self, bandwidth: float, outage: bool = False) -> None:
        if bandwidth > 0:
            self.effective_bandwidth = float(bandwidth)
        self.outage = outage

    def effective_link(self) -> LinkProfile:
        """The nominal profile rebased on the current estimate."""
        return dataclasses.replace(self.base,
                                   bandwidth=self.effective_bandwidth)


# ---------------------------------------------------------------------------
# Paper-faithful profiles (Section III / VI of the paper).
# ---------------------------------------------------------------------------
# Huang et al. LTE/Wi-Fi radio constants quoted by the paper.
ALPHA_U = 283.17    # mW / Mbps
ALPHA_D = 137.01    # mW / Mbps
BETA = 132.86       # mW
PAPER_K = 1.172     # fitted client power constant (paper Section III-C1)

SAMSUNG_J6 = DeviceTier(
    name="samsung-galaxy-j6",
    cores=8, speed_hz=1.6e9,              # Exynos 7870, octa 1.6 GHz
    memory_budget=4 * 1024**3,            # 4 GB RAM
    energy_k=PAPER_K,
)
REDMI_NOTE8 = DeviceTier(
    name="redmi-note-8",
    cores=8, speed_hz=2.0e9,              # SDM665: 4x2.0 + 4x1.8; use 2.0
    memory_budget=4 * 1024**3,
    energy_k=PAPER_K,
)
PAPER_CLOUD = DeviceTier(
    name="paper-cloud-i5",
    cores=4, speed_hz=1.6e9,              # 1.6 GHz quad i5, 8 GB RAM
    memory_budget=8 * 1024**3,
    energy_k=0.0,                         # server energy not billed (Eq. 13)
)
WIFI_10MBPS = LinkProfile(
    name="wifi-10mbps",
    bandwidth=10e6 / 8,                   # 10 Mbps -> bytes/s
    alpha_up_mw_per_mbps=ALPHA_U,
    alpha_down_mw_per_mbps=ALPHA_D,
    beta_mw=BETA,
)

PAPER_ENV_J6 = TwoTierHardware(client=SAMSUNG_J6, server=PAPER_CLOUD,
                               link=WIFI_10MBPS)
PAPER_ENV_NOTE8 = TwoTierHardware(client=REDMI_NOTE8, server=PAPER_CLOUD,
                                  link=WIFI_10MBPS)


# ---------------------------------------------------------------------------
# TPU pod tiers (beyond-paper adaptation).
# ---------------------------------------------------------------------------
def tpu_pod_tier(name: str, chips: int,
                 peak_flops: float = V5E_PEAK_FLOPS_BF16,
                 hbm_bw: float = V5E_HBM_BW,
                 hbm_bytes: float = V5E_HBM_BYTES) -> DeviceTier:
    return DeviceTier(
        name=name, cores=chips, speed_hz=0.0,
        memory_budget=chips * hbm_bytes,
        chips=chips, peak_flops=chips * peak_flops, hbm_bw=chips * hbm_bw,
        pj_per_flop=TPU_PJ_PER_FLOP, pj_per_hbm_byte=TPU_PJ_PER_HBM_BYTE,
    )


DCN_LINK = LinkProfile(name="inter-pod-dcn", bandwidth=DCN_POD_BW,
                       pj_per_byte=TPU_PJ_PER_DCN_BYTE)
ICI_LINK = LinkProfile(name="ici", bandwidth=ICI_LINK_BW,
                       pj_per_byte=TPU_PJ_PER_ICI_BYTE)

# Default production two-tier environment: a small "edge" pod slice fronting
# a big "cloud" pod, connected by DCN -- the TPU analogue of phone+server.
TPU_EDGE_CLOUD = TwoTierHardware(
    client=tpu_pod_tier("v5e-edge-16", chips=16),
    server=tpu_pod_tier("v5e-cloud-256", chips=256),
    link=DCN_LINK,
)
# Symmetric 2-pod environment matching the (2, 16, 16) production mesh.
TPU_TWO_POD = TwoTierHardware(
    client=tpu_pod_tier("v5e-pod0-256", chips=256),
    server=tpu_pod_tier("v5e-pod1-256", chips=256),
    link=DCN_LINK,
)

PROFILES = {
    "paper-j6": PAPER_ENV_J6,
    "paper-note8": PAPER_ENV_NOTE8,
    "tpu-edge-cloud": TPU_EDGE_CLOUD,
    "tpu-two-pod": TPU_TWO_POD,
}


# ---------------------------------------------------------------------------
# N-tier chain profiles (device -> edge -> regional -> core).
# ---------------------------------------------------------------------------
# Intermediate tiers reuse the paper's cores*speed compute model with
# grid-powered servers (energy_k = 0: only the device's battery is billed,
# matching the paper's Eq. 13 server exemption).
PAPER_EDGE = DeviceTier(
    name="paper-edge-server",
    cores=8, speed_hz=2.5e9,
    memory_budget=16 * 1024**3,
    energy_k=0.0,
)
PAPER_REGIONAL = DeviceTier(
    name="paper-regional-dc",
    cores=16, speed_hz=3.0e9,
    memory_budget=32 * 1024**3,
    energy_k=0.0,
)
PAPER_CORE = DeviceTier(
    name="paper-core-dc",
    cores=32, speed_hz=3.0e9,
    memory_budget=64 * 1024**3,
    energy_k=0.0,
)
# Wired backhaul links: no radio power model (the device's Wi-Fi hop is
# the only one drawing battery), bandwidth rises toward the core.
ETH_100MBPS = LinkProfile(name="ethernet-100mbps", bandwidth=100e6 / 8)
ETH_1GBPS = LinkProfile(name="ethernet-1gbps", bandwidth=1e9 / 8)


GALAXY_S21 = DeviceTier(
    name="samsung-galaxy-s21",
    cores=8, speed_hz=2.9e9,              # Exynos 2100: 1x2.9 prime core
    memory_budget=8 * 1024**3,
    energy_k=PAPER_K,
)

# Device-tier registry: the phone classes a deployment plans for
# (flagship / mid-range / low-end) -- ``serve.py`` and the failover
# tests key tiers by these names.
DEVICE_TIERS: dict[str, DeviceTier] = {
    "flagship": GALAXY_S21,
    "mid": REDMI_NOTE8,
    "low": SAMSUNG_J6,
}

# ---------------------------------------------------------------------------
# Standby tiers (tier-failover targets).
# ---------------------------------------------------------------------------
# Each serving-side tier has a warm standby with *slightly different*
# specs (the spare box in the next rack is rarely identical), so a
# failed-over chain has a genuinely different Pareto front -- which is
# why ``core.smartsplit.cached_chain_plan`` memoises fronts per chain
# and the runtime prewarms the standby fronts at construction.  Phones
# have no standby: the device tier is the user's hand.
PAPER_EDGE_STANDBY = DeviceTier(
    name="paper-edge-standby",
    cores=6, speed_hz=2.2e9,
    memory_budget=12 * 1024**3,
    energy_k=0.0,
)
PAPER_REGIONAL_STANDBY = DeviceTier(
    name="paper-regional-standby",
    cores=12, speed_hz=2.8e9,
    memory_budget=24 * 1024**3,
    energy_k=0.0,
)
PAPER_CORE_STANDBY = DeviceTier(
    name="paper-core-standby",
    cores=24, speed_hz=3.2e9,
    memory_budget=48 * 1024**3,
    energy_k=0.0,
)
PAPER_CLOUD_STANDBY = DeviceTier(
    name="paper-cloud-standby",
    cores=4, speed_hz=2.0e9,
    memory_budget=8 * 1024**3,
    energy_k=0.0,
)

STANDBY_TIERS: dict[str, DeviceTier] = {
    PAPER_EDGE.name: PAPER_EDGE_STANDBY,
    PAPER_REGIONAL.name: PAPER_REGIONAL_STANDBY,
    PAPER_CORE.name: PAPER_CORE_STANDBY,
    PAPER_CLOUD.name: PAPER_CLOUD_STANDBY,
}


def standby_for(tier: DeviceTier) -> DeviceTier | None:
    """The warm standby for ``tier``, or None (device tiers, standbys
    themselves, and anything unregistered have no failover target)."""
    return STANDBY_TIERS.get(tier.name)


def standby_chain(hw: ChainHardware, tier_idx: int) -> ChainHardware | None:
    """``hw`` with tier ``tier_idx`` replaced by its standby (same links,
    same download payload), or None when that tier has no standby."""
    spare = standby_for(hw.tiers[tier_idx])
    if spare is None:
        return None
    tiers = list(hw.tiers)
    tiers[tier_idx] = spare
    return dataclasses.replace(hw, tiers=tuple(tiers))


def paper_chain(num_tiers: int) -> ChainHardware:
    """The paper smartphone fronting a K-tier serving chain.

    K=2 is exactly ``chain_of(PAPER_ENV_J6)``; K=3 adds an edge server
    behind the Wi-Fi hop; K=4 inserts a regional DC between edge and
    core (the arxiv 2509.06049 device/edge/core topology)."""
    if num_tiers == 2:
        return chain_of(PAPER_ENV_J6)
    if num_tiers == 3:
        return ChainHardware(tiers=(SAMSUNG_J6, PAPER_EDGE, PAPER_CORE),
                             links=(WIFI_10MBPS, ETH_100MBPS))
    if num_tiers == 4:
        return ChainHardware(
            tiers=(SAMSUNG_J6, PAPER_EDGE, PAPER_REGIONAL, PAPER_CORE),
            links=(WIFI_10MBPS, ETH_100MBPS, ETH_1GBPS))
    raise ValueError(f"paper_chain supports 2-4 tiers, got {num_tiers}")
