"""Beyond-paper: K-cut SmartSplit over a CHAIN of tiers.

The paper splits once between two tiers.  Real fleets have more stages
(device -> edge accelerator -> regional pod -> core pod); the natural
generalisation is a genome of K-1 ordered cut points over a chain of K
tiers -- exactly the multi-gene integer case the NSGA-II implementation
was built for, where exhaustive enumeration is C(L-1, K-1) and stops being
free (K=4, L=80: ~80k points; K=6: ~24M).

Two evaluators live here:

* ``evaluate_multicut`` -- the original beyond-paper chain evaluator
  (bills every tier, normalised peak memory as f3).  Kept verbatim for
  M=1 so its pinned tests stay bit-stable; gains a ``microbatches``
  pipeline term.
* ``smartsplit_chain`` -- the unified planner over
  ``costs.evaluate_chain_objectives`` (paper-faithful objective
  semantics: download excluded from f1, terminal tier exempt from f2,
  first-tier memory as f3).  At K=2 it reproduces ``smartsplit()``
  bit-for-bit; this is what the chain runtime executes and re-picks
  against (``repick_chain``).

Both planners return the unified ``ChainPlan`` (``MultiCutPlan`` is an
alias of it).
"""
from __future__ import annotations

import dataclasses
import itertools
import math

import numpy as np

from repro.core.chainplan import ChainPlan
from repro.core.chainplan import MultiCutPlan as MultiCutPlan  # noqa: F401
from repro.core.costs import (FRAME_HEADER_BYTES, ModelProfile,
                              _codec_passes, _codec_time,
                              chain_feasible_mask,
                              evaluate_chain_objectives, pipeline_latency,
                              resolve_chain_wire)
from repro.core.hardware import ChainHardware as ChainHardware  # noqa: F401
from repro.core.hardware import TwoTierHardware, chain_of
from repro.core.nsga2 import NSGA2Config, nsga2
from repro.core.pareto import exhaustive_pareto
from repro.core.topsis import chain_link_weights, topsis_select

_PENALTY = 1e30

# Above this many exhaustive candidates, smartsplit_chain switches from
# enumeration (provably exact front) to NSGA-II.
_EXHAUSTIVE_LIMIT = 50_000


def _stage_tables(profile: ModelProfile, hw: ChainHardware):
    """Cumulative per-layer tables used by the vectorised evaluator."""
    flops = np.concatenate([[0.0], np.cumsum(
        [l.flops for l in profile.layers])])
    mem = profile.cum_mem()
    bound = profile.boundary()
    return flops, mem, bound


def evaluate_multicut(profile: ModelProfile, hw: ChainHardware,
                      genomes: np.ndarray,
                      microbatches: int = 1, wire=None) -> np.ndarray:
    """genomes: (n, K-1) cut points (unsorted ok; sorted internally).
    Returns (n, 3) objectives with constraint penalties applied.

    ``microbatches`` > 1 replaces the sequential latency sum with the
    pipelined fill-and-drain term (``costs.pipeline_latency``) and adds
    the per-hop framing energy the M-way split costs; M=1 keeps the
    historical numbers bit-for-bit.  ``wire`` prices each hop's bytes in
    its wire format (plus the codec passes on adjacent tiers); the
    default ``follow`` resolution keeps the storage bytes unchanged."""
    L = profile.num_layers
    K = len(hw.tiers)
    flops, mem, bound = _stage_tables(profile, hw)
    ws = resolve_chain_wire(wire, len(hw.links), profile.dtype)
    cuts = np.sort(np.asarray(genomes, np.int64), axis=1)
    n = cuts.shape[0]
    edges = np.concatenate([np.zeros((n, 1), np.int64), cuts,
                            np.full((n, 1), L, np.int64)], axis=1)
    lat = np.zeros(n)
    en = np.zeros(n)
    peak = np.zeros(n)
    stage_T = np.zeros((n, K))
    hop_T = np.zeros((n, K - 1))
    for k, tier in enumerate(hw.tiers):
        f_k = flops[edges[:, k + 1]] - flops[edges[:, k]]
        m_k = mem[edges[:, k + 1]] - mem[edges[:, k]]
        if tier.is_roofline:
            t_k = np.maximum(f_k / tier.peak_flops, m_k / tier.hbm_bw)
            e_k = (f_k * tier.pj_per_flop
                   + m_k * tier.pj_per_hbm_byte) * 1e-12
        else:
            t_k = m_k / tier.compute_scale
            e_k = tier.compute_power_w() * t_k
        lat += t_k
        en += e_k
        peak = np.maximum(peak, m_k / tier.memory_budget)
        stage_T[:, k] = t_k
    for k, link in enumerate(hw.links):
        b_k = profile.wire_boundary(ws[k])[edges[:, k + 1]]
        t_l = b_k / link.bandwidth
        lat += t_l
        hop_T[:, k] = t_l
        if link.pj_per_byte:
            en += b_k * link.pj_per_byte * 1e-12
        else:
            en += link.upload_power_w(link.bandwidth) * t_l
        enc_p, dec_p = _codec_passes(ws[k], profile.dtype)
        if enc_p:
            b_raw = bound[edges[:, k + 1]]
            for t_i, passes in ((k, enc_p), (k + 1, dec_p)):
                tier = hw.tiers[t_i]
                t_c = _codec_time(tier, passes * b_raw)
                lat += t_c
                stage_T[:, t_i] += t_c
                if tier.is_roofline:
                    en += passes * b_raw * tier.pj_per_hbm_byte * 1e-12
                else:
                    en += tier.compute_power_w() * t_c
    if microbatches > 1:
        bws = np.array([link.bandwidth for link in hw.links])
        lat = pipeline_latency(stage_T, hop_T, microbatches,
                               link_bandwidths=bws)
        extra = (microbatches - 1) * FRAME_HEADER_BYTES
        for link in hw.links:
            if link.pj_per_byte:
                en += extra * link.pj_per_byte * 1e-12
            else:
                en += link.upload_power_w(link.bandwidth) \
                    * (extra / link.bandwidth)
    F = np.stack([lat, en, peak], axis=1)
    # constraints: non-empty stages, memory budgets
    widths = np.diff(edges, axis=1)
    bad = (widths < 1).any(axis=1) | (peak > 1.0)
    F[bad] += _PENALTY
    return F


def _chain_plan(profile: ModelProfile, hw: ChainHardware,
                cuts: tuple[int, ...], F_pick: np.ndarray,
                pareto_cuts: np.ndarray, pareto_F: np.ndarray,
                microbatches: int = 1,
                wire_dtypes: tuple[str, ...] = ()) -> ChainPlan:
    return ChainPlan(model=profile.name, num_layers=profile.num_layers,
                     cuts=cuts,
                     objectives=tuple(float(v) for v in F_pick),
                     pareto_cuts=np.asarray(pareto_cuts, np.int64),
                     pareto_F=np.asarray(pareto_F, float),
                     links=tuple(hw.links),
                     tiers=tuple(t.name for t in hw.tiers),
                     microbatches=microbatches,
                     wire_dtypes=wire_dtypes)


def smartsplit_multicut(profile: ModelProfile, hw: ChainHardware,
                        config: NSGA2Config | None = None,
                        microbatches: int = 1, wire=None) -> ChainPlan:
    """Algorithm 1 with the K-cut genome (original chain evaluator)."""
    L = profile.num_layers
    K = len(hw.tiers)
    ws = resolve_chain_wire(wire, len(hw.links), profile.dtype)
    config = config or NSGA2Config(pop_size=128, generations=80, seed=0)
    lower = np.ones(K - 1, np.int64)
    upper = np.full(K - 1, L - 1, np.int64)
    res = nsga2(lambda g: evaluate_multicut(profile, hw, g, microbatches,
                                            ws),
                lower, upper, config)
    F = evaluate_multicut(profile, hw, res.pareto_genomes, microbatches,
                          ws)
    feas = F[:, 0] < _PENALTY / 2
    pick = topsis_select(F, feasible=feas)
    cuts = tuple(int(c) for c in np.sort(res.pareto_genomes[pick]))
    return _chain_plan(profile, hw, cuts, F[pick],
                       np.sort(res.pareto_genomes, axis=1), F,
                       microbatches, ws)


def _chain_candidates(L: int, K: int) -> np.ndarray:
    """All strictly-increasing K-1 cut vectors in [1, L-1] -- (n, K-1)."""
    return np.array(list(itertools.combinations(range(1, L), K - 1)),
                    np.int64).reshape(-1, K - 1)


def smartsplit_chain(profile: ModelProfile,
                     hw: ChainHardware | TwoTierHardware, *,
                     microbatches: int = 1,
                     config: NSGA2Config | None = None,
                     weights: np.ndarray | None = None,
                     use_anti_ideal: bool = False,
                     f3_mode: str = "full",
                     wire=None) -> ChainPlan:
    """Algorithm 1 over a K-tier chain with paper-faithful objectives.

    The unified planner: pass a ``TwoTierHardware`` (wrapped via
    ``chain_of``) and the result is identical to ``smartsplit()`` /
    ``smartsplit_exhaustive()`` -- same objective rows, same Pareto
    front, same TOPSIS pick -- because ``evaluate_chain_objectives``
    degenerates bit-exactly at K=2, M=1.  For larger K the cut-vector
    space is enumerated while C(L-1, K-1) stays small and handed to
    NSGA-II beyond that."""
    if isinstance(hw, TwoTierHardware):
        hw = chain_of(hw)
    L = profile.num_layers
    K = hw.num_tiers
    if K - 1 > L - 1:
        raise ValueError(
            f"smartsplit_chain: {K} tiers need >= {K} layers, "
            f"model {profile.name} has {L}")
    ws = resolve_chain_wire(wire, len(hw.links), profile.dtype)
    n_combos = math.comb(L - 1, K - 1)
    if n_combos <= _EXHAUSTIVE_LIMIT:
        genomes = _chain_candidates(L, K)
        F = evaluate_chain_objectives(profile, hw, genomes, f3_mode,
                                      microbatches, ws)
        feas = chain_feasible_mask(profile, hw, genomes)
        Fp = F.copy()
        Fp[~feas] += _PENALTY
        front = exhaustive_pareto(Fp)
        pareto_cuts = genomes[front]
        pareto_F = F[front]
        feas_front = feas[front]
    else:
        config = config or NSGA2Config(pop_size=128, generations=80,
                                       seed=0)
        lower = np.ones(K - 1, np.int64)
        upper = np.full(K - 1, L - 1, np.int64)

        def evaluate(g: np.ndarray) -> np.ndarray:
            F = evaluate_chain_objectives(profile, hw, g, f3_mode,
                                          microbatches, ws)
            F[~chain_feasible_mask(profile, hw, g)] += _PENALTY
            return F

        res = nsga2(evaluate, lower, upper, config)
        pareto_cuts = np.sort(res.pareto_genomes, axis=1)
        pareto_F = evaluate_chain_objectives(profile, hw, pareto_cuts,
                                             f3_mode, microbatches, ws)
        feas_front = chain_feasible_mask(profile, hw, pareto_cuts)
    pick = topsis_select(pareto_F, feasible=feas_front, weights=weights,
                         use_anti_ideal=use_anti_ideal)
    cuts = tuple(int(c) for c in pareto_cuts[pick])
    return _chain_plan(profile, hw, cuts, pareto_F[pick], pareto_cuts,
                       pareto_F, microbatches, ws)


def repick_chain(plan: ChainPlan, profile: ModelProfile,
                 hw: ChainHardware | TwoTierHardware, *,
                 bandwidths=None,
                 exclude: tuple[tuple[int, ...], ...] = (),
                 weights: np.ndarray | None = None,
                 f3_mode: str = "full") -> ChainPlan:
    """TOPSIS re-pick over a chain plan's cached Pareto front.

    The K-tier generalisation of ``smartsplit.repick_split``: the front
    (``plan.pareto_cuts``) never gets re-enumerated; the objective rows
    are re-priced under the current per-hop bandwidth estimates and the
    selection re-runs with per-hop degradation re-weighting
    (``topsis.chain_link_weights`` -- driven by the worst hop's
    planned/current ratio).

    bandwidths: per-hop current bytes/s; ``None`` entries keep that
      hop's planning bandwidth.  ``None`` overall keeps every hop.
    exclude: cut vectors already tried and failed for this inference.

    Raises ValueError when no feasible non-excluded front member remains
    (the caller merges a stage or surfaces the outage)."""
    if isinstance(hw, TwoTierHardware):
        hw = chain_of(hw)
    ratios = [1.0] * len(hw.links)
    if bandwidths is not None:
        for k, b in enumerate(bandwidths):
            if b is not None:
                ratios[k] = hw.links[k].bandwidth / float(b)
        hw = hw.with_link_bandwidths(bandwidths)
    cand = np.asarray(plan.pareto_cuts, np.int64)
    if cand.size == 0:
        raise ValueError("repick_chain: plan carries no cached front")
    wire = plan.wire_dtypes or None
    F = evaluate_chain_objectives(profile, hw, cand, f3_mode,
                                  plan.microbatches, wire)
    feas = chain_feasible_mask(profile, hw, cand)
    if exclude:
        tried = {tuple(int(c) for c in cuts) for cuts in exclude}
        feas &= np.array([tuple(int(c) for c in row) not in tried
                          for row in cand])
    if weights is None and any(r != 1.0 for r in ratios):
        weights = chain_link_weights(ratios)
    pick = topsis_select(F, feasible=feas, weights=weights)
    cuts = tuple(int(c) for c in cand[pick])
    return dataclasses.replace(
        plan, cuts=cuts,
        objectives=tuple(float(v) for v in F[pick]),
        pareto_F=F,
        links=tuple(hw.links),
        tiers=tuple(t.name for t in hw.tiers))
