"""Beyond-paper: K-cut SmartSplit over a CHAIN of tiers.

The paper splits once between two tiers.  Real fleets have more stages
(edge accelerator -> edge pod -> regional pod -> core pod); the natural
generalisation is a genome of K-1 ordered cut points over a chain of K
tiers -- exactly the multi-gene integer case the NSGA-II implementation
was built for, where exhaustive enumeration is C(L-1, K-1) and stops being
free (K=4, L=80: ~80k points; K=6: ~24M).

Objectives (same structure as the paper's F):
  f1 latency = sum_k stage_compute_k + sum_k boundary_k / link_bw_k
  f2 energy  = per-tier compute energy + per-link transfer energy
  f3 memory  = max over tiers of tier-memory / tier-budget (normalised
               peak pressure -- the multi-tier analogue of M_client)
Constraints: each stage non-empty; every tier within its memory budget.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costs import ModelProfile
from repro.core.hardware import DeviceTier, LinkProfile
from repro.core.nsga2 import NSGA2Config, nsga2
from repro.core.topsis import topsis_select

_PENALTY = 1e30


@dataclasses.dataclass(frozen=True)
class ChainHardware:
    """K tiers connected by K-1 links."""

    tiers: tuple[DeviceTier, ...]
    links: tuple[LinkProfile, ...]

    def __post_init__(self):
        assert len(self.links) == len(self.tiers) - 1


@dataclasses.dataclass(frozen=True)
class MultiCutPlan:
    cuts: tuple[int, ...]            # ordered cut indices, len K-1
    objectives: tuple[float, float, float]
    pareto_cuts: np.ndarray
    pareto_F: np.ndarray

    def stages(self, L: int) -> list[tuple[int, int]]:
        edges = (0,) + self.cuts + (L,)
        return [(edges[i], edges[i + 1]) for i in range(len(edges) - 1)]


def _stage_tables(profile: ModelProfile, hw: ChainHardware):
    """Cumulative per-layer tables used by the vectorised evaluator."""
    flops = np.concatenate([[0.0], np.cumsum(
        [l.flops for l in profile.layers])])
    mem = profile.cum_mem()
    bound = profile.boundary()
    return flops, mem, bound


def evaluate_multicut(profile: ModelProfile, hw: ChainHardware,
                      genomes: np.ndarray) -> np.ndarray:
    """genomes: (n, K-1) cut points (unsorted ok; sorted internally).
    Returns (n, 3) objectives with constraint penalties applied."""
    L = profile.num_layers
    K = len(hw.tiers)
    flops, mem, bound = _stage_tables(profile, hw)
    cuts = np.sort(np.asarray(genomes, np.int64), axis=1)
    n = cuts.shape[0]
    edges = np.concatenate([np.zeros((n, 1), np.int64), cuts,
                            np.full((n, 1), L, np.int64)], axis=1)
    lat = np.zeros(n)
    en = np.zeros(n)
    peak = np.zeros(n)
    for k, tier in enumerate(hw.tiers):
        f_k = flops[edges[:, k + 1]] - flops[edges[:, k]]
        m_k = mem[edges[:, k + 1]] - mem[edges[:, k]]
        if tier.is_roofline:
            t_k = np.maximum(f_k / tier.peak_flops, m_k / tier.hbm_bw)
            e_k = (f_k * tier.pj_per_flop
                   + m_k * tier.pj_per_hbm_byte) * 1e-12
        else:
            t_k = m_k / tier.compute_scale
            e_k = tier.compute_power_w() * t_k
        lat += t_k
        en += e_k
        peak = np.maximum(peak, m_k / tier.memory_budget)
    for k, link in enumerate(hw.links):
        b_k = bound[edges[:, k + 1]]
        t_l = b_k / link.bandwidth
        lat += t_l
        if link.pj_per_byte:
            en += b_k * link.pj_per_byte * 1e-12
        else:
            en += link.upload_power_w(link.bandwidth) * t_l
    F = np.stack([lat, en, peak], axis=1)
    # constraints: non-empty stages, memory budgets
    widths = np.diff(edges, axis=1)
    bad = (widths < 1).any(axis=1) | (peak > 1.0)
    F[bad] += _PENALTY
    return F


def smartsplit_multicut(profile: ModelProfile, hw: ChainHardware,
                        config: NSGA2Config | None = None) -> MultiCutPlan:
    """Algorithm 1 with the K-cut genome."""
    L = profile.num_layers
    K = len(hw.tiers)
    config = config or NSGA2Config(pop_size=128, generations=80, seed=0)
    lower = np.ones(K - 1, np.int64)
    upper = np.full(K - 1, L - 1, np.int64)
    res = nsga2(lambda g: evaluate_multicut(profile, hw, g),
                lower, upper, config)
    F = evaluate_multicut(profile, hw, res.pareto_genomes)
    feas = F[:, 0] < _PENALTY / 2
    pick = topsis_select(F, feasible=feas)
    cuts = tuple(int(c) for c in np.sort(res.pareto_genomes[pick]))
    return MultiCutPlan(cuts=cuts,
                        objectives=tuple(float(v) for v in F[pick]),
                        pareto_cuts=np.sort(res.pareto_genomes, axis=1),
                        pareto_F=F)
