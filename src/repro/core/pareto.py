"""Pareto-dominance utilities: fast non-dominated sort, crowding distance,
and an exhaustive reference front (tractable here because the genome is a
single split index -- used as ground truth in tests)."""
from __future__ import annotations

import numpy as np


def dominates(a: np.ndarray, b: np.ndarray) -> bool:
    """a Pareto-dominates b (minimisation): <= everywhere and < somewhere."""
    return bool(np.all(a <= b) and np.any(a < b))


def non_dominated_sort(F: np.ndarray) -> list[np.ndarray]:
    """Deb's fast non-dominated sort.

    F: (n, m) objective matrix (minimisation).
    Returns a list of fronts, each an index array; front 0 is the Pareto set.
    """
    n = F.shape[0]
    # Vectorised domination matrix: dom[i, j] = i dominates j.
    le = np.all(F[:, None, :] <= F[None, :, :], axis=2)
    lt = np.any(F[:, None, :] < F[None, :, :], axis=2)
    dom = le & lt
    n_dominators = dom.sum(axis=0)          # how many dominate each point
    fronts: list[np.ndarray] = []
    remaining = np.ones(n, bool)
    counts = n_dominators.astype(np.int64).copy()
    while remaining.any():
        current = np.where(remaining & (counts == 0))[0]
        if current.size == 0:  # numerical ties; dump the rest as one front
            current = np.where(remaining)[0]
        fronts.append(current)
        remaining[current] = False
        counts = counts - dom[current].sum(axis=0)
    return fronts


def pareto_front_mask(F: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows of F (minimisation)."""
    n = F.shape[0]
    mask = np.ones(n, bool)
    for i in range(n):
        if not mask[i]:
            continue
        d = np.all(F <= F[i], axis=1) & np.any(F < F[i], axis=1)
        if d.any():
            mask[i] = False
    return mask


def crowding_distance(F: np.ndarray) -> np.ndarray:
    """NSGA-II crowding distance within one front.

    Boundary solutions get +inf; interior ones the normalised Manhattan
    distance between their objective-space neighbours."""
    n, m = F.shape
    if n <= 2:
        return np.full(n, np.inf)
    dist = np.zeros(n)
    for j in range(m):
        order = np.argsort(F[:, j], kind="stable")
        fj = F[order, j]
        span = fj[-1] - fj[0]
        dist[order[0]] = np.inf
        dist[order[-1]] = np.inf
        if span > 0:
            dist[order[1:-1]] += (fj[2:] - fj[:-2]) / span
    return dist


def exhaustive_pareto(F: np.ndarray) -> np.ndarray:
    """Indices of the true Pareto set of F (reference implementation)."""
    return np.where(pareto_front_mask(F))[0]
