"""Registry of every ``REPRO_*`` environment knob, as code.

Every env knob the repo reads is declared here exactly once -- name,
default, type, where it is resolved, and whether a per-hop
``REPRO_LINK{k}_*`` override exists.  ``scripts/gen_knobs.py`` renders
this table into ``docs/knobs.md``, and ``tests/test_knobs.py`` scans the
source tree for ``os.environ`` reads of ``REPRO_*`` names and asserts
each one appears here -- so the docs cannot silently drift from the
code: adding a knob without registering it is a tier-1 failure.

This module is stdlib-only (no jax) so the docs tooling and CI docs job
can import it without the accelerator stack.
"""
from __future__ import annotations

import dataclasses
import re
from pathlib import Path


@dataclasses.dataclass(frozen=True)
class Knob:
    """One environment knob: the registry row ``docs/knobs.md`` renders.

    per_hop: template of the per-hop override accepted alongside the
      chain-wide name (``{k}`` = 0-based hop id), or None."""

    name: str
    default: str        # rendered verbatim; "" = empty/unset
    type: str           # int | float | str | flag | choice | windows
    resolved_in: str    # module.symbol that reads it
    description: str
    per_hop: str | None = None


KNOBS: tuple[Knob, ...] = (
    # -- kernels / numerics --------------------------------------------
    Knob("REPRO_CONV_BACKEND", "xla", "choice: xla|pallas",
         "models.cnn.apply_cnn",
         "Conv2D execution backend: XLA reference or the Pallas kernel."),
    Knob("REPRO_CONV_DTYPE", "fp32", "choice: fp32|bf16",
         "core.dtype_policy.conv_dtype",
         "Storage/compute dtype for conv activations and the boundary "
         "tensor the cost model prices."),
    Knob("REPRO_WIRE_DTYPE", "follow", "choice: follow|fp32|bf16|int8",
         "core.dtype_policy.wire_dtype",
         "Wire dtype for boundary payloads; `follow` streams whatever "
         "the storage dtype is, `int8` adds quantized framing.",
         per_hop="REPRO_LINK{k}_WIRE_DTYPE"),
    Knob("REPRO_CONV_SEARCH", "1", "flag",
         "kernels.conv2d.search_enabled",
         "Enable the W-axis tile-size search for the Pallas conv kernel "
         "(0 pins the default tile)."),
    Knob("REPRO_CONV_TILE_W", "0", "int",
         "kernels.conv2d.forced_tile_w",
         "Force a specific W tile width for the Pallas conv kernel "
         "(0 = let the search/heuristic pick)."),
    Knob("REPRO_PALLAS_COMPILE", "0", "flag",
         "kernels.ops.interpret_mode",
         "1 compiles Pallas kernels for the accelerator; 0 (default) "
         "runs them in interpret mode, which works on CPU."),
    # -- launch / parallelism ------------------------------------------
    Knob("REPRO_FSDP", "1", "flag",
         "launch.partition.partition_params",
         "Shard parameters FSDP-style across the data axis (0 = "
         "replicate)."),
    Knob("REPRO_MOE_EP", "1", "flag",
         "launch.dryrun.main",
         "Give the MoE layer an expert-parallel mesh in the dry-run "
         "launcher (0 = dense placement)."),
    # -- split planning / chain execution ------------------------------
    Knob("REPRO_CHAIN_TIERS", "2", "int",
         "launch.serve / serving.cnn_engine",
         "Number of chain tiers to plan for (2 = the paper's "
         "phone/cloud pair; 3-4 add edge tiers via `paper_chain`)."),
    Knob("REPRO_CHAIN_MICROBATCH", "plan.microbatches", "int",
         "runtime.ChainRuntime",
         "Microbatches per request for the within-request pipeline "
         "schedule (default: whatever the plan was priced with)."),
    # -- link fault injection (all accept per-hop overrides) -----------
    Knob("REPRO_LINK_BW", "plan nominal", "float",
         "runtime.faults.link_from_env",
         "Link bandwidth in bytes/s (default: the bandwidth the plan "
         "was priced with).", per_hop="REPRO_LINK{k}_BW"),
    Knob("REPRO_LINK_LATENCY", "0", "float",
         "runtime.faults.link_from_env",
         "Fixed per-transfer latency in seconds.",
         per_hop="REPRO_LINK{k}_LATENCY"),
    Knob("REPRO_LINK_DROP", "0", "float",
         "runtime.faults.link_from_env",
         "Probability each wire attempt is dropped.",
         per_hop="REPRO_LINK{k}_DROP"),
    Knob("REPRO_LINK_CORRUPT", "0", "float",
         "runtime.faults.link_from_env",
         "Probability each delivered attempt is corrupted (caught by "
         "crc32 framing).", per_hop="REPRO_LINK{k}_CORRUPT"),
    Knob("REPRO_LINK_DELAY", "0", "float",
         "runtime.faults.link_from_env",
         "Probability each attempt is hit by a delay fault.",
         per_hop="REPRO_LINK{k}_DELAY"),
    Knob("REPRO_LINK_DELAY_S", "0.5", "float",
         "runtime.faults.link_from_env",
         "Extra seconds added when a delay fault fires.",
         per_hop="REPRO_LINK{k}_DELAY_S"),
    Knob("REPRO_LINK_OUTAGES", "", "windows",
         "runtime.faults.link_from_env",
         "Outage windows in virtual time, `start:end[,start:end...]` "
         "seconds.", per_hop="REPRO_LINK{k}_OUTAGES"),
    Knob("REPRO_LINK_SEED", "0", "int",
         "runtime.faults.link_from_env",
         "Fault-schedule seed; on a chain, hop k draws from seed+k "
         "unless its per-hop knob pins a seed verbatim.",
         per_hop="REPRO_LINK{k}_SEED"),
    # -- retry policy ---------------------------------------------------
    Knob("REPRO_LINK_RETRIES", "4", "int",
         "runtime.transfer.RetryPolicy.from_env",
         "Max wire attempts per logical transfer."),
    Knob("REPRO_LINK_TIMEOUT", "5.0", "float",
         "runtime.transfer.RetryPolicy.from_env",
         "Per-transfer timeout in virtual seconds."),
    Knob("REPRO_LINK_BACKOFF", "0.05", "float",
         "runtime.transfer.RetryPolicy.from_env",
         "Base backoff after a failed attempt (doubles per retry, "
         "jittered)."),
    Knob("REPRO_LINK_BACKOFF_FACTOR", "2.0", "float",
         "runtime.transfer.RetryPolicy.from_env",
         "Multiplier applied to the backoff base per failed attempt "
         "(attempt i waits base * factor^(i-1))."),
    Knob("REPRO_LINK_JITTER", "0.25", "float",
         "runtime.transfer.RetryPolicy.from_env",
         "Backoff jitter amplitude: each wait is scaled by "
         "1 + jitter * U[0,1) from the caller's seeded rng."),
    # -- tier fault injection (all accept per-tier overrides) -----------
    Knob("REPRO_TIER_CRASH", "0", "float",
         "runtime.tier_faults.tier_from_env",
         "Probability each stage execution crashes on the tier.",
         per_hop="REPRO_TIER{k}_CRASH"),
    Knob("REPRO_TIER_CRASH_WINDOWS", "", "windows",
         "runtime.tier_faults.tier_from_env",
         "Dead windows in virtual time, `start:end[,start:end...]` "
         "seconds: every stage overlapping one dies (restart = the "
         "window ending).", per_hop="REPRO_TIER{k}_CRASH_WINDOWS"),
    Knob("REPRO_TIER_SLOW", "0", "float",
         "runtime.tier_faults.tier_from_env",
         "Straggler probability per stage execution (slowdowns are not "
         "failures: they never trip breakers).",
         per_hop="REPRO_TIER{k}_SLOW"),
    Knob("REPRO_TIER_SLOW_FACTOR", "4.0", "float",
         "runtime.tier_faults.tier_from_env",
         "Compute-time multiplier applied when a straggler fault fires.",
         per_hop="REPRO_TIER{k}_SLOW_FACTOR"),
    Knob("REPRO_TIER_MEM_BUDGET", "0", "float",
         "runtime.tier_faults.tier_from_env",
         "Admission budget in bytes (0 = unlimited): a stage whose "
         "activation footprint exceeds it is shed before running.",
         per_hop="REPRO_TIER{k}_MEM_BUDGET"),
    Knob("REPRO_TIER_MEM_PROFILE", "", "windows",
         "runtime.tier_faults.tier_from_env",
         "Time-varying admission budget, `start:budget[,start:budget"
         "...]` (seconds : bytes), overriding REPRO_TIER_MEM_BUDGET "
         "from each start time onward.",
         per_hop="REPRO_TIER{k}_MEM_PROFILE"),
    Knob("REPRO_TIER_SEED", "0", "int",
         "runtime.tier_faults.tier_from_env",
         "Tier fault-schedule seed; on a chain, tier k draws from "
         "seed+k unless its per-tier knob pins a seed verbatim.",
         per_hop="REPRO_TIER{k}_SEED"),
    # -- serving engine -------------------------------------------------
    Knob("REPRO_SERVE_MAX_BATCH", "4", "int",
         "serving.cnn_engine.CnnServingEngine",
         "Batch packing limit per (model, resolution, dtype, wire) "
         "bucket; also the microbatch count when pipelining."),
    Knob("REPRO_SERVE_QUEUE_DEPTH", "64", "int",
         "serving.cnn_engine.CnnServingEngine",
         "Bounded request-queue depth; beyond it `submit` sheds with "
         "`QueueFullError`."),
    Knob("REPRO_SERVE_PIPELINED", "1", "flag",
         "serving.cnn_engine.CnnServingEngine",
         "Cross-request pipelining on the virtual clock (0 = "
         "sequential baseline: each batch waits out the previous "
         "one's makespan)."),
)


def registry_names() -> set[str]:
    """Every accepted env name, per-hop templates included (with the
    literal ``{k}`` placeholder -- the scanner canonicalises to it)."""
    names = set()
    for k in KNOBS:
        names.add(k.name)
        if k.per_hop:
            names.add(k.per_hop)
    return names


# -- source scanner -----------------------------------------------------
# Matches module-level UPPER_CASE constants bound to a REPRO_* literal
# (SEARCH_ENV, ENV_PREFIX, MAX_BATCH_ENV, ...).
_CONST_RE = re.compile(
    r'^([A-Z][A-Z0-9_]*)\s*=\s*["\'](REPRO_[A-Z0-9_]*)["\']', re.M)
# direct environ reads with a (possibly f-) string literal name
_DIRECT_RE = re.compile(
    r'environ(?:\.get)?\s*[\[(]\s*(f?)["\']([^"\']+)["\']')
# environ.get(CONST) or get(CONST + <literal suffix>) -- the bare `get`
# form catches the `get = os.environ.get` aliasing idiom.
_CONST_USE_RE = re.compile(
    r'\bget\s*\(\s*([A-Z][A-Z0-9_]*)\s*'
    r'(?:\+\s*["\']([A-Za-z0-9_]+)["\'])?\s*[,)]')
# _env_raw("DROP", hop) / _env_float("BW", ...): the faults.py per-hop
# lookup helpers; a literal first arg names a REPRO_LINK_* knob read
# both chain-wide and as REPRO_LINK{k}_*.
_WRAPPER_RE = re.compile(r'\b_env_[a-z]+\(\s*["\']([A-Z0-9_]+)["\']')
# _tier_env_raw("CRASH", tier) / _tier_env_float(...): the
# tier_faults.py per-tier lookup helpers -- same contract with the
# REPRO_TIER_* / REPRO_TIER{k}_* prefix pair.
_TIER_WRAPPER_RE = re.compile(
    r'\b_tier_env_[a-z]+\(\s*["\']([A-Z0-9_]+)["\']')
# f-string placeholders that index a hop or tier (canonicalised to {k})
_HOP_PLACEHOLDER_RE = re.compile(r'\{(?:k|hop)\}')

_LINK_PREFIX = "REPRO_LINK_"
_TIER_PREFIX = "REPRO_TIER_"


def scan_env_reads(root: str | Path | None = None) -> set[str]:
    """Every ``REPRO_*`` env name read under ``root`` (default: the
    ``repro`` package this module lives in), canonicalised: per-hop
    f-string reads become ``REPRO_LINK{k}_X``; reads through the
    faults.py ``_env_*`` helpers yield both the chain-wide and per-hop
    forms.  Docstring mentions are NOT picked up -- only code paths
    that reach ``os.environ``."""
    if root is None:
        root = Path(__file__).resolve().parents[1]
    root = Path(root)
    consts: dict[str, str] = {}
    texts: dict[Path, str] = {}
    for path in sorted(root.rglob("*.py")):
        text = path.read_text()
        texts[path] = text
        for m in _CONST_RE.finditer(text):
            consts[m.group(1)] = m.group(2)
    names: set[str] = set()
    for text in texts.values():
        for is_f, lit in _DIRECT_RE.findall(text):
            if is_f:
                lit = _HOP_PLACEHOLDER_RE.sub("{k}", lit)
                if "{" in lit.replace("{k}", ""):
                    continue    # non-hop placeholder: a helper's
                    # dynamic dispatch, covered by the wrapper scan
            if lit.startswith("REPRO_"):
                names.add(lit)
        for const, suffix in _CONST_USE_RE.findall(text):
            base = consts.get(const)
            if base is None:
                continue
            names.add(base + suffix if suffix else base)
        for suffix in _WRAPPER_RE.findall(text):
            names.add(_LINK_PREFIX + suffix)
            names.add("REPRO_LINK{k}_" + suffix)
        for suffix in _TIER_WRAPPER_RE.findall(text):
            names.add(_TIER_PREFIX + suffix)
            names.add("REPRO_TIER{k}_" + suffix)
    return names


def render_markdown() -> str:
    """The full ``docs/knobs.md`` content (``scripts/gen_knobs.py``
    writes it; the CI docs job regenerates and diffs)."""
    lines = [
        "# Environment knobs",
        "",
        "<!-- GENERATED by scripts/gen_knobs.py from "
        "src/repro/core/knobs.py. Do not edit by hand:",
        "     regenerate with `PYTHONPATH=src python scripts/gen_knobs.py`"
        " -->",
        "",
        "Every `REPRO_*` environment variable the code reads, in one "
        "table. The",
        "registry lives in [`core/knobs.py`](../src/repro/core/knobs.py);"
        " a tier-1",
        "test scans `src/` for `os.environ` reads and fails if any "
        "`REPRO_*` name",
        "is missing from it, so this page cannot drift from the code.",
        "",
        "Knobs marked *per-hop* also accept a `REPRO_LINK{k}_*` form "
        "(`{k}` =",
        "0-based hop id) that overrides the chain-wide value for one "
        "link only --",
        "how the chaos harness aims a fault at a single hop. "
        "`REPRO_TIER_*` knobs",
        "override per *tier* the same way (`REPRO_TIER{k}_*`, `{k}` = "
        "0-based tier id).",
        "",
        "| Knob | Default | Type | Resolved in | Per-hop | What it does |",
        "|---|---|---|---|---|---|",
    ]
    esc = lambda s: s.replace("|", "\\|")  # noqa: E731 -- cell-safe pipes
    for k in KNOBS:
        default = f"`{k.default}`" if k.default else "*(unset)*"
        per_hop = f"`{k.per_hop}`" if k.per_hop else "--"
        lines.append(
            f"| `{k.name}` | {default} | {esc(k.type)} | `{k.resolved_in}` "
            f"| {per_hop} | {esc(k.description)} |")
    lines += [
        "",
        "Precedence everywhere: explicit function argument > per-hop "
        "env knob >",
        "chain-wide env knob > default.",
        "",
    ]
    return "\n".join(lines)
