"""NSGA-II (Deb et al. 2002) over an integer genome.

The paper's genome is the split index l1 in [1, L-1]; we implement the
general integer-box case (genome = vector of ints within per-gene bounds) so
beyond-paper extensions (per-layer precision, multi-cut pipelines) reuse the
same optimiser.  Elitism, binary-tournament mating on (rank, crowding),
uniform crossover and bounded random-reset/creep mutation.

Deterministic given the seed; pure numpy (host-side optimiser -- the
objective evaluation is vectorised and, for TPU plans, derives from the
compiled-HLO cost tables)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.pareto import crowding_distance, non_dominated_sort

# Lifetime GA-run count.  The failover tests assert that a standby-tier
# re-pick is a cached-front TOPSIS pass with NO optimiser re-run by
# reading this before/after the recovery.
RUN_COUNT = 0


@dataclasses.dataclass(frozen=True)
class NSGA2Config:
    pop_size: int = 64
    generations: int = 60
    crossover_prob: float = 0.9
    mutation_prob: float = 0.2      # per-gene
    creep_prob: float = 0.5         # creep (+-step) vs random-reset mutation
    creep_step: int = 2
    seed: int = 0


@dataclasses.dataclass
class NSGA2Result:
    pareto_genomes: np.ndarray      # (n, g) unique non-dominated genomes
    pareto_F: np.ndarray            # (n, m) their objectives
    population: np.ndarray          # final population (pop, g)
    population_F: np.ndarray
    history: list[float]            # per-generation hypervolume proxy


def _tournament(rng, rank, crowd):
    n = rank.shape[0]
    a = rng.integers(0, n, n)
    b = rng.integers(0, n, n)
    a_wins = (rank[a] < rank[b]) | ((rank[a] == rank[b]) & (crowd[a] > crowd[b]))
    return np.where(a_wins, a, b)


def _rank_and_crowd(F: np.ndarray):
    fronts = non_dominated_sort(F)
    rank = np.empty(F.shape[0], np.int64)
    crowd = np.empty(F.shape[0])
    for r, idx in enumerate(fronts):
        rank[idx] = r
        crowd[idx] = crowding_distance(F[idx])
    return rank, crowd, fronts


def nsga2(evaluate: Callable[[np.ndarray], np.ndarray],
          lower: np.ndarray, upper: np.ndarray,
          config: NSGA2Config = NSGA2Config()) -> NSGA2Result:
    """Minimise a vector objective over an integer box [lower, upper].

    evaluate: (pop, g) int genomes -> (pop, m) objectives.  Infeasible
    genomes should be penalised by the caller (we keep the optimiser
    constraint-agnostic; SmartSplit applies the paper's constraints both as
    a penalty here and as the TOPSIS filter, matching Algorithm 1 where the
    reduced matrix F'' drops constraint-violating solutions)."""
    global RUN_COUNT
    RUN_COUNT += 1
    lower = np.asarray(lower, np.int64)
    upper = np.asarray(upper, np.int64)
    g = lower.shape[0]
    rng = np.random.default_rng(config.seed)
    # Stratified (latin-hypercube style) initialisation: per gene, evenly
    # spaced values in [lower, upper] independently shuffled across rows.
    # Small domains are fully covered at init; large ones evenly seeded.
    # Includes both box corners, covering the common boundary optima.
    n = config.pop_size
    pop = np.empty((n, g), np.int64)
    for j in range(g):
        vals = np.rint(np.linspace(lower[j], upper[j], n)).astype(np.int64)
        rng.shuffle(vals)
        pop[:, j] = vals
    F = np.asarray(evaluate(pop), float)
    history: list[float] = []
    # Offline archive: every evaluated (genome, F) pair.  The returned
    # Pareto set is the non-dominated subset of the archive, so a front
    # member visited once is never lost to selection churn.
    arch_G = [pop.copy()]
    arch_F = [F.copy()]

    for _ in range(config.generations):
        rank, crowd, _ = _rank_and_crowd(F)
        parents = pop[_tournament(rng, rank, crowd)]
        # Uniform crossover between consecutive parent pairs.
        child = parents.copy()
        pairs = child.reshape(-1, 2, g) if config.pop_size % 2 == 0 else None
        if pairs is not None:
            swap = (rng.random(pairs.shape[::2]) < 0.5)[:, None, :] \
                & (rng.random((pairs.shape[0], 1, 1)) < config.crossover_prob)
            a, b = pairs[:, 0].copy(), pairs[:, 1].copy()
            pairs[:, 0] = np.where(swap[:, 0], b, a)
            pairs[:, 1] = np.where(swap[:, 0], a, b)
            child = pairs.reshape(-1, g)
        # Mutation: creep or reset.
        mut = rng.random(child.shape) < config.mutation_prob
        creep = rng.random(child.shape) < config.creep_prob
        step = rng.integers(-config.creep_step, config.creep_step + 1,
                            child.shape)
        reset = rng.integers(lower, upper + 1, size=child.shape)
        child = np.where(mut, np.where(creep, child + step, reset), child)
        child = np.clip(child, lower, upper)
        childF = np.asarray(evaluate(child), float)
        arch_G.append(child.copy())
        arch_F.append(childF.copy())
        # Elitist environmental selection over parents + children.
        allP = np.concatenate([pop, child])
        allF = np.concatenate([F, childF])
        rank, crowd, fronts = _rank_and_crowd(allF)
        chosen: list[int] = []
        for idx in fronts:
            if len(chosen) + idx.size <= config.pop_size:
                chosen.extend(idx.tolist())
            else:
                take = config.pop_size - len(chosen)
                order = np.argsort(-crowd[idx], kind="stable")
                chosen.extend(idx[order[:take]].tolist())
                break
        sel = np.array(chosen)
        pop, F = allP[sel], allF[sel]
        # Convergence proxy: sum of front-0 normalised objective means.
        history.append(float(F[rank[sel] == 0].mean()))

    # Offline result: non-dominated subset of everything evaluated.
    G_all = np.concatenate(arch_G)
    F_arch = np.concatenate(arch_F)
    G_uniq, first = np.unique(G_all, axis=0, return_index=True)
    F_uniq = F_arch[first]
    _, _, fronts = _rank_and_crowd(F_uniq)
    front0 = fronts[0]
    return NSGA2Result(pareto_genomes=G_uniq[front0], pareto_F=F_uniq[front0],
                       population=pop, population_F=F, history=history)
