"""SmartSplit (paper Algorithm 1): NSGA-II Pareto set -> TOPSIS pick.

Also provides the exhaustive solver (the split index is one integer, so the
true Pareto front is enumerable -- the paper uses a GA because its framing
is generic; we keep both and test that NSGA-II recovers the exhaustive
front, then use the GA for the multi-cut beyond-paper genome where
enumeration explodes)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.chainplan import ChainPlan
from repro.core.chainplan import SplitPlan as SplitPlan  # noqa: F401  (re-export)
from repro.core.costs import (ModelProfile, evaluate_objectives,
                              feasible_mask)
from repro.core.dtype_policy import resolve_wire_dtype
from repro.core.hardware import TwoTierHardware
from repro.core.nsga2 import NSGA2Config, NSGA2Result, nsga2
from repro.core.pareto import exhaustive_pareto
from repro.core.topsis import link_weights, topsis_select

_PENALTY = 1e30


def _two_tier_plan(profile: ModelProfile, hw: TwoTierHardware,
                   l1: int, pareto_l1: np.ndarray,
                   pareto_F: np.ndarray, F_all: np.ndarray,
                   wire: str) -> ChainPlan:
    """Package a picked K=2 split as the unified chain plan."""
    return ChainPlan(model=profile.name, num_layers=profile.num_layers,
                     cuts=(l1,),
                     objectives=tuple(float(x) for x in F_all[l1]),
                     pareto_cuts=np.asarray(pareto_l1,
                                            np.int64).reshape(-1, 1),
                     pareto_F=pareto_F,
                     links=(hw.link,),
                     tiers=(hw.client.name, hw.server.name),
                     wire_dtypes=(wire,))


def smartsplit(profile: ModelProfile, hw: TwoTierHardware,
               config: NSGA2Config = NSGA2Config(),
               weights: np.ndarray | None = None,
               use_anti_ideal: bool = False,
               f3_mode: str = "full",
               wire: str | None = None) -> SplitPlan:
    """Paper Algorithm 1.

    Line 1:   O <- NSGA2(F)          (Pareto set of split indices)
    Lines 2-7: TOPSIS over the Pareto set with constraint filtering.

    ``wire`` is the boundary wire-dtype policy the objectives are priced
    under (default: env resolution; ``follow`` = the storage dtype, the
    legacy numbers bit-for-bit).  An ``int8`` wire shrinks the upload
    term ~4x, so the pick can move toward earlier, bigger boundaries.
    """
    wire = resolve_wire_dtype(wire, storage=profile.dtype, hop=0)
    F_all = evaluate_objectives(profile, hw, f3_mode, wire)   # (L+1, 3)
    feas_all = feasible_mask(profile, hw)
    L = profile.num_layers

    def evaluate(genomes: np.ndarray) -> np.ndarray:
        l1 = genomes[:, 0]
        F = F_all[l1].copy()
        # Penalise constraint violations so the GA steers feasible; TOPSIS
        # re-applies the filter exactly (Algorithm 1's F'' reduction).
        F[~feas_all[l1]] += _PENALTY
        return F

    # With stratified init, pop_size >= |domain| makes the archive front
    # provably exact for the paper's single-gene genome (the GA's search
    # matters for the beyond-paper multi-cut genomes).
    if config.pop_size < L - 1:
        config = dataclasses.replace(config, pop_size=L - 1)
    result: NSGA2Result = nsga2(evaluate, lower=np.array([1]),
                                upper=np.array([L - 1]), config=config)
    pareto_l1 = result.pareto_genomes[:, 0]
    pareto_F = F_all[pareto_l1]
    feas = feasible_mask(profile, hw)[pareto_l1]
    pick = topsis_select(pareto_F, feasible=feas, weights=weights,
                         use_anti_ideal=use_anti_ideal)
    l1 = int(pareto_l1[pick])
    return _two_tier_plan(profile, hw, l1, pareto_l1, pareto_F, F_all,
                          wire)


def repick_split(plan: SplitPlan, profile: ModelProfile,
                 hw: TwoTierHardware, *,
                 bandwidth: float | None = None,
                 exclude: tuple[int, ...] = (),
                 weights: np.ndarray | None = None,
                 f3_mode: str = "full") -> SplitPlan:
    """Runtime TOPSIS re-pick over a plan's already-computed Pareto front.

    The GA never re-runs: ``plan.pareto_indices`` is the front computed at
    plan time, and split-index Pareto optimality is bandwidth-independent
    for the paper's cost structure (every objective row is affine in 1/B
    through the same boundary term, so dominance among front members is
    re-decided by TOPSIS, not re-enumeration).  This re-evaluates only the
    closed-form objective matrix under the *current* link bandwidth --
    vectorised numpy over <= L rows, microseconds -- and re-runs the
    selection with link-degradation re-weighting (``topsis.link_weights``).

    bandwidth: current effective bytes/s (EWMA estimate); None keeps the
      planning bandwidth and just re-selects (e.g. after an ``exclude``).
    exclude: split indices already tried and failed for this inference --
      the degradation loop walks the front without repeating itself.
    weights: explicit TOPSIS weights; default derives them from the
      planned/current bandwidth ratio.

    Raises ValueError when no feasible non-excluded front member remains
    (the caller falls back or surfaces the outage)."""
    ratio = 1.0
    if bandwidth is not None:
        ratio = hw.link.bandwidth / bandwidth
        hw = hw.with_link_bandwidth(bandwidth)
    wire = plan.wire_dtypes[0] if plan.wire_dtypes else None
    F_all = evaluate_objectives(profile, hw, f3_mode, wire)
    idx = np.asarray(plan.pareto_indices, int)
    feas = feasible_mask(profile, hw)[idx]
    if exclude:
        feas &= ~np.isin(idx, np.asarray(list(exclude), int))
    if weights is None and ratio != 1.0:
        weights = link_weights(ratio)
    pick = topsis_select(F_all[idx], feasible=feas, weights=weights)
    l1 = int(idx[pick])
    return dataclasses.replace(
        plan, cuts=(l1,),
        objectives=tuple(float(x) for x in F_all[l1]),
        pareto_F=F_all[idx],
        links=(hw.link,),
        tiers=(hw.client.name, hw.server.name))


# ---------------------------------------------------------------------------
# Memoised chain plans (per model x tier-chain x dtype x wire).
# ---------------------------------------------------------------------------
# Standby-tier failover must not pay an NSGA-II run on the recovery path:
# the runtime prewarms the standby chains' plans here at construction, and
# a breaker-open failover is then one cached-front TOPSIS re-pick
# (``multicut.repick_chain``).  The cache key captures everything the
# optimiser's objective matrix depends on.

_PLAN_CACHE: dict[tuple, ChainPlan] = {}
_CACHE_HITS = 0
_CACHE_MISSES = 0


def _plan_cache_key(profile: ModelProfile, hw, *, microbatches: int,
                    f3_mode: str, wire) -> tuple:
    from repro.core.hardware import ChainHardware
    if not isinstance(hw, ChainHardware):            # TwoTierHardware
        from repro.core.hardware import chain_of
        hw = chain_of(hw)
    wire_key = wire if isinstance(wire, (str, type(None))) else tuple(wire)
    return (profile.name, profile.num_layers, profile.dtype,
            tuple(int(b) for b in profile.boundary()),
            tuple(t.name for t in hw.tiers),
            tuple((link.name, float(link.bandwidth)) for link in hw.links),
            int(microbatches), f3_mode, wire_key)


def cached_chain_plan(profile: ModelProfile, hw, *, microbatches: int = 1,
                      f3_mode: str = "full",
                      wire=None, **kwargs) -> ChainPlan:
    """``multicut.smartsplit_chain`` behind a per-(model, tier-chain,
    dtype, wire) memo.  First call per key runs the full planner
    (exhaustive or NSGA-II); every later call -- notably the failover
    path re-picking onto a standby chain -- returns the cached plan with
    its Pareto front intact, so recovery never re-runs the GA."""
    global _CACHE_HITS, _CACHE_MISSES
    key = _plan_cache_key(profile, hw, microbatches=microbatches,
                          f3_mode=f3_mode, wire=wire)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _CACHE_HITS += 1
        return plan
    _CACHE_MISSES += 1
    from repro.core.multicut import smartsplit_chain
    plan = smartsplit_chain(profile, hw, microbatches=microbatches,
                            f3_mode=f3_mode, wire=wire, **kwargs)
    _PLAN_CACHE[key] = plan
    return plan


def clear_plan_cache() -> None:
    """Drop every memoised plan (tests and long-lived servers after a
    profile change)."""
    global _CACHE_HITS, _CACHE_MISSES
    _PLAN_CACHE.clear()
    _CACHE_HITS = 0
    _CACHE_MISSES = 0


def plan_cache_stats() -> dict[str, int]:
    return {"hits": _CACHE_HITS, "misses": _CACHE_MISSES,
            "size": len(_PLAN_CACHE)}


def smartsplit_exhaustive(profile: ModelProfile, hw: TwoTierHardware,
                          weights: np.ndarray | None = None,
                          use_anti_ideal: bool = False,
                          f3_mode: str = "full",
                          wire: str | None = None) -> SplitPlan:
    """Ground-truth Algorithm 1 with the GA replaced by enumeration."""
    wire = resolve_wire_dtype(wire, storage=profile.dtype, hop=0)
    F_all = evaluate_objectives(profile, hw, f3_mode, wire)
    feas = feasible_mask(profile, hw)
    L = profile.num_layers
    candidates = np.arange(1, L)                        # 1 <= l1 <= L-1
    Fc = F_all[candidates]
    # True Pareto front among feasible candidates.
    feas_c = feas[candidates]
    Fp = Fc.copy()
    Fp[~feas_c] += _PENALTY
    front = exhaustive_pareto(Fp)
    pareto_l1 = candidates[front]
    pick = topsis_select(F_all[pareto_l1], feasible=feas[pareto_l1],
                         weights=weights, use_anti_ideal=use_anti_ideal)
    l1 = int(pareto_l1[pick])
    return _two_tier_plan(profile, hw, l1, pareto_l1, F_all[pareto_l1],
                          F_all, wire)
