"""Latency / energy / memory cost models (paper Section III).

The unit the optimiser reasons over is a ``LayerProfile``: one entry per
splittable layer with its work (FLOPs), memory traffic, resident memory, and
the size of the activation that would cross the client->server boundary if
the model were split *after* this layer.  Profiles are produced analytically
by ``models/profiles.py`` (for both the paper's CNNs and the assigned
transformer architectures) and cross-checked against compiled-HLO
``cost_analysis`` in tests.

Cost model semantics (paper Eq. 2-13):

  T_client  = M_client|l1 / (C_client * S_client)               (Eq. 2)
  T_server  = M_server|l2 / (C_server * S_server)               (Eq. 3)
  T_upload  = I|l1 / B                                          (Eq. 4)
  E_client  = (k * C * nu^3) * T_client                         (Eq. 7)
  E_upload  = (alpha_u * tau_u + beta_u) * T_upload             (Eq. 9)
  E_download= (alpha_d * tau_d + beta_d) * (d / B)              (Eq. 12)

For roofline (TPU) tiers the compute time per side is
``max(flops/peak, bytes/hbm_bw)`` summed over that side's layers, and the
energy is per-op accounting (pJ/FLOP + pJ/byte + pJ/link-byte); everything
else is identical in form.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dtype_policy import (conv_dtype, dtype_bytes,
                                     resolve_wire_dtype,
                                     wire_payload_bytes_per_elem)
from repro.core.hardware import ChainHardware, DeviceTier, TwoTierHardware

# Per-transfer framing overhead (crc32 + length) the reliable transfer
# layer adds to every wire attempt -- runtime/transfer.py aliases this, so
# the pipeline cost model and the executor charge the same bytes.
FRAME_HEADER_BYTES = 8

# Multipart framing an int8 boundary adds inside the payload: a part-count
# word plus a (length, crc32) header per part -- (scales, data) is two
# parts.  runtime/transfer.py's pack_frames aliases these too.
PART_HEADER_BYTES = 8
MULTIPART_BASE_BYTES = 4
INT8_FRAME_OVERHEAD_BYTES = MULTIPART_BASE_BYTES + 2 * PART_HEADER_BYTES

# One fp32 absmax scale accompanies each quantization channel.
WIRE_SCALE_BYTES = 4

# ``hw.download_bytes`` is calibrated as an fp32-sized result payload
# (paper Eq. 11's fixed d); the wire policy rescales its element bytes.
DOWNLOAD_BASE_ELEM_BYTES = 4.0

# Codec compute surcharge, in passes over the boundary tensor's storage
# bytes: int8 quantize = absmax reduce + scale/round (fused kernel, but the
# tensor is still read twice conceptually), dequantize = one pass; a plain
# float cast = one pass each side.  Charged on the sending/receiving tier
# so the optimiser sees that re-encoding is not free.
QUANT_ENCODE_PASSES = 2.0
QUANT_DECODE_PASSES = 1.0
CAST_PASSES = 1.0


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """Per-layer costs, all in base units (FLOPs, bytes)."""

    name: str
    kind: str                   # conv / fc / pool / act / norm / attn / moe ...
    flops: float                # useful FLOPs for one inference of this layer
    param_bytes: float          # resident weight bytes
    act_bytes: float            # output activation bytes (workspace)
    boundary_bytes: float       # bytes crossing the link if split AFTER this
    # Extra payload that must accompany a split after this layer (e.g. SSM /
    # WKV recurrent state for the remaining layers, paper-CNN: 0).
    state_bytes: float = 0.0
    # Quantization groups of the boundary tensor (channel count for feature
    # maps, 1 for flat activations; 0 = unknown, treated as 1) -- prices the
    # per-channel fp32 scales an int8 wire format ships.
    boundary_channels: float = 0.0

    @property
    def mem_bytes(self) -> float:
        """Paper's M|layer: memory utilised running this layer (weights +
        output tensor) -- the learnopencv counting the paper cites."""
        return self.param_bytes + self.act_bytes


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """A splittable model: ordered layers + input size.

    ``dtype`` records the storage policy every byte term was computed
    under (fp32 | bf16).  The latency/energy/memory models below consume
    bytes, so they are dtype-aware through the profile: a bf16 profile's
    memory and transfer terms are half its fp32 twin's, and the optimiser
    can pick splits that only fit the client budget at bf16."""

    name: str
    layers: tuple[LayerProfile, ...]
    input_bytes: float          # payload if split at l1 = 0 (COC)
    dtype: str = "fp32"         # storage policy the byte terms assume
    # Whether the l1=0 input upload is stored under the policy too.  True
    # for the CNNs (the client casts the image like any activation);
    # False when the input is policy-independent (int32 token ids).
    input_follows_dtype: bool = True
    # Quantization groups of the l1=0 input upload (image channels).
    input_channels: float = 0.0

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def with_dtype(self, dtype: str) -> "ModelProfile":
        """The same model re-profiled under another storage policy: every
        byte term (weights, activations, boundary payloads, migrating
        state, and -- unless ``input_follows_dtype`` is off -- the input
        upload) rescales by the element-size ratio; FLOPs are unchanged
        (the fp32 accumulator does the same arithmetic)."""
        policy = conv_dtype(dtype)
        ratio = dtype_bytes(policy) / dtype_bytes(self.dtype)
        if ratio == 1.0:
            return dataclasses.replace(self, dtype=policy)
        layers = tuple(dataclasses.replace(
            l, param_bytes=l.param_bytes * ratio,
            act_bytes=l.act_bytes * ratio,
            boundary_bytes=l.boundary_bytes * ratio,
            state_bytes=l.state_bytes * ratio) for l in self.layers)
        in_b = self.input_bytes * ratio if self.input_follows_dtype \
            else self.input_bytes
        return dataclasses.replace(self, layers=layers, input_bytes=in_b,
                                   dtype=policy)

    # -- cumulative views (vectorised; the GA evaluates whole populations) --
    def cum_mem(self) -> np.ndarray:
        """cum_mem[i] = M|l1 for l1 = i  (memory of first i layers)."""
        m = np.array([l.mem_bytes for l in self.layers])
        return np.concatenate([[0.0], np.cumsum(m)])

    def cum_flops(self) -> np.ndarray:
        f = np.array([l.flops for l in self.layers])
        return np.concatenate([[0.0], np.cumsum(f)])

    def cum_param_bytes(self) -> np.ndarray:
        p = np.array([l.param_bytes for l in self.layers])
        return np.concatenate([[0.0], np.cumsum(p)])

    def boundary(self) -> np.ndarray:
        """boundary[i] = I|l1 for split index l1 = i (i layers on client).

        boundary[0] = input_bytes (everything on the server);
        boundary[L] = 0 (nothing crosses -- COS)."""
        b = [self.input_bytes]
        for l in self.layers:
            b.append(l.boundary_bytes + l.state_bytes)
        b[-1] = 0.0
        return np.array(b)

    def boundary_groups(self) -> np.ndarray:
        """boundary_groups[i] = quantization channels of boundary ``i``
        (unknown counts fall back to 1 = per-tensor)."""
        g = [self.input_channels or 1.0]
        for l in self.layers:
            g.append(l.boundary_channels or 1.0)
        return np.array(g)

    def wire_boundary(self, wire: str | None = None,
                      hop: int | None = None) -> np.ndarray:
        """boundary() priced in the wire format of one hop.

        ``follow`` (and any wire format equal to the storage dtype) returns
        ``boundary()`` unchanged -- the legacy bytes, exactly.  A float wire
        format rescales element bytes; ``int8`` charges 1 byte/element plus
        the per-channel fp32 scales and the two-part (scales, data) framing
        overhead the transfer layer actually puts on the wire."""
        w = resolve_wire_dtype(wire, storage=self.dtype, hop=hop)
        b = self.boundary()
        if w == self.dtype:
            return b
        elems = b / dtype_bytes(self.dtype)
        if w != "int8":
            return elems * wire_payload_bytes_per_elem(w)
        wb = (elems + WIRE_SCALE_BYTES * self.boundary_groups()
              + INT8_FRAME_OVERHEAD_BYTES)
        return np.where(elems > 0, wb, 0.0)


# ---------------------------------------------------------------------------
# Latency model
# ---------------------------------------------------------------------------
def _tier_compute_time(tier: DeviceTier, mem_bytes, flops, hbm_bytes):
    """Compute time on one tier for (vectorised) cumulative work.

    Paper tiers: Eq. 2/3 -- memory-as-work over cores*speed.
    Roofline tiers: max(flops/peak, bytes/bw).
    """
    if tier.is_roofline:
        return np.maximum(flops / tier.peak_flops, hbm_bytes / tier.hbm_bw)
    return mem_bytes / tier.compute_scale


def _codec_passes(wire: str, storage: str) -> tuple[float, float]:
    """(encode, decode) passes over the boundary tensor for one hop."""
    if wire == storage:
        return 0.0, 0.0
    if wire == "int8":
        return QUANT_ENCODE_PASSES, QUANT_DECODE_PASSES
    return CAST_PASSES, CAST_PASSES


def _codec_time(tier: DeviceTier, touched_bytes):
    """Seconds one tier spends re-encoding ``touched_bytes`` of boundary."""
    if tier.is_roofline:
        return touched_bytes / tier.hbm_bw
    return touched_bytes / tier.compute_scale


def download_wire_bytes(download_bytes: float, wire: str) -> float:
    """The fixed result payload priced in the wire format (satellite fix:
    a bf16/int8 plan no longer charges an fp32-sized download)."""
    if wire == "fp32":
        return float(download_bytes)
    elems = download_bytes / DOWNLOAD_BASE_ELEM_BYTES
    if wire == "int8":
        # per-tensor quantized result vector: one scale, two-part framing
        return elems + WIRE_SCALE_BYTES + INT8_FRAME_OVERHEAD_BYTES
    return elems * wire_payload_bytes_per_elem(wire)


def latency_terms(profile: ModelProfile, hw: TwoTierHardware,
                  wire: str | None = None):
    """Return (T_client, T_upload, T_server, T_download) arrays indexed by
    split index l1 = 0..L (l1 layers on the client).

    ``wire`` is the hop's wire-dtype policy (default: env resolution;
    ``follow`` prices the storage bytes, unchanged).  A re-encoding wire
    format also bills the quantize/dequantize passes on each tier."""
    cm = profile.cum_mem()
    cf = profile.cum_flops()
    # HBM traffic proxy: weights + activations each touched once.
    ch = cm
    t_client = _tier_compute_time(hw.client, cm, cf, ch)
    t_server = _tier_compute_time(hw.server, cm[-1] - cm, cf[-1] - cf,
                                  ch[-1] - ch)
    w = resolve_wire_dtype(wire, storage=profile.dtype, hop=0)
    t_upload = profile.wire_boundary(w) / hw.link.bandwidth
    enc_p, dec_p = _codec_passes(w, profile.dtype)
    if enc_p:
        bound = profile.boundary()
        t_client = t_client + _codec_time(hw.client, enc_p * bound)
        t_server = t_server + _codec_time(hw.server, dec_p * bound)
    d_bytes = download_wire_bytes(hw.download_bytes, w)
    t_download = np.full_like(t_upload, d_bytes / hw.link.bandwidth)
    # COS (l1 = L): no server interaction at all.
    t_download[-1] = 0.0
    # COC (l1 = 0): client does nothing.
    return t_client, t_upload, t_server, t_download


def total_latency(profile: ModelProfile, hw: TwoTierHardware,
                  wire: str | None = None) -> np.ndarray:
    """Paper Eq. 5 (download latency measured negligible, excluded)."""
    t_c, t_u, t_s, _ = latency_terms(profile, hw, wire)
    return t_c + t_u + t_s


# ---------------------------------------------------------------------------
# Energy model (client-side energy only, per the paper)
# ---------------------------------------------------------------------------
def energy_terms(profile: ModelProfile, hw: TwoTierHardware,
                 wire: str | None = None):
    """Return (E_client, E_upload, E_download) arrays indexed by l1."""
    t_c, t_u, _, t_d = latency_terms(profile, hw, wire)
    w = resolve_wire_dtype(wire, storage=profile.dtype, hop=0)
    cf = profile.cum_flops()
    cm = profile.cum_mem()
    if hw.client.is_roofline:
        e_client = (cf * hw.client.pj_per_flop
                    + cm * hw.client.pj_per_hbm_byte) * 1e-12
        e_link_up = profile.wire_boundary(w) * hw.link.pj_per_byte * 1e-12
        e_link_down = np.full_like(
            e_link_up,
            download_wire_bytes(hw.download_bytes, w)
            * hw.link.pj_per_byte * 1e-12)
        e_link_down[-1] = 0.0
        return e_client, e_link_up, e_link_down
    # Paper model: throughput tau == link bandwidth while transferring
    # (constraint tau <= B holds with equality under saturation).
    p_client = hw.client.compute_power_w()
    p_up = hw.link.upload_power_w(hw.link.bandwidth)
    p_down = hw.link.download_power_w(hw.link.bandwidth)
    return p_client * t_c, p_up * t_u, p_down * t_d


def total_energy(profile: ModelProfile, hw: TwoTierHardware,
                 wire: str | None = None) -> np.ndarray:
    """Paper Eq. 13."""
    e_c, e_u, e_d = energy_terms(profile, hw, wire)
    return e_c + e_u + e_d


def client_memory(profile: ModelProfile, mode: str = "full") -> np.ndarray:
    """Paper Eq. 16: f3 = M_client | l1.

    mode='full': weights + activations (literal reading of M).
    mode='activations': activation footprint only -- the *table-calibrated*
    variant: reconstructing Table I from the paper's equations leaves the
    composition of M|l1 in f3 under-specified, and the activations-only
    reading reproduces the paper's published splits for AlexNet/VGG13/VGG16
    exactly (see EXPERIMENTS.md 'Calibration')."""
    if mode == "full":
        return profile.cum_mem()
    if mode == "activations":
        a = np.array([l.act_bytes for l in profile.layers])
        return np.concatenate([[0.0], np.cumsum(a)])
    raise ValueError(mode)


def evaluate_objectives(profile: ModelProfile, hw: TwoTierHardware,
                        f3_mode: str = "full",
                        wire: str | None = None) -> np.ndarray:
    """(L+1, 3) matrix of (f1 latency, f2 energy, f3 memory) per split l1."""
    return np.stack([total_latency(profile, hw, wire),
                     total_energy(profile, hw, wire),
                     client_memory(profile, f3_mode)], axis=1)


def feasible_mask(profile: ModelProfile, hw: TwoTierHardware,
                  allow_degenerate: bool = False) -> np.ndarray:
    """Constraints of Eq. 17 over split index l1 = 0..L.

    * M_client|l1 <= memory budget,
    * 1 <= l1 <= L-1 and l2 = L - l1 >= 1 (unless ``allow_degenerate`` for
      the COS/COC baselines),
    * tau <= B holds by construction (we model saturation at B).
    """
    L = profile.num_layers
    mem_ok = profile.cum_mem() <= hw.client.memory_budget
    idx = np.arange(L + 1)
    if allow_degenerate:
        rng_ok = np.ones(L + 1, bool)
    else:
        rng_ok = (idx >= 1) & (idx <= L - 1)
    return mem_ok & rng_ok


# ---------------------------------------------------------------------------
# Chain (K-tier) generalisation with microbatch pipelining
# ---------------------------------------------------------------------------
def _chain_edges(profile: ModelProfile, genomes: np.ndarray) -> np.ndarray:
    """(n, K+1) stage-edge matrix [0 | sorted cuts | L] per genome row."""
    L = profile.num_layers
    cuts = np.sort(np.asarray(genomes, np.int64), axis=1)
    n = cuts.shape[0]
    return np.concatenate([np.zeros((n, 1), np.int64), cuts,
                           np.full((n, 1), L, np.int64)], axis=1)


def resolve_chain_wire(wire, n_hops: int, storage: str) -> tuple[str, ...]:
    """Concrete per-hop wire formats for a K-1-hop chain.

    ``wire`` may be None (env resolution per hop: ``REPRO_LINK{k}_
    WIRE_DTYPE`` over ``REPRO_WIRE_DTYPE`` over ``follow``), one policy
    string for every hop, or a per-hop sequence of policies/None."""
    if wire is None or isinstance(wire, str):
        return tuple(resolve_wire_dtype(wire, storage=storage, hop=k)
                     for k in range(n_hops))
    ws = tuple(wire)
    if len(ws) != n_hops:
        raise ValueError(
            f"per-hop wire needs {n_hops} entries, got {len(ws)}")
    return tuple(resolve_wire_dtype(wk, storage=storage, hop=k)
                 for k, wk in enumerate(ws))


def chain_stage_hop_times(profile: ModelProfile, hw: ChainHardware,
                          genomes: np.ndarray, wire=None
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Per-stage compute and per-hop transfer seconds for cut vectors.

    genomes: (n, K-1) cut points (unsorted ok; sorted internally).
    Returns ``(stage_T, hop_T)`` with shapes (n, K) and (n, K-1) -- the
    whole-batch times the pipeline latency model (and the chain runtime's
    virtual-clock schedule) are built from.  ``wire`` prices each hop in
    its wire format and bills the codec passes on the adjacent tiers."""
    edges = _chain_edges(profile, genomes)
    cf = profile.cum_flops()
    cm = profile.cum_mem()
    bound = profile.boundary()
    ws = resolve_chain_wire(wire, len(hw.links), profile.dtype)
    n, K = edges.shape[0], len(hw.tiers)
    stage_T = np.zeros((n, K))
    for k, tier in enumerate(hw.tiers):
        f_k = cf[edges[:, k + 1]] - cf[edges[:, k]]
        m_k = cm[edges[:, k + 1]] - cm[edges[:, k]]
        stage_T[:, k] = _tier_compute_time(tier, m_k, f_k, m_k)
    hop_T = np.zeros((n, K - 1))
    for k, link in enumerate(hw.links):
        wb = profile.wire_boundary(ws[k])
        hop_T[:, k] = wb[edges[:, k + 1]] / link.bandwidth
        enc_p, dec_p = _codec_passes(ws[k], profile.dtype)
        if enc_p:
            b_k = bound[edges[:, k + 1]]
            stage_T[:, k] += _codec_time(hw.tiers[k], enc_p * b_k)
            stage_T[:, k + 1] += _codec_time(hw.tiers[k + 1], dec_p * b_k)
    return stage_T, hop_T


def pipeline_latency(stage_T: np.ndarray, hop_T: np.ndarray,
                     microbatches: int = 1,
                     link_bandwidths: np.ndarray | None = None
                     ) -> np.ndarray:
    """End-to-end chain latency with M microbatches (GPipe-style).

    Each whole-batch unit time T (stage computes and hop transfers,
    interleaved) becomes M per-microbatch units of T/M; the first
    microbatch fills the pipeline in sum(T)/M and the remaining M-1
    drain behind the slowest unit:

        latency = (sum_i T_i + (M - 1) * max_i T_i) / M

    M=1 reduces exactly to the sequential sum the two-tier paper model
    uses.  ``link_bandwidths`` (per hop, bytes/s) prices the extra
    framing headers the M-way split puts on each hop -- the term that
    keeps the optimiser honest about oversplitting tiny boundaries."""
    if microbatches < 1:
        raise ValueError(
            f"microbatches must be >= 1, got {microbatches}")
    # Interleave [stage0, hop0, stage1, hop1, ..., stageK-1] -- the actual
    # pipeline unit order (and, for K=2 at M=1, the exact t_c + t_u + t_s
    # summation order of the two-tier model).
    n, K = stage_T.shape
    units = np.zeros((n, 2 * K - 1))
    units[:, 0::2] = stage_T
    units[:, 1::2] = hop_T
    total = units.sum(axis=1)
    if microbatches == 1:
        return total
    lat = (total + (microbatches - 1) * units.max(axis=1)) / microbatches
    if link_bandwidths is not None:
        overhead = (microbatches - 1) * FRAME_HEADER_BYTES
        lat = lat + (overhead / np.asarray(link_bandwidths, float)).sum()
    return lat


def chain_feasible_mask(profile: ModelProfile, hw: ChainHardware,
                        genomes: np.ndarray) -> np.ndarray:
    """Chain constraints: every stage non-empty, every tier within its
    memory budget (the K-tier Eq. 17)."""
    edges = _chain_edges(profile, genomes)
    cm = profile.cum_mem()
    ok = (np.diff(edges, axis=1) >= 1).all(axis=1)
    for k, tier in enumerate(hw.tiers):
        m_k = cm[edges[:, k + 1]] - cm[edges[:, k]]
        ok &= m_k <= tier.memory_budget
    return ok


def evaluate_chain_objectives(profile: ModelProfile, hw: ChainHardware,
                              genomes: np.ndarray, f3_mode: str = "full",
                              microbatches: int = 1,
                              wire=None) -> np.ndarray:
    """(n, 3) chain objectives -- the exact K-tier generalisation of
    ``evaluate_objectives``.

    f1: pipeline latency over stage computes + hop uploads (download
        excluded per paper Eq. 5; M=1 degenerates to the sequential sum,
        so a K=2 chain reproduces the two-tier rows bit-for-bit).
    f2: battery-billed energy -- every tier except the terminal one
        (the paper's Eq. 13 server exemption, generalised: the core end
        is grid-powered) plus per-hop transfer energy and the download
        radio term on hop 0 (the device's radio).
    f3: first-tier memory, ``client_memory`` semantics (constraints on
        the other tiers' budgets live in ``chain_feasible_mask``)."""
    edges = _chain_edges(profile, genomes)
    cf = profile.cum_flops()
    cm = profile.cum_mem()
    ws = resolve_chain_wire(wire, len(hw.links), profile.dtype)
    stage_T, hop_T = chain_stage_hop_times(profile, hw, genomes, wire=ws)
    bws = np.array([link.bandwidth for link in hw.links])
    lat = pipeline_latency(stage_T, hop_T, microbatches,
                           link_bandwidths=bws)

    en = np.zeros(edges.shape[0])
    for k, tier in enumerate(hw.tiers[:-1]):
        if tier.is_roofline:
            f_k = cf[edges[:, k + 1]] - cf[edges[:, k]]
            m_k = cm[edges[:, k + 1]] - cm[edges[:, k]]
            en += (f_k * tier.pj_per_flop
                   + m_k * tier.pj_per_hbm_byte) * 1e-12
        else:
            en += tier.compute_power_w() * stage_T[:, k]
    for k, link in enumerate(hw.links):
        b_k = profile.wire_boundary(ws[k])[edges[:, k + 1]]
        if link.pj_per_byte:
            en += b_k * link.pj_per_byte * 1e-12
        else:
            en += link.upload_power_w(link.bandwidth) * hop_T[:, k]
    # result download, charged on the device's hop-0 radio (Eq. 12),
    # priced in hop 0's wire format
    down = hw.links[0]
    d_bytes = download_wire_bytes(hw.download_bytes, ws[0])
    if down.pj_per_byte:
        en += d_bytes * down.pj_per_byte * 1e-12
    else:
        en += down.download_power_w(down.bandwidth) \
            * (d_bytes / down.bandwidth)
    if microbatches > 1:
        extra = (microbatches - 1) * FRAME_HEADER_BYTES
        for k, link in enumerate(hw.links):
            if link.pj_per_byte:
                en += extra * link.pj_per_byte * 1e-12
            else:
                en += link.upload_power_w(link.bandwidth) \
                    * (extra / link.bandwidth)

    mem = client_memory(profile, f3_mode)[edges[:, 1]]
    return np.stack([lat, en, mem], axis=1)


def check_profile(profile: ModelProfile) -> None:
    """Sanity-check invariants every profile must satisfy."""
    assert profile.num_layers >= 2, profile.name
    for l in profile.layers:
        assert l.flops >= 0 and l.param_bytes >= 0 and l.act_bytes >= 0, l
        assert l.boundary_bytes >= 0 and l.state_bytes >= 0, l
    assert profile.input_bytes > 0
