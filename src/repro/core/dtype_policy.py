"""The conv-path storage-dtype policy (fp32 | bf16).

SmartSplit's objectives are dominated by bytes: per-layer memory on the
client, and the boundary activation shipped across the link.  Storing conv
weights/activations in bf16 -- while keeping the kernel's fp32 accumulator
-- halves per-tile VMEM (bigger ``tile_h``, fewer launches), halves the
split-boundary transfer payload, and doubles effective MXU throughput.

One policy string is plumbed end to end:

* kernels (``repro.kernels.ops.conv2d``): cast storage, accumulate fp32;
* models (``repro.models.cnn.apply_cnn``): activations flow in the policy
  dtype, boundary payloads are serialized in it;
* cost model (``repro.models.profiles`` / ``repro.core.costs``): memory and
  transfer terms scale with ``dtype_bytes`` so the optimiser can choose
  splits that are only feasible at bf16;
* split executors (``repro.launch.smartsplit_exec``): the inter-pod
  boundary tensor crosses the link in the policy dtype.

Resolution order everywhere: explicit ``dtype=`` argument, else the
``REPRO_CONV_DTYPE`` env var, else ``fp32``.  ``fp32`` is the no-downcast
default: tensors keep whatever dtype they already have.
"""
from __future__ import annotations

import os

ENV_VAR = "REPRO_CONV_DTYPE"

CONV_DTYPES = ("fp32", "bf16")

_DTYPE_BYTES = {"fp32": 4, "bf16": 2}


def conv_dtype(dtype: str | None = None) -> str:
    """Resolve the storage-dtype policy *now* (mirrors ``conv_backend``).

    Explicit argument wins, else ``REPRO_CONV_DTYPE``, else ``fp32``."""
    d = dtype or os.environ.get(ENV_VAR, "fp32")
    if d not in CONV_DTYPES:
        source = "dtype argument" if dtype else ENV_VAR
        raise ValueError(f"{source} must be one of {CONV_DTYPES}, got {d!r}")
    return d


def dtype_bytes(policy: str) -> int:
    """Bytes per element stored under ``policy``."""
    return _DTYPE_BYTES[conv_dtype(policy)]


def policy_jnp_dtype(policy: str):
    """The jnp dtype tensors are stored in under ``policy``.

    Imported lazily so the numpy-only core modules stay jax-free."""
    import jax.numpy as jnp

    return {"fp32": jnp.float32, "bf16": jnp.bfloat16}[conv_dtype(policy)]
