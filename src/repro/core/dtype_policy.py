"""The conv-path storage-dtype policy (fp32 | bf16).

SmartSplit's objectives are dominated by bytes: per-layer memory on the
client, and the boundary activation shipped across the link.  Storing conv
weights/activations in bf16 -- while keeping the kernel's fp32 accumulator
-- halves per-tile VMEM (bigger ``tile_h``, fewer launches), halves the
split-boundary transfer payload, and doubles effective MXU throughput.

One policy string is plumbed end to end:

* kernels (``repro.kernels.ops.conv2d``): cast storage, accumulate fp32;
* models (``repro.models.cnn.apply_cnn``): activations flow in the policy
  dtype, boundary payloads are serialized in it;
* cost model (``repro.models.profiles`` / ``repro.core.costs``): memory and
  transfer terms scale with ``dtype_bytes`` so the optimiser can choose
  splits that are only feasible at bf16;
* split executors (``repro.launch.smartsplit_exec``): the inter-pod
  boundary tensor crosses the link in the policy dtype.

Resolution order everywhere: explicit ``dtype=`` argument, else the
``REPRO_CONV_DTYPE`` env var, else ``fp32``.  ``fp32`` is the no-downcast
default: tensors keep whatever dtype they already have.

On top of the storage policy sits the *wire*-dtype tier: the format a
split-boundary activation takes while crossing a link may differ from the
format it is stored/computed in.  ``REPRO_WIRE_DTYPE`` picks the chain-wide
wire policy (``follow`` ships the storage dtype unchanged -- the legacy
path, bit-identical); ``REPRO_LINK{k}_WIRE_DTYPE`` overrides it for hop
``k`` (a WiFi device->edge hop wants int8 while an Ethernet edge->core hop
may not).  ``int8`` means per-channel symmetric quantization: a 1-byte
payload element plus one fp32 scale per channel (see
``repro.kernels.quant``), priced by ``core.costs`` and executed by
``runtime.wire``.
"""
from __future__ import annotations

import os

ENV_VAR = "REPRO_CONV_DTYPE"

CONV_DTYPES = ("fp32", "bf16")

WIRE_ENV_VAR = "REPRO_WIRE_DTYPE"

# "follow" = ship the storage dtype as-is (no re-encode; the default and
# the bit-identical legacy behaviour).  The rest force a wire format.
WIRE_DTYPES = ("follow", "fp32", "bf16", "int8")

_DTYPE_BYTES = {"fp32": 4, "bf16": 2}

# Bytes per *payload* element on the wire (scales/framing priced separately
# by core.costs for int8).
WIRE_PAYLOAD_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


def conv_dtype(dtype: str | None = None) -> str:
    """Resolve the storage-dtype policy *now* (mirrors ``conv_backend``).

    Explicit argument wins, else ``REPRO_CONV_DTYPE``, else ``fp32``."""
    d = dtype or os.environ.get(ENV_VAR, "fp32")
    if d not in CONV_DTYPES:
        source = "dtype argument" if dtype else ENV_VAR
        raise ValueError(f"{source} must be one of {CONV_DTYPES}, got {d!r}")
    return d


def dtype_bytes(policy: str) -> int:
    """Bytes per element stored under ``policy``."""
    return _DTYPE_BYTES[conv_dtype(policy)]


def policy_jnp_dtype(policy: str):
    """The jnp dtype tensors are stored in under ``policy``.

    Imported lazily so the numpy-only core modules stay jax-free."""
    import jax.numpy as jnp

    return {"fp32": jnp.float32, "bf16": jnp.bfloat16}[conv_dtype(policy)]


# ---------------------------------------------------------------------------
# Wire-dtype tier
# ---------------------------------------------------------------------------
def _check_wire(value: str, source: str) -> str:
    if value not in WIRE_DTYPES:
        raise ValueError(
            f"{source} must be one of {WIRE_DTYPES}, got {value!r}")
    return value


def wire_dtype(wire: str | None = None, hop: int | None = None) -> str:
    """Resolve the wire-dtype policy *now* (may still be ``follow``).

    Explicit argument wins, else the per-hop ``REPRO_LINK{hop}_WIRE_DTYPE``
    env var (when ``hop`` is given -- mirrors the per-hop fault knobs),
    else chain-wide ``REPRO_WIRE_DTYPE``, else ``follow``."""
    if wire is not None:
        return _check_wire(wire, "wire argument")
    if hop is not None:
        per_hop = os.environ.get(f"REPRO_LINK{hop}_WIRE_DTYPE")
        if per_hop is not None:
            return _check_wire(per_hop, f"REPRO_LINK{hop}_WIRE_DTYPE")
    return _check_wire(os.environ.get(WIRE_ENV_VAR, "follow"), WIRE_ENV_VAR)


def resolve_wire_dtype(wire: str | None = None, *,
                       storage: str | None = None,
                       hop: int | None = None) -> str:
    """The concrete wire format for one hop: ``fp32 | bf16 | int8``.

    ``follow`` (the default policy) resolves to the storage dtype, i.e. the
    boundary crosses the link exactly as stored -- the legacy byte stream."""
    w = wire_dtype(wire, hop=hop)
    if w == "follow":
        return conv_dtype(storage)
    return w


def wire_payload_bytes_per_elem(wire: str) -> int:
    """Bytes per payload element for a concrete (non-``follow``) format."""
    try:
        return WIRE_PAYLOAD_BYTES[wire]
    except KeyError:
        raise ValueError(
            f"wire format must be one of {tuple(WIRE_PAYLOAD_BYTES)}, "
            f"got {wire!r}") from None
