"""TOPSIS decision analysis (paper Section V-B, Algorithm 1 lines 2-7).

Paper variant: column-normalise the decision matrix, drop constraint
violators (the reduced matrix F''), take the per-objective minimum as the
ideal point, and pick the solution with the minimum Euclidean distance to
it.  The classical TOPSIS closeness coefficient (distance to anti-ideal /
(d+ + d-)) is provided as an option; the paper uses ideal-distance only and
that is the default everywhere."""
from __future__ import annotations

import math

import numpy as np


def column_normalise(F: np.ndarray) -> np.ndarray:
    """Vector (L2) column normalisation -- standard TOPSIS step 1."""
    F = np.asarray(F, float)
    norms = np.linalg.norm(F, axis=0)
    norms = np.where(norms == 0, 1.0, norms)
    return F / norms


def topsis_rank(F: np.ndarray,
                feasible: np.ndarray | None = None,
                weights: np.ndarray | None = None,
                use_anti_ideal: bool = False) -> np.ndarray:
    """Full TOPSIS preference order: feasible row indices, best first.

    Same normalisation/weighting/distance as ``topsis_select`` -- the
    selection is ``rank[0]`` -- but exposing the whole ordering lets the
    fault-tolerant runtime walk "next-best feasible split" without
    re-running the analysis after each failure.

    F: (n, m) objective matrix, all objectives minimised.
    feasible: optional boolean mask; infeasible rows are removed before the
      ideal point is computed (the paper's F' -> F'' reduction).
    weights: optional per-objective weights applied after normalisation.
    """
    F = np.asarray(F, float)
    n = F.shape[0]
    if feasible is None:
        feasible = np.ones(n, bool)
    idx = np.where(feasible)[0]
    if idx.size == 0:
        raise ValueError("TOPSIS: no feasible solutions")
    Fn = column_normalise(F)[idx]
    if weights is not None:
        Fn = Fn * np.asarray(weights, float)
    ideal = Fn.min(axis=0)
    d_plus = np.sqrt(((Fn - ideal) ** 2).sum(axis=1))
    if use_anti_ideal:
        anti = Fn.max(axis=0)
        d_minus = np.sqrt(((Fn - anti) ** 2).sum(axis=1))
        denom = d_plus + d_minus
        denom = np.where(denom == 0, 1.0, denom)
        # maximise closeness == minimise -closeness (stable sort keeps the
        # first-listed solution on ties, matching argmax/argmin semantics)
        order = np.argsort(-d_minus / denom, kind="stable")
    else:
        order = np.argsort(d_plus, kind="stable")
    return idx[order]


def topsis_select(F: np.ndarray,
                  feasible: np.ndarray | None = None,
                  weights: np.ndarray | None = None,
                  use_anti_ideal: bool = False) -> int:
    """Return the index (into F's rows) of the TOPSIS-chosen solution.

    See ``topsis_rank`` for parameter semantics; this is ``rank[0]``."""
    return int(topsis_rank(F, feasible=feasible, weights=weights,
                           use_anti_ideal=use_anti_ideal)[0])


def link_weights(bandwidth_ratio: float,
                 base: tuple[float, float, float] = (1.0, 1.0, 1.0)
                 ) -> np.ndarray:
    """Per-objective TOPSIS weights for a re-pick under a changed link.

    ``bandwidth_ratio`` is planned/current bandwidth (> 1 means the link
    degraded).  The latency objective f1 carries the upload term I|l1 / B
    linearly, so its weight scales by the full ratio; client energy f2
    contains the radio term (also ~1/B) diluted by compute energy, so it
    scales by sqrt(ratio); the memory objective f3 is link-independent.
    Under a degraded link this steers the pick toward splits with smaller
    boundary payloads; ratio 1 reduces to ``base`` (classic TOPSIS)."""
    r = float(bandwidth_ratio)
    if not np.isfinite(r) or r <= 0:
        raise ValueError(f"bandwidth_ratio must be positive, got {r}")
    w = np.asarray(base, float).copy()
    w[0] *= r
    w[1] *= math.sqrt(r)
    return w


def chain_link_weights(bandwidth_ratios,
                       base: tuple[float, float, float] = (1.0, 1.0, 1.0)
                       ) -> np.ndarray:
    """Per-objective weights for a chain re-pick under per-hop degradation.

    ``bandwidth_ratios`` holds one planned/current ratio per hop.  The
    pipeline latency term is dominated by the slowest unit, and every hop's
    payload enters f1/f2 through the same 1/B structure as the two-tier
    case, so the re-weighting is driven by the *worst* hop: a chain is as
    degraded as its most degraded link.  Degenerates to ``link_weights``
    for a single hop."""
    ratios = [float(r) for r in bandwidth_ratios]
    if not ratios:
        raise ValueError("chain_link_weights needs >= 1 bandwidth ratio")
    return link_weights(max(ratios), base=base)
