"""TOPSIS decision analysis (paper Section V-B, Algorithm 1 lines 2-7).

Paper variant: column-normalise the decision matrix, drop constraint
violators (the reduced matrix F''), take the per-objective minimum as the
ideal point, and pick the solution with the minimum Euclidean distance to
it.  The classical TOPSIS closeness coefficient (distance to anti-ideal /
(d+ + d-)) is provided as an option; the paper uses ideal-distance only and
that is the default everywhere."""
from __future__ import annotations

import numpy as np


def column_normalise(F: np.ndarray) -> np.ndarray:
    """Vector (L2) column normalisation -- standard TOPSIS step 1."""
    F = np.asarray(F, float)
    norms = np.linalg.norm(F, axis=0)
    norms = np.where(norms == 0, 1.0, norms)
    return F / norms


def topsis_select(F: np.ndarray,
                  feasible: np.ndarray | None = None,
                  weights: np.ndarray | None = None,
                  use_anti_ideal: bool = False) -> int:
    """Return the index (into F's rows) of the TOPSIS-chosen solution.

    F: (n, m) objective matrix, all objectives minimised.
    feasible: optional boolean mask; infeasible rows are removed before the
      ideal point is computed (the paper's F' -> F'' reduction).
    weights: optional per-objective weights applied after normalisation.
    """
    F = np.asarray(F, float)
    n = F.shape[0]
    if feasible is None:
        feasible = np.ones(n, bool)
    idx = np.where(feasible)[0]
    if idx.size == 0:
        raise ValueError("TOPSIS: no feasible solutions")
    Fn = column_normalise(F)[idx]
    if weights is not None:
        Fn = Fn * np.asarray(weights, float)
    ideal = Fn.min(axis=0)
    d_plus = np.sqrt(((Fn - ideal) ** 2).sum(axis=1))
    if use_anti_ideal:
        anti = Fn.max(axis=0)
        d_minus = np.sqrt(((Fn - anti) ** 2).sum(axis=1))
        denom = d_plus + d_minus
        denom = np.where(denom == 0, 1.0, denom)
        closeness = d_minus / denom
        best = int(np.argmax(closeness))
    else:
        best = int(np.argmin(d_plus))
    return int(idx[best])
