"""The unified split-plan abstraction: an ordered chain of K stages.

Every planner output in the repo is a ``ChainPlan``: ``smartsplit()`` /
``smartsplit_exhaustive()`` return the degenerate K=2 instance (one cut,
one link -- the paper's phone/cloud split), ``smartsplit_multicut()`` /
``smartsplit_chain()`` return the general K-tier case.  ``SplitPlan`` and
``MultiCutPlan`` are aliases of this class, kept so existing callers (and
the paper-faithful tests) read naturally.

A plan carries everything the runtime needs to *execute and degrade*
without re-running the optimiser: the picked cuts, the cached Pareto
front over cut vectors, the per-hop ``LinkProfile``s the objectives were
priced against, and the microbatch count the pipeline latency term
assumed.  ``runtime.ChainRuntime`` walks the stages, re-picks from the
cached front under per-hop bandwidth estimates, and collapses cuts
(``merge_hop``) when a hop dies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.hardware import LinkProfile


@dataclasses.dataclass(frozen=True)
class ChainPlan:
    """K-stage placement of ``num_layers`` layers over a tier chain.

    cuts: K-1 strictly-increasing layer indices; stage k runs layers
      ``[edges[k], edges[k+1])`` with ``edges = (0, *cuts, L)``.
    pareto_cuts: (n, K-1) cut vectors of the cached Pareto front (rows
      sorted ascending) -- the runtime re-pick search space.
    pareto_F: (n, 3) objective rows matching ``pareto_cuts``.
    links: the K-1 nominal per-hop link profiles the plan assumed.
    tiers: the K tier names (``tiers[0]`` is the legacy ``hardware``
      field of the old two-tier SplitPlan).
    microbatches: pipeline depth M the latency objective was priced at
      (1 = sequential stage execution).
    wire_dtypes: the concrete per-hop wire formats (``fp32``/``bf16``/
      ``int8``) the objectives were priced under -- () on plans from
      before the wire tier (the runtime then resolves from env).
    """

    model: str
    num_layers: int
    cuts: tuple[int, ...]
    objectives: tuple[float, float, float]   # (latency s, energy J, mem)
    pareto_cuts: np.ndarray
    pareto_F: np.ndarray
    links: tuple[LinkProfile, ...]
    tiers: tuple[str, ...]
    microbatches: int = 1
    wire_dtypes: tuple[str, ...] = ()

    def __post_init__(self):
        L = self.num_layers
        for c in self.cuts:
            if not 1 <= c <= L - 1:
                raise ValueError(
                    f"ChainPlan cut {c} out of range [1, {L - 1}] "
                    f"for a {L}-layer model")
        for a, b in zip(self.cuts, self.cuts[1:]):
            if b <= a:
                raise ValueError(
                    f"ChainPlan cuts must be strictly increasing, got "
                    f"{self.cuts}")
        if len(self.tiers) != len(self.cuts) + 1:
            raise ValueError(
                f"ChainPlan tier/cut mismatch: {len(self.cuts)} cuts "
                f"need {len(self.cuts) + 1} tiers, got {len(self.tiers)}")
        if len(self.links) != len(self.tiers) - 1:
            raise ValueError(
                f"ChainPlan tier/link mismatch: {len(self.tiers)} tiers "
                f"need {len(self.tiers) - 1} links, got {len(self.links)}")
        if self.microbatches < 1:
            raise ValueError(
                f"ChainPlan microbatches must be >= 1, got "
                f"{self.microbatches}")
        if self.wire_dtypes and len(self.wire_dtypes) != len(self.links):
            raise ValueError(
                f"ChainPlan wire/link mismatch: {len(self.links)} links "
                f"need {len(self.links)} wire dtypes, got "
                f"{len(self.wire_dtypes)}")

    # -- chain views ----------------------------------------------------
    @property
    def num_tiers(self) -> int:
        return len(self.cuts) + 1

    @property
    def edges(self) -> tuple[int, ...]:
        return (0,) + self.cuts + (self.num_layers,)

    def stages(self, L: int | None = None) -> list[tuple[int, int]]:
        """Per-stage (start, stop) layer ranges.  ``L`` is accepted for
        back-compat with the old ``MultiCutPlan.stages(L)`` call shape
        and must match ``num_layers`` when given."""
        if L is not None and L != self.num_layers:
            raise ValueError(
                f"stages(L={L}) disagrees with plan num_layers="
                f"{self.num_layers}")
        e = self.edges
        return [(e[i], e[i + 1]) for i in range(len(e) - 1)]

    def merge_hop(self, hop: int) -> "ChainPlan":
        """Collapse cut ``hop``: stage ``hop+1``'s layers fold into stage
        ``hop``'s tier and the hop's link drops out of the chain -- the
        planning-side mirror of the runtime's stage-merge degradation.
        The cached front is not carried over (it indexes the old cut
        arity)."""
        if not 0 <= hop < len(self.cuts):
            raise ValueError(
                f"merge_hop: hop must be in [0, {len(self.cuts) - 1}], "
                f"got {hop}")
        cuts = self.cuts[:hop] + self.cuts[hop + 1:]
        wires = self.wire_dtypes
        if wires:
            wires = wires[:hop] + wires[hop + 1:]
        return dataclasses.replace(
            self, cuts=cuts,
            pareto_cuts=np.empty((0, len(cuts)), np.int64),
            pareto_F=np.empty((0, 3)),
            links=self.links[:hop] + self.links[hop + 1:],
            tiers=self.tiers[:hop + 1] + self.tiers[hop + 2:],
            wire_dtypes=wires)

    # -- two-tier (K=2) legacy surface ---------------------------------
    @property
    def split_index(self) -> int:
        """l1 of the paper's single split (K=2 plans only)."""
        if len(self.cuts) != 1:
            raise ValueError(
                f"split_index is a two-tier view; this plan has "
                f"{len(self.cuts)} cuts")
        return self.cuts[0]

    @property
    def pareto_indices(self) -> tuple[int, ...]:
        """Pareto-set split indices (K=2 plans only; plot/test surface)."""
        if self.pareto_cuts.ndim != 2 or self.pareto_cuts.shape[1] != 1:
            raise ValueError(
                "pareto_indices is a two-tier view; use pareto_cuts")
        return tuple(int(c) for c in self.pareto_cuts[:, 0])

    @property
    def hardware(self) -> str:
        """Legacy SplitPlan field: the first (client/device) tier name."""
        return self.tiers[0]

    @property
    def client_layers(self) -> int:
        return self.split_index

    @property
    def server_layers(self) -> int:
        return self.num_layers - self.split_index


# The legacy names: the paper's two-tier plan and the beyond-paper K-cut
# plan are the same abstraction now.
SplitPlan = ChainPlan
MultiCutPlan = ChainPlan
