"""SmartSplit core: cost models, NSGA-II, TOPSIS, the split planner and the
paper's competing baselines."""
from repro.core.baselines import ALGORITHMS, coc, cos, ebo, lbo, mbo, rs
from repro.core.costs import (LayerProfile, ModelProfile, client_memory,
                              energy_terms, evaluate_objectives,
                              feasible_mask, latency_terms, total_energy,
                              total_latency)
from repro.core.dtype_policy import (CONV_DTYPES, conv_dtype, dtype_bytes,
                                     policy_jnp_dtype)
from repro.core.hardware import (PAPER_ENV_J6, PAPER_ENV_NOTE8, PROFILES,
                                 TPU_EDGE_CLOUD, TPU_TWO_POD, DeviceTier,
                                 LinkProfile, NetworkState, TwoTierHardware,
                                 tpu_pod_tier)
from repro.core.nsga2 import NSGA2Config, NSGA2Result, nsga2
from repro.core.pareto import (crowding_distance, exhaustive_pareto,
                               non_dominated_sort, pareto_front_mask)
from repro.core.smartsplit import (SplitPlan, repick_split, smartsplit,
                                   smartsplit_exhaustive)
from repro.core.topsis import (column_normalise, link_weights, topsis_rank,
                               topsis_select)

__all__ = [
    "ALGORITHMS", "coc", "cos", "ebo", "lbo", "mbo", "rs",
    "LayerProfile", "ModelProfile", "client_memory", "energy_terms",
    "evaluate_objectives", "feasible_mask", "latency_terms", "total_energy",
    "total_latency",
    "CONV_DTYPES", "conv_dtype", "dtype_bytes", "policy_jnp_dtype",
    "PAPER_ENV_J6", "PAPER_ENV_NOTE8", "PROFILES", "TPU_EDGE_CLOUD",
    "TPU_TWO_POD", "DeviceTier", "LinkProfile", "NetworkState",
    "TwoTierHardware", "tpu_pod_tier",
    "NSGA2Config", "NSGA2Result", "nsga2",
    "crowding_distance", "exhaustive_pareto", "non_dominated_sort",
    "pareto_front_mask",
    "SplitPlan", "repick_split", "smartsplit", "smartsplit_exhaustive",
    "column_normalise", "link_weights", "topsis_rank", "topsis_select",
]
