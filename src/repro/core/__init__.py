"""SmartSplit core: cost models, NSGA-II, TOPSIS, the split planner and the
paper's competing baselines."""
from repro.core.baselines import ALGORITHMS, coc, cos, ebo, lbo, mbo, rs
from repro.core.chainplan import ChainPlan, MultiCutPlan, SplitPlan
from repro.core.costs import (FRAME_HEADER_BYTES, INT8_FRAME_OVERHEAD_BYTES,
                              MULTIPART_BASE_BYTES, PART_HEADER_BYTES,
                              WIRE_SCALE_BYTES, LayerProfile, ModelProfile,
                              chain_feasible_mask, chain_stage_hop_times,
                              client_memory, download_wire_bytes,
                              energy_terms, evaluate_chain_objectives,
                              evaluate_objectives, feasible_mask,
                              latency_terms, pipeline_latency,
                              resolve_chain_wire, total_energy,
                              total_latency)
from repro.core.dtype_policy import (CONV_DTYPES, WIRE_DTYPES, conv_dtype,
                                     dtype_bytes, policy_jnp_dtype,
                                     resolve_wire_dtype, wire_dtype,
                                     wire_payload_bytes_per_elem)
from repro.core.hardware import (ETH_100MBPS, ETH_1GBPS, PAPER_CORE,
                                 PAPER_EDGE, PAPER_ENV_J6, PAPER_ENV_NOTE8,
                                 PAPER_REGIONAL, PROFILES, TPU_EDGE_CLOUD,
                                 TPU_TWO_POD, ChainHardware, DeviceTier,
                                 LinkProfile, NetworkState, TwoTierHardware,
                                 chain_of, paper_chain, tpu_pod_tier)
from repro.core.multicut import (evaluate_multicut, repick_chain,
                                 smartsplit_chain, smartsplit_multicut)
from repro.core.nsga2 import NSGA2Config, NSGA2Result, nsga2
from repro.core.pareto import (crowding_distance, exhaustive_pareto,
                               non_dominated_sort, pareto_front_mask)
from repro.core.smartsplit import (repick_split, smartsplit,
                                   smartsplit_exhaustive)
from repro.core.topsis import (chain_link_weights, column_normalise,
                               link_weights, topsis_rank, topsis_select)

__all__ = [
    "ALGORITHMS", "coc", "cos", "ebo", "lbo", "mbo", "rs",
    "ChainPlan", "MultiCutPlan", "SplitPlan",
    "FRAME_HEADER_BYTES", "INT8_FRAME_OVERHEAD_BYTES",
    "MULTIPART_BASE_BYTES", "PART_HEADER_BYTES", "WIRE_SCALE_BYTES",
    "LayerProfile", "ModelProfile",
    "chain_feasible_mask", "chain_stage_hop_times", "client_memory",
    "download_wire_bytes", "energy_terms", "evaluate_chain_objectives",
    "evaluate_objectives", "feasible_mask", "latency_terms",
    "pipeline_latency", "resolve_chain_wire", "total_energy",
    "total_latency",
    "CONV_DTYPES", "WIRE_DTYPES", "conv_dtype", "dtype_bytes",
    "policy_jnp_dtype", "resolve_wire_dtype", "wire_dtype",
    "wire_payload_bytes_per_elem",
    "ETH_100MBPS", "ETH_1GBPS", "PAPER_CORE", "PAPER_EDGE", "PAPER_ENV_J6",
    "PAPER_ENV_NOTE8", "PAPER_REGIONAL", "PROFILES", "TPU_EDGE_CLOUD",
    "TPU_TWO_POD", "ChainHardware", "DeviceTier", "LinkProfile",
    "NetworkState", "TwoTierHardware", "chain_of", "paper_chain",
    "tpu_pod_tier",
    "evaluate_multicut", "repick_chain", "smartsplit_chain",
    "smartsplit_multicut",
    "NSGA2Config", "NSGA2Result", "nsga2",
    "crowding_distance", "exhaustive_pareto", "non_dominated_sort",
    "pareto_front_mask",
    "repick_split", "smartsplit", "smartsplit_exhaustive",
    "chain_link_weights", "column_normalise", "link_weights", "topsis_rank",
    "topsis_select",
]
