"""Competing algorithms from paper Section VI-C.

LBO  -- latency-based optimisation: argmin f1 over feasible splits.
EBO  -- energy-based optimisation:  argmin f2.
MBO  -- memory-based optimisation:  argmin f3 (implied by f3; beyond-paper
        completeness -- trivially l1=1, included for the ablation).
COS  -- CNN on smartphone: l1 = L.
COC  -- CNN on cloud:      l1 = 0.
RS   -- random split, uniform over [1, L-1] per run.
"""
from __future__ import annotations

import numpy as np

from repro.core.costs import (ModelProfile, evaluate_objectives,
                              feasible_mask)
from repro.core.hardware import TwoTierHardware


def _argmin_feasible(F: np.ndarray, feas: np.ndarray, col: int) -> int:
    masked = np.where(feas, F[:, col], np.inf)
    return int(np.argmin(masked))


def lbo(profile: ModelProfile, hw: TwoTierHardware) -> int:
    F = evaluate_objectives(profile, hw)
    return _argmin_feasible(F, feasible_mask(profile, hw), 0)


def ebo(profile: ModelProfile, hw: TwoTierHardware) -> int:
    F = evaluate_objectives(profile, hw)
    return _argmin_feasible(F, feasible_mask(profile, hw), 1)


def mbo(profile: ModelProfile, hw: TwoTierHardware) -> int:
    F = evaluate_objectives(profile, hw)
    return _argmin_feasible(F, feasible_mask(profile, hw), 2)


def cos(profile: ModelProfile, hw: TwoTierHardware) -> int:  # noqa: ARG001
    return profile.num_layers


def coc(profile: ModelProfile, hw: TwoTierHardware) -> int:  # noqa: ARG001
    return 0


def rs(profile: ModelProfile, hw: TwoTierHardware,  # noqa: ARG001
       rng: np.random.Generator | None = None) -> int:
    rng = rng or np.random.default_rng()
    return int(rng.integers(1, profile.num_layers))


ALGORITHMS = {"LBO": lbo, "EBO": ebo, "MBO": mbo, "COS": cos, "COC": coc,
              "RS": rs}
