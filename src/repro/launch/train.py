"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it drives the REDUCED config (full configs are
exercised via the dry-run); on a real TPU fleet the same entrypoint runs
the full config under the production mesh with the partition rules from
``launch/partition.py``."""
from __future__ import annotations

import argparse
import dataclasses

from repro.configs import all_configs
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(all_configs()))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) config -- TPU fleets")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = all_configs()[args.arch]
    if not args.full:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 512))
    tcfg = TrainConfig(steps=args.steps, batch=args.batch,
                       seq_len=args.seq_len, ckpt_dir=args.ckpt_dir)
    out = train(cfg, tcfg)
    print(f"final loss {out['losses'][-1][1]:.4f} "
          f"in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
