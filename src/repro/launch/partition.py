"""Sharding rules and jit-ready step functions.

Baseline sharding policy (guaranteed to lower for every assigned arch x
shape; section-Perf iterates on the chosen three):

* parameters -- explicit rules for embed/unembed/attention/MLP/MoE weights
  (tensor parallel over ``model``; expert parallel when E % model == 0),
  generic best-effort for everything else: shard the last dimension
  divisible by the model-axis size, replicate otherwise.
* batch / caches / optimiser state -- batch dims over (pod, data) when
  divisible; a best-effort model-axis dim for large cache tensors.

No shard_map here: the baseline relies on GSPMD propagation from these
anchors.  The SmartSplit two-stage executor (the paper's technique) lives
in ``launch/smartsplit_exec.py``."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.core.dtype_policy import conv_dtype, policy_jnp_dtype
from repro.launch.mesh import data_axes
from repro.models import transformer as T
from repro.training import optimizer as opt


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------
def _axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def best_effort_spec(shape: tuple, mesh, *, skip_dims: tuple = (),
                     batch_dim: int | None = None) -> P:
    """Shard batch_dim over (pod,data) if divisible; then the last other
    dim divisible by the model axis."""
    model = _axis_size(mesh, "model")
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    spec: list = [None] * len(shape)
    if batch_dim is not None and dsize > 1 \
            and shape[batch_dim] % dsize == 0:
        spec[batch_dim] = daxes if len(daxes) > 1 else daxes[0]
    if model > 1:
        for i in range(len(shape) - 1, -1, -1):
            if i in skip_dims or i == batch_dim or spec[i] is not None:
                continue
            if shape[i] % model == 0 and shape[i] >= model:
                spec[i] = "model"
                break
    return P(*spec)


FSDP_MIN_ELEMENTS = 1 << 22      # only bother sharding big leaves


def _maybe_fsdp(spec: P, shape: tuple, mesh, cfg=None) -> P:
    """§Perf P1 iter-2: additionally shard the largest still-replicated dim
    of big parameters over the data axes (FSDP/ZeRO-1 -- the optimiser
    moments mirror parameter shardings, so they shard too).  Enabled by
    default; REPRO_FSDP=0 restores the baseline.

    Applies only to non-recurrent patterns: inside the doubly-nested
    recurrent scans (mamba/zamba/rwkv) GSPMD cannot hoist the per-layer
    weight all-gathers and falls back to involuntary rematerialisation
    (measured: zamba train collective 1.6e12 -> 8.8e12 B, temp 461 GB)."""
    import os
    if os.environ.get("REPRO_FSDP", "1") != "1":
        return spec
    if cfg is not None and cfg.pattern in ("mamba", "rwkv"):
        return spec
    import numpy as _np
    if _np.prod(shape) < FSDP_MIN_ELEMENTS:
        return spec
    daxes = data_axes(mesh)
    if not daxes:
        return spec
    dsize = int(_np.prod([mesh.shape[a] for a in daxes]))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    if used & set(daxes):
        return spec          # a data axis is already in use on this leaf
    cands = [i for i, e in enumerate(entries)
             if e is None and shape[i] % dsize == 0 and shape[i] >= dsize]
    if not cands:
        return spec
    tgt = max(cands, key=lambda i: shape[i])
    entries[tgt] = daxes if len(daxes) > 1 else daxes[0]
    return P(*entries)


def _param_spec(path: str, shape: tuple, cfg: ModelConfig, mesh) -> P:
    """Explicit TP rules keyed on parameter name, generic fallback."""
    model = _axis_size(mesh, "model")
    stacked = path.startswith(("blocks/", "tail_blocks/"))
    lead = (0,) if stacked else ()
    name = path.split("/")[-1]

    def ok(dim_size):
        return model > 1 and dim_size % model == 0 and dim_size >= model

    nd = len(shape)
    if name == "embed" and ok(shape[0]):
        return P("model", *([None] * (nd - 1)))
    if name == "unembed" and ok(shape[-1]):
        return P(*([None] * (nd - 1)), "model")
    if name in ("wq", "wk", "wv", "wg", "wu", "ck", "wr", "wv_", "in_proj") \
            and nd >= 2 and ok(shape[-1]):
        return P(*([None] * (nd - 1)), "model")          # column parallel
    if name in ("wo", "wd", "cv", "out_proj") and nd >= 2 \
            and ok(shape[-2]):
        spec = [None] * nd
        spec[-2] = "model"                               # row parallel
        return P(*spec)
    if path.split("/")[-2:][0] == "moe" or "/moe/" in path:
        # expert-stacked weights (L, E, d, f) or (E, d, f)
        e_dim = 1 if stacked else 0
        if name in ("wg", "wu", "wd") and nd >= 3:
            if ok(shape[e_dim]):
                spec = [None] * nd
                spec[e_dim] = "model"                    # expert parallel
                return P(*spec)
            # granite: E=40 not divisible -> shard within-expert dim
            tgt = nd - 1 if name in ("wg", "wu") else nd - 2
            if ok(shape[tgt]):
                spec = [None] * nd
                spec[tgt] = "model"
                return P(*spec)
    # Small per-layer vectors (norm scales, token-shift mus, biases):
    # REPLICATE.  Sharding a (d,)-vector poisons every activation it
    # multiplies into a d-sharded layout, and each downstream projection
    # then all-gathers the full activation (section-Perf P3: 7 gathers of
    # (B,S,d) per rwkv layer; same pathology in every arch's norms).
    per_layer = int(np.prod(shape[1:] if stacked else shape))
    if per_layer <= 1 << 20:
        return P()
    return best_effort_spec(shape, mesh, skip_dims=lead)


def _tree_paths(tree) -> Any:
    """Map each leaf to its 'a/b/c' key path string."""
    paths = {}

    def walk(node, prefix):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{prefix}/{k}" if prefix else k)
        elif isinstance(node, (tuple, list)) and not hasattr(node, "shape"):
            for i, v in enumerate(node):
                walk(v, f"{prefix}/{i}")
        else:
            paths[prefix] = node
    walk(tree, "")
    return paths


def param_struct(cfg: ModelConfig, mesh, dtype=jnp.bfloat16,
                 mode: str = "train"):
    """ShapeDtypeStructs (no allocation) for params with shardings.

    FSDP data-axis sharding applies to training only (§Perf P1/P2):
    inference wants weights resident (model-sharded), not re-gathered
    every step."""
    shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype))

    def attach(path, leaf):
        spec = _param_spec(path, leaf.shape, cfg, mesh)
        # FSDP for PARAMETERS only pays off on MoE expert weights (their
        # replicated-over-data payload dominates); for dense weights the
        # in-loop re-gather regresses memory (qwen train 16 -> 174 GB/dev
        # measured).  Optimiser moments are ZeRO-sharded for everyone in
        # opt_state_struct (they live outside the layer loop).
        if mode == "train" and "moe/" in path:
            spec = _maybe_fsdp(spec, leaf.shape, mesh, cfg)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return _map_with_paths(shapes, attach)


def _map_with_paths(tree, fn, prefix=""):
    if isinstance(tree, dict):
        return {k: _map_with_paths(v, fn, f"{prefix}/{k}" if prefix else k)
                for k, v in tree.items()}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):   # NamedTuple
        return type(tree)(*[
            _map_with_paths(v, fn, f"{prefix}/{f}")
            for f, v in zip(tree._fields, tree)])
    if isinstance(tree, (tuple, list)):
        return type(tree)(
            _map_with_paths(v, fn, f"{prefix}/{i}")
            for i, v in enumerate(tree))
    if tree is None:
        return None
    return fn(prefix, tree)


def opt_state_struct(params_struct, cfg=None):
    """AdamW state structs: parameter shardings + ZeRO-1 data-axis
    sharding of the f32 moments (they are touched only at the update,
    outside the layer loop, so extra sharding is free of in-loop
    collectives -- section-Perf P1/global)."""
    def f32_like(leaf):
        spec = leaf.sharding.spec
        mesh = leaf.sharding.mesh
        spec = _maybe_fsdp(spec, leaf.shape, mesh, None)
        return jax.ShapeDtypeStruct(leaf.shape, jnp.float32,
                                    sharding=NamedSharding(mesh, spec))
    mu = jax.tree.map(f32_like, params_struct)
    nu = jax.tree.map(f32_like, params_struct)
    step = jax.ShapeDtypeStruct((), jnp.int32)
    return opt.AdamWState(step=step, mu=mu, nu=nu)


def batch_struct(cfg: ModelConfig, shape: InputShape, mesh,
                 dtype=jnp.bfloat16) -> dict:
    """Input ShapeDtypeStructs for one (arch, input-shape) cell."""
    B = shape.global_batch
    S = shape.seq_len if shape.mode != "decode" else 1
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    bspec = (daxes if len(daxes) > 1 else daxes[0]) \
        if dsize > 1 and B % dsize == 0 else None

    def tok(s):
        return jax.ShapeDtypeStruct(
            (B, s), jnp.int32, sharding=NamedSharding(mesh, P(bspec, None)))

    batch = {}
    if shape.mode == "train":
        if cfg.frontend == "audio":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, shape.seq_len, cfg.d_model), dtype,
                sharding=NamedSharding(mesh, P(bspec, None, None)))
            batch["labels"] = tok(shape.seq_len)
        elif cfg.frontend == "vision":
            n_patch = min(1024, shape.seq_len // 4)
            n_text = shape.seq_len - n_patch
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, n_patch, cfg.d_model), dtype,
                sharding=NamedSharding(mesh, P(bspec, None, None)))
            batch["tokens"] = tok(n_text)
            batch["labels"] = tok(n_text)
        else:
            batch["tokens"] = tok(shape.seq_len)
            batch["labels"] = tok(shape.seq_len)
    elif shape.mode == "prefill":
        if cfg.frontend == "audio":
            batch["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, shape.seq_len, cfg.d_model), dtype,
                sharding=NamedSharding(mesh, P(bspec, None, None)))
        else:
            batch["tokens"] = tok(shape.seq_len)
    else:   # decode: ONE token
        batch["tokens"] = tok(1)
    return batch


def cache_struct(cfg: ModelConfig, shape: InputShape, mesh,
                 dtype=jnp.bfloat16):
    """KV/SSM cache structs for decode shapes, best-effort sharded."""
    shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len, dtype))

    model = _axis_size(mesh, "model")

    def attach(path, leaf):
        if leaf.ndim == 0:
            return jax.ShapeDtypeStruct(
                leaf.shape, leaf.dtype,
                sharding=NamedSharding(mesh, P()))
        name = path.split("/")[-1]
        # KV caches (L, B, M, KV, hd): shard kv heads over `model` when
        # divisible; otherwise REPLICATE over model (sharding M or hd
        # forces an all-gather per layer in the attention contraction --
        # §Perf P2 measured it at 2.15 GB x layers per step; redundant
        # data-parallel decode attention is far cheaper).
        if name in ("k", "v") and leaf.ndim == 5:
            bspec = best_effort_spec((leaf.shape[1],), mesh,
                                     batch_dim=0)[0]
            if model > 1 and leaf.shape[3] % model == 0:
                # kv heads divide the model axis: head-sharded cache
                spec = P(None, bspec, None, "model", None)
            elif model > 1 and leaf.shape[2] % model == 0:
                # flash-decoding style: shard the sequence dim; softmax
                # over the sharded axis costs only tiny stat reductions
                spec = P(None, bspec, "model", None, None)
            else:
                spec = P(None, bspec, None, None, None)
        elif name == "slot_pos":
            spec = P(None, "model") if model > 1 \
                and leaf.ndim == 2 and leaf.shape[1] % model == 0 else P()
        elif name in ("x_tm", "x_cm"):
            # token-shift states (L, B, d) are tiny; sharding d poisons
            # every projection input via the shift-concat (section-Perf P3:
            # 7 full-activation all-gathers per layer)
            bspec = best_effort_spec((leaf.shape[1],), mesh,
                                     batch_dim=0)[0]
            spec = P(None, bspec, None)
        elif name in ("wkv", "h") and leaf.ndim == 5:
            # recurrent states (L, B, nh, hd, hd|ds): shard HEADS over
            # `model` to match the head-sharded projections -- sharding a
            # state feature dim forces per-layer gathers of the whole
            # scan input stream (§Perf P3: 4.8 s of all-gather).
            bspec = best_effort_spec((leaf.shape[1],), mesh,
                                     batch_dim=0)[0]
            nh_ok = model > 1 and leaf.shape[2] % model == 0
            spec = P(None, bspec, "model" if nh_ok else None, None, None)
        else:
            # other states: dim0 = layer, dim1 = batch
            bdim = 1 if leaf.ndim >= 2 else None
            spec = best_effort_spec(leaf.shape, mesh, skip_dims=(0,),
                                    batch_dim=bdim)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return _map_with_paths(shapes, attach)


def split_boundary_struct(cfg: ModelConfig, batch: int, seq_len: int,
                          mesh=None, dtype: str | None = None):
    """The tensor that crosses the client->server link under a SmartSplit
    placement, serialized in the storage-policy dtype.

    Returns ``(struct, nbytes)``: a ShapeDtypeStruct for the boundary
    hidden state (batch, seq_len, d_model) -- replicated over the mesh
    when one is given, since both pods touch it -- and its wire size in
    bytes, which is exactly the I|l1 the dtype-aware cost model feeds
    Eq. 4.  ``two_stage_apply(..., boundary_dtype=...)`` transfers this
    very tensor; keeping the accounting here means the planner, the
    executor, and the serving launcher can never disagree about the
    payload."""
    jdt = policy_jnp_dtype(conv_dtype(dtype))
    shape = (batch, seq_len, cfg.d_model)
    sharding = NamedSharding(mesh, P()) if mesh is not None else None
    struct = jax.ShapeDtypeStruct(shape, jdt, sharding=sharding)
    return struct, int(np.prod(shape)) * jnp.dtype(jdt).itemsize


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig | None = None,
                    unroll_layers: bool = False):
    ocfg = ocfg or opt.AdamWConfig()

    def train_step(params, opt_state, batch):
        def loss(p):
            l, metrics = T.loss_fn(cfg, p, batch, unroll_layers=unroll_layers)
            return l, metrics
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params, opt_state, om = opt.apply_updates(ocfg, params, grads,
                                                  opt_state)
        return params, opt_state, {"loss": l, **metrics, **om}
    return train_step


def make_prefill_step(cfg: ModelConfig, unroll_layers: bool = False):
    def prefill_step(params, batch, cache):
        logits, cache, _ = T.forward(cfg, params, batch, mode="prefill",
                                     cache=cache, unroll_layers=unroll_layers)
        return logits[:, -1:], cache
    return prefill_step


def make_encode_step(cfg: ModelConfig, unroll_layers: bool = False):
    """Encoder-only archs: prefill == full forward, no cache."""
    def encode_step(params, batch):
        logits, _, _ = T.forward(cfg, params, batch, mode="prefill",
                                 unroll_layers=unroll_layers)
        return logits
    return encode_step


def make_decode_step(cfg: ModelConfig, unroll_layers: bool = False):
    def serve_step(params, tokens, cache):
        return T.decode_step(cfg, params, tokens, cache,
                             unroll_layers=unroll_layers)
    return serve_step
