"""Production meshes.

A FUNCTION (not module-level state) so importing never touches jax device
initialisation -- the dry-run sets XLA_FLAGS before any jax call, and smoke
tests must keep seeing 1 CPU device."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod:   (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small host-device mesh for CPU integration tests."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the batch dimension shards over (pod joins data-parallel in the
    baseline multi-pod configuration)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
