"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots the bucketed batch engine on the reduced config, optionally planning
the SmartSplit placement first (prints the chosen split and its predicted
objective triple).

``--cnn <model>`` instead serves one of the paper's CNNs through the
fault-tolerant chain runtime (``repro.runtime``): plans a K-tier chain
placement (``--tiers``, K=2 being the paper's phone/cloud environment),
executes microbatch-pipelined requests across per-hop ``FaultyLink``s
whose fault profiles come from ``REPRO_LINK_*`` / ``REPRO_LINK{k}_*``
env knobs (or ``--drop``), and reports recoveries -- retries, stage
merges, Pareto-front re-picks -- next to throughput.  ``--tier-faults
{crash,straggler,shed}`` layers a canned compute-side chaos profile on
the first server tier (over any ``REPRO_TIER_*`` / ``REPRO_TIER{k}_*``
env config), exercising circuit breakers and standby-tier failover."""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs
from repro.core import CONV_DTYPES, TPU_EDGE_CLOUD, WIRE_DTYPES, smartsplit
from repro.core.dtype_policy import conv_dtype
from repro.core.dtype_policy import dtype_bytes as policy_bytes
from repro.launch.partition import split_boundary_struct
from repro.models import transformer as T
from repro.models.profiles import transformer_profile
from repro.serving.engine import Engine


def _tier_fault_models(profile, hw, clock):
    """Per-tier ``FaultyTier`` list for ``--tier-faults`` / env knobs.

    Env knobs (``REPRO_TIER_*`` / ``REPRO_TIER{k}_*``) are the baseline;
    a canned ``--tier-faults`` profile then replaces the first server
    tier's spec (never the phone -- tier 0 failing has no failover
    story).  Returns ``None`` when everything is fault free so callers
    keep the unprotected legacy runtime path."""
    from repro.runtime.tier_faults import (FaultyTier, TierFaultSpec,
                                           tier_faults_from_env)
    names = [t.name for t in hw.tiers]
    tiers = tier_faults_from_env(names, clock=clock)
    if profile is None:
        if all(t.faults.fault_free for t in tiers):
            return None
        return tiers
    canned = {
        # dies for the first quarter-second of virtual time: every early
        # request hits the window -> breaker trips -> standby failover
        "crash": TierFaultSpec(crash_windows=((0.0, 0.25),)),
        # half the stage executions run 6x slow: no failures, just
        # honest tail latency (TIER_SLOW events)
        "straggler": TierFaultSpec(slow_rate=0.5, slow_factor=6.0),
        # 1-byte admission budget: every stage is shed at dispatch
        "shed": TierFaultSpec(mem_budget=1.0),
    }[profile]
    k = 1 if len(names) > 1 else 0
    tiers[k] = FaultyTier(names[k], faults=canned, seed=tiers[k].seed,
                          clock=clock)
    return tiers


def serve_cnn_stream(args) -> None:
    """``--cnn --concurrency N``: a stream of N single-sample requests
    through the batched split-serving engine (``serving.cnn_engine``):
    bounded queue, (model, resolution, dtype, wire) batch buckets,
    cross-request pipelining on the virtual clock (``--no-pipeline``
    for the sequential baseline)."""
    from repro.core import paper_chain
    from repro.models import cnn as cnn_lib
    from repro.runtime import FaultSpec, RetryPolicy
    from repro.runtime.faults import chain_links_from_env
    from repro.serving.cnn_engine import CnnServingEngine

    import os
    num_tiers = args.tiers if args.tiers is not None \
        else int(os.environ.get("REPRO_CHAIN_TIERS", 2))
    hw = paper_chain(num_tiers)
    links = chain_links_from_env([link.bandwidth for link in hw.links])
    if args.drop:
        for link in links:
            link.faults = FaultSpec(drop_rate=args.drop)
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0),
                              cnn_lib.CNN_MODELS[args.cnn])
    tier_models = _tier_fault_models(args.tier_faults, hw,
                                     links[0]._clock if links else None)
    eng = CnnServingEngine(
        {args.cnn: params}, hw=hw, max_batch=args.max_batch,
        pipelined=False if args.no_pipeline else None, dtype=args.dtype,
        wire=args.wire_dtype, links=links, tier_faults=tier_models,
        policy=RetryPolicy.from_env())
    rng = np.random.default_rng(0)
    for i in range(args.concurrency):
        x = rng.normal(size=cnn_lib.INPUT_SHAPE).astype(np.float32)
        eng.submit(x, args.cnn, at=0.0)
    t0 = time.perf_counter()
    eng.run_until_idle()
    dt = time.perf_counter() - t0
    s = eng.stats()
    mode = "pipelined" if s["pipelined"] else "sequential"
    print(f"served {s['served']}/{s['submitted']} requests "
          f"({mode}, {s['batches']} batches of "
          f"~{s['avg_batch_size']:.1f}) in {dt:.1f}s wall / "
          f"{s['virtual_span_s']:.4f}s virtual "
          f"({s['requests_per_s']:.1f} req/s virtual; "
          f"p50={s['latency_p50_s'] * 1e3:.1f}ms "
          f"p99={s['latency_p99_s'] * 1e3:.1f}ms) "
          f"repicks={s['repicks']} merges={s['merges']}")
    if tier_models is not None:
        for k, (ft, br) in enumerate(zip(s["tiers"], s["breakers"])):
            print(f"  tier{k}: exec={ft['executions']} "
                  f"crashes={ft['crashes']} sheds={ft['sheds']} "
                  f"slow={ft['slowdowns']} breaker={br['state']} "
                  f"(opened {br['opens']}x)")
        print(f"  failovers={s['failovers']} "
              f"fallback_device={s['fallback_device']}")
    for h in s["hops"]:
        link_c = h["link"]
        print(f"  hop{h['hop']}: wire={h['wire_dtype']} "
              f"attempts={h['attempts']} sent={h['wire_bytes']}B "
              f"goodput={h['goodput_Bps']:.3g}B/s "
              f"retx={h['retransmitted_bytes']}B "
              f"degradation={h['degradation']:.2f} "
              f"({link_c['dropped']} dropped / {link_c['timeouts']} "
              f"timeouts)")


def serve_cnn(args) -> None:
    """Fault-tolerant CNN chain serving (the paper's actual workload).

    Plans a K-tier chain placement (``--tiers``; K=2 is the paper's
    phone/cloud split bit-for-bit) and executes requests through
    ``ChainRuntime``: per-hop ``FaultyLink``s on a shared virtual clock,
    microbatch pipelining (``--microbatch``), stage-merge / re-pick
    degradation.  Per-hop fault knobs: ``REPRO_LINK{k}_*`` overrides
    ``REPRO_LINK_*`` for hop k."""
    import os

    from repro.core import paper_chain, smartsplit_chain
    from repro.models import cnn as cnn_lib
    from repro.models.profiles import cnn_profile
    from repro.runtime import (ChainRuntime, FaultSpec, RetryPolicy,
                               chain_links_from_env)

    policy = conv_dtype(args.dtype)
    num_tiers = args.tiers if args.tiers is not None \
        else int(os.environ.get("REPRO_CHAIN_TIERS", 2))
    microbatch = args.microbatch if args.microbatch is not None \
        else int(os.environ.get("REPRO_CHAIN_MICROBATCH", 1))
    hw = paper_chain(num_tiers)
    prof = cnn_profile(args.cnn, batch=args.batch, dtype=policy)
    plan = smartsplit_chain(prof, hw, microbatches=microbatch,
                            wire=args.wire_dtype)
    lat, en, mem = plan.objectives
    chain = " -> ".join(f"{t}[{a}:{b})" for t, (a, b)
                        in zip(plan.tiers, plan.stages()))
    wires = plan.wire_dtypes or ("?",) * len(hw.links)
    print(f"SmartSplit chain: {chain}")
    print(f"  cuts={list(plan.cuts)}/{prof.num_layers} M={microbatch} "
          f"latency={lat:.2e}s energy={en:.2e}J "
          f"device-mem={mem / 2**20:.1f}MiB ({policy}, "
          f"wire={'/'.join(wires)})")

    links = chain_links_from_env([link.bandwidth for link in hw.links])
    if args.drop:
        for link in links:
            link.faults = FaultSpec(drop_rate=args.drop)
    tier_models = _tier_fault_models(args.tier_faults, hw,
                                     links[0]._clock if links else None)
    rt = ChainRuntime(args.cnn, cnn_lib.init_cnn(
        jax.random.PRNGKey(0), cnn_lib.CNN_MODELS[args.cnn]),
        plan, prof, hw, links=links, dtype=policy,
        wire=args.wire_dtype, microbatches=microbatch,
        tier_faults=tier_models, policy=RetryPolicy.from_env())
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(args.batch,) + cnn_lib.INPUT_SHAPE),
                    jnp.float32)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        r = rt.infer(x)
        jax.block_until_ready(r.logits)
    dt = time.perf_counter() - t0
    s = rt.stats()
    print(f"served {s['requests']} requests in {dt:.1f}s "
          f"({s['requests'] / dt:.2f} req/s); recovered={s['recovered']} "
          f"merges={s['merges']} repicks={s['repicks']} "
          f"proactive={s['proactive_resplits']} "
          f"active_cuts={s['active_cuts']}")
    if tier_models is not None:
        for k, (ft, br) in enumerate(zip(s["tiers"], s["breakers"])):
            print(f"  tier{k} ({s['active_tiers'][k]}): "
                  f"exec={ft['executions']} crashes={ft['crashes']} "
                  f"sheds={ft['sheds']} slow={ft['slowdowns']} "
                  f"breaker={br['state']} (opened {br['opens']}x)")
        print(f"  failovers={s['failovers']} "
              f"fallback_device={s['fallback_device']}")
    for h in s["hops"]:
        link_c = h["link"]
        print(f"  hop{h['hop']}: wire={h['wire_dtype']} "
              f"attempts={h['attempts']} "
              f"sent={h['wire_bytes']}B (raw {h['raw_bytes']}B) "
              f"retx={h['retransmitted_bytes']}B merges={h['merges']} "
              f"est_bw={h['est_bandwidth']:.3g}B/s "
              f"degradation={h['degradation']:.2f} "
              f"({link_c['dropped']} dropped / {link_c['timeouts']} "
              f"timeouts / {link_c['outage_hits']} outage-hits)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b",
                    choices=sorted(all_configs()))
    ap.add_argument("--cnn", default=None,
                    help="serve a paper CNN through the fault-tolerant "
                         "split runtime instead (alexnet/vgg16/...)")
    ap.add_argument("--drop", type=float, default=0.0,
                    help="--cnn only: injected per-attempt drop rate "
                         "(REPRO_LINK_* env knobs cover the rest)")
    ap.add_argument("--tier-faults", default=None,
                    choices=("crash", "straggler", "shed"),
                    help="--cnn only: canned compute-fault profile on the "
                         "first server tier (layered over REPRO_TIER_* / "
                         "REPRO_TIER{k}_* env knobs); exercises circuit "
                         "breakers and standby-tier failover")
    ap.add_argument("--tiers", type=int, default=None,
                    help="--cnn only: chain length K (2=paper phone/cloud, "
                         "3=+edge, 4=+regional; default REPRO_CHAIN_TIERS "
                         "or 2)")
    ap.add_argument("--microbatch", type=int, default=None,
                    help="--cnn only: pipeline depth M (default "
                         "REPRO_CHAIN_MICROBATCH or 1)")
    ap.add_argument("--batch", type=int, default=4,
                    help="--cnn only: request batch size (microbatching "
                         "splits this)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--concurrency", type=int, default=None,
                    help="--cnn only: serve a stream of N concurrent "
                         "single-sample requests through the batched "
                         "split-serving engine instead of synchronous "
                         "whole-batch calls")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="--cnn --concurrency only: sequential baseline "
                         "(no cross-request pipelining)")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--plan-split", action="store_true")
    ap.add_argument("--dtype", default=None, choices=CONV_DTYPES,
                    help="boundary/storage dtype policy for --plan-split "
                         "(default: REPRO_CONV_DTYPE, else fp32)")
    ap.add_argument("--wire-dtype", default=None, choices=WIRE_DTYPES,
                    help="--cnn only: boundary wire format for every hop "
                         "(int8 = quantized streaming; default: "
                         "REPRO_LINK{k}_WIRE_DTYPE / REPRO_WIRE_DTYPE, "
                         "else follow = the storage dtype)")
    args = ap.parse_args()

    if args.cnn:
        if args.concurrency:
            serve_cnn_stream(args)
        else:
            serve_cnn(args)
        return

    cfg = all_configs()[args.arch].reduced()
    cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 512))
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only: no serving decode")

    if args.plan_split:
        policy = conv_dtype(args.dtype)
        prof = transformer_profile(cfg, seq_len=64, batch=args.max_batch,
                                   mode="prefill",
                                   dtype_bytes=policy_bytes(policy))
        plan = smartsplit(prof, TPU_EDGE_CLOUD)
        lat, en, mem = plan.objectives
        _, link_bytes = split_boundary_struct(cfg, args.max_batch, 64,
                                              dtype=policy)
        print(f"SmartSplit: l1={plan.split_index}/{cfg.num_layers} "
              f"latency={lat:.2e}s energy={en:.2e}J "
              f"edge-mem={mem / 2**20:.1f}MiB "
              f"boundary={link_bytes}B ({policy})")

    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = Engine(cfg, params, max_len=128, max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(args.requests):
        plen = int(rng.choice([8, 16, 24]))
        reqs.append(eng.submit(rng.integers(0, cfg.vocab_size,
                                            plen).tolist(),
                               max_new_tokens=args.max_new_tokens))
    t0 = time.perf_counter()
    eng.run_until_idle()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, {int(eng.stats['batches'])} batches, "
          f"p50={eng.stats['latency_p50_s'] * 1e3:.0f}ms "
          f"p99={eng.stats['latency_p99_s'] * 1e3:.0f}ms)")


if __name__ == "__main__":
    main()
