import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture x input shape x mesh) cell with
ShapeDtypeStruct parameters/inputs -- no allocation -- and records
memory_analysis / cost_analysis / collective-bytes JSON artefacts that the
roofline report (deliverable g) consumes.

The two lines above MUST stay the first statements in this module: jax
locks the device count at first initialisation, and the production meshes
need 512 host devices.  Never import this module from tests/benches that
expect 1 CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --force
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.analysis.hlo import (collective_bytes, collective_counts,
                                cost_analysis_dict)
from repro.configs import INPUT_SHAPES, all_configs, shape_skips
from repro.configs.base import InputShape, ModelConfig
from repro.launch import partition as PT
from repro.launch.mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "out", "dryrun")

LONG_WINDOW = 8192


def cell_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Per-cell variant: dense/MoE/VLM archs run long_500k with the
    sliding-window attention variant (DESIGN.md section 5); SSM/hybrid run
    natively."""
    if shape.name == "long_500k" and cfg.pattern in ("attn_mlp", "attn_moe") \
            and not cfg.sliding_window:
        return dataclasses.replace(cfg, sliding_window=LONG_WINDOW)
    return cfg


def _measure(cfg: ModelConfig, shape: InputShape, mesh, *,
             unroll_layers: bool, scan_unroll: int):
    """Lower + compile one variant; return scalar cost terms + artefacts."""
    from repro.models import layers as Lmod
    from repro.models import moe_ep
    Lmod.SCAN_UNROLL = scan_unroll
    Lmod.HINT_AXIS = "model"      # TP sharding hints (§Perf P3)
    Lmod.HINT_MESH = mesh
    # §Perf P1: expert-parallel all-to-all dispatch whenever E % model == 0
    moe_ep.EP_MESH = mesh if os.environ.get("REPRO_MOE_EP", "1") == "1" \
        else None
    t0 = time.time()
    try:
        params = PT.param_struct(cfg, mesh, mode=shape.mode)
        batch = PT.batch_struct(cfg, shape, mesh)
        if shape.mode == "train":
            step = PT.make_train_step(cfg, unroll_layers=unroll_layers)
            opt_state = PT.opt_state_struct(params)
            # donate params+opt so outputs alias inputs (in-place update)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params, opt_state, batch)
        elif shape.mode == "prefill":
            if cfg.is_encoder:
                step = PT.make_encode_step(cfg, unroll_layers=unroll_layers)
                lowered = jax.jit(step).lower(params, batch)
            else:
                step = PT.make_prefill_step(cfg,
                                            unroll_layers=unroll_layers)
                cache = PT.cache_struct(cfg, shape, mesh)
                lowered = jax.jit(step).lower(params, batch, cache)
        else:  # decode: ONE token against a seq_len cache
            step = PT.make_decode_step(cfg, unroll_layers=unroll_layers)
            cache = PT.cache_struct(cfg, shape, mesh)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params, batch["tokens"], cache)
        compiled = lowered.compile()
    finally:
        Lmod.SCAN_UNROLL = 1
        Lmod.HINT_AXIS = None
        Lmod.HINT_MESH = None
        moe_ep.EP_MESH = None
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    ma = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(hlo),
        "coll_counts": collective_counts(hlo),
        "memory": {k: getattr(ma, k) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")},
        "hlo_bytes": len(hlo),
        "wall_s": round(time.time() - t0, 2),
    }


# Loop-cost extrapolation (see EXPERIMENTS.md 'Dry-run methodology').
# XLA's cost_analysis counts a while-loop body ONCE, not x trip count
# (verified experimentally).  We therefore compile small python-unrolled
# variants (loop-free HLO => exact costs, linear in layer count) and
# reconstruct the true totals; inner sequential scans (mamba2 chunks,
# rwkv6 tokens) get one extra compile at scan-unroll=2 to separate the
# inner-body cost.  memory_analysis comes from the REAL config's compile
# (buffer sizes are exact regardless of loops).
def _inner_trips(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.mode == "decode":
        return 1 if cfg.pattern == "rwkv" else 0
    if cfg.pattern == "rwkv":
        return shape.seq_len
    if cfg.pattern == "mamba":
        return -(-shape.seq_len // 64)      # mamba2 chunk=64
    return 0


def _extrapolate(vals: dict[str, float], cfg: ModelConfig,
                 shape: InputShape) -> float:
    """vals: measured scalar per variant tag -> true total."""
    # Every coefficient is a sum of HLO op costs, hence non-negative in
    # truth; measured deltas can go negative when XLA fuses across the
    # unrolled copies (notably 'bytes accessed'), so clamp per-coefficient.
    if cfg.pattern == "mamba" and cfg.attn_every:
        n_seg, _ = __import__(
            "repro.models.transformer", fromlist=["x"])._zamba_segments(cfg)
        k = cfg.attn_every
        q1 = max(vals["Z2"] - vals["Z1"], 0.0)
        c0 = max(vals["Z1"] - q1, 0.0)
        t3 = _inner_trips(cfg, shape)
        i = max((vals["C"] - vals["Z2"]) / (2 * k), 0.0) \
            if "C" in vals else 0.0
        per_seg = q1 + k * i * max(t3 - 1, 0)
        return c0 + n_seg * per_seg
    slope = max((vals["B4"] - vals["B2"]) / 2.0, 0.0)
    c0 = max(vals["B2"] - 2 * slope, 0.0)
    t2 = _inner_trips(cfg, shape)
    i = max((vals["C"] - vals["B2"]) / 2.0, 0.0) if "C" in vals else 0.0
    per_layer = slope + i * max(t2 - 1, 0)
    return c0 + cfg.num_layers * per_layer


def _variant_plan(cfg: ModelConfig, shape: InputShape):
    """[(tag, cfg_variant, unroll_layers, scan_unroll)]"""
    need_inner = _inner_trips(cfg, shape) > 1
    if cfg.pattern == "mamba" and cfg.attn_every:
        k = cfg.attn_every
        plan = [("Z1", dataclasses.replace(cfg, num_layers=k), True, 1),
                ("Z2", dataclasses.replace(cfg, num_layers=2 * k), True, 1)]
        if need_inner:
            plan.append(("C", dataclasses.replace(cfg, num_layers=2 * k),
                         True, 2))
        return plan
    plan = [("B2", dataclasses.replace(cfg, num_layers=2), True, 1),
            ("B4", dataclasses.replace(cfg, num_layers=4), True, 1)]
    if need_inner:
        plan.append(("C", dataclasses.replace(cfg, num_layers=2), True, 2))
    return plan


def lower_cell(cfg: ModelConfig, shape: InputShape, mesh, mesh_name: str):
    """Compile the real cell + extrapolation variants; return the record."""
    cfg = cell_config(cfg, shape)
    with jax.default_device(jax.devices("cpu")[0]):
        real = _measure(cfg, shape, mesh, unroll_layers=False,
                        scan_unroll=1)
        variants = {}
        for tag, vcfg, unroll, su in _variant_plan(cfg, shape):
            variants[tag] = _measure(vcfg, shape, mesh,
                                     unroll_layers=unroll, scan_unroll=su)

    def extract(key, sub=None):
        vals = {t: (m[key] if sub is None else m[key].get(sub, 0.0))
                for t, m in variants.items()}
        return _extrapolate(vals, cfg, shape)

    coll_kinds = set()
    for m in list(variants.values()) + [real]:
        coll_kinds |= set(m["coll"])
    coll_true = {kind: extract("coll", kind) for kind in coll_kinds}
    coll_true["total"] = sum(v for k, v in coll_true.items()
                             if k != "total")
    rec = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "num_devices": int(mesh.devices.size),
        "mode": shape.mode,
        "sliding_window": cfg.sliding_window,
        "cost": {"flops": extract("flops"),
                 "bytes accessed": extract("bytes")},
        "cost_scan_raw": {"flops": real["flops"],
                          "bytes accessed": real["bytes"]},
        "memory": real["memory"],
        "collective_bytes": coll_true,
        "collective_bytes_raw": real["coll"],
        "collective_counts": real["coll_counts"],
        "model_flops": cfg.model_flops(
            seq_len=shape.seq_len, batch=shape.global_batch,
            mode=shape.mode),
        "compile_s": real["wall_s"],
        "variant_wall_s": {t: m["wall_s"] for t, m in variants.items()},
        "hlo_bytes": real["hlo_bytes"],
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(OUT_DIR, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi2x16x16", make_production_mesh(multi_pod=True)))

    cfgs = all_configs()
    archs = [args.arch] if args.arch else sorted(cfgs)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            cfg = cfgs[arch]
            for shape_name in shapes:
                shape = INPUT_SHAPES[shape_name]
                tag = f"{mesh_name}.{arch}.{shape_name}"
                path = os.path.join(OUT_DIR, f"{tag}.json")
                skip = shape_skips(cfg, shape)
                if skip:
                    print(f"SKIP {tag}: {skip}", flush=True)
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape_name,
                                   "mesh": mesh_name, "skipped": skip}, f)
                    n_skip += 1
                    continue
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        if "error" not in json.load(f):
                            print(f"CACHED {tag}", flush=True)
                            n_ok += 1
                            continue
                try:
                    rec = lower_cell(cfg, shape, mesh, mesh_name)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"OK {tag}: flops/dev={rec['cost'].get('flops', 0):.3e} "
                          f"coll={rec['collective_bytes'].get('total', 0):.3e}B "
                          f"compile={rec['compile_s']}s", flush=True)
                    n_ok += 1
                except Exception as e:   # noqa: BLE001 -- record and continue
                    n_fail += 1
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape_name,
                                   "mesh": mesh_name, "error": str(e)}, f)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
