"""Two-stage SmartSplit executor: the paper's client->server handoff as an
SPMD program over the ``pod`` mesh axis.

Pod 0 ("client", paper: smartphone) owns transformer blocks [0, l1); pod 1
("server", paper: cloud) owns [l1, L).  Both pods hold Lmax = max(l1, L-l1)
padded block slots (inactive slots masked with jnp.where -- the same
uniformity idiom as zamba2's padded segments), so ONE program serves any
split index.  The boundary activation -- the paper's "intermediate model
upload" -- crosses pods with ``jax.lax.ppermute`` over the inter-pod link;
its byte count is exactly the I|l1 term the optimiser's Eq. 4 models.

Phase structure (SPMD-uniform):
  phase 1: every pod scans its local slots over the embedded input
           (only pod 0's result is meaningful),
  transfer: ppermute pod0 -> pod1,
  phase 2: every pod scans its local slots again, pod 1 starting from the
           received boundary activation (only pod 1's result is meaningful),
  return:  pod 1's logits are ppermuted back so every pod holds the output.

Wall-clock is ~2 x Lmax x t_layer -- the inherent cost of a sequential
2-stage split without microbatching; ``pipelined=True`` adds GPipe-style
microbatch pipelining over the same weights (the beyond-paper §Perf item),
bringing steady-state utilisation of both pods to ~m/(m+1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.dtype_policy import (conv_dtype, policy_jnp_dtype,
                                     resolve_wire_dtype)
from repro.kernels.quant import dequantize_jnp, quantize_jnp
from repro.models import layers as L
from repro.models import transformer as T


def stage_params(cfg: ModelConfig, params, l1: int):
    """Reorganise stacked blocks (L, ...) into (2, Lmax, ...) stage slots +
    (2, Lmax) active mask.  Works for the uniform-pattern archs."""
    Lt = cfg.num_layers
    lmax = max(l1, Lt - l1)

    def pack(t):
        pad = jnp.zeros((2, lmax) + t.shape[1:], t.dtype)
        pad = pad.at[0, :l1].set(t[:l1])
        pad = pad.at[1, :Lt - l1].set(t[l1:])
        return pad

    staged = jax.tree.map(pack, params["blocks"])
    mask = np.zeros((2, lmax), bool)
    mask[0, :l1] = True
    mask[1, :Lt - l1] = True
    return staged, jnp.asarray(mask)


def build_two_stage_forward(cfg: ModelConfig, mesh, l1: int,
                            pipelined: bool = False, microbatches: int = 4,
                            boundary_dtype: str | None = None,
                            wire_dtype: str | None = None):
    """Returns fn(staged_blocks, mask, embed, unembed, final_norm, tokens)
    -> logits, to be called with staged blocks sharded P('pod') on dim 0.

    ``boundary_dtype`` is the storage policy (``conv_dtype``; env
    ``REPRO_CONV_DTYPE``): under ``bf16`` the boundary activation -- the
    paper's "intermediate model upload" -- crosses the inter-pod link
    serialized as bfloat16 (half the ppermute payload, matching the
    dtype-aware cost model's I|l1 term) and is upcast back to the compute
    dtype on arrival.  ``fp32`` transfers the activation as-is.

    ``wire_dtype`` decouples the link format from that storage policy
    (``follow``/``fp32``/``bf16``/``int8``; None resolves the
    ``REPRO_WIRE_DTYPE`` env, default ``follow`` = the storage dtype as
    before).  ``int8`` quantizes the hidden state per feature (axis -1,
    ``kernels.quant.quantize_jnp`` -- usable inside shard_map) and ships
    the int8 values plus fp32 scales as two ppermutes, dequantizing to
    the compute dtype on arrival: ~4x less ppermute payload at a bounded
    accuracy cost.

    Restricted to the uniform-pattern architectures (attn/MoE/RWKV/Mamba
    without shared blocks); zamba2 splits at segment granularity via the
    same machinery applied to segments (see DESIGN.md §4)."""
    kind = cfg.pattern
    assert not (kind == "mamba" and cfg.attn_every), \
        "zamba2: split at segment granularity"
    w = resolve_wire_dtype(wire_dtype, storage=conv_dtype(boundary_dtype))
    int8_wire = w == "int8"
    link_dt = None if int8_wire else (
        policy_jnp_dtype(w) if w == "bf16" else None)

    def run_stage(blocks, mask, h, positions):
        def body(carry, inp):
            hh = carry
            p_i, m = inp
            out, _, _ = T._apply_block(cfg, kind, p_i, hh,
                                       positions=positions)
            return jnp.where(m, out, hh), None
        h, _ = jax.lax.scan(body, h, (blocks, mask))
        return h

    def shard_fn(blocks, mask, embed, unembed, final_norm, tokens):
        # inside shard_map: blocks leaves (1, Lmax, ...), mask (1, Lmax)
        blocks = jax.tree.map(lambda t: t[0], blocks)
        mask = mask[0]
        B, S = tokens.shape
        positions = jnp.arange(S, dtype=jnp.int32)[None, :] \
            + jnp.zeros((B, 1), jnp.int32)
        h0 = embed[tokens]

        if not pipelined:
            h1 = run_stage(blocks, mask, h0, positions)          # phase 1
            pod = jax.lax.axis_index("pod")
            if int8_wire:
                # upload: per-feature int8 values + fp32 scales cross as
                # two ppermutes (~4x less payload than fp32)
                q, scales = quantize_jnp(h1, axis=-1)
                q_r = jax.lax.ppermute(q, "pod", [(0, 1)])
                s_r = jax.lax.ppermute(scales, "pod", [(0, 1)])
                recv = dequantize_jnp(q_r, s_r, axis=-1,
                                      out_dtype=h1.dtype)
                h2_in = jnp.where(pod == 1, recv, h1)
            else:
                # upload: the boundary activation crosses the link in the
                # wire dtype (bf16 halves the ppermute payload)
                sent = h1 if link_dt is None else h1.astype(link_dt)
                recv = jax.lax.ppermute(sent, "pod", [(0, 1)])
                h2_in = jnp.where(pod == 1, recv.astype(h1.dtype), h1)
            h2 = run_stage(blocks, mask, h2_in, positions)       # phase 2
        else:
            # GPipe-style: m microbatches, 2-stage pipeline.
            m = microbatches
            assert B % m == 0
            mb = h0.reshape(m, B // m, S, -1)
            pos_mb = positions[:B // m]
            pod = jax.lax.axis_index("pod")

            def tick(carry, xs):
                mb_in = xs                # next microbatch (for pod 0)
                if int8_wire:             # carry = (int8 values, scales)
                    q_in, s_in = carry
                    upstream = dequantize_jnp(q_in, s_in, axis=-1,
                                              out_dtype=mb_in.dtype)
                else:                     # carry = link-dtype activation
                    upstream = carry.astype(mb_in.dtype)
                my_in = jnp.where(pod == 0, mb_in, upstream)
                out = run_stage(blocks, mask, my_in, pos_mb)
                if int8_wire:
                    q, s = quantize_jnp(out, axis=-1)
                    inflight = (jax.lax.ppermute(q, "pod", [(0, 1)]),
                                jax.lax.ppermute(s, "pod", [(0, 1)]))
                else:
                    sent = out if link_dt is None else out.astype(link_dt)
                    inflight = jax.lax.ppermute(sent, "pod", [(0, 1)])
                return inflight, out      # pod1's out = finished microbatch

            if int8_wire:
                pad = (jnp.zeros(mb[0].shape, jnp.int8),
                       jnp.ones((mb.shape[-1],), jnp.float32))
            else:
                pad = jnp.zeros_like(mb[0])
                if link_dt is not None:
                    pad = pad.astype(link_dt)
            feed = jnp.concatenate([mb, jnp.zeros_like(mb[0])[None]],
                                   axis=0)                       # m+1 ticks
            _, outs = jax.lax.scan(tick, pad, feed)
            h2 = outs[1:].reshape(B, S, -1)  # pod1 finished mb i at tick i+1

        h2 = L.rmsnorm(h2, final_norm, cfg.norm_eps)
        logits = (h2 @ unembed).astype(jnp.float32)
        # give every pod the stage-1 result
        back = jax.lax.ppermute(logits, "pod", [(1, 0)])
        pod = jax.lax.axis_index("pod")
        return jnp.where(pod == 0, back, logits)

    pod_spec = jax.tree.map(lambda _: P("pod"), {"x": 0})["x"]
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P("pod"), P("pod"), P(), P(), P(), P()),
        out_specs=P(),
        check_rep=False)
    return fn


def two_stage_apply(cfg: ModelConfig, params, tokens, mesh, l1: int,
                    pipelined: bool = False, microbatches: int = 4,
                    boundary_dtype: str | None = None,
                    wire_dtype: str | None = None):
    """Convenience wrapper: stage, place, and run. Returns logits identical
    (up to float assoc; bf16 boundary adds ~1e-2 relative, int8 wire a
    bounded per-channel quantization error) to the monolithic
    ``forward``."""
    staged, mask = stage_params(cfg, params, l1)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    fn = build_two_stage_forward(cfg, mesh, l1, pipelined, microbatches,
                                 boundary_dtype=boundary_dtype,
                                 wire_dtype=wire_dtype)
    staged = jax.device_put(
        staged, jax.tree.map(lambda _: NamedSharding(mesh, P("pod")),
                             staged))
    mask_p = jax.device_put(mask, NamedSharding(mesh, P("pod")))
    return fn(staged, mask_p, params["embed"], unembed,
              params["final_norm"], tokens)
