"""Synthetic data pipeline: deterministic token/embedding batches with
background prefetch and mesh-aware placement.

The paper needs no dataset (its metric surface is systems-level), but the
end-to-end training driver does: this generates a reproducible synthetic
language-modelling stream (Zipf-ish unigram mixture with a induced bigram
structure so the loss actually decreases) and, for frontend archs, frame /
patch embeddings."""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLM:
    """Deterministic synthetic LM stream with learnable structure:
    next-token depends on current token via a fixed random permutation,
    mixed with noise -- a model that learns p(next|cur) reaches a loss well
    below uniform."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, noise: float = 0.3):
        self.cfg, self.batch, self.seq_len = cfg, batch, seq_len
        self.noise = noise
        rng = np.random.default_rng(seed)
        V = cfg.vocab_size
        self.perm = rng.permutation(V)
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        V = self.cfg.vocab_size
        B, S = self.batch, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        for t in range(1, S + 1):
            follow = self.perm[toks[:, t - 1]]
            noise = rng.integers(0, V, B)
            use_noise = rng.random(B) < self.noise
            toks[:, t] = np.where(use_noise, noise, follow)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "loss_mask": np.ones((B, S), np.float32)}
        if self.cfg.frontend == "audio":
            emb = rng.standard_normal((B, S, self.cfg.d_model),
                                      np.float32) * 0.02
            batch = {"prefix_embeds": emb, "labels": toks[:, 1:],
                     "loss_mask": np.ones((B, S), np.float32)}
        return batch

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue (CPU pipeline overlap
    with device compute)."""

    def __init__(self, it: Iterator[dict], depth: int = 2,
                 place=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._place = place or (lambda x: x)
        self._stop = False

        def work():
            for item in it:
                if self._stop:
                    return
                self._q.put(self._place(item))
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop = True
