"""End-to-end training driver: train a small decoder on the synthetic
bigram-structured stream for a few hundred steps, verify the loss drops
well below the uniform baseline, and round-trip a checkpoint.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import dataclasses
import math
import tempfile

import jax
import numpy as np

from repro.configs import all_configs
from repro.training import checkpoint as ckpt
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        all_configs()[args.arch].reduced(),
        vocab_size=64, num_layers=2, d_model=128, d_ff=256,
        name=args.arch + "-train-demo")
    with tempfile.TemporaryDirectory() as tmp:
        tcfg = TrainConfig(steps=args.steps, batch=8, seq_len=64,
                           ckpt_dir=tmp, log_every=max(args.steps // 10, 1))
        out = train(cfg, tcfg)
        first, last = out["losses"][0][1], out["losses"][-1][1]
        uniform = math.log(cfg.padded_vocab)
        print(f"\nloss: {first:.3f} -> {last:.3f} "
              f"(uniform over padded vocab = {uniform:.3f})")
        assert last < first - 0.5, "training did not learn"

        # checkpoint round-trip
        step, restored = ckpt.restore(
            tmp, {"params": out["params"], "opt_state": out["opt_state"]})
        leaves_a = jax.tree.leaves(out["params"])
        leaves_b = jax.tree.leaves(restored["params"])
        for a, b in zip(leaves_a, leaves_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print(f"checkpoint at step {step} restored bit-exact: OK")


if __name__ == "__main__":
    main()
