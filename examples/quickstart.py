"""Quickstart: the paper in one minute.

1. Build the per-layer cost profile of AlexNet (the paper's Table-I model).
2. Run SmartSplit (NSGA-II + TOPSIS) on the paper's smartphone environment.
3. Execute the actual split CNN inference in JAX and verify the boundary
   payload matches the optimiser's I|l1 term and the logits match the
   monolithic network.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import PAPER_ENV_J6, evaluate_objectives, smartsplit
from repro.models import cnn
from repro.models.profiles import cnn_profile


def main():
    name = "alexnet"
    profile = cnn_profile(name)
    print(f"{name}: {profile.num_layers} layers "
          f"(paper counts 21 for AlexNet)")

    # --- the optimiser -----------------------------------------------------
    plan = smartsplit(profile, PAPER_ENV_J6, f3_mode="activations")
    lat, en, mem = plan.objectives
    print(f"SmartSplit split index l1 = {plan.split_index} "
          f"(paper Table I: 3)")
    print(f"  predicted latency {lat:.3f}s  energy {en:.3f}J  "
          f"client memory {mem / 2**20:.2f} MiB")
    print(f"  Pareto set: {sorted(plan.pareto_indices)}")

    # --- the runtime -------------------------------------------------------
    layers = cnn.CNN_MODELS[name]
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 224, 224)) * 0.1

    full_logits = cnn.apply_cnn(layers, params, x)
    split_logits, boundary = cnn.apply_split(layers, params, x,
                                             plan.split_index)
    np.testing.assert_allclose(np.asarray(split_logits),
                               np.asarray(full_logits), rtol=1e-5,
                               atol=1e-5)
    # boundary dtype follows the storage policy (REPRO_CONV_DTYPE)
    sent = boundary.size * boundary.dtype.itemsize
    modelled = profile.boundary()[plan.split_index]
    print(f"boundary payload: runtime {sent} B == model {modelled:.0f} B")
    assert sent == modelled
    print("split execution matches monolithic network: OK")

    # --- the trade-off curve ----------------------------------------------
    F = evaluate_objectives(profile, PAPER_ENV_J6)
    print("\n l1   latency_s  energy_J  memory_MiB")
    for l1 in sorted(set([1, 3, 6, 13, 20])):
        print(f"{l1:3d}   {F[l1, 0]:9.3f} {F[l1, 1]:9.3f} "
              f"{F[l1, 2] / 2**20:11.2f}")


if __name__ == "__main__":
    main()
