"""Quickstart: the paper in one minute.

1. Build the per-layer cost profile of AlexNet (the paper's Table-I model).
2. Run SmartSplit (NSGA-II + TOPSIS) on the paper's smartphone environment.
3. Execute the actual split CNN inference in JAX and verify the boundary
   payload matches the optimiser's I|l1 term and the logits match the
   monolithic network.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import PAPER_ENV_J6, evaluate_objectives, smartsplit
from repro.core.dtype_policy import conv_dtype, resolve_wire_dtype
from repro.models import cnn
from repro.models.profiles import cnn_profile
from repro.runtime import encode_boundary


def main():
    name = "alexnet"
    profile = cnn_profile(name)
    print(f"{name}: {profile.num_layers} layers "
          f"(paper counts 21 for AlexNet)")

    # --- the optimiser -----------------------------------------------------
    plan = smartsplit(profile, PAPER_ENV_J6, f3_mode="activations")
    lat, en, mem = plan.objectives
    print(f"SmartSplit split index l1 = {plan.split_index} "
          f"(paper Table I: 3)")
    print(f"  predicted latency {lat:.3f}s  energy {en:.3f}J  "
          f"client memory {mem / 2**20:.2f} MiB")
    print(f"  Pareto set: {sorted(plan.pareto_indices)}")

    # --- the runtime -------------------------------------------------------
    layers = cnn.CNN_MODELS[name]
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 224, 224)) * 0.1

    full_logits = cnn.apply_cnn(layers, params, x)
    split_logits, boundary = cnn.apply_split(layers, params, x,
                                             plan.split_index)
    wire = resolve_wire_dtype(storage=conv_dtype())
    if wire == conv_dtype():
        # follow/storage wire: the split is bit-for-bit the monolithic run
        np.testing.assert_allclose(np.asarray(split_logits),
                                   np.asarray(full_logits), rtol=1e-5,
                                   atol=1e-5)
        print("split execution matches monolithic network: OK")
    else:
        # re-encoding wire (e.g. REPRO_WIRE_DTYPE=int8): bounded
        # quantization error, same top-1
        err = float(np.max(np.abs(np.asarray(split_logits)
                                  - np.asarray(full_logits))))
        assert np.array_equal(np.argmax(split_logits, -1),
                              np.argmax(full_logits, -1))
        print(f"split execution matches monolithic top-1 "
              f"({wire} wire, max|dlogit| {err:.1e}): OK")
    # what actually crosses the link, vs the optimiser's I|l1 term
    payload, _ = encode_boundary(boundary, wire)
    sent = len(payload)
    modelled = profile.wire_boundary(wire)[plan.split_index]
    print(f"boundary payload ({wire}): runtime {sent} B "
          f"== model {modelled:.0f} B")
    assert sent == modelled


    # --- the trade-off curve ----------------------------------------------
    F = evaluate_objectives(profile, PAPER_ENV_J6)
    print("\n l1   latency_s  energy_J  memory_MiB")
    for l1 in sorted(set([1, 3, 6, 13, 20])):
        print(f"{l1:3d}   {F[l1, 0]:9.3f} {F[l1, 1]:9.3f} "
              f"{F[l1, 2] / 2**20:11.2f}")


if __name__ == "__main__":
    main()
