"""End-to-end serving driver (the paper's kind: inference).

Serves a small qwen3-family model with batched requests through the
bucketed engine, THEN plans a SmartSplit two-tier placement for the same
model on the TPU edge+cloud profile and executes the split across a 2-pod
host-device mesh with the shard_map executor, verifying split == monolithic
logits and reporting the boundary bytes against the plan's prediction.

Run:  PYTHONPATH=src python examples/split_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_configs
from repro.core import TPU_EDGE_CLOUD, smartsplit
from repro.launch.smartsplit_exec import two_stage_apply
from repro.models import transformer as T
from repro.models.profiles import transformer_profile
from repro.serving.engine import Engine


def main():
    cfg = dataclasses.replace(all_configs()["qwen3-4b"].reduced(),
                              num_layers=4, name="qwen3-mini")
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)

    # ---- batched serving ---------------------------------------------------
    eng = Engine(cfg, params, max_len=96, max_batch=4)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(10):
        plen = int(rng.choice([8, 8, 8, 16, 16, 24]))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        reqs.append(eng.submit(prompt, max_new_tokens=8))
    t0 = time.time()
    eng.run_until_idle()
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.output) for r in reqs)
    print(f"served {done}/10 requests, {toks} tokens in {dt:.1f}s "
          f"({eng.stats['batches']:.0f} batches, bucketed by length)")
    assert done == 10

    # ---- SmartSplit plan on the TPU two-tier profile ------------------------
    prof = transformer_profile(cfg, seq_len=32, batch=4, mode="prefill",
                               dtype_bytes=4)   # example runs f32
    plan = smartsplit(prof, TPU_EDGE_CLOUD)
    print(f"SmartSplit plan for {cfg.name}: l1={plan.split_index}/"
          f"{cfg.num_layers} layers on the edge pod "
          f"(boundary {prof.boundary()[plan.split_index]:.0f} B predicted)")

    # ---- execute the split across the pod axis -----------------------------
    mesh = jax.make_mesh((2,), ("pod",))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                              cfg.vocab_size)
    mono, _, _ = T.forward(cfg, params, {"tokens": toks}, mode="train")
    split = two_stage_apply(cfg, params, toks, mesh, plan.split_index)
    np.testing.assert_allclose(np.asarray(split), np.asarray(mono),
                               rtol=2e-3, atol=2e-3)
    print("two-stage (pod0=edge, pod1=cloud) logits match monolithic: OK")

    # boundary payload actually transferred = hidden state bytes
    actual = 4 * 32 * cfg.d_model * 4   # B x S x d, f32
    print(f"boundary activation transferred per ppermute: {actual} B")

    # ---- pipelined variant (beyond-paper) -----------------------------------
    piped = two_stage_apply(cfg, params, toks, mesh, plan.split_index,
                            pipelined=True, microbatches=2)
    np.testing.assert_allclose(np.asarray(piped), np.asarray(mono),
                               rtol=2e-3, atol=2e-3)
    print("GPipe-style microbatched split matches monolithic: OK")

    # ---- N-tier CNN chain: device -> edge -> core ---------------------------
    # The paper's CNN workload on a 3-tier chain plan (K-1=2 cuts), executed
    # through the fault-tolerant chain runtime with M=2 microbatch pipelining.
    from repro.core import paper_chain, smartsplit_chain
    from repro.models import cnn as cnn_lib
    from repro.models.profiles import cnn_profile
    from repro.runtime import ChainRuntime

    in_shape, batch = (3, 64, 64), 4
    hw3 = paper_chain(3)                    # J6 phone -> edge server -> core DC
    cprof = cnn_profile("alexnet", batch=batch, in_shape=in_shape)
    cplan = smartsplit_chain(cprof, hw3, microbatches=2)
    chain = " -> ".join(f"{t}[{a}:{b})" for t, (a, b)
                        in zip(cplan.tiers, cplan.stages()))
    print(f"chain plan: {chain} "
          f"(predicted latency {cplan.objectives[0]:.3f}s at M=2)")

    layers = cnn_lib.CNN_MODELS["alexnet"]
    cparams = cnn_lib.init_cnn(jax.random.PRNGKey(0), layers, in_shape)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(batch,) + in_shape), jnp.float32)
    crt = ChainRuntime("alexnet", cparams, cplan, cprof, hw3,
                       microbatches=2)
    res = crt.infer(x)
    mono_cnn = cnn_lib.apply_cnn(layers, cparams, x)
    np.testing.assert_allclose(np.asarray(res.logits),
                               np.asarray(mono_cnn), rtol=1e-5, atol=1e-5)
    print(f"device->edge->core chain logits match single-device: OK "
          f"(M={res.microbatches}, virtual makespan "
          f"{res.chain_elapsed_s:.3f}s)")


if __name__ == "__main__":
    main()
