"""Batched CNN split serving: a mixed-resolution request stream.

Submits a stream of single-sample AlexNet requests at two input
resolutions through the split-serving engine
(``repro.serving.cnn_engine``): requests bucket per (model, resolution,
dtype, wire) -- each resolution gets its own SmartSplit chain plan --
pack into batches, and pipeline across requests on the virtual clock
(request i+1's client stage overlaps request i's boundary transfer).
AlexNet's adaptive average pool makes one parameter set valid at any
resolution, so both buckets share the same weights.

Also demonstrates the two backpressure mechanisms: a deadline tight
enough to expire a queued request, and the bounded queue shedding with
``QueueFullError``.

Run:  PYTHONPATH=src python examples/batch_serving.py
"""
import json

import jax
import numpy as np

from repro.core.hardware import paper_chain
from repro.models import cnn as cnn_lib
from repro.serving.cnn_engine import CnnServingEngine, QueueFullError


def main():
    layers = cnn_lib.CNN_MODELS["alexnet"]
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), layers,
                              in_shape=(3, 64, 64))
    eng = CnnServingEngine({"alexnet": params}, hw=paper_chain(3),
                           max_batch=4, max_queue=16)

    # ---- mixed-resolution stream ------------------------------------
    rng = np.random.default_rng(0)
    reqs = []
    t = 0.0
    for i in range(12):
        shape = (3, 64, 64) if i % 3 else (3, 96, 96)
        t += float(rng.exponential(0.004))
        x = rng.normal(size=shape).astype(np.float32)
        reqs.append(eng.submit(x, "alexnet", at=t))
    # one request with an impossible deadline: expired, never computed
    tight = eng.submit(rng.normal(size=(3, 64, 64)).astype(np.float32),
                       "alexnet", at=t, deadline_s=1e-6)
    eng.run_until_idle()
    served = sum(r.status == "served" for r in reqs)
    print(f"served {served}/{len(reqs)} mixed-resolution requests; "
          f"tight-deadline request -> {tight.status}")
    assert served == len(reqs)
    assert tight.status == "expired"

    # ---- backpressure ------------------------------------------------
    now = eng.clock.now
    for _ in range(eng.max_queue):
        eng.submit(rng.normal(size=(3, 64, 64)).astype(np.float32),
                   "alexnet", at=now)
    try:
        eng.submit(rng.normal(size=(3, 64, 64)).astype(np.float32),
                   "alexnet", at=now)
        raise AssertionError("queue should have been full")
    except QueueFullError as e:
        print(f"backpressure: {e}")
    eng.run_until_idle()

    # ---- stats -------------------------------------------------------
    s = eng.stats()
    print(f"\nengine stats: served={s['served']} shed={s['shed']} "
          f"expired={s['deadline_expired']} batches={s['batches']} "
          f"(avg size {s['avg_batch_size']:.1f}) "
          f"p50={s['latency_p50_s'] * 1e3:.1f}ms "
          f"p99={s['latency_p99_s'] * 1e3:.1f}ms "
          f"{s['requests_per_s']:.0f} req/s virtual")
    for b in s["buckets"]:
        print(f"  bucket {b['model']}@{tuple(b['in_shape'])} "
              f"{b['dtype']}: cuts={b['cuts']} served={b['served']} "
              f"in {b['batches']} batches")
    print("\nper-hop link accounting:")
    print(json.dumps(s["hops"], indent=1, default=str))


if __name__ == "__main__":
    main()
