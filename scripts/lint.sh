#!/usr/bin/env bash
# Lint gate -- the exact commands CI's lint job runs (see
# .github/workflows/ci.yml), so the local gate matches CI.
# Run from anywhere: scripts/lint.sh
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v ruff >/dev/null 2>&1; then
    echo "lint: ruff not installed (pip install -r requirements-dev.txt);" \
         "skipping -- CI will still enforce it" >&2
    exit 0
fi

ruff check .

# Formatting is advisory until the legacy files are migrated in one
# mechanical PR; CI mirrors this with continue-on-error.
if ! ruff format --check .; then
    echo "lint: ruff format drift (advisory only for now)" >&2
fi
