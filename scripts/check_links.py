#!/usr/bin/env python
"""Check that intra-repo markdown links in README.md and docs/ resolve.

Usage (from anywhere): python scripts/check_links.py
Exit 1 listing every broken link.  External (http/https/mailto) links
and pure #anchors are skipped -- this guards the file-path links that
rot when files move.  Stdlib-only; the CI docs job runs it.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# [text](target) -- excluding images' inner ! is irrelevant, same rule
_LINK_RE = re.compile(r'\[[^\]]*\]\(([^)\s]+)\)')


def md_files() -> list[Path]:
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check(files: list[Path]) -> list[str]:
    broken = []
    for f in files:
        for m in _LINK_RE.finditer(f.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (f.parent / path).resolve()
            if not resolved.is_relative_to(REPO):
                continue    # web-relative (e.g. the CI badge), not a file
            if not resolved.exists():
                broken.append(
                    f"{f.relative_to(REPO)}: broken link -> {target}")
    return broken


def main() -> int:
    files = md_files()
    broken = check(files)
    for line in broken:
        print(line, file=sys.stderr)
    print(f"checked {len(files)} files: "
          f"{'all links resolve' if not broken else f'{len(broken)} broken'}")
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main())
