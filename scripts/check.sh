#!/usr/bin/env bash
# Tier-1 verification -- the exact command ROADMAP.md documents.
# Run from the repo root: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow" "$@"
