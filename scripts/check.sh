#!/usr/bin/env bash
# Tier-1 verification -- the exact command ROADMAP.md documents (and the
# blocking `tier1` job in .github/workflows/ci.yml runs).  Lint first when
# available (scripts/lint.sh no-ops without ruff), then the fast test gate.
# Run from the repo root: scripts/check.sh [extra pytest args]
set -euo pipefail
cd "$(dirname "$0")/.."
scripts/lint.sh
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q -m "not slow" "$@"
