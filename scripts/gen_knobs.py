#!/usr/bin/env python
"""Render docs/knobs.md from the knob registry in core/knobs.py.

Usage (from the repo root):
    PYTHONPATH=src python scripts/gen_knobs.py           # (re)write
    PYTHONPATH=src python scripts/gen_knobs.py --check   # diff, exit 1
                                                         # if stale

The --check mode is what the CI docs job runs; tests/test_knobs.py runs
the same comparison in tier-1.  Stdlib-only -- no jax needed.
"""
from __future__ import annotations

import argparse
import difflib
import importlib.util
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

# Load knobs.py by path: importing the repro.core package would pull in
# the whole numpy/jax stack, which the CI docs job deliberately lacks.
_spec = importlib.util.spec_from_file_location(
    "repro_knobs", REPO / "src" / "repro" / "core" / "knobs.py")
_mod = importlib.util.module_from_spec(_spec)
sys.modules[_spec.name] = _mod  # dataclasses resolves types via sys.modules
_spec.loader.exec_module(_mod)
render_markdown = _mod.render_markdown

OUT = REPO / "docs" / "knobs.md"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true",
                    help="exit 1 (with a diff) if docs/knobs.md is stale "
                         "instead of rewriting it")
    args = ap.parse_args()
    want = render_markdown()
    if args.check:
        have = OUT.read_text() if OUT.exists() else ""
        if have == want:
            print(f"{OUT.relative_to(REPO)} is up to date")
            return 0
        sys.stderr.writelines(difflib.unified_diff(
            have.splitlines(keepends=True), want.splitlines(keepends=True),
            fromfile=str(OUT.relative_to(REPO)), tofile="generated"))
        sys.stderr.write(
            f"\n{OUT.relative_to(REPO)} is stale: regenerate with "
            f"`PYTHONPATH=src python scripts/gen_knobs.py`\n")
        return 1
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(want)
    print(f"wrote {OUT.relative_to(REPO)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
