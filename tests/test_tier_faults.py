"""Tier-level fault injection, circuit breakers, and standby failover.

Deterministic like tests/test_chain_runtime.py: crash windows, seeded
fault draws, and the shared virtual clock force exact failure/recovery
sequences.  The acceptance sweep at the bottom pins the PR's contract:
under crash-window and straggler profiles on three fixed seeds, every
request is either bit-identical to the fault-free reference or carries
recorded failover/fallback events (success rate 1.0, never a silent
wrong answer), and a standby failover never re-runs the NSGA-II
optimiser -- it is one TOPSIS pass over the memoised Pareto front."""
import dataclasses
import importlib

import jax
import numpy as np
import pytest

from repro.core import PAPER_ENV_J6, paper_chain, smartsplit_chain, \
    smartsplit_exhaustive
from repro.core.hardware import (DEVICE_TIERS, STANDBY_TIERS, standby_chain,
                                 standby_for)
from repro.core.smartsplit import (cached_chain_plan, clear_plan_cache,
                                   plan_cache_stats)
from repro.models import cnn as cnn_lib
from repro.models.cnn import avgpool, conv, linear, maxpool, relu
from repro.models.profiles import cnn_profile
from repro.runtime import (ChainRuntime, CircuitBreaker, FaultyLink,
                           FaultyTier, SplitRuntime, SplitUnrecoverable,
                           TierCrash, TierFaultSpec, TierShed, VirtualClock,
                           events, microbatch_slices, parse_mem_profile,
                           tier_breakers, tier_faults_from_env,
                           tier_from_env)

# ``repro.core`` re-exports the nsga2 *function*, which shadows the
# submodule under `import a.b as x` semantics -- go through importlib.
nsga2_mod = importlib.import_module("repro.core.nsga2")

TINY_LAYERS = [conv(8, 3, 1, 1), relu(), maxpool(2, 2),
               conv(16, 3, 1, 1), relu(), avgpool(2), linear(10)]
TINY_SHAPE = (3, 16, 16)


@pytest.fixture(scope="module")
def tiny():
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), TINY_LAYERS,
                              TINY_SHAPE)
    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(4,) + TINY_SHAPE), np.float32)
    return params, x


def _chain_plan(K=3, microbatches=1):
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS)
    hw = paper_chain(K)
    return prof, hw, smartsplit_chain(prof, hw, microbatches=microbatches)


def _links(hw, seed=0):
    clock = VirtualClock()
    return [FaultyLink(link.bandwidth, clock=clock, seed=seed + k)
            for k, link in enumerate(hw.links)]


def _tiers(hw, clock, spec=None, faulty=1, seed=0):
    return [FaultyTier(t.name,
                       faults=spec if k == faulty and spec is not None
                       else TierFaultSpec(),
                       seed=seed + k, clock=clock)
            for k, t in enumerate(hw.tiers)]


def _full_ref(params, x):
    return np.asarray(cnn_lib.apply_cnn(TINY_LAYERS, params, x))


# ---------------------------------------------------------------------------
# TierFaultSpec + FaultyTier unit behaviour
# ---------------------------------------------------------------------------
def test_spec_validation():
    with pytest.raises(ValueError):
        TierFaultSpec(crash_rate=1.5)
    with pytest.raises(ValueError):
        TierFaultSpec(slow_rate=-0.1)
    with pytest.raises(ValueError):
        TierFaultSpec(slow_factor=0.5)
    with pytest.raises(ValueError):
        TierFaultSpec(mem_budget=-1)
    with pytest.raises(ValueError):
        TierFaultSpec(crash_windows=((2.0, 1.0),))
    assert TierFaultSpec().fault_free
    assert not TierFaultSpec(slow_rate=0.1).fault_free


def test_faulty_tier_is_seed_deterministic():
    def outcomes(seed):
        ft = FaultyTier("t", faults=TierFaultSpec(crash_rate=0.4,
                                                  slow_rate=0.3,
                                                  slow_factor=2.0),
                        seed=seed)
        out = []
        for i in range(32):
            try:
                out.append(round(ft.execute(float(i), 0.5), 6))
            except TierCrash:
                out.append("crash")
        return out

    a, b, c = outcomes(7), outcomes(7), outcomes(8)
    assert a == b
    assert a != c
    assert "crash" in a and any(isinstance(v, float) for v in a)


def test_faulty_tier_draws_are_outcome_invariant():
    """The rng consumes the same number of draws per call whatever the
    outcome, so one tier's fault schedule does not depend on payload
    sizes or on which faults actually fired."""
    spec = TierFaultSpec(crash_rate=0.3)
    a = FaultyTier("t", faults=spec, seed=3)
    b = FaultyTier("t", faults=spec, seed=3)
    seq_a, seq_b = [], []
    for i in range(24):
        try:
            a.execute(float(i), 0.1, mem_bytes=1.0)
            seq_a.append("ok")
        except TierCrash:
            seq_a.append("crash")
        try:  # different compute/mem args, same draw schedule
            b.execute(float(i), 7.0, mem_bytes=1e9)
            seq_b.append("ok")
        except TierCrash:
            seq_b.append("crash")
    assert seq_a == seq_b


def test_crash_window_and_overlap():
    ft = FaultyTier("t", faults=TierFaultSpec(
        crash_windows=((1.0, 2.0), (5.0, 6.0))))
    assert ft.in_crash_window(1.0) and not ft.in_crash_window(2.0)
    assert ft.crash_overlaps(0.5, 1.5) and ft.crash_overlaps(1.9, 5.1)
    assert not ft.crash_overlaps(2.0, 5.0)
    with pytest.raises(TierCrash):
        ft.execute(0.9, 0.5)        # runs into the window mid-stage
    assert ft.window_hits == 1
    assert ft.execute(2.0, 0.5) == 0.5


def test_mem_budget_shed_and_profile():
    ft = FaultyTier("t", faults=TierFaultSpec(mem_budget=100.0))
    with pytest.raises(TierShed):
        ft.execute(0.0, 0.1, mem_bytes=101.0)
    assert ft.sheds == 1
    assert ft.execute(0.0, 0.1, mem_bytes=100.0) == 0.1
    # piecewise budget: unlimited until t=1, then 10 bytes, then free
    prof = FaultyTier("t", faults=TierFaultSpec(
        mem_profile=((1.0, 10.0), (2.0, 0.0))))
    assert prof.budget_at(0.5) == 0.0           # 0 = unlimited
    assert prof.budget_at(1.5) == 10.0
    assert prof.budget_at(2.5) == 0.0
    prof.execute(0.5, 0.01, mem_bytes=1e9)      # before the squeeze
    with pytest.raises(TierShed):
        prof.execute(1.5, 0.01, mem_bytes=11.0)
    prof.execute(2.5, 0.01, mem_bytes=1e9)      # squeeze lifted


def test_straggler_stretches_not_fails():
    ft = FaultyTier("t", faults=TierFaultSpec(slow_rate=1.0,
                                              slow_factor=4.0))
    assert ft.execute(0.0, 0.5) == pytest.approx(2.0)
    assert ft.slowdowns == 1 and ft.crashes == 0


# ---------------------------------------------------------------------------
# Env knob round-trips
# ---------------------------------------------------------------------------
def test_parse_mem_profile():
    assert parse_mem_profile("0:100, 2.5:0") == ((0.0, 100.0), (2.5, 0.0))
    assert parse_mem_profile("") == ()


def test_tier_from_env_round_trip(monkeypatch):
    monkeypatch.setenv("REPRO_TIER_CRASH", "0.25")
    monkeypatch.setenv("REPRO_TIER_CRASH_WINDOWS", "1:2")
    monkeypatch.setenv("REPRO_TIER_SLOW", "0.5")
    monkeypatch.setenv("REPRO_TIER_SLOW_FACTOR", "8")
    monkeypatch.setenv("REPRO_TIER_MEM_BUDGET", "1024")
    monkeypatch.setenv("REPRO_TIER_SEED", "9")
    ft = tier_from_env("edge")
    assert ft.faults.crash_rate == 0.25
    assert ft.faults.crash_windows == ((1.0, 2.0),)
    assert ft.faults.slow_rate == 0.5 and ft.faults.slow_factor == 8.0
    assert ft.faults.mem_budget == 1024.0
    assert ft.seed == 9
    # explicit args beat env
    ft = tier_from_env("edge", faults=TierFaultSpec(), seed=1)
    assert ft.faults.fault_free and ft.seed == 1


def test_per_tier_env_override(monkeypatch):
    """REPRO_TIER1_* beats the chain-wide REPRO_TIER_* for tier 1 only,
    and per-tier seeds default to base+k but pin via REPRO_TIER{k}_SEED."""
    monkeypatch.setenv("REPRO_TIER_CRASH", "0.1")
    monkeypatch.setenv("REPRO_TIER1_CRASH", "0.9")
    monkeypatch.setenv("REPRO_TIER_SEED", "100")
    monkeypatch.setenv("REPRO_TIER2_SEED", "7")
    tiers = tier_faults_from_env(["phone", "edge", "cloud"])
    assert [t.name for t in tiers] == ["phone", "edge", "cloud"]
    assert tiers[0].faults.crash_rate == 0.1
    assert tiers[1].faults.crash_rate == 0.9
    assert tiers[2].faults.crash_rate == 0.1
    assert tiers[0].seed == 100 and tiers[1].seed == 101
    assert tiers[2].seed == 7


# ---------------------------------------------------------------------------
# Circuit breaker state machine
# ---------------------------------------------------------------------------
def test_breaker_walks_closed_open_halfopen_closed():
    from repro.runtime.breakers import CLOSED, HALF_OPEN, OPEN
    from repro.runtime.events import EventLog
    log = EventLog()
    br = CircuitBreaker("edge", failure_threshold=3, cooldown_s=1.0,
                        log=log)
    assert br.state == CLOSED
    assert br.record_failure(0.1) is False
    assert br.record_failure(0.2) is False
    assert br.record_failure(0.3) is True           # trips
    assert br.state == OPEN and br.opened_at == 0.3
    assert not br.allow(0.5)                        # cooling down
    assert br.n_rejected == 1
    assert br.allow(1.4)                            # past cooldown: probe
    assert br.state == HALF_OPEN
    br.record_success(1.5)
    assert br.state == CLOSED and br.failures == 0
    assert log.count(events.BREAKER_OPEN) == 1
    assert log.count(events.BREAKER_HALF_OPEN) == 1
    assert log.count(events.BREAKER_CLOSE) == 1


def test_breaker_probe_failure_reopens():
    from repro.runtime.breakers import OPEN
    br = CircuitBreaker("edge", failure_threshold=1, cooldown_s=1.0)
    br.record_failure(0.0)
    assert br.state == OPEN
    assert br.allow(1.1)                            # half-open probe
    assert br.record_failure(1.2) is True           # probe failed
    assert br.state == OPEN and br.opened_at == 1.2
    assert not br.allow(1.3)
    # an intervening success in CLOSED resets the consecutive count
    br2 = CircuitBreaker("t", failure_threshold=2)
    br2.record_failure(0.0)
    br2.record_success(0.1)
    assert br2.record_failure(0.2) is False
    assert br2.failures == 1


def test_tier_breakers_builder():
    brs = tier_breakers(["a", "b"], failure_threshold=5, cooldown_s=2.0)
    assert [b.name for b in brs] == ["a", "b"]
    assert all(b.failure_threshold == 5 and b.cooldown_s == 2.0
               for b in brs)


# ---------------------------------------------------------------------------
# Standby registry + plan-front memoisation
# ---------------------------------------------------------------------------
def test_standby_registry_covers_server_tiers_only():
    hw = paper_chain(4)
    # every non-device tier has a standby; standbys themselves do not
    # (no failover chains), and neither do the phones
    for tier in hw.tiers[1:]:
        spare = standby_for(tier)
        assert spare is not None and spare.name != tier.name
        assert standby_for(spare) is None
    assert standby_for(hw.tiers[0]) is None
    for phone in DEVICE_TIERS.values():
        assert standby_for(phone) is None
    served = {t.name for t in paper_chain(4).tiers[1:]} \
        | {t.name for t in paper_chain(2).tiers[1:]}
    assert set(STANDBY_TIERS) == served


def test_standby_chain_replaces_one_tier():
    hw = paper_chain(3)
    new = standby_chain(hw, 1)
    assert new is not None
    assert new.tiers[1].name == standby_for(hw.tiers[1]).name
    assert new.tiers[0] is hw.tiers[0] and new.tiers[2] is hw.tiers[2]
    assert new.links == hw.links
    assert standby_chain(hw, 0) is None             # the phone: no spare


def test_plan_cache_memoises_by_chain_key():
    clear_plan_cache()
    prof, hw, _ = _chain_plan(3)
    p1 = cached_chain_plan(prof, hw)
    assert plan_cache_stats() == {"hits": 0, "misses": 1, "size": 1}
    p2 = cached_chain_plan(prof, hw)
    assert p2 is p1
    assert plan_cache_stats()["hits"] == 1
    other = standby_chain(hw, 1)
    p3 = cached_chain_plan(prof, other)
    assert p3 is not p1
    assert plan_cache_stats() == {"hits": 1, "misses": 2, "size": 2}
    clear_plan_cache()
    assert plan_cache_stats() == {"hits": 0, "misses": 0, "size": 0}


# ---------------------------------------------------------------------------
# ChainRuntime degradation ladder, rung by rung
# ---------------------------------------------------------------------------
def test_straggler_slows_but_stays_clean(tiny):
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    links = _links(hw)
    tiers = _tiers(hw, links[0]._clock,
                   TierFaultSpec(slow_rate=1.0, slow_factor=8.0))
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers)
    base = ChainRuntime(TINY_LAYERS, params, plan, prof, hw,
                        links=_links(hw)).infer(x)
    r = rt.infer(x)
    assert not r.degraded
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x))
    assert rt.log.count(events.TIER_SLOW) >= 1
    assert r.chain_elapsed_s > base.chain_elapsed_s
    assert rt.stats()["tiers"][1]["slowdowns"] >= 1


def test_crash_merges_onto_upstream_tier(tiny):
    """Rung 2: a crashed middle stage folds onto the tier that already
    holds its input boundary -- same layers, same bytes, bit-identical."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    links = _links(hw)
    tiers = _tiers(hw, links[0]._clock,
                   TierFaultSpec(crash_windows=((0.0, 1e9),)), faulty=2)
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers)
    r = rt.infer(x)
    assert r.degraded and r.merged_hops
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x))
    assert rt.log.count(events.TIER_CRASH) >= 1
    assert rt.log.count(events.STAGE_MERGE) >= 1
    assert rt.n_failovers == 0


def test_crash_window_fails_over_to_standby(tiny):
    """Rung 4: merge disabled, in-window crash is persistent (re-pick
    skipped) -> cached-front failover onto the standby tier."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    links = _links(hw)
    tiers = _tiers(hw, links[0]._clock,
                   TierFaultSpec(crash_windows=((0.0, 1e9),)))
    before = nsga2_mod.RUN_COUNT
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers, merge_fallback=False)
    after_init = nsga2_mod.RUN_COUNT
    r = rt.infer(x)
    assert rt.n_failovers == 1 and r.degraded
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x))
    # the standby is live in the runtime's hardware and stats
    spare = standby_for(hw.tiers[1]).name
    assert rt.hw.tiers[1].name == spare
    assert rt.stats()["active_tiers"][1] == spare
    fo = [e for e in rt.log.events if e.kind == events.TIER_FAILOVER]
    assert len(fo) == 1 and fo[0].detail["new_tier"] == spare
    # re-pick rung skipped: the failure was persistent
    assert rt.log.count(events.REPICK) == 0
    # failover itself never runs the GA (prewarm at init is allowed)
    assert nsga2_mod.RUN_COUNT == after_init
    # the healed tier model replaces the crashed one in-place
    assert tiers[1].faults.fault_free
    # follow-up requests ride the spare cleanly
    r2 = rt.infer(x)
    assert not r2.degraded
    np.testing.assert_array_equal(np.asarray(r2.logits),
                                  _full_ref(params, x))
    del before


def test_breaker_trips_then_proactive_failover(tiny):
    """Consecutive shed failures trip the breaker; the NEXT request sees
    it open at dispatch and fails over before burning an attempt."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    links = _links(hw)
    # permanent shed on tier 1 (transient per-failure, so the ladder
    # re-picks/merges its way through while failures accumulate)
    tiers = _tiers(hw, links[0]._clock, TierFaultSpec(mem_budget=1.0))
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers)
    r1 = rt.infer(x)
    assert r1.degraded
    np.testing.assert_array_equal(np.asarray(r1.logits),
                                  _full_ref(params, x))
    assert rt.log.count(events.TIER_SHED) >= 1
    assert rt.stats()["breakers"][1]["opens"] >= 0  # schema present
    # drive until the breaker has tripped and failover has happened
    for _ in range(6):
        if rt.n_failovers:
            break
        rt.infer(x)
    assert rt.n_failovers >= 1
    assert rt.log.count(events.BREAKER_OPEN) >= 1


def test_device_fallback_when_no_standby(tiny):
    """Rung 5: standby disabled -> the whole model runs on the phone."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    links = _links(hw)
    tiers = _tiers(hw, links[0]._clock,
                   TierFaultSpec(crash_windows=((0.0, 1e9),)))
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers, merge_fallback=False,
                      standby=False)
    r = rt.infer(x)
    assert r.degraded and rt.n_fallback_device == 1
    assert rt.n_failovers == 0
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x))
    assert rt.log.count(events.FALLBACK_DEVICE) == 1


def test_unrecoverable_when_every_rung_exhausted(tiny):
    """Rung 6: no merge, no standby, phone too small -> raise."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    phone = dataclasses.replace(hw.tiers[0], memory_budget=1.0)
    hw = dataclasses.replace(hw, tiers=(phone,) + tuple(hw.tiers[1:]))
    links = _links(hw)
    tiers = _tiers(hw, links[0]._clock,
                   TierFaultSpec(crash_windows=((0.0, 1e9),)))
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers, merge_fallback=False,
                      standby=False)
    with pytest.raises(SplitUnrecoverable):
        rt.infer(x)
    assert rt.log.count(events.UNRECOVERABLE) == 1


def test_unprotected_runtime_keeps_legacy_contract(tiny):
    """Without tier_faults/breakers the link-failure ladder must NOT
    grow failover/device rungs: a dead hop with merge disabled is still
    unrecoverable (the PR-4 contract, pinned by the existing suite)."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    from repro.runtime import FaultSpec
    clock = VirtualClock()
    links = [FaultyLink(link.bandwidth, clock=clock, seed=k,
                        faults=FaultSpec(outages=((0.0, 1e9),))
                        if k == 1 else FaultSpec())
             for k, link in enumerate(hw.links)]
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      merge_fallback=False)
    with pytest.raises(SplitUnrecoverable):
        rt.infer(x)


def test_protected_runtime_survives_dead_link_via_failover(tiny):
    """With the tier layer active, a permanently dead link escalates
    past the exhausted re-pick rung into standby failover instead of
    raising."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    from repro.runtime import FaultSpec
    clock = VirtualClock()
    links = [FaultyLink(link.bandwidth, clock=clock, seed=k,
                        faults=FaultSpec(outages=((0.0, 1e9),))
                        if k == 1 else FaultSpec())
             for k, link in enumerate(hw.links)]
    tiers = _tiers(hw, clock)               # all fault-free, but protected
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers, merge_fallback=False)
    r = rt.infer(x)
    assert r.degraded
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x))
    assert rt.n_failovers + rt.n_fallback_device >= 1


# ---------------------------------------------------------------------------
# Two-tier SplitRuntime mirror
# ---------------------------------------------------------------------------
def test_split_runtime_server_crash_fails_over(tiny):
    params, x = tiny
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS)
    plan = smartsplit_exhaustive(prof, PAPER_ENV_J6)
    clock = VirtualClock()
    link = FaultyLink(PAPER_ENV_J6.link.bandwidth, clock=clock)
    tiers = [FaultyTier(PAPER_ENV_J6.client.name, clock=clock),
             FaultyTier(PAPER_ENV_J6.server.name,
                        faults=TierFaultSpec(crash_windows=((0.0, 1e9),)),
                        clock=clock)]
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      link=link, tier_faults=tiers)
    r = rt.infer(x)
    assert r.degraded and rt.n_failovers == 1
    assert rt.hw.server.name == standby_for(PAPER_ENV_J6.server).name
    # bit-identical to apply_split at the split that actually executed
    ref, _ = cnn_lib.apply_split(TINY_LAYERS, params, x, r.split_index)
    np.testing.assert_array_equal(np.asarray(r.logits), np.asarray(ref))
    assert rt.log.count(events.TIER_FAILOVER) == 1
    assert rt.stats()["failovers"] == 1


def test_split_runtime_shed_repicks_first(tiny):
    """A transient shed walks the re-pick rung before failover."""
    params, x = tiny
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS)
    plan = smartsplit_exhaustive(prof, PAPER_ENV_J6)
    l1 = plan.split_index
    cm = prof.cum_mem()
    # budget squeezed so the planned split sheds but a later cut fits
    budget = float(cm[-1] - cm[l1]) - 1.0
    clock = VirtualClock()
    link = FaultyLink(PAPER_ENV_J6.link.bandwidth, clock=clock)
    tiers = [FaultyTier("phone", clock=clock),
             FaultyTier("cloud", faults=TierFaultSpec(mem_budget=budget),
                        clock=clock)]
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      link=link, tier_faults=tiers)
    r = rt.infer(x)
    assert r.degraded
    assert rt.log.count(events.TIER_SHED) >= 1
    assert rt.n_repicks >= 1 or rt.n_failovers >= 1
    ref, _ = cnn_lib.apply_split(TINY_LAYERS, params, x, r.split_index,
                                 )
    np.testing.assert_array_equal(np.asarray(r.logits), np.asarray(ref))


# ---------------------------------------------------------------------------
# Acceptance sweep: 3 fixed seeds x {crash-window, straggler}
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("profile,spec,merge", [
    ("crash_window", TierFaultSpec(crash_windows=((0.0, 1e9),)), False),
    ("straggler", TierFaultSpec(slow_rate=0.6, slow_factor=8.0), None),
])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_acceptance_never_silent_wrong_answer(tiny, profile, spec, merge,
                                              seed):
    """The PR contract: under tier chaos every request is bit-identical
    to the fault-free reference OR carries recorded recovery events --
    success rate 1.0, and failover never re-runs NSGA-II."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3, microbatches=2)
    links = _links(hw, seed=seed)
    tiers = _tiers(hw, links[0]._clock, spec, seed=seed)
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      tier_faults=tiers, merge_fallback=merge,
                      jitter_seed=seed, microbatches=2)
    ga_after_init = nsga2_mod.RUN_COUNT
    outs = [cnn_lib.apply_cnn(TINY_LAYERS, params, x[a:b])
            for a, b in microbatch_slices(x.shape[0], 2)]
    ref = np.concatenate([np.asarray(o) for o in outs], axis=0)
    completed = 0
    for _ in range(4):
        r = rt.infer(x)
        completed += 1
        same = bool(np.array_equal(np.asarray(r.logits), ref))
        if not same:
            assert r.degraded, "silent wrong answer"
            kinds = {e.kind for e in r.events}
            assert kinds & {events.TIER_FAILOVER, events.FALLBACK_DEVICE,
                            events.STAGE_MERGE, events.REPICK}
    assert completed == 4                           # success rate 1.0
    assert nsga2_mod.RUN_COUNT == ga_after_init     # no GA during serving
    if profile == "crash_window":
        assert rt.n_failovers == 1
        assert rt.log.count(events.TIER_FAILOVER) == 1
    else:
        assert rt.log.count(events.TIER_SLOW) >= 1
        assert rt.n_failovers == 0
