"""End-to-end tests of the SmartSplit planner on the paper's models and the
paper's hardware environment -- the reproduction claims live here."""
import numpy as np
import pytest

from repro.core import (PAPER_ENV_J6, PAPER_ENV_NOTE8, TPU_EDGE_CLOUD,
                        coc, cos, ebo, evaluate_objectives, feasible_mask,
                        lbo, mbo, rs, smartsplit, smartsplit_exhaustive,
                        total_energy, total_latency)
from repro.core.costs import check_profile
from repro.models.profiles import cnn_profile

MODELS = ["alexnet", "vgg11", "vgg13", "vgg16", "mobilenetv2"]
PAPER_TABLE1 = {"alexnet": 3, "vgg11": 11, "vgg13": 10, "vgg16": 10}


@pytest.mark.parametrize("name", MODELS)
def test_profiles_valid(name):
    check_profile(cnn_profile(name))


@pytest.mark.parametrize("name", MODELS)
def test_ga_matches_exhaustive(name):
    """NSGA-II + TOPSIS == enumeration + TOPSIS on every paper model."""
    p = cnn_profile(name)
    for f3 in ("full", "activations"):
        ga = smartsplit(p, PAPER_ENV_J6, f3_mode=f3)
        ex = smartsplit_exhaustive(p, PAPER_ENV_J6, f3_mode=f3)
        assert ga.split_index == ex.split_index
        assert set(ga.pareto_indices) == set(ex.pareto_indices)


def test_table1_calibrated_reproduction():
    """Table I: optimal split layers 3/11/10/10. Under the table-calibrated
    memory counting (see DESIGN.md §9 / EXPERIMENTS.md Calibration) we
    reproduce AlexNet, VGG13 and VGG16 exactly; VGG11 selects 6 with the
    paper's 11 present in the Pareto set."""
    got = {m: smartsplit_exhaustive(cnn_profile(m), PAPER_ENV_J6,
                                    f3_mode="activations")
           for m in PAPER_TABLE1}
    assert got["alexnet"].split_index == 3
    assert got["vgg13"].split_index == 10
    assert got["vgg16"].split_index == 10
    assert 11 in got["vgg11"].pareto_indices


@pytest.mark.parametrize("name", MODELS)
def test_paper_split_in_pareto_set(name):
    """Every Table-I split the paper reports is Pareto-optimal under our
    cost model too (both memory countings)."""
    if name not in PAPER_TABLE1:
        pytest.skip("not in Table I")
    p = cnn_profile(name)
    plan = smartsplit_exhaustive(p, PAPER_ENV_J6)
    assert PAPER_TABLE1[name] in plan.pareto_indices


@pytest.mark.parametrize("name", MODELS)
def test_split_constraints(name):
    p = cnn_profile(name)
    for hw in (PAPER_ENV_J6, PAPER_ENV_NOTE8, TPU_EDGE_CLOUD):
        plan = smartsplit(p, hw)
        assert 1 <= plan.split_index <= p.num_layers - 1
        assert plan.client_layers + plan.server_layers == p.num_layers
        # memory constraint
        F = evaluate_objectives(p, hw)
        assert F[plan.split_index, 2] <= hw.client.memory_budget


def test_memory_budget_constraint_binds():
    """Shrink the client budget and the planner must move the split earlier."""
    import dataclasses
    p = cnn_profile("vgg16")
    free = smartsplit_exhaustive(p, PAPER_ENV_J6)
    mem_at_free = evaluate_objectives(p, PAPER_ENV_J6)[free.split_index, 2]
    tight_client = dataclasses.replace(PAPER_ENV_J6.client,
                                       memory_budget=mem_at_free * 0.5)
    tight = dataclasses.replace(PAPER_ENV_J6, client=tight_client)
    plan = smartsplit_exhaustive(p, tight)
    F = evaluate_objectives(p, tight)
    assert F[plan.split_index, 2] <= tight_client.memory_budget
    assert plan.split_index < free.split_index


def test_baselines_order():
    """LBO minimises f1, EBO f2, MBO f3 among feasible interior splits;
    COS/COC are the degenerate ends."""
    p = cnn_profile("vgg16")
    hw = PAPER_ENV_J6
    F = evaluate_objectives(p, hw)
    feas = feasible_mask(p, hw)
    l_lbo, l_ebo, l_mbo = lbo(p, hw), ebo(p, hw), mbo(p, hw)
    interior = np.where(feas)[0]
    assert F[l_lbo, 0] == F[interior, 0].min()
    assert F[l_ebo, 1] == F[interior, 1].min()
    assert F[l_mbo, 2] == F[interior, 2].min()
    assert cos(p, hw) == p.num_layers
    assert coc(p, hw) == 0
    r = rs(p, hw, np.random.default_rng(0))
    assert 1 <= r <= p.num_layers - 1


def test_smartsplit_dominates_or_ties_single_objective_baselines():
    """SmartSplit's pick cannot be dominated by LBO's or EBO's pick (it is
    on the Pareto front)."""
    for name in MODELS:
        p = cnn_profile(name)
        hw = PAPER_ENV_J6
        F = evaluate_objectives(p, hw)
        plan = smartsplit_exhaustive(p, hw)
        ours = F[plan.split_index]
        for other in (lbo(p, hw), ebo(p, hw)):
            o = F[other]
            assert not (np.all(o <= ours) and np.any(o < ours)), \
                f"{name}: dominated by split {other}"


def test_upload_latency_dominates_at_early_split():
    """Pilot-study claim: upload latency is the primary contributor for
    early splits on 10 Mbps (paper Figs 1-2)."""
    from repro.core import latency_terms
    p = cnn_profile("vgg16")
    t_c, t_u, t_s, _ = latency_terms(p, PAPER_ENV_J6)
    # at the first conv output (224x224x64 fp32 ~ 12.8 MB over 1.25 MB/s)
    assert t_u[1] > t_c[1] and t_u[1] > t_s[1]
    assert t_u[1] > 5.0


def test_note8_less_upload_energy_share():
    """Paper Fig 3-5: the J6 (802.11n) spends relatively more energy on
    upload than on compute vs the Note 8's faster CPU -- with identical
    radio constants, the faster client lowers the client-energy share."""
    from repro.core import energy_terms
    p = cnn_profile("vgg16")
    e_c_j6, e_u_j6, _ = energy_terms(p, PAPER_ENV_J6)
    e_c_n8, e_u_n8, _ = energy_terms(p, PAPER_ENV_NOTE8)
    mid = p.num_layers // 2
    assert e_u_j6[mid] == pytest.approx(e_u_n8[mid])  # same radio model
    # client energy grows with nu^3/(C*S) ~ nu^2: Note 8 (2.0 GHz) burns
    # MORE compute energy than J6 (1.6 GHz) -- the paper's Fig 4 contrast.
    assert e_c_n8[mid] > e_c_j6[mid]


def test_total_latency_energy_positive_and_finite():
    for name in MODELS:
        p = cnn_profile(name)
        for hw in (PAPER_ENV_J6, TPU_EDGE_CLOUD):
            assert np.all(np.isfinite(total_latency(p, hw)))
            assert np.all(total_latency(p, hw) >= 0)
            assert np.all(np.isfinite(total_energy(p, hw)))
            assert np.all(total_energy(p, hw) >= 0)
