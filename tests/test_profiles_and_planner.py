"""Transformer cost profiles + planner property tests."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import all_configs
from repro.core import (PAPER_ENV_J6, TPU_EDGE_CLOUD, evaluate_objectives,
                        feasible_mask, smartsplit_exhaustive)
from repro.core.costs import LayerProfile, ModelProfile, check_profile
from repro.models.profiles import transformer_profile

DECODERS = [a for a, c in all_configs().items() if not c.is_encoder]


@pytest.mark.parametrize("arch", sorted(all_configs()))
def test_transformer_profile_wellformed(arch):
    cfg = all_configs()[arch]
    for mode in ("prefill", "decode"):
        if cfg.is_encoder and mode == "decode":
            continue
        p = transformer_profile(cfg, seq_len=4096, batch=4, mode=mode)
        check_profile(p)
        assert p.num_layers == cfg.num_layers
        # recurrent archs carry state across the boundary
        if cfg.pattern in ("rwkv", "mamba"):
            assert any(l.state_bytes > 0 for l in p.layers)


@pytest.mark.parametrize("arch", ["qwen3-4b", "kimi-k2-1t-a32b"])
def test_profile_flops_match_config_totals(arch):
    """Sum of per-block profile FLOPs ~= cfg.model_flops (inference)."""
    cfg = all_configs()[arch]
    p = transformer_profile(cfg, seq_len=2048, batch=2, mode="prefill")
    total = sum(l.flops for l in p.layers)
    model = cfg.model_flops(seq_len=2048, batch=2, mode="prefill")
    # profile includes attention-score FLOPs, model_flops is 2*N*D;
    # they must agree within the attention-quadratic margin
    assert total == pytest.approx(model, rel=0.35)


@pytest.mark.parametrize("arch", DECODERS)
def test_tpu_split_plan_valid(arch):
    cfg = all_configs()[arch]
    p = transformer_profile(cfg, seq_len=8192, batch=8, mode="prefill")
    plan = smartsplit_exhaustive(p, TPU_EDGE_CLOUD)
    assert 1 <= plan.split_index <= cfg.num_layers - 1
    F = evaluate_objectives(p, TPU_EDGE_CLOUD)
    # the plan's objectives must be consistent with the cost matrix
    np.testing.assert_allclose(np.asarray(plan.objectives),
                               F[plan.split_index], rtol=1e-9)


def test_rwkv_boundary_is_state_dominated_late():
    """The O(1)-state property: for RWKV the boundary payload does not
    grow with split depth (unlike CNN activations)."""
    cfg = all_configs()["rwkv6-7b"]
    p = transformer_profile(cfg, seq_len=32768, batch=1, mode="decode")
    b = p.boundary()
    assert np.allclose(b[1:-1], b[1], rtol=1e-6)  # constant interior


# ---------------------------------------------------------------------------
# Random-profile planner properties
# ---------------------------------------------------------------------------
@st.composite
def profiles(draw):
    L = draw(st.integers(3, 25))
    layers = []
    for i in range(L):
        layers.append(LayerProfile(
            name=f"l{i}", kind="x",
            flops=draw(st.floats(1e6, 1e12)),
            param_bytes=draw(st.floats(0, 1e9)),
            act_bytes=draw(st.floats(1e3, 1e8)),
            boundary_bytes=draw(st.floats(1e3, 1e8)),
            state_bytes=draw(st.floats(0, 1e6))))
    return ModelProfile(name="rand", layers=tuple(layers), input_bytes=1e5)


@given(profiles(), st.sampled_from(["full", "activations"]))
@settings(max_examples=25, deadline=None)
def test_planner_invariants_on_random_profiles(profile, f3):
    plan = smartsplit_exhaustive(profile, PAPER_ENV_J6, f3_mode=f3)
    L = profile.num_layers
    assert 1 <= plan.split_index <= L - 1
    F = evaluate_objectives(profile, PAPER_ENV_J6, f3)
    # the chosen split is on the Pareto front of interior candidates
    ours = F[plan.split_index]
    for l1 in range(1, L):
        other = F[l1]
        assert not (np.all(other <= ours) and np.any(other < ours))


@given(profiles())
@settings(max_examples=15, deadline=None)
def test_cost_model_monotonicity(profile):
    """Structural invariants of the cost model."""
    F = evaluate_objectives(profile, PAPER_ENV_J6)
    # memory strictly non-decreasing in l1
    assert np.all(np.diff(F[:, 2]) >= -1e-9)
    # all objectives finite and non-negative
    assert np.all(np.isfinite(F)) and np.all(F >= 0)
    feas = feasible_mask(profile, PAPER_ENV_J6)
    assert not feas[0] and not feas[-1]   # degenerate ends excluded
