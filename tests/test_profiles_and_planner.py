"""Transformer cost profiles + planner tests.

Hypothesis property tests on random profiles live in
tests/test_planner_properties.py, which skips itself when ``hypothesis``
is not installed."""
import numpy as np
import pytest

from repro.configs import all_configs
from repro.core import (TPU_EDGE_CLOUD, evaluate_objectives,
                        smartsplit_exhaustive)
from repro.core.costs import check_profile
from repro.models.profiles import transformer_profile

DECODERS = [a for a, c in all_configs().items() if not c.is_encoder]


@pytest.mark.parametrize("arch", sorted(all_configs()))
def test_transformer_profile_wellformed(arch):
    cfg = all_configs()[arch]
    for mode in ("prefill", "decode"):
        if cfg.is_encoder and mode == "decode":
            continue
        p = transformer_profile(cfg, seq_len=4096, batch=4, mode=mode)
        check_profile(p)
        assert p.num_layers == cfg.num_layers
        # recurrent archs carry state across the boundary
        if cfg.pattern in ("rwkv", "mamba"):
            assert any(l.state_bytes > 0 for l in p.layers)


@pytest.mark.parametrize("arch", ["qwen3-4b", "kimi-k2-1t-a32b"])
def test_profile_flops_match_config_totals(arch):
    """Sum of per-block profile FLOPs ~= cfg.model_flops (inference)."""
    cfg = all_configs()[arch]
    p = transformer_profile(cfg, seq_len=2048, batch=2, mode="prefill")
    total = sum(l.flops for l in p.layers)
    model = cfg.model_flops(seq_len=2048, batch=2, mode="prefill")
    # profile includes attention-score FLOPs, model_flops is 2*N*D;
    # they must agree within the attention-quadratic margin
    assert total == pytest.approx(model, rel=0.35)


@pytest.mark.parametrize("arch", DECODERS)
def test_tpu_split_plan_valid(arch):
    cfg = all_configs()[arch]
    p = transformer_profile(cfg, seq_len=8192, batch=8, mode="prefill")
    plan = smartsplit_exhaustive(p, TPU_EDGE_CLOUD)
    assert 1 <= plan.split_index <= cfg.num_layers - 1
    F = evaluate_objectives(p, TPU_EDGE_CLOUD)
    # the plan's objectives must be consistent with the cost matrix
    np.testing.assert_allclose(np.asarray(plan.objectives),
                               F[plan.split_index], rtol=1e-9)


def test_rwkv_boundary_is_state_dominated_late():
    """The O(1)-state property: for RWKV the boundary payload does not
    grow with split depth (unlike CNN activations)."""
    cfg = all_configs()["rwkv6-7b"]
    p = transformer_profile(cfg, seq_len=32768, batch=1, mode="decode")
    b = p.boundary()
    assert np.allclose(b[1:-1], b[1], rtol=1e-6)  # constant interior
