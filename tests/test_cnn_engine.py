"""Batched CNN split-serving engine: packing, pipelining, backpressure,
deadlines, fault recovery mid-stream, and per-request bit-identity.

Deterministic: all timing is on the shared virtual clock, faults come
from seeded outage windows (same idiom as tests/test_chain_runtime.py),
so every schedule and recovery sequence is exact per seed."""
import jax
import numpy as np
import pytest

from repro.core import paper_chain, smartsplit
from repro.models import cnn as cnn_lib
from repro.models.cnn import avgpool, conv, linear, maxpool, relu
from repro.models.profiles import cnn_profile
from repro.runtime import (FaultSpec, FaultyLink, RetryPolicy,
                           SplitRuntime, VirtualClock, events)
from repro.serving.cnn_engine import CnnRequest, CnnServingEngine, \
    QueueFullError

TINY_LAYERS = [conv(8, 3, 1, 1), relu(), maxpool(2, 2),
               conv(16, 3, 1, 1), relu(), avgpool(2), linear(10)]
TINY_SHAPE = (3, 16, 16)
TINY_SHAPE_B = (3, 24, 24)      # second resolution, same params (GAP-free
                                # but avgpool(2) fixes the linear fan-in)


@pytest.fixture(scope="module")
def tiny():
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), TINY_LAYERS,
                              TINY_SHAPE)
    rng = np.random.default_rng(0)
    xs = [np.asarray(rng.normal(size=TINY_SHAPE), np.float32)
          for _ in range(16)]
    return params, xs


def _engine(params, *, tiers=3, links=None, **kw):
    kw.setdefault("policy", RetryPolicy(max_attempts=2, timeout_s=0.05,
                                        backoff_base_s=0.005))
    return CnnServingEngine({"tiny": (TINY_LAYERS, params)},
                            hw=paper_chain(tiers), links=links, **kw)


def _links(hw, seed=0, fault_hop=None, spec=None):
    clock = VirtualClock()
    return [FaultyLink(link.bandwidth, clock=clock, seed=seed + k,
                       faults=spec if k == fault_hop else FaultSpec())
            for k, link in enumerate(hw.links)]


def _ref(params, x1):
    """Single-sample single-device reference (split placement cannot
    change numerics, so this is the apply_split reference too)."""
    return np.asarray(cnn_lib.apply_cnn(TINY_LAYERS, params, x1[None]))[0]


# ---------------------------------------------------------------------------
# Degeneracy + bit-identity
# ---------------------------------------------------------------------------
def test_single_request_bitwise_equals_split_runtime(tiny):
    """One submitted request == a direct SplitRuntime run, bitwise."""
    params, xs = tiny
    eng = _engine(params, tiers=2)
    req = eng.submit(xs[0])
    eng.run_until_idle()
    assert req.status == "served"

    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS)
    from repro.core import PAPER_ENV_J6
    plan = smartsplit(prof, PAPER_ENV_J6)
    srt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6)
    direct = srt.infer(xs[0][None])
    np.testing.assert_array_equal(np.asarray(req.logits),
                                  np.asarray(direct.logits)[0])
    np.testing.assert_array_equal(np.asarray(req.logits),
                                  _ref(params, xs[0]))


def test_batched_requests_each_bit_identical(tiny):
    """Requests packed into one batch still match the single-sample
    reference bit for bit (one request = one microbatch = batch 1)."""
    params, xs = tiny
    eng = _engine(params, max_batch=4)
    reqs = [eng.submit(x, at=0.0) for x in xs[:4]]
    eng.run_until_idle()
    s = eng.stats()
    assert s["batches"] == 1 and s["avg_batch_size"] == 4.0
    for req, x in zip(reqs, xs):
        assert req.status == "served"
        np.testing.assert_array_equal(np.asarray(req.logits),
                                      _ref(params, x))


def test_mixed_resolution_buckets(tiny):
    """Two resolutions bucket separately (own plans), one weight set;
    every request still matches its own single-sample reference."""
    params, xs = tiny
    rng = np.random.default_rng(1)
    eng = _engine(params, max_batch=4)
    reqs = []
    for i in range(8):
        shape = TINY_SHAPE if i % 2 else TINY_SHAPE_B
        reqs.append(eng.submit(
            np.asarray(rng.normal(size=shape), np.float32), at=0.0))
    eng.run_until_idle()
    s = eng.stats()
    assert len(s["buckets"]) == 2
    assert {tuple(b["in_shape"]) for b in s["buckets"]} \
        == {TINY_SHAPE, TINY_SHAPE_B}
    for req in reqs:
        assert req.status == "served"
        ref = np.asarray(cnn_lib.apply_cnn(
            TINY_LAYERS, params, np.asarray(req.x)[None]))[0]
        np.testing.assert_array_equal(np.asarray(req.logits), ref)


# ---------------------------------------------------------------------------
# Backpressure + deadlines
# ---------------------------------------------------------------------------
def test_queue_full_sheds_with_named_error(tiny):
    params, xs = tiny
    eng = _engine(params, max_queue=3)
    for x in xs[:3]:
        eng.submit(x, at=0.0)
    with pytest.raises(QueueFullError) as ei:
        eng.submit(xs[3], at=0.0)
    assert isinstance(ei.value.request, CnnRequest)
    assert ei.value.request.status == "shed"
    s = eng.stats()
    assert s["shed"] == 1 and s["submitted"] == 4
    assert s["events"].get(events.QUEUE_SHED) == 1
    eng.run_until_idle()
    assert eng.stats()["served"] == 3       # shed request never served


def test_deadline_expired_before_dispatch(tiny):
    """A queued request whose earliest start already misses its deadline
    is expired without burning compute."""
    params, xs = tiny
    eng = _engine(params, max_batch=1)
    first = eng.submit(xs[0], at=0.0)
    # arrives at 0 but can only start after the first request drains
    late = eng.submit(xs[1], at=0.0, deadline_s=1e-9)
    eng.run_until_idle()
    assert first.status == "served"
    assert late.status == "expired"
    assert late.logits is None              # never dispatched
    assert eng.stats()["deadline_expired"] == 1
    assert eng.stats()["events"].get(events.DEADLINE_EXPIRED) == 1


def test_deadline_expired_mid_flight_keeps_result(tiny):
    """A request that starts in time but finishes late is flagged
    expired -- and the (late) result is kept, not destroyed."""
    params, xs = tiny
    eng = _engine(params)
    # starts immediately (est start == arrival), but any chain makespan
    # exceeds this deadline
    req = eng.submit(xs[0], at=0.0, deadline_s=1e-9)
    eng.run_until_idle()
    assert req.status == "expired"
    assert req.logits is not None           # computed, just late
    assert req.latency_s > req.deadline_s
    np.testing.assert_array_equal(np.asarray(req.logits),
                                  _ref(params, xs[0]))
    assert eng.stats()["served"] == 0


# ---------------------------------------------------------------------------
# Faults mid-stream
# ---------------------------------------------------------------------------
def test_repick_mid_stream_no_cross_batch_corruption(tiny):
    """Hop 1 is down for a window covering the first batch's transfer:
    the runtime re-picks a different cut from the Pareto front while
    later batches sit queued.  Every request -- the degraded batch and
    the queued ones -- still matches its single-sample reference."""
    params, xs = tiny
    hw = paper_chain(3)
    links = _links(hw, fault_hop=1,
                   spec=FaultSpec(outages=((0.0, 0.012),)))
    eng = _engine(params, links=links, max_batch=2,
                  merge_fallback=False,
                  policy=RetryPolicy(max_attempts=1, timeout_s=0.01,
                                     backoff_base_s=0.005))
    reqs = [eng.submit(x, at=0.0) for x in xs[:6]]
    eng.run_until_idle()
    s = eng.stats()
    assert s["repicks"] >= 1
    assert s["served"] == 6 and s["failed"] == 0
    assert s["events"].get(events.REPICK, 0) >= 1
    for req, x in zip(reqs, xs):
        np.testing.assert_array_equal(np.asarray(req.logits),
                                      _ref(params, x))


def test_unrecoverable_batch_marked_failed_later_batches_survive(tiny):
    """A permanently dead hop with merges disabled fails the in-flight
    batch; once the outage window would matter no more (it covers all
    time here, so every batch fails) the engine keeps serving order and
    statuses consistent -- nothing is silently wrong."""
    params, xs = tiny
    hw = paper_chain(3)
    links = _links(hw, fault_hop=1,
                   spec=FaultSpec(outages=((0.0, 1e9),)))
    eng = _engine(params, links=links, max_batch=2,
                  merge_fallback=False,
                  policy=RetryPolicy(max_attempts=1, timeout_s=0.01,
                                     backoff_base_s=0.005))
    reqs = [eng.submit(x, at=0.0) for x in xs[:4]]
    eng.run_until_idle()
    s = eng.stats()
    assert s["failed"] == 4 and s["served"] == 0
    assert all(r.status == "failed" for r in reqs)
    assert s["events"].get(events.UNRECOVERABLE, 0) >= 1


# ---------------------------------------------------------------------------
# Pipelining
# ---------------------------------------------------------------------------
def test_pipelined_beats_sequential_throughput():
    """Cross-request pipelining on the 3-tier clean chain: >= 1.3x
    requests/sec over the sequential whole-batch baseline (the
    acceptance bar the serving bench also enforces).  Uses alexnet --
    its planned chain spreads compute across the tiers, so there is
    overlap to win (the tiny chain is bottleneck-dominated)."""
    shape = (3, 64, 64)
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0),
                              cnn_lib.CNN_MODELS["alexnet"],
                              in_shape=shape)
    rng = np.random.default_rng(0)
    xs = [np.asarray(rng.normal(size=shape), np.float32)
          for _ in range(16)]

    def run(pipelined):
        eng = CnnServingEngine({"alexnet": params}, hw=paper_chain(3),
                               max_batch=4, pipelined=pipelined)
        for x in xs:
            eng.submit(x, at=0.0)
        eng.run_until_idle()
        return eng.stats()

    sp, sq = run(True), run(False)
    assert sp["served"] == sq["served"] == len(xs)
    assert sp["requests_per_s"] >= 1.3 * sq["requests_per_s"]
    # pipelined span is the overlap win, not a bookkeeping artifact
    assert sp["virtual_span_s"] < sq["virtual_span_s"]


def test_no_clairvoyant_batching(tiny):
    """A request that arrives after a batch's launch time rides the
    NEXT batch, even when the first had spare capacity."""
    params, xs = tiny
    eng = _engine(params, max_batch=4)
    eng.submit(xs[0], at=0.0)
    eng.submit(xs[1], at=1e9)               # far future
    assert eng.step()                       # dispatches only request 0
    assert eng.stats()["batches"] == 1
    assert eng.stats()["avg_batch_size"] == 1.0


# ---------------------------------------------------------------------------
# Stats shape
# ---------------------------------------------------------------------------
def test_stats_hops_schema_matches_chain_runtime(tiny):
    """Engine per-hop stats carry the ChainRuntime hop keys (plus the
    serving-level goodput rate), so dashboards can consume either."""
    params, xs = tiny
    eng = _engine(params)
    eng.submit(xs[0])
    eng.run_until_idle()
    s = eng.stats()
    rt = next(iter(eng._buckets.values())).rt
    chain_keys = set(rt.stats()["hops"][0])
    for hop in s["hops"]:
        assert chain_keys <= set(hop)
        assert "goodput_Bps" in hop
    assert {"submitted", "queued", "served", "shed", "deadline_expired",
            "failed", "latency_p50_s", "latency_p99_s",
            "requests_per_s", "buckets", "hops", "events"} <= set(s)


def test_submit_validation(tiny):
    params, xs = tiny
    eng = _engine(params)
    with pytest.raises(ValueError):
        eng.submit(xs[0], "nope")
    with pytest.raises(ValueError):
        eng.submit(xs[0], deadline_s=0.0)
    with pytest.raises(ValueError):
        CnnServingEngine({"tiny": (TINY_LAYERS, params)}, max_batch=0)
    with pytest.raises(ValueError):
        CnnServingEngine({"tiny": (TINY_LAYERS, params)}, max_queue=0)
