"""Knob registry <-> source <-> docs consistency (tier-1).

Three guards that keep docs/knobs.md from silently drifting:
1. every ``REPRO_*`` env name read anywhere under src/ is registered in
   ``core.knobs.KNOBS`` (the scanner canonicalises per-hop f-strings and
   the faults.py ``_env_*`` helper dispatch);
2. docs/knobs.md is byte-identical to what the registry renders
   (``scripts/gen_knobs.py --check`` runs the same comparison in CI);
3. README links every docs page and all intra-repo markdown links in
   README/docs resolve.
"""
import importlib.util
import re
from pathlib import Path

from repro.core.knobs import (KNOBS, registry_names, render_markdown,
                              scan_env_reads)

REPO = Path(__file__).resolve().parents[1]


def test_every_env_read_is_registered():
    scanned = scan_env_reads(REPO / "src")
    missing = scanned - registry_names()
    assert not missing, (
        f"REPRO_* env reads missing from core/knobs.py KNOBS: "
        f"{sorted(missing)} -- register them and regenerate "
        f"docs/knobs.md")


def test_no_dead_registry_entries():
    """Every registered knob is actually read somewhere -- entries must
    be pruned when the code stops reading them."""
    scanned = scan_env_reads(REPO / "src")
    dead = registry_names() - scanned
    assert not dead, (
        f"registered knobs no longer read anywhere under src/: "
        f"{sorted(dead)}")


def test_scanner_sees_known_knobs():
    """The scanner itself works: spot-check one of each read idiom --
    direct literal, module constant, constant+suffix composition,
    per-hop f-string, and the _env_* helper dispatch."""
    scanned = scan_env_reads(REPO / "src")
    assert "REPRO_CHAIN_MICROBATCH" in scanned      # direct literal
    assert "REPRO_CONV_SEARCH" in scanned           # SEARCH_ENV constant
    assert "REPRO_LINK_RETRIES" in scanned          # ENV_PREFIX + "RETRIES"
    assert "REPRO_LINK{k}_WIRE_DTYPE" in scanned    # per-hop f-string
    assert "REPRO_LINK{k}_DROP" in scanned          # _env_float("DROP", ...)
    assert "REPRO_TIER_CRASH" in scanned            # _tier_env_float(...)
    assert "REPRO_TIER{k}_CRASH_WINDOWS" in scanned  # per-tier wrapper
    assert "REPRO_LINK_BACKOFF_FACTOR" in scanned   # RetryPolicy.from_env


def test_knobs_md_up_to_date():
    path = REPO / "docs" / "knobs.md"
    assert path.exists(), "docs/knobs.md missing: run scripts/gen_knobs.py"
    assert path.read_text() == render_markdown(), (
        "docs/knobs.md is stale: regenerate with "
        "`PYTHONPATH=src python scripts/gen_knobs.py`")


def test_registry_rows_well_formed():
    names = [k.name for k in KNOBS]
    assert len(names) == len(set(names)), "duplicate knob names"
    for k in KNOBS:
        assert k.name.startswith("REPRO_")
        assert k.description and k.resolved_in
        if k.per_hop:
            assert "{k}" in k.per_hop


def test_readme_links_all_docs_pages():
    readme = (REPO / "README.md").read_text()
    for page in ("docs/architecture.md", "docs/runtime.md",
                 "docs/serving.md", "docs/knobs.md"):
        assert page in readme, f"README does not link {page}"
        assert (REPO / page).exists()


def test_intra_repo_markdown_links_resolve():
    """Same check the CI docs job runs via scripts/check_links.py."""
    spec = importlib.util.spec_from_file_location(
        "check_links", REPO / "scripts" / "check_links.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    broken = mod.check(mod.md_files())
    assert not broken, "\n".join(broken)


def test_docs_reference_real_modules():
    """Module paths cited in the hand-written docs exist (cheap rot
    guard for the architecture pages)."""
    pat = re.compile(r"`((?:core|runtime|serving|kernels|models|launch)/"
                     r"[a-z_0-9]+\.py)`")
    for page in ("architecture.md", "runtime.md", "serving.md"):
        text = (REPO / "docs" / page).read_text()
        for mod_path in pat.findall(text):
            assert (REPO / "src" / "repro" / mod_path).exists(), (
                f"docs/{page} cites missing module {mod_path}")
