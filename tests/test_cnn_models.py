"""Paper CNN definitions: layer counts, split-execution equivalence, and
analytic-profile vs compiled-HLO FLOPs crosschecks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import cnn
from repro.models.profiles import cnn_profile

PAPER_LAYER_COUNTS = {"alexnet": 21, "vgg11": 29, "vgg13": 33, "vgg16": 39,
                      "mobilenetv2": 21}
PUBLISHED_PARAMS_M = {"alexnet": 61.1, "vgg11": 132.9, "vgg13": 133.0,
                      "vgg16": 138.4, "mobilenetv2": 3.5}


@pytest.mark.parametrize("name,count", PAPER_LAYER_COUNTS.items())
def test_layer_counts_match_paper(name, count):
    assert len(cnn.CNN_MODELS[name]) == count


@pytest.mark.parametrize("name", PAPER_LAYER_COUNTS)
def test_param_counts_match_published(name):
    p = cnn_profile(name)
    params_m = sum(l.param_bytes for l in p.layers) / 4 / 1e6
    assert params_m == pytest.approx(PUBLISHED_PARAMS_M[name], rel=0.02)


@pytest.mark.parametrize("name", [
    "alexnet",
    # mobilenetv2 at 224 costs ~20 s of XLA compiles; tier-1 keeps the
    # alexnet variant, full runs cover both
    pytest.param("mobilenetv2", marks=pytest.mark.slow),
])
def test_split_execution_equivalent_to_monolithic(name):
    """Running client[0,l1) + server[l1,L) must equal the unsplit network
    bit-for-bit, at every split index (subsampled for speed)."""
    layers = cnn.CNN_MODELS[name]
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 224, 224)) * 0.1
    full = cnn.apply_cnn(layers, params, x)
    L = len(layers)
    for l1 in {1, 2, 3, L // 2, L - 2, L - 1}:
        split_logits, boundary = cnn.apply_split(layers, params, x, l1)
        np.testing.assert_allclose(np.asarray(split_logits),
                                   np.asarray(full), rtol=1e-5, atol=1e-5)
        # boundary payload bytes must match the profile's boundary entry
        prof = cnn_profile(name)
        assert boundary.size * 4 == prof.boundary()[l1]


@pytest.mark.parametrize("name", PAPER_LAYER_COUNTS)
def test_profile_shapes_consistent_with_execution(name):
    """Analytic per-layer activation sizes == real traced shapes."""
    layers = cnn.CNN_MODELS[name]
    shapes = cnn.shapes_through(layers)
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)

    x = jax.ShapeDtypeStruct((1, 3, 224, 224), jnp.float32)

    def run(x):
        outs = []
        h = x
        for l, p in zip(layers, params):
            h = cnn.apply_layer(l, p, h)
            outs.append(h)
        return outs

    traced = jax.eval_shape(run, x)
    for analytic, real in zip(shapes, traced):
        assert int(np.prod(analytic)) == int(np.prod(real.shape))


def _np_adaptive_avgpool(x: np.ndarray, t: int) -> np.ndarray:
    """Independent reference for torchvision AdaptiveAvgPool2d: output cell
    (i, j) averages input [floor(i*H/t), ceil((i+1)*H/t)) x [..W..]."""
    n, c, h, w = x.shape
    out = np.zeros((n, c, t, t), np.float64)
    for i in range(t):
        hs, he = (i * h) // t, -(-((i + 1) * h) // t)
        for j in range(t):
            ws, we = (j * w) // t, -(-((j + 1) * w) // t)
            out[:, :, i, j] = x[:, :, hs:he, ws:we].mean(axis=(2, 3))
    return out.astype(np.float32)


@pytest.mark.parametrize("hw,t", [
    (227, 6),    # AlexNet's original 227-px input: 227 % 6 != 0
    (192, 7),    # VGG avgpool target at a 192-px input
    (13, 6),     # AlexNet 224-px path (13 % 6 != 0 -- even the default
                 # resolution hits the truncation bug before the avgpool)
    (224, 7),    # divisible: the cheap uniform-window path
    (5, 7),      # output larger than input (windows of 1, repeated)
])
def test_adaptive_avgpool_matches_torchvision_semantics(hw, t):
    """Regression: the old reshape implementation truncated trailing
    rows/cols whenever H % out_hw != 0, silently diverging from
    AdaptiveAvgPool2d's variable windows at any non-divisible input."""
    x = np.random.RandomState(0).randn(2, 3, hw, hw).astype(np.float32)
    got = cnn.apply_layer(cnn.avgpool(t), {}, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), _np_adaptive_avgpool(x, t),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_alexnet_head_odd_resolution_parity(backend):
    """AlexNet conv stack + avgpool at a non-224 resolution (192 px): the
    feature map reaching avgpool(6) is 5x5, so the variable-window path is
    exercised inside a real network on both backends (the old truncating
    implementation produced an empty window here and NaNs out)."""
    layers = cnn.ALEXNET[:14]          # through avgpool(6)
    in_shape = (3, 192, 192)
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers, in_shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (1,) + in_shape) * 0.3
    got = cnn.apply_cnn(layers, params, x, backend=backend)
    assert got.shape == (1, 256, 6, 6)
    want_tail = _np_adaptive_avgpool(
        np.asarray(cnn.apply_cnn(layers[:-1], params[:-1], x,
                                 backend="xla")), 6)
    np.testing.assert_allclose(np.asarray(got), want_tail,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Degenerate geometry: clear errors instead of opaque lax failures
# ---------------------------------------------------------------------------
def test_layer_out_shape_rejects_too_small_input():
    with pytest.raises(ValueError, match="conv1.*too small"):
        cnn.layer_out_shape(
            cnn.Layer(kind="conv", name="conv1", cout=8, ksize=7), (3, 4, 4))
    with pytest.raises(ValueError, match="maxpool"):
        cnn.layer_out_shape(cnn.maxpool(3, 2), (8, 2, 2))
    with pytest.raises(ValueError, match="avgpool"):
        cnn.layer_out_shape(cnn.avgpool(0), (8, 4, 4))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_apply_rejects_too_small_input_with_named_layer(backend):
    """Regression: the xla path used to die deep inside lax with an opaque
    shape error; both backends must now raise a ValueError naming the
    offending layer before touching the conv lowering."""
    layers = [cnn.conv(8, 11, 4, 2), cnn.relu(), cnn.maxpool(3, 2)]
    in_shape = (3, 8, 8)               # conv out 1x1 -> maxpool empty
    params = [cnn._init_conv(jax.random.PRNGKey(0), 3, 8, 11), {}, {}]
    x = jnp.zeros((1,) + in_shape)
    with pytest.raises(ValueError, match="maxpool"):
        cnn.apply_cnn(layers, params, x, backend=backend)
    with pytest.raises(ValueError, match="conv"):
        cnn.apply_layer(cnn.conv(8, 11, 4, 0), params[0],
                        jnp.zeros((1, 3, 6, 6)), backend=backend)


def test_shapes_through_names_layer_for_bad_input():
    with pytest.raises(ValueError, match="maxpool.*ksize=2"):
        cnn.shapes_through(cnn.CNN_MODELS["vgg16"], (3, 20, 20))


def test_analytic_flops_match_hlo_alexnet():
    """Our analytic FLOPs vs XLA's cost model on the full network.

    XLA counts only a subset of elementwise ops and fuses; we assert the
    *matmul/conv-dominated* total agrees within 20% -- the profile drives
    relative split decisions, so proportional agreement is what matters."""
    layers = cnn.CNN_MODELS["alexnet"]
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)
    fn = jax.jit(lambda x: cnn.apply_cnn(layers, params, x))
    comp = fn.lower(jax.ShapeDtypeStruct((1, 3, 224, 224),
                                         jnp.float32)).compile()
    from repro.analysis.hlo import cost_analysis_dict
    hlo_flops = cost_analysis_dict(comp)["flops"]
    ours = sum(l.flops for l in cnn_profile("alexnet").layers)
    assert hlo_flops == pytest.approx(ours, rel=0.2)
