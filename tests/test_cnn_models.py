"""Paper CNN definitions: layer counts, split-execution equivalence, and
analytic-profile vs compiled-HLO FLOPs crosschecks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import cnn
from repro.models.profiles import cnn_profile

PAPER_LAYER_COUNTS = {"alexnet": 21, "vgg11": 29, "vgg13": 33, "vgg16": 39,
                      "mobilenetv2": 21}
PUBLISHED_PARAMS_M = {"alexnet": 61.1, "vgg11": 132.9, "vgg13": 133.0,
                      "vgg16": 138.4, "mobilenetv2": 3.5}


@pytest.mark.parametrize("name,count", PAPER_LAYER_COUNTS.items())
def test_layer_counts_match_paper(name, count):
    assert len(cnn.CNN_MODELS[name]) == count


@pytest.mark.parametrize("name", PAPER_LAYER_COUNTS)
def test_param_counts_match_published(name):
    p = cnn_profile(name)
    params_m = sum(l.param_bytes for l in p.layers) / 4 / 1e6
    assert params_m == pytest.approx(PUBLISHED_PARAMS_M[name], rel=0.02)


@pytest.mark.parametrize("name", [
    "alexnet",
    # mobilenetv2 at 224 costs ~20 s of XLA compiles; tier-1 keeps the
    # alexnet variant, full runs cover both
    pytest.param("mobilenetv2", marks=pytest.mark.slow),
])
def test_split_execution_equivalent_to_monolithic(name):
    """Running client[0,l1) + server[l1,L) must equal the unsplit network
    bit-for-bit, at every split index (subsampled for speed)."""
    layers = cnn.CNN_MODELS[name]
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 224, 224)) * 0.1
    full = cnn.apply_cnn(layers, params, x)
    L = len(layers)
    for l1 in {1, 2, 3, L // 2, L - 2, L - 1}:
        split_logits, boundary = cnn.apply_split(layers, params, x, l1)
        np.testing.assert_allclose(np.asarray(split_logits),
                                   np.asarray(full), rtol=1e-5, atol=1e-5)
        # boundary payload bytes must match the profile's boundary entry
        prof = cnn_profile(name)
        assert boundary.size * 4 == prof.boundary()[l1]


@pytest.mark.parametrize("name", PAPER_LAYER_COUNTS)
def test_profile_shapes_consistent_with_execution(name):
    """Analytic per-layer activation sizes == real traced shapes."""
    layers = cnn.CNN_MODELS[name]
    shapes = cnn.shapes_through(layers)
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)

    x = jax.ShapeDtypeStruct((1, 3, 224, 224), jnp.float32)

    def run(x):
        outs = []
        h = x
        for l, p in zip(layers, params):
            h = cnn.apply_layer(l, p, h)
            outs.append(h)
        return outs

    traced = jax.eval_shape(run, x)
    for analytic, real in zip(shapes, traced):
        assert int(np.prod(analytic)) == int(np.prod(real.shape))


def test_analytic_flops_match_hlo_alexnet():
    """Our analytic FLOPs vs XLA's cost model on the full network.

    XLA counts only a subset of elementwise ops and fuses; we assert the
    *matmul/conv-dominated* total agrees within 20% -- the profile drives
    relative split decisions, so proportional agreement is what matters."""
    layers = cnn.CNN_MODELS["alexnet"]
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)
    fn = jax.jit(lambda x: cnn.apply_cnn(layers, params, x))
    comp = fn.lower(jax.ShapeDtypeStruct((1, 3, 224, 224),
                                         jnp.float32)).compile()
    from repro.analysis.hlo import cost_analysis_dict
    hlo_flops = cost_analysis_dict(comp)["flops"]
    ours = sum(l.flops for l in cnn_profile("alexnet").layers)
    assert hlo_flops == pytest.approx(ours, rel=0.2)
