"""The unified ChainPlan stack: construction validation, K=2 degeneracy
against the paper's two-tier planner, pipeline-latency pricing, and the
generalised (per-hop) re-pick machinery."""
import numpy as np
import pytest

from repro.core import (PAPER_ENV_J6, ChainHardware, ChainPlan,
                        MultiCutPlan, SplitPlan,
                        chain_link_weights, chain_of,
                        chain_stage_hop_times, evaluate_chain_objectives,
                        evaluate_multicut, link_weights, paper_chain,
                        pipeline_latency, repick_chain, repick_split,
                        smartsplit, smartsplit_chain, smartsplit_exhaustive)
from repro.models.cnn import avgpool, conv, linear, maxpool, relu
from repro.models.profiles import cnn_profile

TINY_LAYERS = [conv(8, 3, 1, 1), relu(), maxpool(2, 2),
               conv(16, 3, 1, 1), relu(), avgpool(2), linear(10)]
TINY_SHAPE = (3, 16, 16)


def _tiny_profile(**kw):
    return cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS,
                       **kw)


def _plan(cuts, L=10, tiers=None, links=None, **kw):
    K = len(cuts) + 1
    hw = paper_chain(3)
    tiers = tiers if tiers is not None else tuple(
        f"t{i}" for i in range(K))
    links = links if links is not None else tuple(
        [hw.links[0]] * (len(tiers) - 1))
    return ChainPlan(model="m", num_layers=L, cuts=tuple(cuts),
                     objectives=(1.0, 2.0, 3.0),
                     pareto_cuts=np.asarray([cuts], np.int64),
                     pareto_F=np.ones((1, 3)),
                     links=links, tiers=tiers, **kw)


# ---------------------------------------------------------------------------
# Validation (satellite: named ValueErrors on malformed plans/chains)
# ---------------------------------------------------------------------------
def test_chain_plan_rejects_out_of_range_cut():
    with pytest.raises(ValueError, match="out of range"):
        _plan((0, 5))
    with pytest.raises(ValueError, match="out of range"):
        _plan((3, 10), L=10)


def test_chain_plan_rejects_non_increasing_cuts():
    with pytest.raises(ValueError, match="strictly increasing"):
        _plan((5, 5))
    with pytest.raises(ValueError, match="strictly increasing"):
        _plan((6, 3))


def test_chain_plan_rejects_tier_and_link_mismatch():
    with pytest.raises(ValueError, match="tier/cut mismatch"):
        _plan((3, 6), tiers=("a", "b"))
    hw = paper_chain(3)
    with pytest.raises(ValueError, match="tier/link mismatch"):
        _plan((3, 6), links=(hw.links[0],))
    with pytest.raises(ValueError, match="microbatches"):
        _plan((3,), tiers=("a", "b"), microbatches=0)


def test_chain_hardware_validation():
    hw = paper_chain(3)
    with pytest.raises(ValueError, match=">= 2 tiers"):
        ChainHardware(tiers=(hw.tiers[0],), links=())
    with pytest.raises(ValueError, match="tier/link mismatch"):
        ChainHardware(tiers=hw.tiers, links=(hw.links[0],))
    with pytest.raises(ValueError, match="per-hop bandwidths"):
        hw.with_link_bandwidths((1e6,))


def test_chain_plan_views_and_merge_hop():
    p = _plan((3, 6), L=10)
    assert p.num_tiers == 3
    assert p.edges == (0, 3, 6, 10)
    assert p.stages() == [(0, 3), (3, 6), (6, 10)]
    assert p.stages(10) == p.stages()
    with pytest.raises(ValueError, match="disagrees"):
        p.stages(9)
    m = p.merge_hop(1)         # stage 2 folds onto stage 1's tier
    assert m.cuts == (3,)
    assert m.tiers == ("t0", "t1")
    assert len(m.links) == 1
    assert m.pareto_cuts.shape == (0, 1)   # cached front not carried
    with pytest.raises(ValueError, match="merge_hop"):
        p.merge_hop(2)
    # K=3 plans have no single split index
    with pytest.raises(ValueError, match="two-tier view"):
        _ = p.split_index


def test_legacy_aliases_are_chain_plan():
    assert SplitPlan is ChainPlan
    assert MultiCutPlan is ChainPlan


# ---------------------------------------------------------------------------
# K=2 degeneracy: the unified planner IS the paper planner
# ---------------------------------------------------------------------------
def test_two_tier_chain_plan_matches_smartsplit_exactly():
    p = _tiny_profile()
    legacy = smartsplit_exhaustive(p, PAPER_ENV_J6)
    chain = smartsplit_chain(p, PAPER_ENV_J6)   # TwoTierHardware accepted
    assert chain.cuts == (legacy.split_index,)
    assert chain.split_index == legacy.split_index
    assert chain.objectives == legacy.objectives        # bitwise
    assert chain.pareto_indices == legacy.pareto_indices
    np.testing.assert_array_equal(chain.pareto_F, legacy.pareto_F)
    assert chain.hardware == legacy.hardware
    assert chain.client_layers + chain.server_layers == p.num_layers


def test_two_tier_chain_matches_nsga2_smartsplit_pick():
    p = _tiny_profile()
    ga = smartsplit(p, PAPER_ENV_J6)
    chain = smartsplit_chain(p, PAPER_ENV_J6)
    # both TOPSIS-pick from the same exhaustive front on 7 layers
    assert chain.split_index == ga.split_index
    np.testing.assert_allclose(chain.objectives, ga.objectives,
                               rtol=1e-12)


def test_repick_chain_matches_repick_split_at_k2():
    p = _tiny_profile()
    plan = smartsplit_exhaustive(p, PAPER_ENV_J6)
    B = PAPER_ENV_J6.link.bandwidth
    legacy = repick_split(plan, p, PAPER_ENV_J6, bandwidth=B / 4)
    chain = repick_chain(plan, p, PAPER_ENV_J6, bandwidths=(B / 4,))
    assert chain.cuts == (legacy.split_index,)
    np.testing.assert_allclose(chain.objectives, legacy.objectives,
                               rtol=1e-12)


def test_repick_chain_exclusion_and_empty_front():
    p = _tiny_profile()
    plan = smartsplit_exhaustive(p, PAPER_ENV_J6)
    repicked = repick_chain(plan, p, PAPER_ENV_J6,
                            exclude=(plan.cuts,))
    assert repicked.cuts != plan.cuts           # tried cut skipped
    all_cuts = tuple(tuple(int(c) for c in row)
                     for row in plan.pareto_cuts)
    with pytest.raises(ValueError):
        repick_chain(plan, p, PAPER_ENV_J6, exclude=all_cuts)


# ---------------------------------------------------------------------------
# Pipeline latency pricing
# ---------------------------------------------------------------------------
def test_pipeline_latency_m1_is_sequential_sum():
    stage_T = np.array([[0.3, 0.2, 0.5]])
    hop_T = np.array([[0.1, 0.4]])
    lat = pipeline_latency(stage_T, hop_T, microbatches=1)
    np.testing.assert_allclose(lat, [1.5])
    # M large: bounded below by the slowest unit, above by the M=1 sum
    lat8 = pipeline_latency(stage_T, hop_T, microbatches=8)
    assert 0.5 <= lat8[0] <= 1.5
    assert lat8[0] < lat[0]


def test_pipeline_latency_headers_penalise_microbatching():
    stage_T = np.array([[0.5, 0.5]])
    hop_T = np.array([[0.5]])
    bw = np.array([1000.0])
    m1 = pipeline_latency(stage_T, hop_T, 1, link_bandwidths=bw)
    m4 = pipeline_latency(stage_T, hop_T, 4, link_bandwidths=bw)
    # framing overhead exists but is small vs the pipelining win
    assert m4[0] < m1[0]
    m4_free = pipeline_latency(stage_T, hop_T, 4)
    assert m4[0] > m4_free[0]


def test_evaluate_multicut_microbatching_reduces_latency():
    p = _tiny_profile()
    hw = paper_chain(3)
    genomes = np.array([[2, 5], [3, 6]], np.int64)
    F1 = evaluate_multicut(p, hw, genomes)
    F4 = evaluate_multicut(p, hw, genomes, microbatches=4)
    # pipelining wins where units overlap (framing overhead can eat the
    # gain when one unit dominates, so assert the balanced cut improves)
    assert F4[0, 0] < F1[0, 0]
    np.testing.assert_array_equal(F1[:, 2], F4[:, 2])   # memory unchanged
    # both evaluators share the pipeline latency model at M=1 (f2/f3 use
    # different normalisations: billed Joules vs peak-mem fraction)
    np.testing.assert_allclose(
        F1[:, 0], evaluate_chain_objectives(p, hw, genomes)[:, 0],
        rtol=1e-12)


def test_chain_stage_hop_times_shapes():
    p = _tiny_profile()
    hw = paper_chain(4)
    genomes = np.array([[1, 3, 5]], np.int64)
    stage_T, hop_T = chain_stage_hop_times(p, hw, genomes)
    assert stage_T.shape == (1, 4)
    assert hop_T.shape == (1, 3)
    assert (stage_T > 0).all() and (hop_T > 0).all()


# ---------------------------------------------------------------------------
# Per-hop degradation weighting
# ---------------------------------------------------------------------------
def test_chain_link_weights_degenerates_to_link_weights():
    np.testing.assert_array_equal(chain_link_weights((3.0,)),
                                  link_weights(3.0))
    # worst hop drives the chain weighting
    np.testing.assert_array_equal(chain_link_weights((1.0, 5.0, 2.0)),
                                  link_weights(5.0))
    with pytest.raises(ValueError):
        chain_link_weights(())


def test_paper_chain_shapes():
    for K in (2, 3, 4):
        hw = paper_chain(K)
        assert hw.num_tiers == K
        assert len(hw.links) == K - 1
        assert hw.tiers[0].name == "samsung-galaxy-j6"
    assert chain_of(PAPER_ENV_J6).num_tiers == 2
    with pytest.raises(ValueError):
        paper_chain(5)
