"""Hypothesis property tests for the planner on random profiles.

Kept separate from tests/test_profiles_and_planner.py so environments
without ``hypothesis`` (dev-only dependency) still run the unit and
parametrized tests there."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (PAPER_ENV_J6, evaluate_objectives,  # noqa: E402
                        feasible_mask, smartsplit_exhaustive)
from repro.core.costs import LayerProfile, ModelProfile  # noqa: E402


@st.composite
def profiles(draw):
    L = draw(st.integers(3, 25))
    layers = []
    for i in range(L):
        layers.append(LayerProfile(
            name=f"l{i}", kind="x",
            flops=draw(st.floats(1e6, 1e12)),
            param_bytes=draw(st.floats(0, 1e9)),
            act_bytes=draw(st.floats(1e3, 1e8)),
            boundary_bytes=draw(st.floats(1e3, 1e8)),
            state_bytes=draw(st.floats(0, 1e6))))
    return ModelProfile(name="rand", layers=tuple(layers), input_bytes=1e5)


@given(profiles(), st.sampled_from(["full", "activations"]))
@settings(max_examples=25, deadline=None)
def test_planner_invariants_on_random_profiles(profile, f3):
    plan = smartsplit_exhaustive(profile, PAPER_ENV_J6, f3_mode=f3)
    L = profile.num_layers
    assert 1 <= plan.split_index <= L - 1
    F = evaluate_objectives(profile, PAPER_ENV_J6, f3)
    # the chosen split is on the Pareto front of interior candidates
    ours = F[plan.split_index]
    for l1 in range(1, L):
        other = F[l1]
        assert not (np.all(other <= ours) and np.any(other < ours))


@given(profiles())
@settings(max_examples=15, deadline=None)
def test_cost_model_monotonicity(profile):
    """Structural invariants of the cost model."""
    F = evaluate_objectives(profile, PAPER_ENV_J6)
    # memory strictly non-decreasing in l1
    assert np.all(np.diff(F[:, 2]) >= -1e-9)
    # all objectives finite and non-negative
    assert np.all(np.isfinite(F)) and np.all(F >= 0)
    feas = feasible_mask(profile, PAPER_ENV_J6)
    assert not feas[0] and not feas[-1]   # degenerate ends excluded
