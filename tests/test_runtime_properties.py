"""Hypothesis property tests for the fault-tolerant split runtime.

The headline invariant: for ANY injected sequence of drops, corruptions,
delays, and outages, a completed request's logits are bit-identical to
the fault-free ``apply_split`` run at the split that actually executed,
and any deviation from the planned split carries recorded recovery
events -- never a silent wrong answer.

Kept separate from tests/test_runtime.py so environments without
``hypothesis`` (dev-only dependency) still run the deterministic suite."""
import jax
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import PAPER_ENV_J6, smartsplit_exhaustive  # noqa: E402
from repro.models import cnn as cnn_lib  # noqa: E402
from repro.models.cnn import (avgpool, conv, linear,  # noqa: E402
                              maxpool, relu)
from repro.models.profiles import cnn_profile  # noqa: E402
from repro.runtime import (FaultSpec, FaultyLink,  # noqa: E402
                           RetryPolicy, SplitRuntime, events)

LAYERS = [conv(8, 3, 1, 1), relu(), maxpool(2, 2),
          conv(16, 3, 1, 1), relu(), avgpool(2), linear(10)]
IN_SHAPE = (3, 16, 16)
L = len(LAYERS)

PARAMS = cnn_lib.init_cnn(jax.random.PRNGKey(0), LAYERS, IN_SHAPE)
X = np.asarray(np.random.default_rng(0).normal(size=(1,) + IN_SHAPE),
               np.float32)
PROF = cnn_profile("tiny", in_shape=IN_SHAPE, layers=LAYERS)
PLAN = smartsplit_exhaustive(PROF, PAPER_ENV_J6)
# Fault-free reference logits for every possible split placement.
REFS = {l1: np.asarray(cnn_lib.apply_split(LAYERS, PARAMS, X, l1)[0])
        for l1 in range(L + 1)}

RECOVERY_KINDS = {events.FALLBACK_DEVICE, events.REPICK,
                  events.PROACTIVE_RESPLIT, events.GIVE_UP}


@given(drop=st.floats(0.0, 1.0), corrupt=st.floats(0.0, 1.0),
       delay=st.floats(0.0, 1.0),
       outage_at=st.none() | st.floats(0.0, 0.05),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_never_a_silent_wrong_answer(drop, corrupt, delay, outage_at,
                                     seed):
    """Any fault mix: each request's logits are bit-identical to the
    fault-free run of its executed split, and a non-planned outcome is
    always explained by recovery events."""
    outages = () if outage_at is None else ((outage_at, outage_at + 0.2),)
    spec = FaultSpec(drop_rate=drop, corrupt_rate=corrupt,
                     delay_rate=delay, delay_s=0.05, outages=outages)
    link = FaultyLink(PAPER_ENV_J6.link.bandwidth, faults=spec, seed=seed)
    rt = SplitRuntime(LAYERS, PARAMS, PLAN, PROF, PAPER_ENV_J6, link=link,
                      jitter_seed=seed,
                      policy=RetryPolicy(max_attempts=3, timeout_s=0.1,
                                         backoff_base_s=0.02))
    for _ in range(3):
        r = rt.infer(X)  # PAPER_ENV_J6's client fits the model: no raise
        assert np.array_equal(np.asarray(r.logits), REFS[r.split_index])
        if r.degraded:
            kinds = {e.kind for e in r.events}
            assert kinds & RECOVERY_KINDS, (
                f"degraded result with no recovery event: {kinds}")
        else:
            # non-degraded => the planned split's exact logits
            assert r.split_index == r.planned_split
            assert np.array_equal(np.asarray(r.logits),
                                  REFS[r.planned_split])


@given(seed=st.integers(0, 2**31 - 1),
       sizes=st.lists(st.integers(1, 10_000), min_size=1, max_size=12))
@settings(max_examples=40, deadline=None)
def test_fault_schedule_reproducible_and_size_invariant(seed, sizes):
    """Same seed => identical outcome sequence, regardless of payload
    sizes (the schedule must not leak payload geometry)."""
    spec = FaultSpec(drop_rate=0.5, corrupt_rate=0.3)

    def outcomes(szs):
        link = FaultyLink(1e9, faults=spec, seed=seed)
        res = []
        for n in szs:
            try:
                data, _ = link.send(b"q" * n, timeout_s=1.0)
                res.append("corrupt" if data != b"q" * n else "ok")
            except Exception as e:
                res.append(type(e).__name__)
        return res

    assert outcomes(sizes) == outcomes(sizes)
    assert outcomes(sizes) == outcomes([1] * len(sizes))


@given(seed=st.integers(0, 2**31 - 1), drop=st.floats(0.0, 0.9))
@settings(max_examples=25, deadline=None)
def test_runtime_is_deterministic_per_seed(seed, drop):
    """Two runtimes with identical seeds replay the same recovery story:
    same attempts, same split, same virtual-clock spend, same logits."""
    def run():
        link = FaultyLink(PAPER_ENV_J6.link.bandwidth,
                          faults=FaultSpec(drop_rate=drop), seed=seed)
        rt = SplitRuntime(LAYERS, PARAMS, PLAN, PROF, PAPER_ENV_J6,
                          link=link, jitter_seed=seed,
                          policy=RetryPolicy(max_attempts=4,
                                             timeout_s=0.1,
                                             backoff_base_s=0.02))
        r = rt.infer(X)
        return (r.attempts, r.split_index, r.on_device,
                r.link_elapsed_s, np.asarray(r.logits))

    a, b = run(), run()
    assert a[:4] == b[:4]
    assert np.array_equal(a[4], b[4])
