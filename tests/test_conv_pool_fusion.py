"""Fused conv->relu->maxpool triple: kernel parity, VMEM planning, the
apply_cnn fusion walk (launch counts, split-boundary semantics), and the
pool-geometry corner cases (overlapping AlexNet-style windows, remainder
pooled tiles).

Everything runs in interpret mode on CPU; full-resolution triples whose
conv exceeds ~2e8 MACs are marked ``slow`` (tier-1 runs ``-m "not slow"``)
but still pass under a plain ``pytest`` run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.conv2d import (DEFAULT_VMEM_BUDGET, conv2d, plan_conv)
from repro.models import cnn

KEY = jax.random.PRNGKey(0)

POOL_MODELS = ("alexnet", "vgg11", "vgg13", "vgg16")


def _inputs(n, cin, hw, cout, k, scale=0.3):
    x = jax.random.normal(KEY, (n, cin, hw, hw)) * scale
    w = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (cout, cin, k, k)) * 0.2
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (cout,)) * 0.1
    return x, w, b


def _ref_triple(x, w, b, *, stride, pad, act, pool_k, pool_s):
    y = ref.conv2d_ref(x, w, stride=stride, pad=pad, bias=b, activation=act)
    return jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                                 (1, 1, pool_k, pool_k),
                                 (1, 1, pool_s, pool_s), "VALID")


def _model_pool_triples(name):
    """(cin, hw, cout, k, stride, pad, act, pool_k, pool_s) for every
    conv->relu->maxpool triple the model executes, deduplicated.  The
    enumeration itself is cnn.conv_pool_triples -- the same source the
    fusion benchmarks use, mirroring apply_cnn's fusion condition."""
    seen, out = set(), []
    for spec in cnn.conv_pool_triples(cnn.CNN_MODELS[name]):
        spec = spec[1:]                 # drop the layer index
        if spec not in seen:
            seen.add(spec)
            out.append(spec)
    return out


def _triple_params():
    params, seen = [], set()
    for model in POOL_MODELS:
        for spec in _model_pool_triples(model):
            if spec in seen:
                continue            # VGG variants share most triples
            seen.add(spec)
            cin, hw, cout, k, stride, pad, act, pk, ps = spec
            macs = k * k * cin * cout * hw * hw
            marks = [pytest.mark.slow] if macs > 2e8 else []
            params.append(pytest.param(
                spec, marks=marks,
                id=f"{model}-{cin}x{hw}-{cout}c{k}s{stride}p{pk}_{ps}"))
    return params


# ---------------------------------------------------------------------------
# Kernel-level parity: every AlexNet/VGG triple shape + geometry sweep
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec", _triple_params())
def test_fused_triple_parity_model_shapes(spec):
    """Acceptance: fused kernel == XLA conv->act->reduce_window to 1e-5 on
    every conv->relu->maxpool triple of the paper's pooling models."""
    cin, hw, cout, k, stride, pad, act, pk, ps = spec
    x, w, b = _inputs(1, cin, hw, cout, k)
    got = conv2d(x, w, stride=stride, pad=pad, bias=b, activation=act,
                 pool_k=pk, pool_s=ps)
    want = _ref_triple(x, w, b, stride=stride, pad=pad, act=act,
                       pool_k=pk, pool_s=ps)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("spec", _triple_params())
def test_fused_triple_vmem_within_budget(spec):
    """Acceptance: the fused plan fits the 12 MiB budget for all paper
    triples at full 224 resolution (planning only -- no execution)."""
    cin, hw, cout, k, stride, pad, act, pk, ps = spec
    plan = plan_conv((1, cin, hw, hw), (cout, cin, k, k), stride=stride,
                     pad=pad, pool_k=pk, pool_s=ps)
    assert plan.vmem_bytes <= DEFAULT_VMEM_BUDGET, plan
    assert plan.pool_k == pk and plan.pool_s == ps
    # pooled geometry must match the layer-shape contract
    h_out = (hw + 2 * pad - k) // stride + 1
    assert plan.p_out == (h_out - pk) // ps + 1
    assert plan.pw_out == (plan.w_out - pk) // ps + 1
    assert plan.n_h_blocks * plan.tile_h >= plan.p_out
    # each grid step spans the conv rows its pool windows need
    assert plan.tile_conv_h == (plan.tile_h - 1) * ps + pk


@pytest.mark.parametrize("k,stride,pad,pk,ps", sorted({
    (k, s, p, pk, ps)
    for m in POOL_MODELS
    for (_, _, _, k, s, p, _, pk, ps) in _model_pool_triples(m)}))
def test_fused_triple_geometry_sweep_small(k, stride, pad, pk, ps):
    """Every distinct (K, stride, pad, pool) geometry of the paper models,
    shrunk to small channels/resolution so tier-1 covers the halo/pool
    interaction cheaply."""
    hw = 31 if k > 5 else 23
    x, w, b = _inputs(2, 6, hw, 8, k, scale=0.4)
    got = conv2d(x, w, stride=stride, pad=pad, bias=b, activation="relu",
                 pool_k=pk, pool_s=ps)
    want = _ref_triple(x, w, b, stride=stride, pad=pad, act="relu",
                       pool_k=pk, pool_s=ps)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("tile_h", [1, 2, 3, 5])
@pytest.mark.parametrize("pk,ps", [(2, 2), (3, 2)])
def test_fused_pool_remainder_tiles(tile_h, pk, ps):
    """p_out not a multiple of tile_h: the padded pooled rows (and the
    zero conv rows feeding only them) must not leak into the output --
    including the overlapping-window case pk > ps where neighbouring
    tiles recompute shared conv rows."""
    x, w, b = _inputs(2, 6, 17, 12, 3)
    got = conv2d(x, w, stride=1, pad=1, bias=b, activation="relu",
                 pool_k=pk, pool_s=ps, tile_h=tile_h)
    want = _ref_triple(x, w, b, stride=1, pad=1, act="relu",
                       pool_k=pk, pool_s=ps)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_pool_s_defaults_to_pool_k():
    x, w, b = _inputs(1, 4, 12, 8, 3)
    got = conv2d(x, w, stride=1, pad=1, bias=b, pool_k=2)
    want = _ref_triple(x, w, b, stride=1, pad=1, act=None, pool_k=2,
                       pool_s=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_pool_degenerate_geometry_raises():
    """Pool window larger than the conv output must fail in the planner
    with a geometry error, not deep inside the kernel."""
    with pytest.raises(ValueError, match="geometry"):
        plan_conv((1, 4, 6, 6), (8, 4, 3, 3), stride=1, pad=0,
                  pool_k=5, pool_s=2)


# ---------------------------------------------------------------------------
# apply_cnn fusion walk: launch counts + split-boundary semantics
# ---------------------------------------------------------------------------
_TRIPLE = [cnn.conv(8, 3, 1, 1), cnn.relu(), cnn.maxpool(3, 2),
           cnn.conv(16, 3, 1, 1), cnn.relu(), cnn.maxpool(2, 2),
           cnn.conv(16, 1, 1, 0), cnn.relu(),   # pair, no pool follows
           cnn.linear(10)]
_TRIPLE_IN = (3, 17, 17)


def _spy_counts(monkeypatch):
    """Count fused-kernel launches and separate reduce_window launches."""
    counts = {"conv": 0, "pool_k": [], "reduce_window": 0}
    real_conv = ops.conv2d
    real_rw = jax.lax.reduce_window

    def conv_spy(*a, **kw):
        counts["conv"] += 1
        counts["pool_k"].append(kw.get("pool_k", 0))
        return real_conv(*a, **kw)

    def rw_spy(*a, **kw):
        counts["reduce_window"] += 1
        return real_rw(*a, **kw)

    monkeypatch.setattr(ops, "conv2d", conv_spy)
    monkeypatch.setattr(jax.lax, "reduce_window", rw_spy)
    return counts


def test_triple_fuses_to_single_launch(monkeypatch):
    """Acceptance: a conv->relu->maxpool triple wholly on one side of the
    split is ONE kernel launch (ops.conv2d with pool_k set) and zero
    separate reduce_window launches."""
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TRIPLE, _TRIPLE_IN)
    x = jax.random.normal(KEY, (1,) + _TRIPLE_IN) * 0.5
    counts = _spy_counts(monkeypatch)
    cnn.apply_cnn(_TRIPLE, params, x, backend="pallas")
    # 3 convs -> 3 launches: two fused triples + one fused pair
    assert counts["conv"] == 3
    assert counts["pool_k"] == [3, 2, 0]
    assert counts["reduce_window"] == 0


def test_split_inside_triple_does_not_fuse_across(monkeypatch):
    """A split landing inside a triple (conv|relu or relu|maxpool) must
    not fuse across the client/server boundary: the maxpool (and/or relu)
    runs unfused on the far side and the boundary payload is unchanged."""
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TRIPLE, _TRIPLE_IN)
    x = jax.random.normal(KEY, (1,) + _TRIPLE_IN) * 0.5
    for split in (1, 2):            # conv|relu..., conv,relu|maxpool...
        lx, bx = cnn.apply_split(_TRIPLE, params, x, split, backend="xla")
        counts = _spy_counts(monkeypatch)
        lp, bp = cnn.apply_split(_TRIPLE, params, x, split,
                                 backend="pallas")
        assert bp.shape == bx.shape          # payload bytes unchanged
        np.testing.assert_allclose(np.asarray(bp), np.asarray(bx),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                                   rtol=1e-5, atol=1e-5)
        # the split triple's maxpool must have launched separately
        assert counts["reduce_window"] == 1
        assert counts["pool_k"][0] == 0      # first conv: no fused pool
        monkeypatch.undo()


@pytest.mark.parametrize("split", range(1, len(_TRIPLE)))
def test_triple_model_split_parity_all_indices(split):
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TRIPLE, _TRIPLE_IN)
    x = jax.random.normal(KEY, (1,) + _TRIPLE_IN) * 0.5
    lx, bx = cnn.apply_split(_TRIPLE, params, x, split, backend="xla")
    lp, bp = cnn.apply_split(_TRIPLE, params, x, split, backend="pallas")
    np.testing.assert_allclose(np.asarray(bp), np.asarray(bx),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("model", POOL_MODELS)
def test_full_model_walk_fuses_every_triple(model, monkeypatch):
    """Acceptance: walking the whole model at 224 px on the pallas backend,
    every conv->relu->maxpool triple goes through ONE fused launch (pool_k
    set) and no separate reduce_window ever runs.  The conv kernel is
    stubbed with a shape-faithful zeros output so the full-resolution walk
    stays cheap -- this checks the *fusion decisions*, the parity tests
    above check the kernel itself."""
    layers = cnn.CNN_MODELS[model]
    calls = []

    def fake_conv2d(x, w, b, stride, pad, groups=1, activation=None,
                    pool_k=0, pool_s=0, backend=None, dtype=None):
        calls.append((activation, pool_k, pool_s))
        n, _, h, wd = x.shape
        cout, _, k, _ = w.shape
        oh = (h + 2 * pad - k) // stride + 1
        ow = (wd + 2 * pad - k) // stride + 1
        if pool_k:
            oh = (oh - pool_k) // pool_s + 1
            ow = (ow - pool_k) // pool_s + 1
        return jnp.zeros((n, cout, oh, ow), x.dtype)

    rw_calls = []
    real_rw = jax.lax.reduce_window
    monkeypatch.setattr(cnn, "_conv2d", fake_conv2d)
    monkeypatch.setattr(jax.lax, "reduce_window",
                        lambda *a, **kw: (rw_calls.append(1),
                                          real_rw(*a, **kw))[1])
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers)
    out = cnn.apply_cnn(layers, params, jnp.zeros((1,) + cnn.INPUT_SHAPE),
                        backend="pallas")
    assert out.shape == (1, 1000)
    n_triples = len(_model_pool_triples(model))
    n_convs = sum(l.kind == "conv" for l in layers)
    assert sum(pk > 0 for _, pk, _ in calls) == n_triples
    assert len(calls) == n_convs
    assert rw_calls == []              # no maxpool launched separately


@pytest.mark.slow
@pytest.mark.parametrize("model", ["alexnet", "vgg11"])
def test_pool_model_end_to_end_backend_parity_224(model):
    """Full 224 forward with triple fusion active, pallas vs xla."""
    layers = cnn.CNN_MODELS[model]
    params = cnn.init_cnn(jax.random.PRNGKey(1), layers)
    x = jax.random.normal(KEY, (1,) + cnn.INPUT_SHAPE) * 0.5
    want = cnn.apply_cnn(layers, params, x, backend="xla")
    got = cnn.apply_cnn(layers, params, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
