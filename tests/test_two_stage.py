"""SmartSplit two-stage executor: split-across-pods == monolithic forward.

Needs >1 jax device, so it runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count (the parent test session
must keep seeing exactly 1 CPU device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import all_configs
    from repro.launch.smartsplit_exec import two_stage_apply
    from repro.models import transformer as T

    cfg = dataclasses.replace(all_configs()["{arch}"].reduced(),
                              num_layers=4, name="split-test")
    if cfg.num_experts:
        # microbatching changes per-dispatch token counts; drop-free
        # capacity keeps split == monolithic exact (real MoE capacity
        # semantics -- documented in DESIGN.md section 9)
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    mono, _, _ = T.forward(cfg, params, {{"tokens": toks}}, mode="train")
    mesh = jax.make_mesh((2,), ("pod",))
    for l1 in (1, 2, 3):
        split = two_stage_apply(cfg, params, toks, mesh, l1)
        np.testing.assert_allclose(np.asarray(split), np.asarray(mono),
                                   rtol=2e-3, atol=2e-3)
    piped = two_stage_apply(cfg, params, toks, mesh, 2, pipelined=True,
                            microbatches=2)
    np.testing.assert_allclose(np.asarray(piped), np.asarray(mono),
                               rtol=2e-3, atol=2e-3)
    if not cfg.num_experts:
        # bf16 boundary policy: the ppermuted activation crosses the link
        # as bfloat16 (MoE is excluded: a rounded hidden state can flip
        # near-tie router decisions, which is a semantic change, not noise)
        for kwargs in ({{}}, {{"pipelined": True, "microbatches": 2}}):
            b16 = two_stage_apply(cfg, params, toks, mesh, 2,
                                  boundary_dtype="bf16", **kwargs)
            np.testing.assert_allclose(np.asarray(b16), np.asarray(mono),
                                       rtol=5e-2, atol=5e-2)
    print("TWO_STAGE_OK {arch}")
""")


def _run(arch: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT.format(arch=arch)],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.parametrize("arch", [
    "qwen3-4b",
    # one arch per pattern is enough for tier-1; the alternate patterns
    # each cost ~20 s of subprocess compile time
    pytest.param("rwkv6-7b", marks=pytest.mark.slow),
    pytest.param("granite-moe-3b-a800m", marks=pytest.mark.slow),
])
def test_two_stage_equals_monolithic(arch):
    assert f"TWO_STAGE_OK {arch}" in _run(arch)
