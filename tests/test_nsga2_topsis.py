"""NSGA-II converges to known fronts; TOPSIS obeys its axioms.

Hypothesis property tests live in tests/test_nsga2_topsis_properties.py,
which skips itself when ``hypothesis`` is not installed."""
import numpy as np
import pytest

from repro.core.nsga2 import NSGA2Config, nsga2
from repro.core.topsis import column_normalise, topsis_select


def _eval_from_table(table):
    def evaluate(genomes):
        return table[genomes[:, 0]]
    return evaluate


def test_nsga2_multigene_sphere():
    """2-gene problem with known front: f = (x, (10-x) + y^2). Front is
    y == 0, any x."""
    def evaluate(g):
        x, y = g[:, 0].astype(float), g[:, 1].astype(float)
        return np.stack([x, (10 - x) + y**2], 1)
    res = nsga2(evaluate, np.array([0, -5]), np.array([10, 5]),
                NSGA2Config(pop_size=48, generations=40, seed=1))
    assert np.all(res.pareto_genomes[:, 1] == 0)
    assert set(res.pareto_genomes[:, 0].tolist()) == set(range(11))


def test_nsga2_deterministic_given_seed():
    rng = np.random.default_rng(7)
    table = rng.random((30, 3))
    cfg = NSGA2Config(pop_size=16, generations=10, seed=42)
    a = nsga2(_eval_from_table(table), np.array([0]), np.array([29]), cfg)
    b = nsga2(_eval_from_table(table), np.array([0]), np.array([29]), cfg)
    assert np.array_equal(a.pareto_genomes, b.pareto_genomes)


# ---------------------------------------------------------------------------
# TOPSIS
# ---------------------------------------------------------------------------
def test_column_normalise_unit_norm():
    rng = np.random.default_rng(0)
    F = rng.random((10, 3)) + 0.1
    Fn = column_normalise(F)
    np.testing.assert_allclose(np.linalg.norm(Fn, axis=0), 1.0, rtol=1e-12)


def test_topsis_picks_dominating_solution():
    # One row at the per-column minimum must be chosen.
    F = np.array([[1.0, 1.0, 1.0], [2.0, 3.0, 4.0], [5.0, 2.0, 9.0]])
    assert topsis_select(F) == 0


def test_topsis_respects_feasibility_filter():
    F = np.array([[1.0, 1.0, 1.0], [2.0, 2.0, 2.0], [3.0, 3.0, 3.0]])
    feas = np.array([False, True, True])
    assert topsis_select(F, feasible=feas) == 1


def test_topsis_no_feasible_raises():
    F = np.ones((3, 3))
    with pytest.raises(ValueError):
        topsis_select(F, feasible=np.zeros(3, bool))
