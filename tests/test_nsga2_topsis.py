"""NSGA-II converges to known fronts; TOPSIS obeys its axioms."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.nsga2 import NSGA2Config, nsga2
from repro.core.pareto import exhaustive_pareto, pareto_front_mask
from repro.core.topsis import column_normalise, topsis_select


def _eval_from_table(table):
    def evaluate(genomes):
        return table[genomes[:, 0]]
    return evaluate


@given(st.integers(5, 60), st.integers(0, 5000))
@settings(max_examples=25, deadline=None)
def test_nsga2_recovers_exhaustive_front_1d(n, seed):
    """Single-integer genome (the paper's case): with stratified init and
    pop_size >= |domain| the offline-archive front is provably the exact
    Pareto front (this is how `smartsplit` configures the GA)."""
    rng = np.random.default_rng(seed)
    table = rng.random((n, 3))
    res = nsga2(_eval_from_table(table), np.array([0]), np.array([n - 1]),
                NSGA2Config(pop_size=max(32, n), generations=30, seed=seed))
    got = set(res.pareto_genomes[:, 0].tolist())
    full_front = set(exhaustive_pareto(table).tolist())
    assert got == full_front


@given(st.integers(5, 60), st.integers(0, 5000))
@settings(max_examples=15, deadline=None)
def test_nsga2_underprovisioned_returns_nondominated_subset(n, seed):
    """With pop < domain there is no exactness guarantee, but every
    returned genome must still be non-dominated *among visited points*:
    the archive front can never contain a point dominated by another
    returned point."""
    rng = np.random.default_rng(seed)
    table = rng.random((n, 3))
    res = nsga2(_eval_from_table(table), np.array([0]), np.array([n - 1]),
                NSGA2Config(pop_size=8, generations=10, seed=seed))
    F = res.pareto_F
    assert np.all(pareto_front_mask(F))


def test_nsga2_multigene_sphere():
    """2-gene problem with known front: f = (x, (10-x) + y^2). Front is
    y == 0, any x."""
    def evaluate(g):
        x, y = g[:, 0].astype(float), g[:, 1].astype(float)
        return np.stack([x, (10 - x) + y**2], 1)
    res = nsga2(evaluate, np.array([0, -5]), np.array([10, 5]),
                NSGA2Config(pop_size=48, generations=40, seed=1))
    assert np.all(res.pareto_genomes[:, 1] == 0)
    assert set(res.pareto_genomes[:, 0].tolist()) == set(range(11))


def test_nsga2_deterministic_given_seed():
    rng = np.random.default_rng(7)
    table = rng.random((30, 3))
    cfg = NSGA2Config(pop_size=16, generations=10, seed=42)
    a = nsga2(_eval_from_table(table), np.array([0]), np.array([29]), cfg)
    b = nsga2(_eval_from_table(table), np.array([0]), np.array([29]), cfg)
    assert np.array_equal(a.pareto_genomes, b.pareto_genomes)


# ---------------------------------------------------------------------------
# TOPSIS
# ---------------------------------------------------------------------------
def test_column_normalise_unit_norm():
    rng = np.random.default_rng(0)
    F = rng.random((10, 3)) + 0.1
    Fn = column_normalise(F)
    np.testing.assert_allclose(np.linalg.norm(Fn, axis=0), 1.0, rtol=1e-12)


def test_topsis_picks_dominating_solution():
    # One row at the per-column minimum must be chosen.
    F = np.array([[1.0, 1.0, 1.0], [2.0, 3.0, 4.0], [5.0, 2.0, 9.0]])
    assert topsis_select(F) == 0


def test_topsis_respects_feasibility_filter():
    F = np.array([[1.0, 1.0, 1.0], [2.0, 2.0, 2.0], [3.0, 3.0, 3.0]])
    feas = np.array([False, True, True])
    assert topsis_select(F, feasible=feas) == 1


def test_topsis_no_feasible_raises():
    F = np.ones((3, 3))
    with pytest.raises(ValueError):
        topsis_select(F, feasible=np.zeros(3, bool))


@given(st.integers(2, 30), st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_topsis_scale_invariance(n, seed):
    """Column normalisation makes the pick invariant to per-objective unit
    changes (seconds vs ms, bytes vs MB) -- the property that justifies
    mixing heterogeneous objectives."""
    rng = np.random.default_rng(seed)
    F = rng.random((n, 3)) + 0.01
    scale = np.array([1e-3, 1e6, 123.0])
    assert topsis_select(F) == topsis_select(F * scale)


@given(st.integers(2, 20), st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_topsis_pick_is_pareto_when_input_is_front(n, seed):
    rng = np.random.default_rng(seed)
    F = rng.random((n, 3))
    front = F[pareto_front_mask(F)]
    pick = topsis_select(front)
    assert 0 <= pick < front.shape[0]
    # picked point is itself non-dominated within the front (trivially true
    # for a front input; guards against index bugs after filtering)
    assert pareto_front_mask(front)[pick]
