"""Hypothesis property tests for the conv tiling planner.

Kept separate from tests/test_conv2d_tiled.py so environments without
``hypothesis`` (dev-only dependency) still run the unit and parametrized
tests there -- same convention as the other ``*_properties.py`` modules.

Invariants (planning only -- no kernel execution, so hundreds of random
geometries stay cheap):

* the grid tiles exactly cover ``p_out x pw_out``: every output element
  falls in some tile, and no tile (in particular the remainder tile) is
  entirely padding;
* remainder tiles stay in-bounds: the last tile's haloed input read ends
  within the rows/cols the ``conv2d`` wrapper is committed to pad;
* the VMEM estimate is monotone in ``tile_h`` and ``tile_w`` and never
  falls below the bias + fp32-accumulator floor;
* searched plans respect the budget whenever any tiling does, and never
  need more grid launches than the legacy greedy planner."""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.conv2d import (DEFAULT_VMEM_BUDGET,  # noqa: E402
                                  conv_vmem_bytes, plan_conv)


@st.composite
def conv_geometries(draw):
    """Random but valid (x_shape, w_shape, stride, pad, pool) tuples."""
    cin = draw(st.sampled_from([1, 3, 8, 24, 64]))
    cout = draw(st.sampled_from([4, 16, 48, 64, 192]))
    K = draw(st.sampled_from([1, 3, 5, 11]))
    stride = draw(st.integers(1, 4))
    pad = draw(st.integers(0, 3))
    H = draw(st.integers(max(1, K - 2 * pad), 64))
    W = draw(st.integers(max(1, K - 2 * pad), 640))
    pool = draw(st.sampled_from([(0, 0), (2, 2), (3, 2)]))
    h_out = (H + 2 * pad - K) // stride + 1
    w_out = (W + 2 * pad - K) // stride + 1
    if h_out < 1 or w_out < 1 or (pool[0] and (
            h_out < pool[0] or w_out < pool[0])):
        pool = (0, 0)
    return ((1, cin, H, W), (cout, cin, K, K), stride, pad) + pool


@given(conv_geometries())
@settings(max_examples=120, deadline=None)
def test_grid_tiles_exactly_cover_output(geom):
    x_shape, w_shape, stride, pad, pk, ps = geom
    plan = plan_conv(x_shape, w_shape, stride=stride, pad=pad,
                     pool_k=pk, pool_s=ps)
    # full cover: the padded grid reaches past the real output ...
    assert plan.n_h_blocks * plan.tile_h >= plan.p_out
    assert plan.n_w_blocks * plan.tile_w >= plan.pw_out
    # ... but the last tile still contains at least one real element
    assert (plan.n_h_blocks - 1) * plan.tile_h < plan.p_out
    assert (plan.n_w_blocks - 1) * plan.tile_w < plan.pw_out
    assert plan.launches == plan.n_h_blocks * plan.n_w_blocks * \
        (w_shape[0] // plan.block_co) * x_shape[0]
    # the plan's per-step tile never exceeds what it believes fits
    assert plan.vmem_bytes <= DEFAULT_VMEM_BUDGET


@given(conv_geometries())
@settings(max_examples=120, deadline=None)
def test_remainder_tiles_read_in_bounds(geom):
    """The last tile's haloed read must end within the padded extents the
    conv2d wrapper allocates (rows_needed / cols_needed)."""
    x_shape, w_shape, stride, pad, pk, ps = geom
    plan = plan_conv(x_shape, w_shape, stride=stride, pad=pad,
                     pool_k=pk, pool_s=ps)
    K = w_shape[2]
    for n_blocks, tile, tile_in, full in (
            (plan.n_h_blocks, plan.tile_h, plan.tile_in_h,
             plan.n_h_blocks * plan.tile_h),
            (plan.n_w_blocks, plan.tile_w, plan.tile_in_w,
             plan.n_w_blocks * plan.tile_w)):
        step = tile * plan.pool_s * stride
        conv_ext = (full - 1) * plan.pool_s + plan.pool_k if plan.pool_k \
            else full
        needed = (conv_ext - 1) * stride + K
        assert (n_blocks - 1) * step + tile_in <= max(
            needed, tile_in)  # single full-width tile stages w_in as-is


@given(conv_geometries(), st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=120, deadline=None)
def test_vmem_estimate_monotone_and_floored(geom, th, tw):
    x_shape, w_shape, stride, pad, pk, ps = geom
    _, cin, _, W = x_shape
    cout, _, K, _ = w_shape
    w_in = W + 2 * pad
    w_out = (w_in - K) // stride + 1
    kw = dict(cin_block=cin, block_co=cout, w_in=w_in, w_out=w_out, K=K,
              stride=stride, cin_per_group=cin, pool_k=pk,
              pool_s=ps or 1)
    est = conv_vmem_bytes(tile_h=th, tile_w=tw, **kw)
    # monotone in both tile axes
    assert conv_vmem_bytes(tile_h=th + 1, tile_w=tw, **kw) > est
    assert conv_vmem_bytes(tile_h=th, tile_w=tw + 1, **kw) >= est
    # never below the double-buffered bias column + fp32 accumulator floor
    tile_conv_h = (th - 1) * (ps or 1) + pk if pk else th
    tile_conv_w = min((tw - 1) * (ps or 1) + pk if pk else tw, w_out)
    assert est >= 2 * cout * 4 + cout * tile_conv_h * tile_conv_w * 4


@given(conv_geometries())
@settings(max_examples=60, deadline=None)
def test_search_never_beaten_by_greedy(geom):
    """The joint search subsumes the greedy point (same block_co ladder
    entry, full-width column tile, max-fit row tile), so whenever greedy
    finds a feasible tiling the search's cost-model bytes are <= greedy's.
    (On arbitrary geometry the cost optimum may trade a launch or two for
    less halo/lane-padded traffic; the launch-count <= guarantee asserted
    per paper shape lives in test_conv2d_tiled.py.)"""
    x_shape, w_shape, stride, pad, pk, ps = geom
    try:
        greedy = plan_conv(x_shape, w_shape, stride=stride, pad=pad,
                           pool_k=pk, pool_s=ps, search=False)
    except ValueError:
        return  # row-only planner infeasible; search-only territory
    searched = plan_conv(x_shape, w_shape, stride=stride, pad=pad,
                         pool_k=pk, pool_s=ps, search=True)
    assert searched.searched and not greedy.searched
    assert searched.cost_bytes <= greedy.cost_bytes
    assert searched.vmem_bytes <= DEFAULT_VMEM_BUDGET
