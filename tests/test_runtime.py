"""Fault-tolerant split runtime: deterministic recovery-path tests.

Every scenario here is seed/window-deterministic (outage windows and
virtual-clock arithmetic force exact failure counts), so each recovery
path -- retry success, device fallback, Pareto-front re-pick, proactive
re-split, unrecoverable -- is pinned down without flakiness.  The
randomised "never a silent wrong answer" sweep lives in
tests/test_runtime_properties.py (hypothesis, dev-only dep)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (PAPER_ENV_J6, NetworkState, link_weights,
                        repick_split, smartsplit_exhaustive, topsis_rank)
from repro.models import cnn as cnn_lib
from repro.models.cnn import avgpool, conv, linear, maxpool, relu
from repro.models.profiles import cnn_profile
from repro.runtime import (EventLog, EwmaLinkEstimator, FaultSpec,
                           FaultyLink, RetryPolicy, SplitRuntime,
                           SplitUnrecoverable, TransferFailed,
                           TransferOutcome, events, link_from_env,
                           parse_outages, send_with_retry)

# ---------------------------------------------------------------------------
# Shared tiny model: 7 layers, plans in microseconds, runs in milliseconds.
# ---------------------------------------------------------------------------
TINY_LAYERS = [conv(8, 3, 1, 1), relu(), maxpool(2, 2),
               conv(16, 3, 1, 1), relu(), avgpool(2), linear(10)]
TINY_SHAPE = (3, 16, 16)
L = len(TINY_LAYERS)


@pytest.fixture(scope="module")
def tiny():
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), TINY_LAYERS,
                              TINY_SHAPE)
    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(1,) + TINY_SHAPE), np.float32)
    return params, x


def _plan(dtype=None, hw=PAPER_ENV_J6):
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, dtype=dtype,
                       layers=TINY_LAYERS)
    return prof, smartsplit_exhaustive(prof, hw)


def _ref(params, x, split, dtype=None):
    logits, _ = cnn_lib.apply_split(TINY_LAYERS, params, x, split,
                                    dtype=dtype)
    return np.asarray(logits)


# ---------------------------------------------------------------------------
# FaultyLink channel model
# ---------------------------------------------------------------------------
def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec(drop_rate=1.5)
    with pytest.raises(ValueError):
        FaultSpec(corrupt_rate=-0.1)
    with pytest.raises(ValueError):
        FaultSpec(delay_s=-1.0)
    with pytest.raises(ValueError):
        FaultSpec(outages=((2.0, 1.0),))
    assert FaultSpec().fault_free
    assert not FaultSpec(drop_rate=0.1).fault_free


def test_faulty_link_clean_transfer_and_clock():
    link = FaultyLink(100.0, latency_s=0.5)
    out, elapsed = link.send(b"x" * 200, timeout_s=10.0)
    assert out == b"x" * 200
    assert elapsed == pytest.approx(0.5 + 200 / 100.0)
    assert link.clock == pytest.approx(elapsed)
    assert link.counters()["delivered"] == 1
    link.advance(1.0)
    assert link.clock == pytest.approx(elapsed + 1.0)
    with pytest.raises(ValueError):
        link.advance(-1.0)


def test_faulty_link_deterministic_from_seed():
    spec = FaultSpec(drop_rate=0.4, corrupt_rate=0.3)

    def trace(seed):
        link = FaultyLink(1e6, faults=spec, seed=seed)
        out = []
        for n in (100, 5000, 1, 333):
            try:
                data, _ = link.send(b"a" * n, timeout_s=1.0)
                out.append("corrupt" if data != b"a" * n else "ok")
            except Exception as e:
                out.append(type(e).__name__)
        return out, link.counters()

    assert trace(7) == trace(7)
    t3, _ = trace(3)
    t4, _ = trace(4)
    assert t3 != t4 or True  # seeds may collide; determinism is the claim


def test_fault_schedule_is_size_invariant():
    """Same seed, different payload sizes => same drop/corrupt pattern."""
    spec = FaultSpec(drop_rate=0.5)

    def outcomes(sizes):
        link = FaultyLink(1e9, faults=spec, seed=11)
        res = []
        for n in sizes:
            try:
                link.send(b"z" * n, timeout_s=1.0)
                res.append("ok")
            except Exception:
                res.append("drop")
        return res

    assert outcomes([10] * 8) == outcomes([10_000, 1, 77, 2, 9, 5, 3, 8])


def test_outage_overlap_kills_inflight_transfer():
    # 1000 B at 100 B/s = 10 s wire time; window (5, 6) sits mid-flight.
    link = FaultyLink(100.0, faults=FaultSpec(outages=((5.0, 6.0),)))
    with pytest.raises(Exception) as ei:
        link.send(b"x" * 1000, timeout_s=20.0)
    assert "outage" in str(ei.value).lower()
    assert link.clock == pytest.approx(20.0)  # failed attempt burns timeout
    # after the window the same payload sails through
    out, _ = link.send(b"x" * 1000, timeout_s=20.0)
    assert out == b"x" * 1000
    assert link.outage_hits == 1


def test_timeout_when_transfer_too_slow():
    link = FaultyLink(10.0)
    with pytest.raises(Exception) as ei:
        link.send(b"x" * 1000, timeout_s=1.0)  # needs 100 s
    assert "timeout" in str(ei.value).lower()
    assert link.timeouts == 1 and link.bytes_lost == 1000


def test_bandwidth_profile_piecewise():
    link = FaultyLink(100.0, bandwidth_profile=((1.0, 10.0), (2.0, 50.0)))
    assert link.bandwidth_at(0.0) == 100.0
    assert link.bandwidth_at(1.5) == 10.0
    assert link.bandwidth_at(99.0) == 50.0


def test_parse_outages_and_env(monkeypatch):
    assert parse_outages("0:1, 2.5:3") == ((0.0, 1.0), (2.5, 3.0))
    assert parse_outages("") == ()
    monkeypatch.setenv("REPRO_LINK_DROP", "0.25")
    monkeypatch.setenv("REPRO_LINK_OUTAGES", "1:2")
    monkeypatch.setenv("REPRO_LINK_SEED", "9")
    monkeypatch.setenv("REPRO_LINK_BW", "12345")
    link = link_from_env(999.0)
    assert link.bandwidth == 12345.0
    assert link.faults.drop_rate == 0.25
    assert link.faults.outages == ((1.0, 2.0),)
    assert link.seed == 9
    # explicit args beat env
    link = link_from_env(999.0, seed=1, faults=FaultSpec())
    assert link.seed == 1 and link.faults.fault_free


# ---------------------------------------------------------------------------
# Transfer layer
# ---------------------------------------------------------------------------
def test_send_with_retry_clean_is_one_attempt():
    link = FaultyLink(1e6)
    log = EventLog()
    out = send_with_retry(link, b"payload", RetryPolicy(), log=log)
    assert out.payload == b"payload"
    assert out.attempts == 1 and out.retransmitted_bytes == 0
    assert log.count(events.TRANSFER_OK) == 1


def test_send_with_retry_detects_corruption_and_recovers():
    # corrupt every delivery on attempt 1..n? corrupt_rate=1 corrupts all,
    # so retries exhaust on checksum; corrupt_rate picked per-send uniform
    # means rate 1.0 always corrupts -- verify the crc catches it.
    link = FaultyLink(1e6, faults=FaultSpec(corrupt_rate=1.0), seed=0)
    log = EventLog()
    with pytest.raises(TransferFailed):
        send_with_retry(link, b"payload", RetryPolicy(max_attempts=3),
                        log=log)
    assert log.count(events.CHECKSUM_FAIL) == 3
    assert log.count(events.GIVE_UP) == 1
    assert link.corrupted == 3  # delivered-but-flipped, caught by crc32


def test_send_with_retry_outage_then_success():
    # window (0, 0.5): attempt 1 dies, backoff pushes attempt 2 past it.
    link = FaultyLink(1e6, faults=FaultSpec(outages=((0.0, 0.5),)))
    log = EventLog()
    out = send_with_retry(
        link, b"x" * 100,
        RetryPolicy(max_attempts=3, timeout_s=0.6, backoff_base_s=0.01),
        log=log)
    assert out.attempts == 2
    assert out.retransmitted_bytes == 108  # one lost attempt (+8B header)
    assert [e.kind for e in log.events] == [
        events.ATTEMPT, events.OUTAGE, events.BACKOFF,
        events.ATTEMPT, events.TRANSFER_OK]


def test_retry_policy_backoff_and_validation():
    p = RetryPolicy(backoff_base_s=0.1, backoff_factor=2.0, jitter=0.5)
    assert p.backoff_s(1) == pytest.approx(0.1)
    assert p.backoff_s(3) == pytest.approx(0.4)
    assert p.backoff_s(1, u=1.0) == pytest.approx(0.15)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(timeout_s=0.0)


def test_retry_policy_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_LINK_RETRIES", "7")
    monkeypatch.setenv("REPRO_LINK_TIMEOUT", "2.5")
    p = RetryPolicy.from_env()
    assert p.max_attempts == 7 and p.timeout_s == 2.5
    # defaults survive when the env says nothing
    assert p.backoff_factor == 2.0 and p.jitter == 0.25


def test_retry_policy_from_env_backoff_round_trip(monkeypatch):
    """REPRO_LINK_BACKOFF_FACTOR / REPRO_LINK_JITTER round-trip through
    from_env and land in the backoff schedule."""
    monkeypatch.setenv("REPRO_LINK_BACKOFF", "0.1")
    monkeypatch.setenv("REPRO_LINK_BACKOFF_FACTOR", "3.0")
    monkeypatch.setenv("REPRO_LINK_JITTER", "0.5")
    p = RetryPolicy.from_env()
    assert p.backoff_factor == 3.0 and p.jitter == 0.5
    assert p.backoff_s(2) == pytest.approx(0.3)
    assert p.backoff_s(2, u=1.0) == pytest.approx(0.45)
    # env values still go through __post_init__ validation
    monkeypatch.setenv("REPRO_LINK_BACKOFF_FACTOR", "0.5")
    with pytest.raises(ValueError):
        RetryPolicy.from_env()


def test_observed_bandwidth_is_finite_for_instant_transfers():
    """A zero-virtual-time win must not feed `inf` into the EWMA
    estimator (regression: 1/inf -> 0 -> permanent degraded verdict)."""
    out = TransferOutcome(payload=b"x", attempts=1, elapsed_s=0.0,
                          success_elapsed_s=0.0, wire_bytes=9,
                          goodput_bytes=9)
    assert out.observed_bandwidth == TransferOutcome.BANDWIDTH_CLAMP
    assert np.isfinite(out.observed_bandwidth)
    # a merely absurd-but-positive time still clamps
    fast = TransferOutcome(payload=b"x", attempts=1, elapsed_s=1e-30,
                           success_elapsed_s=1e-30, wire_bytes=9,
                           goodput_bytes=9)
    assert fast.observed_bandwidth == TransferOutcome.BANDWIDTH_CLAMP
    est = EwmaLinkEstimator(1000.0, alpha=0.5)
    est.observe(out.observed_bandwidth, 1.0)
    assert np.isfinite(est.bandwidth) and np.isfinite(est.degradation())


# ---------------------------------------------------------------------------
# Estimator + NetworkState + re-pick API
# ---------------------------------------------------------------------------
def test_ewma_estimator_decays_toward_observations():
    est = EwmaLinkEstimator(1000.0, alpha=0.5)
    assert est.degradation() == pytest.approx(1.0)
    est.observe(100.0, 1.0)     # observed 100 B/s
    assert est.bandwidth == pytest.approx(550.0)
    est.observe(0.0, 2.0)       # failed transfer: floor-clamped zero
    assert est.bandwidth == pytest.approx(275.5)
    assert est.degradation() > 3.0
    assert est.observe(0.0, 0.0) == est.bandwidth  # zero-time no-op


def test_network_state_tracks_estimate():
    ns = NetworkState(PAPER_ENV_J6.link)
    assert ns.degradation == pytest.approx(1.0)
    ns.update(PAPER_ENV_J6.link.bandwidth / 4)
    assert ns.degradation == pytest.approx(4.0)
    assert ns.effective_link().bandwidth == \
        pytest.approx(PAPER_ENV_J6.link.bandwidth / 4)


def test_link_weights_shift_toward_latency():
    w = link_weights(1.0)
    assert np.allclose(w, [1.0, 1.0, 1.0])
    w4 = link_weights(4.0)
    assert np.allclose(w4, [4.0, 2.0, 1.0])
    with pytest.raises(ValueError):
        link_weights(0.0)


def test_topsis_rank_orders_all_feasible_rows():
    F = np.array([[1.0, 1.0], [2.0, 2.0], [0.5, 3.0]])
    rank = topsis_rank(F)
    assert sorted(rank.tolist()) == [0, 1, 2]
    # rank[0] dominates row 1 outright, so 1 cannot be first
    assert rank[0] != 1
    masked = topsis_rank(F, feasible=np.array([False, True, True]))
    assert 0 not in masked.tolist() and len(masked) == 2


def test_repick_split_walks_front_without_ga(tiny):
    prof, plan = _plan()
    alt = repick_split(plan, prof, PAPER_ENV_J6,
                       exclude=(plan.split_index,))
    assert alt.split_index != plan.split_index
    assert alt.split_index in plan.pareto_indices
    # degraded link steers toward smaller boundary payloads
    slow = repick_split(plan, prof, PAPER_ENV_J6,
                        bandwidth=PAPER_ENV_J6.link.bandwidth / 100)
    assert slow.split_index in plan.pareto_indices
    # excluding the whole front leaves nothing to pick
    with pytest.raises(ValueError):
        repick_split(plan, prof, PAPER_ENV_J6,
                     exclude=tuple(plan.pareto_indices))


# ---------------------------------------------------------------------------
# apply_cnn / apply_split bounds (satellite: named validation)
# ---------------------------------------------------------------------------
def test_apply_split_bounds_validated(tiny):
    params, x = tiny
    for bad in (-1, L + 1):
        with pytest.raises(ValueError, match="split_index"):
            cnn_lib.apply_split(TINY_LAYERS, params, x, bad)
    with pytest.raises(ValueError, match="start"):
        cnn_lib.apply_cnn(TINY_LAYERS, params, x, start=-1)
    with pytest.raises(ValueError, match="stop"):
        cnn_lib.apply_cnn(TINY_LAYERS, params, x, start=3, stop=2)


def test_apply_split_degenerate_placements(tiny):
    """l1=0 (all-on-server, the paper's COC baseline) and l1=L (all on
    device) are legal splits, and both match the unsplit forward pass."""
    params, x = tiny
    full = np.asarray(cnn_lib.apply_cnn(TINY_LAYERS, params, x))
    coc, boundary0 = cnn_lib.apply_split(TINY_LAYERS, params, x, 0)
    assert np.array_equal(np.asarray(coc), full)
    assert boundary0.shape == (1,) + TINY_SHAPE  # raw input crosses
    dev, boundary_l = cnn_lib.apply_split(TINY_LAYERS, params, x, L)
    assert np.array_equal(np.asarray(dev), full)
    assert np.array_equal(np.asarray(boundary_l), full)  # logits "cross"


# ---------------------------------------------------------------------------
# SplitRuntime recovery paths (all deterministic via outage windows)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["fp32", "bf16"])
def test_zero_fault_runtime_bit_identical(tiny, dtype):
    """Acceptance: a zero-fault FaultyLink through the full runtime path
    (serialize -> checksumed transfer -> deserialize) reproduces the
    fault-free apply_split logits bit-identically."""
    params, x = tiny
    prof, plan = _plan(dtype=dtype)
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      dtype=dtype)
    r = rt.infer(x)
    assert not r.degraded and not r.on_device
    assert r.attempts == 1 and r.retransmitted_bytes == 0
    assert np.array_equal(np.asarray(r.logits),
                          _ref(params, x, plan.split_index, dtype))
    assert rt.stats()["recovered"] == 0


def test_runtime_retry_recovers_and_records(tiny):
    """One outage-killed attempt, then success: same logits, recovery in
    the event log, retransmitted bytes accounted."""
    params, x = tiny
    prof, plan = _plan()
    link = FaultyLink(PAPER_ENV_J6.link.bandwidth,
                      faults=FaultSpec(outages=((0.0, 0.001),)))
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      link=link,
                      policy=RetryPolicy(max_attempts=3, timeout_s=0.01,
                                         backoff_base_s=0.02))
    r = rt.infer(x)
    assert r.attempts == 2 and not r.degraded
    assert r.retransmitted_bytes > 0
    assert np.array_equal(np.asarray(r.logits),
                          _ref(params, x, plan.split_index))
    kinds = [e.kind for e in r.events]
    assert events.OUTAGE in kinds and events.TRANSFER_OK in kinds
    assert rt.stats()["recovered"] == 1


def test_runtime_device_fallback_bit_identical(tiny):
    """Retries exhausted + roomy client => finish on-device from the
    boundary activation; logits stay bit-identical (same chunked
    computation, no transfer)."""
    params, x = tiny
    prof, plan = _plan()
    link = FaultyLink(PAPER_ENV_J6.link.bandwidth,
                      faults=FaultSpec(drop_rate=1.0), seed=0)
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      link=link,
                      policy=RetryPolicy(max_attempts=2, timeout_s=0.01,
                                         backoff_base_s=0.001))
    r = rt.infer(x)
    assert r.degraded and r.on_device
    assert r.split_index == plan.split_index
    assert np.array_equal(np.asarray(r.logits),
                          _ref(params, x, plan.split_index))
    kinds = [e.kind for e in r.events]
    assert events.GIVE_UP in kinds and events.FALLBACK_DEVICE in kinds
    assert rt.stats()["fallback_device"] == 1


def test_runtime_repick_when_device_infeasible(tiny):
    """Tight client memory forbids the device fallback, so exhaustion
    walks the cached Pareto front: a different split completes the request
    and its logits match that split's fault-free run."""
    params, x = tiny
    prof, _ = _plan()
    full_mem = float(prof.cum_mem()[-1])
    hw = dataclasses.replace(
        PAPER_ENV_J6, client=dataclasses.replace(
            PAPER_ENV_J6.client, memory_budget=0.9 * full_mem))
    plan = smartsplit_exhaustive(prof, hw)
    link = FaultyLink(hw.link.bandwidth,
                      faults=FaultSpec(outages=((0.0, 0.8),)))
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, hw, link=link,
                      policy=RetryPolicy(max_attempts=2, timeout_s=0.5,
                                         backoff_base_s=0.05))
    r = rt.infer(x)
    assert r.degraded and not r.on_device
    assert r.split_index != plan.split_index
    assert r.split_index in plan.pareto_indices
    assert np.array_equal(np.asarray(r.logits),
                          _ref(params, x, r.split_index))
    kinds = [e.kind for e in r.events]
    assert events.REPICK in kinds and events.TRANSFER_OK in kinds
    assert rt.stats()["repicks"] == 1


def test_runtime_unrecoverable_raises_with_evidence(tiny):
    """All drops + no device fallback + front exhausted => a loud
    SplitUnrecoverable with the tried splits, never a wrong answer."""
    params, x = tiny
    prof, plan = _plan()
    link = FaultyLink(PAPER_ENV_J6.link.bandwidth,
                      faults=FaultSpec(drop_rate=1.0), seed=0)
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      link=link, device_fallback=False,
                      policy=RetryPolicy(max_attempts=2, timeout_s=0.01,
                                         backoff_base_s=0.001))
    with pytest.raises(SplitUnrecoverable):
        rt.infer(x)
    assert rt.log.count(events.UNRECOVERABLE) == 1
    assert rt.log.count(events.REPICK) >= 1  # it did try the front


def test_runtime_proactive_resplit_on_sustained_degradation(tiny):
    """A 500x bandwidth collapse (piecewise profile, no random faults)
    drags the EWMA estimate down until degradation() crosses the trigger
    and the runtime re-picks BEFORE burning retries."""
    params, x = tiny
    prof, plan = _plan()
    bw = PAPER_ENV_J6.link.bandwidth
    link = FaultyLink(bw, bandwidth_profile=((0.003, bw / 500),))
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      link=link, resplit_ratio=2.0,
                      policy=RetryPolicy(max_attempts=3, timeout_s=60.0))
    results = [rt.infer(x) for _ in range(8)]
    assert rt.n_proactive >= 1
    assert rt.log.count(events.PROACTIVE_RESPLIT) == rt.n_proactive
    # every request still completed with that split's exact logits
    for r in results:
        assert np.array_equal(np.asarray(r.logits),
                              _ref(params, x, r.split_index))
    # the re-pick actually moved the active split
    assert rt.stats()["active_split"] != plan.split_index


def test_runtime_rejects_mismatched_profile(tiny):
    params, _ = tiny
    prof, plan = _plan()
    with pytest.raises(ValueError, match="layers"):
        SplitRuntime(TINY_LAYERS[:-1], params, plan, prof, PAPER_ENV_J6)


def test_runtime_acceptance_profile_completes_all(tiny):
    """The chaos harness's acceptance profile (30% drops + one outage
    window) at tiny scale: every request completes, recoveries recorded."""
    params, x = tiny
    prof, plan = _plan()
    spec = FaultSpec(drop_rate=0.3, outages=((0.0, 1.0),))
    for seed in (0, 1, 2):
        link = FaultyLink(PAPER_ENV_J6.link.bandwidth, faults=spec,
                          seed=seed)
        rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                          link=link, jitter_seed=seed,
                          policy=RetryPolicy(max_attempts=5, timeout_s=2.0,
                                             backoff_base_s=0.05))
        for _ in range(6):
            r = rt.infer(x)
            assert np.array_equal(np.asarray(r.logits),
                                  _ref(params, x, r.split_index))
        s = rt.stats()
        assert s["requests"] == 6
        # the outage window guarantees at least the first transfer failed
        assert s["link"]["outage_hits"] >= 1
        assert s["recovered"] >= 1
