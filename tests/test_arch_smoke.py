"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward pass + one grad step + (for decoder
archs) prefill->decode consistency, on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models import transformer as T

ARCHS = sorted(all_configs().keys())
DTYPE = jnp.float32   # CPU smoke: f32 keeps numerics clean


def _smoke_batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.frontend == "audio":
        batch["prefix_embeds"] = jax.random.normal(
            ks[0], (B, S, cfg.d_model), DTYPE) * 0.02
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0,
                                             cfg.vocab_size)
    elif cfg.frontend == "vision":
        P = 4
        batch["prefix_embeds"] = jax.random.normal(
            ks[0], (B, P, cfg.d_model), DTYPE) * 0.02
        batch["tokens"] = jax.random.randint(ks[1], (B, S - P), 0,
                                             cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[2], (B, S - P), 0,
                                             cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0,
                                             cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[1], (B, S), 0,
                                             cfg.vocab_size)
    return batch


@pytest.fixture(scope="module")
def smoke_models():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = all_configs()[arch].reduced()
            params = T.init_params(cfg, jax.random.PRNGKey(0), DTYPE)
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_limits(arch):
    cfg = all_configs()[arch].reduced()
    assert cfg.num_layers == 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch, smoke_models):
    cfg, params = smoke_models(arch)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, _, aux = jax.jit(
        lambda p, b: T.forward(cfg, p, b, mode="train"))(params, batch)
    n_tok = 16
    assert logits.shape == (2, n_tok, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_grad_step(arch, smoke_models):
    """One SGD step decreases nothing NaN-wise and produces finite grads for
    every parameter leaf."""
    cfg, params = smoke_models(arch)
    batch = _smoke_batch(cfg, jax.random.PRNGKey(2))

    def loss(p):
        l, _ = T.loss_fn(cfg, p, batch)
        return l

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert bool(jnp.isfinite(val)), f"{arch}: loss {val}"
    flat, _ = jax.tree.flatten(grads)
    for g in flat:
        assert bool(jnp.isfinite(g).all()), f"{arch}: non-finite grad"
    # loss should be near log(V) at init (uniform predictions)
    assert float(val) < np.log(cfg.padded_vocab) + 2.0


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not all_configs()[a].is_encoder])
def test_prefill_then_decode_matches_full_forward(arch, smoke_models):
    """Teacher-forced decode after prefill must reproduce the full-sequence
    forward logits (the cache/state machinery is exact, not approximate).

    MoE archs: capacity drops depend on the token count per dispatch, so
    exact equality only holds drop-free -- raise the capacity factor."""
    import dataclasses
    cfg, params = smoke_models(arch)
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    full_logits, _, _ = T.forward(cfg, params, {"tokens": toks},
                                  mode="train")

    n_pre = S // 2
    cache = T.init_cache(cfg, B, max_len=S, dtype=DTYPE)
    pre_logits, cache, _ = T.forward(cfg, params,
                                     {"tokens": toks[:, :n_pre]},
                                     mode="prefill", cache=cache)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full_logits[:, :n_pre]),
                               rtol=2e-3, atol=2e-3)
    logits_steps = []
    for t in range(n_pre, S):
        step_logits, cache = T.decode_step(cfg, params, toks[:, t:t + 1],
                                           cache)
        logits_steps.append(step_logits)
    dec = jnp.concatenate(logits_steps, axis=1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full_logits[:, n_pre:]),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-4b", "phi3-mini-3.8b"])
def test_sliding_window_decode_consistency(arch, smoke_models):
    """The long-context sliding-window variant: ring-buffer decode equals a
    full-cache run that applies the same window mask."""
    import dataclasses
    cfg0, _ = smoke_models(arch)
    cfg = dataclasses.replace(cfg0, sliding_window=6)
    params = T.init_params(cfg, jax.random.PRNGKey(0), DTYPE)
    B, S = 1, 14
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                              cfg.vocab_size)
    # reference: full forward with window mask applied in-sequence
    ref_logits, _, _ = T.forward(cfg, params, {"tokens": toks}, mode="train")
    # ring buffer of exactly window size
    cache = T.init_cache(cfg, B, max_len=S, dtype=DTYPE)
    assert cache.kv.k.shape[2] == 6  # (layers, B, M, kv, hd) -> M == window
    logits = []
    for t in range(S):
        lg, cache = T.decode_step(cfg, params, toks[:, t:t + 1], cache)
        logits.append(lg)
    dec = jnp.concatenate(logits, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.25 and uniform-ish routing, the fraction of
    dropped (token, expert) assignments should be small."""
    from repro.models import layers as L
    cfg = all_configs()["granite-moe-3b-a800m"].reduced()
    params = L.init_moe_params(cfg, jax.random.PRNGKey(0), DTYPE)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model),
                          DTYPE) * 0.5
    y, aux = L.moe(cfg, params, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # aux loss ~ 1 for balanced routing (E * sum(me*ce) with me=ce=1/E)
    assert 0.5 < float(aux) < 4.0


def test_moe_matches_dense_reference():
    """Sort-based dispatch == brute-force per-token expert evaluation
    (modulo capacity drops; use high capacity so nothing drops)."""
    import dataclasses
    from repro.models import layers as L
    cfg = dataclasses.replace(
        all_configs()["granite-moe-3b-a800m"].reduced(),
        moe_capacity_factor=8.0)
    params = L.init_moe_params(cfg, jax.random.PRNGKey(0), DTYPE)
    B, S = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          DTYPE) * 0.5
    y, _ = L.moe(cfg, params, x)

    # dense reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xt @ params["wg"][e]) * (xt @ params["wu"][e])
        ye = h @ params["wd"][e]
        w = jnp.where(eidx == e, gate, 0.0).sum(-1)
        ref = ref + ye * w[:, None].astype(ye.dtype)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-3, atol=2e-3)
