"""Unit tests for Pareto utilities (non-dominated sort, crowding).

Hypothesis property tests live in tests/test_pareto_properties.py, which
skips itself when ``hypothesis`` is not installed."""
import numpy as np

from repro.core.pareto import dominates, pareto_front_mask


def test_dominates_basic():
    assert dominates(np.array([1.0, 1.0]), np.array([2.0, 2.0]))
    assert dominates(np.array([1.0, 2.0]), np.array([1.0, 3.0]))
    assert not dominates(np.array([1.0, 3.0]), np.array([2.0, 2.0]))
    assert not dominates(np.array([1.0, 1.0]), np.array([1.0, 1.0]))


def test_pareto_mask_monotone_memory_structure():
    # f3 strictly increasing (like cumulative memory): a point is on the
    # front iff no earlier point is <= in both other objectives.
    lat = np.array([5.0, 4.0, 6.0, 3.0, 7.0])
    en = np.array([5.0, 6.0, 4.0, 3.0, 7.0])
    mem = np.arange(5, dtype=float)
    F = np.stack([lat, en, mem], 1)
    mask = pareto_front_mask(F)
    assert mask[0] and mask[1] and mask[2] and mask[3]
    assert not mask[4]  # dominated by row 3 in all objectives
