"""Property + unit tests for Pareto utilities (non-dominated sort, crowding)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pareto import (crowding_distance, dominates,
                               exhaustive_pareto, non_dominated_sort,
                               pareto_front_mask)


def _random_F(draw_rows, m=3, seed=0):
    rng = np.random.default_rng(seed)
    return rng.random((draw_rows, m))


def test_dominates_basic():
    assert dominates(np.array([1.0, 1.0]), np.array([2.0, 2.0]))
    assert dominates(np.array([1.0, 2.0]), np.array([1.0, 3.0]))
    assert not dominates(np.array([1.0, 3.0]), np.array([2.0, 2.0]))
    assert not dominates(np.array([1.0, 1.0]), np.array([1.0, 1.0]))


@given(st.integers(1, 40), st.integers(1, 4), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_front0_is_exactly_the_nondominated_set(n, m, seed):
    rng = np.random.default_rng(seed)
    F = rng.integers(0, 5, (n, m)).astype(float)  # ties are common
    fronts = non_dominated_sort(F)
    # Partition property: every index appears exactly once.
    all_idx = np.sort(np.concatenate(fronts))
    assert np.array_equal(all_idx, np.arange(n))
    # Front 0 == brute-force Pareto set.
    assert set(fronts[0].tolist()) == set(exhaustive_pareto(F).tolist())
    # No point is dominated by a point in its own front or later fronts.
    for k, front in enumerate(fronts):
        later = np.concatenate(fronts[k:])
        for i in front:
            assert not any(dominates(F[j], F[i]) for j in later)
    # Points in front k>0 are each dominated by someone in an earlier front.
    for k in range(1, len(fronts)):
        earlier = np.concatenate(fronts[:k])
        for i in fronts[k]:
            assert any(dominates(F[j], F[i]) for j in earlier)


@given(st.integers(3, 30), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_crowding_boundaries_infinite(n, seed):
    rng = np.random.default_rng(seed)
    F = rng.random((n, 3))
    d = crowding_distance(F)
    for j in range(3):
        assert np.isinf(d[np.argmin(F[:, j])])
        assert np.isinf(d[np.argmax(F[:, j])])
    assert np.all(d[~np.isinf(d)] >= 0)


def test_pareto_mask_monotone_memory_structure():
    # f3 strictly increasing (like cumulative memory): a point is on the
    # front iff no earlier point is <= in both other objectives.
    lat = np.array([5.0, 4.0, 6.0, 3.0, 7.0])
    en = np.array([5.0, 6.0, 4.0, 3.0, 7.0])
    mem = np.arange(5, dtype=float)
    F = np.stack([lat, en, mem], 1)
    mask = pareto_front_mask(F)
    assert mask[0] and mask[1] and mask[2] and mask[3]
    assert not mask[4]  # dominated by row 3 in all objectives
