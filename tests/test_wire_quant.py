"""Int8 quantized boundary streaming: wire-dtype policy resolution,
quantize/dequantize codec, multipart framing, wire-aware cost pricing and
planning, and the runtime end-to-end paths.

Two invariants anchor everything:

* ``follow``/fp32/bf16 wire formats are *bit-identical* to the legacy
  serialisation (the wire tier must be invisible until asked for), and
* the fault-free runtime int8 path decodes to exactly
  ``apply_split(..., wire="int8")`` -- the codec has one reference
  implementation (``kernels.quant.boundary_roundtrip``) and every layer
  agrees with it bitwise.

Randomised round-trip bounds live in tests/test_wire_quant_properties.py
(hypothesis, dev-only dep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PAPER_ENV_J6, latency_terms, paper_chain,
                        smartsplit_chain, smartsplit_exhaustive)
from repro.core.costs import (INT8_FRAME_OVERHEAD_BYTES, WIRE_SCALE_BYTES,
                              total_latency)
from repro.core.dtype_policy import resolve_wire_dtype, wire_dtype
from repro.kernels.quant import (boundary_roundtrip, default_channel_axis,
                                 dequantize_boundary, dequantize_jnp,
                                 quantize_boundary, quantize_jnp,
                                 scale_count)
from repro.models import cnn as cnn_lib
from repro.models.cnn import avgpool, conv, linear, maxpool, relu
from repro.models.profiles import cnn_profile
from repro.runtime import (ChainRuntime, FaultSpec, FaultyLink, FrameError,
                           SplitRuntime, TransferFailed, decode_boundary,
                           encode_boundary, events, pack_frames,
                           send_with_retry, unpack_frames)

TINY_LAYERS = [conv(8, 3, 1, 1), relu(), maxpool(2, 2),
               conv(16, 3, 1, 1), relu(), avgpool(2), linear(10)]
TINY_SHAPE = (3, 16, 16)


@pytest.fixture(scope="module")
def tiny():
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), TINY_LAYERS,
                              TINY_SHAPE)
    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(2,) + TINY_SHAPE), np.float32)
    return params, x


def _plan(wire=None):
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS)
    return prof, smartsplit_exhaustive(prof, PAPER_ENV_J6, wire=wire)


# ---------------------------------------------------------------------------
# Wire-dtype policy resolution
# ---------------------------------------------------------------------------
def test_wire_policy_default_follows_storage(monkeypatch):
    monkeypatch.delenv("REPRO_WIRE_DTYPE", raising=False)
    assert wire_dtype() == "follow"
    assert resolve_wire_dtype(None, storage="fp32") == "fp32"
    assert resolve_wire_dtype(None, storage="bf16") == "bf16"
    assert resolve_wire_dtype("follow", storage="bf16") == "bf16"
    assert resolve_wire_dtype("int8", storage="bf16") == "int8"


def test_wire_policy_env_and_per_hop_override(monkeypatch):
    monkeypatch.setenv("REPRO_WIRE_DTYPE", "int8")
    assert resolve_wire_dtype(None, storage="fp32") == "int8"
    # per-hop env beats the chain-wide env; explicit arg beats both
    monkeypatch.setenv("REPRO_LINK1_WIRE_DTYPE", "fp32")
    assert resolve_wire_dtype(None, storage="fp32", hop=1) == "fp32"
    assert resolve_wire_dtype(None, storage="fp32", hop=0) == "int8"
    assert resolve_wire_dtype("bf16", storage="fp32", hop=1) == "bf16"


def test_wire_policy_rejects_unknown(monkeypatch):
    with pytest.raises(ValueError, match="wire argument"):
        wire_dtype("int4")
    monkeypatch.setenv("REPRO_WIRE_DTYPE", "fp8")
    with pytest.raises(ValueError, match="REPRO_WIRE_DTYPE"):
        wire_dtype()


# ---------------------------------------------------------------------------
# Quantize/dequantize codec
# ---------------------------------------------------------------------------
def test_quantize_roundtrip_bounds_and_grid():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 5, 4, 4)), jnp.float32) * 3.0
    q, scales = quantize_boundary(x)
    assert q.dtype == jnp.int8 and scales.shape == (5,)
    assert int(jnp.max(jnp.abs(q))) <= 127
    y = dequantize_boundary(q, scales, out_dtype=jnp.float32)
    # error bound: half a quantization step per channel
    err = np.max(np.abs(np.asarray(y - x)), axis=(0, 2, 3))
    assert np.all(err <= np.asarray(scales) / 2 + 1e-7)


def test_quantize_zero_channel_is_safe():
    x = jnp.zeros((1, 3, 2, 2), jnp.float32)
    q, scales = quantize_boundary(x)
    np.testing.assert_array_equal(np.asarray(scales), np.ones(3))
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(
        np.asarray(dequantize_boundary(q, scales)), 0.0)


def test_channel_convention_matches_ndim():
    assert default_channel_axis(4) == 1
    assert default_channel_axis(3) == 1
    assert default_channel_axis(2) is None
    assert scale_count((2, 5, 4, 4), 1) == 5
    assert scale_count((2, 4096), None) == 1
    # flat boundary quantizes per-tensor: one scale
    flat = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)),
                       jnp.float32)
    _, scales = quantize_boundary(flat)
    assert scales.shape == (1,)


def test_pallas_and_jnp_backends_agree_bitwise():
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(1, 6, 8, 8)), jnp.float32)
    qp, sp = quantize_boundary(x, backend="pallas")
    qj, sj = quantize_jnp(x, axis=1)
    np.testing.assert_array_equal(np.asarray(qp), np.asarray(qj))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(sj))
    yp = dequantize_boundary(qp, sp, backend="pallas")
    yj = dequantize_jnp(qj, sj, axis=1)
    np.testing.assert_array_equal(np.asarray(yp), np.asarray(yj))


def test_float_wire_roundtrip_identity():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 4, 6, 6)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(boundary_roundtrip(x, "fp32")),
                                  np.asarray(x))
    xb = x.astype(jnp.bfloat16)
    np.testing.assert_array_equal(
        np.asarray(boundary_roundtrip(xb, "bf16").astype(jnp.float32)),
        np.asarray(xb.astype(jnp.float32)))


# ---------------------------------------------------------------------------
# Multipart framing
# ---------------------------------------------------------------------------
def test_pack_unpack_frames_roundtrip():
    parts = (b"scales-bytes", b"payload" * 100, b"")
    got = unpack_frames(pack_frames(*parts), ("a", "b", "c"))
    assert tuple(got) == parts


def test_unpack_frames_localises_corruption():
    buf = bytearray(pack_frames(b"S" * 16, b"D" * 64))
    # flip one byte inside the second part's data
    buf[-1] ^= 0xFF
    with pytest.raises(FrameError) as ei:
        unpack_frames(bytes(buf), ("scales", "data"))
    assert ei.value.part == "data"
    # flip inside the first part
    buf2 = bytearray(pack_frames(b"S" * 16, b"D" * 64))
    buf2[13] ^= 0x01
    with pytest.raises(FrameError) as ei:
        unpack_frames(bytes(buf2), ("scales", "data"))
    assert ei.value.part == "scales"
    # structural damage: wrong part count
    buf3 = bytearray(pack_frames(b"S", b"D"))
    buf3[0] = 9
    with pytest.raises(FrameError) as ei:
        unpack_frames(bytes(buf3), ("scales", "data"))
    assert ei.value.part == "header"


def test_send_with_retry_framed_corruption_sets_part():
    payload = pack_frames(b"S" * 8, b"D" * 128)
    link = FaultyLink(1e6, faults=FaultSpec(corrupt_rate=1.0), seed=0)
    log = events.EventLog()
    with pytest.raises(TransferFailed):
        send_with_retry(link, payload, log=log,
                        framed=("scales", "data"))
    fails = [e for e in log.events if e.kind == events.CHECKSUM_FAIL]
    assert fails and all(e.detail["part"] in ("scales", "data", "header")
                         for e in fails)


# ---------------------------------------------------------------------------
# Boundary codec == reference roundtrip, and the raw path == legacy bytes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("wire", ["fp32", "bf16", "int8"])
def test_encode_decode_matches_boundary_roundtrip(wire):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(2, 6, 5, 5)), jnp.float32)
    payload, meta = encode_boundary(x, wire)
    got = decode_boundary(payload, meta)
    assert got.dtype == x.dtype
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(boundary_roundtrip(x, wire)))
    assert meta.raw_bytes == x.size * 4
    if wire == "int8":
        assert meta.framed == ("scales", "data")
        assert len(payload) == x.size + WIRE_SCALE_BYTES * x.shape[1] \
            + INT8_FRAME_OVERHEAD_BYTES
    else:
        assert meta.framed is None


def test_raw_wire_path_is_legacy_bytes():
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(1, 4, 5, 5)), jnp.float32)
    payload, _ = encode_boundary(x, "fp32")
    assert payload == SplitRuntime._serialize(x)[0]


# ---------------------------------------------------------------------------
# Cost model pricing
# ---------------------------------------------------------------------------
def test_wire_boundary_pricing():
    prof = cnn_profile("alexnet")
    b = prof.boundary()
    live = b > 0
    # follow == storage: unchanged, exactly
    np.testing.assert_array_equal(prof.wire_boundary("follow"), b)
    np.testing.assert_array_equal(prof.wire_boundary("fp32"), b)
    np.testing.assert_array_equal(prof.wire_boundary("bf16")[live],
                                  b[live] / 2)
    wb8 = prof.wire_boundary("int8")
    elems = b[live] / 4
    expect = elems + WIRE_SCALE_BYTES * prof.boundary_groups()[live] \
        + INT8_FRAME_OVERHEAD_BYTES
    np.testing.assert_allclose(wb8[live], expect)
    assert np.all(wb8[~live] == 0)
    # the paper-split acceptance ratio: >= 3.5x on every live split
    assert np.min(b[live] / wb8[live]) >= 3.5


def test_int8_wire_shrinks_upload_latency():
    prof = cnn_profile("alexnet")
    t_up32 = latency_terms(prof, PAPER_ENV_J6, wire="fp32")[1]
    t_up8 = latency_terms(prof, PAPER_ENV_J6, wire="int8")[1]
    live = prof.boundary() > 0
    assert np.all(t_up8[live] < t_up32[live])
    # but total latency never ignores the codec surcharge entirely
    assert np.all(total_latency(prof, PAPER_ENV_J6, wire="int8") > 0)


def test_planner_is_wire_aware():
    prof = cnn_profile("alexnet")
    p32 = smartsplit_exhaustive(prof, PAPER_ENV_J6, wire="fp32")
    p8 = smartsplit_exhaustive(prof, PAPER_ENV_J6, wire="int8")
    # int8 pricing can only improve the latency objective at a given split
    assert p8.objectives[0] <= p32.objectives[0] + 1e-12
    chain = smartsplit_chain(prof, paper_chain(2), wire="int8")
    assert chain.wire_dtypes == ("int8",)
    follow = smartsplit_chain(prof, paper_chain(2))
    assert follow.wire_dtypes == ("fp32",)


# ---------------------------------------------------------------------------
# Runtime end to end
# ---------------------------------------------------------------------------
def test_split_runtime_int8_matches_reference(tiny):
    params, x = tiny
    prof, plan = _plan(wire="int8")
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      wire="int8")
    r = rt.infer(x)
    want, _ = cnn_lib.apply_split(TINY_LAYERS, params, x,
                                  plan.split_index, wire="int8")
    np.testing.assert_array_equal(np.asarray(r.logits), np.asarray(want))
    h = rt.stats()["hops"][0]
    assert h["wire_dtype"] == "int8"
    assert h["raw_bytes"] > 0 and h["wire_bytes"] < h["raw_bytes"]
    assert rt.log.count(events.WIRE_ENCODE) == 1


@pytest.mark.parametrize("wire", [None, "follow", "fp32"])
def test_split_runtime_float_wire_bit_identical_to_legacy(tiny, wire):
    params, x = tiny
    prof, plan = _plan()
    legacy = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6)
    got = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                       wire=wire)
    rl, rg = legacy.infer(x), got.infer(x)
    np.testing.assert_array_equal(np.asarray(rl.logits),
                                  np.asarray(rg.logits))
    assert got.log.count(events.WIRE_ENCODE) == 0
    assert legacy.stats()["hops"][0]["wire_bytes"] \
        == got.stats()["hops"][0]["wire_bytes"]


def test_split_runtime_int8_recovers_from_corruption(tiny):
    params, x = tiny
    prof, plan = _plan(wire="int8")
    link = FaultyLink(PAPER_ENV_J6.link.bandwidth,
                      faults=FaultSpec(corrupt_rate=0.5), seed=2)
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6,
                      link=link, wire="int8")
    want, _ = cnn_lib.apply_split(TINY_LAYERS, params, x,
                                  plan.split_index, wire="int8")
    for _ in range(4):
        r = rt.infer(x)
        np.testing.assert_array_equal(np.asarray(r.logits),
                                      np.asarray(want))
    fails = [e for e in rt.log.events if e.kind == events.CHECKSUM_FAIL]
    assert fails  # seed 2 at 50% corrupt must hit at least once
    assert all(e.detail.get("part") in ("scales", "data", "header")
               for e in fails)


def test_chain_runtime_per_hop_wire(tiny):
    params, x = tiny
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS)
    hw = paper_chain(3)
    plan = smartsplit_chain(prof, hw, wire=("int8", "fp32"))
    assert plan.wire_dtypes == ("int8", "fp32")
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw)
    assert rt.wire_dtypes == ("int8", "fp32")
    r = rt.infer(x)
    # hop0 re-encodes int8, hop1 ships storage fp32 raw: the reference
    # walk round-trips the boundary only at the int8 hop
    h = cnn_lib.apply_cnn(TINY_LAYERS, params, x, stop=plan.cuts[0])
    h = boundary_roundtrip(h, "int8")
    h = cnn_lib.apply_cnn(TINY_LAYERS, params, h, start=plan.cuts[0],
                          stop=plan.cuts[1])
    want = cnn_lib.apply_cnn(TINY_LAYERS, params, h, start=plan.cuts[1])
    np.testing.assert_array_equal(np.asarray(r.logits), np.asarray(want))
    hops = rt.stats()["hops"]
    assert [h["wire_dtype"] for h in hops] == ["int8", "fp32"]
    assert hops[0]["wire_bytes"] < hops[0]["raw_bytes"]
    assert hops[1]["wire_bytes"] == hops[1]["raw_bytes"] \
        + 8 * hops[1]["attempts"]  # outer frame header per attempt
