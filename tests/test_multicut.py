"""Multi-cut (K-tier chain) SmartSplit: correctness vs brute force on small
instances, constraint enforcement, and reduction to the 2-tier case."""
import numpy as np

from repro.core.hardware import (DCN_LINK, TwoTierHardware,
                                 tpu_pod_tier)
from repro.core.multicut import (ChainHardware, evaluate_multicut,
                                 smartsplit_multicut)
from repro.core.nsga2 import NSGA2Config
from repro.core.pareto import exhaustive_pareto
from repro.core.smartsplit import smartsplit_exhaustive
from repro.core.topsis import topsis_select
from repro.models.profiles import cnn_profile


def _chain(K: int) -> ChainHardware:
    tiers = tuple(tpu_pod_tier(f"tier{k}", chips=4 * (k + 1))
                  for k in range(K))
    return ChainHardware(tiers=tiers, links=tuple([DCN_LINK] * (K - 1)))


def test_three_tier_matches_bruteforce_alexnet():
    p = cnn_profile("alexnet")
    hw = _chain(3)
    L = p.num_layers
    # brute force over all ordered cut pairs
    cands = np.array([(a, b) for a in range(1, L)
                      for b in range(a + 1, L)], np.int64)
    F = evaluate_multicut(p, hw, cands)
    front = exhaustive_pareto(F)
    pick = topsis_select(F[front])
    best_bf = tuple(cands[front][pick])

    plan = smartsplit_multicut(
        p, hw, NSGA2Config(pop_size=128, generations=120, seed=0))
    # GA's pick must be on (or dominate nothing on) the brute-force front
    ours = evaluate_multicut(p, hw, np.array([plan.cuts]))[0]
    for idx in front:
        other = F[idx]
        assert not (np.all(other <= ours) and np.any(other < ours)), \
            (plan.cuts, best_bf)
    # and objective-wise it should be close to the brute-force TOPSIS pick
    best_F = F[front][pick]
    assert ours[0] <= best_F[0] * 1.25 + 1e-12


def test_stage_structure_and_constraints():
    p = cnn_profile("vgg11")
    hw = _chain(4)
    plan = smartsplit_multicut(p, hw)
    stages = plan.stages(p.num_layers)
    assert len(stages) == 4
    widths = [b - a for a, b in stages]
    assert all(w >= 1 for w in widths)
    assert sum(widths) == p.num_layers
    assert plan.cuts == tuple(sorted(plan.cuts))
    assert plan.objectives[2] <= 1.0          # memory pressure within budget


def test_two_tier_chain_consistent_with_paper_planner():
    """K=2 chain with the TPU tiers ~ the TwoTierHardware planner (cost
    models differ in the memory objective normalisation, so compare the
    latency at the chosen splits, not the split indices)."""
    p = cnn_profile("alexnet")
    t0, t1 = tpu_pod_tier("edge", 16), tpu_pod_tier("cloud", 256)
    chain = ChainHardware(tiers=(t0, t1), links=(DCN_LINK,))
    plan = smartsplit_multicut(p, chain)
    two = smartsplit_exhaustive(
        p, TwoTierHardware(client=t0, server=t1, link=DCN_LINK))
    F_chain = evaluate_multicut(p, chain,
                                np.array([[two.split_index]]))[0]
    assert plan.objectives[0] <= F_chain[0] * 1.5
    assert 1 <= plan.cuts[0] <= p.num_layers - 1
