"""N-tier chain runtime: pipeline scheduling, degradation ladder
(stage-merge -> Pareto re-pick -> unrecoverable), and bit-identity against
the single-device reference.

Deterministic like tests/test_runtime.py: outage windows + the shared
virtual clock force exact failure/recovery sequences per seed."""
import jax
import numpy as np
import pytest

from repro.core import (PAPER_ENV_J6, paper_chain, smartsplit_chain,
                        smartsplit_exhaustive)
from repro.models import cnn as cnn_lib
from repro.models.cnn import avgpool, conv, linear, maxpool, relu
from repro.models.profiles import cnn_profile
from repro.runtime import (ChainRuntime, FaultSpec, FaultyLink, RetryPolicy,
                           SplitRuntime, SplitUnrecoverable, VirtualClock,
                           chain_links_from_env, events, microbatch_slices)

TINY_LAYERS = [conv(8, 3, 1, 1), relu(), maxpool(2, 2),
               conv(16, 3, 1, 1), relu(), avgpool(2), linear(10)]
TINY_SHAPE = (3, 16, 16)


@pytest.fixture(scope="module")
def tiny():
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), TINY_LAYERS,
                              TINY_SHAPE)
    rng = np.random.default_rng(0)
    x = np.asarray(rng.normal(size=(4,) + TINY_SHAPE), np.float32)
    return params, x


def _chain_plan(K=3, dtype=None, microbatches=1):
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, dtype=dtype,
                       layers=TINY_LAYERS)
    hw = paper_chain(K)
    return prof, hw, smartsplit_chain(prof, hw, microbatches=microbatches)


def _links(hw, seed=0, fault_hop=None, spec=None):
    clock = VirtualClock()
    return [FaultyLink(link.bandwidth, clock=clock, seed=seed + k,
                       faults=spec if k == fault_hop else FaultSpec())
            for k, link in enumerate(hw.links)]


def _full_ref(params, x, dtype=None):
    return np.asarray(cnn_lib.apply_cnn(TINY_LAYERS, params, x,
                                        dtype=dtype))


# ---------------------------------------------------------------------------
# Clean path: chain == single device, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [None, "bf16"])
def test_three_tier_clean_bit_identical(tiny, dtype):
    params, x = tiny
    prof, hw, plan = _chain_plan(3, dtype=dtype)
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, dtype=dtype)
    r = rt.infer(x)
    assert not r.degraded and r.merged_hops == ()
    assert r.cuts == plan.cuts and len(r.cuts) == 2
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x, dtype))
    assert r.attempts == len(hw.links)      # one clean send per hop
    assert r.chain_elapsed_s > 0
    assert rt.stats()["recovered"] == 0


@pytest.mark.parametrize("dtype", [None, "bf16"])
def test_one_hop_chain_matches_split_runtime(tiny, dtype):
    """K=2 ChainRuntime == the paper's SplitRuntime on the clean path."""
    params, x = tiny
    prof, hw, plan = _chain_plan(2, dtype=dtype)
    two = smartsplit_exhaustive(prof, PAPER_ENV_J6)
    assert plan.cuts == (two.split_index,)
    crt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, dtype=dtype)
    srt = SplitRuntime(TINY_LAYERS, params, two, prof, PAPER_ENV_J6,
                       dtype=dtype)
    rc = crt.infer(x)
    rs = srt.infer(x)
    np.testing.assert_array_equal(np.asarray(rc.logits),
                                  np.asarray(rs.logits))
    assert rc.goodput_bytes == rs.goodput_bytes


def test_microbatching_bit_identical_and_faster(tiny):
    """M=4 overlaps hop transfers with downstream compute: the virtual
    makespan shrinks while logits stay bit-identical to a single-device
    run sliced at the same microbatch granularity."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    elapsed = {}
    for m in (1, 4):
        rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw,
                          microbatches=m)
        r = rt.infer(x)
        assert r.microbatches == m
        elapsed[m] = r.chain_elapsed_s
        ref = np.concatenate(
            [_full_ref(params, x[a:b]) for a, b in
             microbatch_slices(x.shape[0], m)], axis=0)
        np.testing.assert_array_equal(np.asarray(r.logits), ref)
    assert elapsed[4] < elapsed[1]
    # M=1 batched execution equals the plain batched reference
    # (microbatch_slices(batch, 1) is the whole batch)
    assert microbatch_slices(4, 1) == [(0, 4)]
    assert microbatch_slices(4, 4) == [(0, 1), (1, 2), (2, 3), (3, 4)]
    assert microbatch_slices(5, 2) == [(0, 3), (3, 5)]
    with pytest.raises(ValueError):
        microbatch_slices(0, 1)


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------
def test_mid_chain_outage_merges_stage(tiny):
    """A permanently dead hop 1 folds the downstream stage onto the
    upstream tier (the cut collapses) and the answer stays bit-exact."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    links = _links(hw, fault_hop=1,
                   spec=FaultSpec(outages=((0.0, 1e9),)))
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      policy=RetryPolicy(max_attempts=2, timeout_s=0.01,
                                         backoff_base_s=0.005))
    r = rt.infer(x)
    assert r.degraded
    assert r.merged_hops == (1,)
    assert len(r.cuts) == 1                 # one cut collapsed
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x))
    s = rt.stats()
    assert s["merges"] == 1 and s["recovered"] == 1
    assert any(e.kind == events.STAGE_MERGE for e in r.events)
    assert s["hops"][1]["merges"] == 1
    assert s["hops"][1]["link"]["outage_hits"] >= 1


def test_transient_outage_recovers_via_repick(tiny):
    """With merges disabled and hop 1 down only for a window, the runtime
    re-picks a different cut vector from the cached front and finishes."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    assert len(plan.pareto_cuts) >= 2       # front has an alternative
    links = _links(hw, fault_hop=1,
                   spec=FaultSpec(outages=((0.0, 0.012),)))
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      merge_fallback=False,
                      policy=RetryPolicy(max_attempts=1, timeout_s=0.01,
                                         backoff_base_s=0.005))
    r = rt.infer(x[:1])
    assert r.degraded and r.merged_hops == ()
    assert r.cuts != r.planned_cuts
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  _full_ref(params, x[:1]))
    s = rt.stats()
    assert s["repicks"] == 1 and s["merges"] == 0
    assert any(e.kind == events.REPICK for e in r.events)


def test_permanent_outage_without_merge_is_unrecoverable(tiny):
    """Every cut vector of a K=3 chain crosses hop 1, so a dead hop with
    merges disabled exhausts the front and surfaces the outage."""
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    links = _links(hw, fault_hop=1,
                   spec=FaultSpec(outages=((0.0, 1e9),)))
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw, links=links,
                      merge_fallback=False,
                      policy=RetryPolicy(max_attempts=1, timeout_s=0.01,
                                         backoff_base_s=0.005))
    with pytest.raises(SplitUnrecoverable):
        rt.infer(x[:1])
    assert rt.log.count(events.UNRECOVERABLE) == 1


# ---------------------------------------------------------------------------
# Observability: per-hop counters in both runtimes
# ---------------------------------------------------------------------------
def test_chain_stats_per_hop(tiny):
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw)
    rt.infer(x)
    s = rt.stats()
    assert len(s["hops"]) == 2
    for k, h in enumerate(s["hops"]):
        assert h["hop"] == k
        assert h["attempts"] == 1
        assert h["goodput_bytes"] > 0
        assert h["retransmitted_bytes"] == 0
        assert h["degradation"] > 0
    assert s["active_cuts"] == list(plan.cuts)


def test_split_runtime_stats_expose_hops(tiny):
    from repro.core import PAPER_ENV_J6
    params, x = tiny
    prof = cnn_profile("tiny", in_shape=TINY_SHAPE, layers=TINY_LAYERS)
    plan = smartsplit_exhaustive(prof, PAPER_ENV_J6)
    rt = SplitRuntime(TINY_LAYERS, params, plan, prof, PAPER_ENV_J6)
    rt.infer(x)
    s = rt.stats()
    assert len(s["hops"]) == 1
    h = s["hops"][0]
    assert h["hop"] == 0
    assert h["attempts"] == 1
    assert h["wire_bytes"] == h["goodput_bytes"] > 0
    assert "est_bandwidth" in h and "degradation" in h


# ---------------------------------------------------------------------------
# Shared virtual clock + per-hop env knobs
# ---------------------------------------------------------------------------
def test_virtual_clock_shared_across_hops():
    clock = VirtualClock()
    a = FaultyLink(100.0, clock=clock)
    b = FaultyLink(100.0, clock=clock)
    a.send(b"x" * 100, timeout_s=10.0)      # 1s of wire time
    assert b.clock == pytest.approx(1.0)    # b sees a's progress
    out, elapsed = b.send_at(5.0, b"y" * 50, timeout_s=10.0)
    assert out == b"y" * 50
    assert clock.now == pytest.approx(5.5)  # explicit start, not now
    clock.advance_to(2.0)                   # monotone: never rewinds
    assert clock.now == pytest.approx(5.5)


def test_chain_links_from_env_per_hop_override(monkeypatch):
    monkeypatch.setenv("REPRO_LINK_DROP", "0.1")
    monkeypatch.setenv("REPRO_LINK1_DROP", "0.5")
    monkeypatch.setenv("REPRO_LINK_SEED", "7")
    links = chain_links_from_env([1e6, 2e6, 3e6])
    assert [link.faults.drop_rate for link in links] == [0.1, 0.5, 0.1]
    assert [link.seed for link in links] == [7, 8, 9]   # base + hop
    assert links[0]._clock is links[1]._clock is links[2]._clock
    monkeypatch.setenv("REPRO_LINK2_SEED", "99")
    assert chain_links_from_env([1e6, 2e6, 3e6])[2].seed == 99


def test_chain_runtime_microbatch_env_default(tiny, monkeypatch):
    params, x = tiny
    prof, hw, plan = _chain_plan(3)
    monkeypatch.setenv("REPRO_CHAIN_MICROBATCH", "4")
    rt = ChainRuntime(TINY_LAYERS, params, plan, prof, hw)
    assert rt.infer(x).microbatches == 4


# ---------------------------------------------------------------------------
# Acceptance: 4-tier VGG16 at the paper's native input
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_four_tier_vgg16_end_to_end_bit_identical():
    in_shape = cnn_lib.INPUT_SHAPE
    layers = cnn_lib.CNN_MODELS["vgg16"]
    prof = cnn_profile("vgg16", batch=2, in_shape=in_shape)
    hw = paper_chain(4)
    plan = smartsplit_chain(prof, hw)
    assert len(plan.cuts) == 3
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), layers, in_shape)
    x = np.asarray(np.random.default_rng(0).normal(
        size=(2,) + in_shape), np.float32)
    rt = ChainRuntime("vgg16", params, plan, prof, hw, microbatches=1)
    r = rt.infer(x)
    assert not r.degraded
    ref = np.asarray(cnn_lib.apply_cnn(layers, params, x))
    np.testing.assert_array_equal(np.asarray(r.logits), ref)
