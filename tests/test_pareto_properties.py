"""Hypothesis property tests for Pareto utilities.

Kept separate from tests/test_pareto.py so environments without
``hypothesis`` (it is a dev-only dependency, see requirements-dev.txt)
still collect and run the unit tests there."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.pareto import (crowding_distance, dominates,  # noqa: E402
                               exhaustive_pareto, non_dominated_sort)


@given(st.integers(1, 40), st.integers(1, 4), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_front0_is_exactly_the_nondominated_set(n, m, seed):
    rng = np.random.default_rng(seed)
    F = rng.integers(0, 5, (n, m)).astype(float)  # ties are common
    fronts = non_dominated_sort(F)
    # Partition property: every index appears exactly once.
    all_idx = np.sort(np.concatenate(fronts))
    assert np.array_equal(all_idx, np.arange(n))
    # Front 0 == brute-force Pareto set.
    assert set(fronts[0].tolist()) == set(exhaustive_pareto(F).tolist())
    # No point is dominated by a point in its own front or later fronts.
    for k, front in enumerate(fronts):
        later = np.concatenate(fronts[k:])
        for i in front:
            assert not any(dominates(F[j], F[i]) for j in later)
    # Points in front k>0 are each dominated by someone in an earlier front.
    for k in range(1, len(fronts)):
        earlier = np.concatenate(fronts[:k])
        for i in fronts[k]:
            assert any(dominates(F[j], F[i]) for j in earlier)


@given(st.integers(3, 30), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_crowding_boundaries_infinite(n, seed):
    rng = np.random.default_rng(seed)
    F = rng.random((n, 3))
    d = crowding_distance(F)
    for j in range(3):
        assert np.isinf(d[np.argmin(F[:, j])])
        assert np.isinf(d[np.argmax(F[:, j])])
    assert np.all(d[~np.isinf(d)] >= 0)
