"""Hypothesis property tests for NSGA-II and TOPSIS.

Kept separate from tests/test_nsga2_topsis.py so environments without
``hypothesis`` (dev-only dependency) still run the unit tests there."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.nsga2 import NSGA2Config, nsga2  # noqa: E402
from repro.core.pareto import exhaustive_pareto, pareto_front_mask  # noqa: E402
from repro.core.topsis import topsis_select  # noqa: E402


def _eval_from_table(table):
    def evaluate(genomes):
        return table[genomes[:, 0]]
    return evaluate


@given(st.integers(5, 60), st.integers(0, 5000))
@settings(max_examples=25, deadline=None)
def test_nsga2_recovers_exhaustive_front_1d(n, seed):
    """Single-integer genome (the paper's case): with stratified init and
    pop_size >= |domain| the offline-archive front is provably the exact
    Pareto front (this is how `smartsplit` configures the GA)."""
    rng = np.random.default_rng(seed)
    table = rng.random((n, 3))
    res = nsga2(_eval_from_table(table), np.array([0]), np.array([n - 1]),
                NSGA2Config(pop_size=max(32, n), generations=30, seed=seed))
    got = set(res.pareto_genomes[:, 0].tolist())
    full_front = set(exhaustive_pareto(table).tolist())
    assert got == full_front


@given(st.integers(5, 60), st.integers(0, 5000))
@settings(max_examples=15, deadline=None)
def test_nsga2_underprovisioned_returns_nondominated_subset(n, seed):
    """With pop < domain there is no exactness guarantee, but every
    returned genome must still be non-dominated *among visited points*:
    the archive front can never contain a point dominated by another
    returned point."""
    rng = np.random.default_rng(seed)
    table = rng.random((n, 3))
    res = nsga2(_eval_from_table(table), np.array([0]), np.array([n - 1]),
                NSGA2Config(pop_size=8, generations=10, seed=seed))
    F = res.pareto_F
    assert np.all(pareto_front_mask(F))


@given(st.integers(2, 30), st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_topsis_scale_invariance(n, seed):
    """Column normalisation makes the pick invariant to per-objective unit
    changes (seconds vs ms, bytes vs MB) -- the property that justifies
    mixing heterogeneous objectives."""
    rng = np.random.default_rng(seed)
    F = rng.random((n, 3)) + 0.01
    scale = np.array([1e-3, 1e6, 123.0])
    assert topsis_select(F) == topsis_select(F * scale)


@given(st.integers(2, 20), st.integers(0, 2000))
@settings(max_examples=40, deadline=None)
def test_topsis_pick_is_pareto_when_input_is_front(n, seed):
    rng = np.random.default_rng(seed)
    F = rng.random((n, 3))
    front = F[pareto_front_mask(F)]
    pick = topsis_select(front)
    assert 0 <= pick < front.shape[0]
    # picked point is itself non-dominated within the front (trivially true
    # for a front input; guards against index bugs after filtering)
    assert pareto_front_mask(front)[pick]
