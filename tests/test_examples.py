"""Smoke-execute the runnable examples (tier-1 keeps them honest).

Each example is a subprocess with PYTHONPATH=src, exactly as the README
tells a user to run it -- so a drifting import or API rename fails the
gate, not the user.  Only the fast CNN-serving example runs in tier-1;
the transformer examples spin up bigger models and stay manual.
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def test_batch_serving_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, str(REPO / "examples" / "batch_serving.py")],
        capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = proc.stdout
    assert "served 12/12 mixed-resolution requests" in out
    assert "backpressure" in out
    assert "engine stats" in out
