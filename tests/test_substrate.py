"""Substrate tests: optimizer, checkpointing, data pipeline, serving
engine, HLO collective parser."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_bytes, collective_counts
from repro.configs import all_configs
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.models import transformer as T
from repro.serving.engine import BucketScheduler, Engine, Request
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, grad_clip=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_grad_clip_and_schedule():
    cfg = opt.AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=10,
                          total_steps=100)
    params = {"w": jnp.ones(4)}
    state = opt.init_state(params)
    _, state, m = opt.apply_updates(cfg, params, {"w": jnp.full(4, 100.0)},
                                    state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert float(m["lr"]) == pytest.approx(cfg.lr / 10, rel=0.01)
    # schedule decays to min_lr_ratio at the end
    end = opt.schedule(cfg, jnp.asarray(100))
    assert float(end) == pytest.approx(cfg.lr * cfg.min_lr_ratio, rel=0.01)


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_with_namedtuples():
    cfg = all_configs()["qwen3-4b"].reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    state = opt.init_state(params)
    with tempfile.TemporaryDirectory() as tmp:
        ckpt.save(tmp, 7, params, state)
        step, restored = ckpt.restore(tmp, {"params": params,
                                            "opt_state": state})
        assert step == 7
        for a, b in zip(jax.tree.leaves({"params": params,
                                         "opt_state": state}),
                        jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected():
    params = {"w": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as tmp:
        ckpt.save(tmp, 0, params)
        bad = {"params": {"w": jnp.ones((3, 2))}}
        with pytest.raises(ValueError, match="shape mismatch"):
            ckpt.restore(tmp, bad)


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_lm_deterministic_and_learnable():
    cfg = all_configs()["phi3-mini-3.8b"].reduced()
    ds1 = SyntheticLM(cfg, batch=4, seq_len=32, seed=1)
    ds2 = SyntheticLM(cfg, batch=4, seq_len=32, seed=1)
    b1, b2 = ds1.batch_at(5), ds2.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # structure: next token equals perm[cur] 70% of the time
    toks, labels = b1["tokens"], b1["labels"]
    match = (ds1.perm[toks] == labels).mean()
    assert 0.5 < match < 0.95


def test_prefetcher_delivers_in_order():
    cfg = all_configs()["phi3-mini-3.8b"].reduced()
    ds = SyntheticLM(cfg, batch=2, seq_len=8, seed=0)
    pf = Prefetcher(iter(ds), depth=2)
    a = next(pf)
    b = next(pf)
    pf.close()
    np.testing.assert_array_equal(a["tokens"], ds.batch_at(0)["tokens"])
    np.testing.assert_array_equal(b["tokens"], ds.batch_at(1)["tokens"])


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def small_served_model():
    cfg = dataclasses.replace(all_configs()["qwen3-4b"].reduced(),
                              vocab_size=128, name="serve-test")
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def test_bucket_scheduler_groups_by_length():
    s = BucketScheduler(max_batch=2)
    for i, plen in enumerate([4, 4, 4, 6]):
        s.add(Request(rid=i, prompt=list(range(plen))))
    batch = s.next_batch()
    assert len(batch) == 2
    assert all(len(r.prompt) == 4 for r in batch)
    assert s.n_pending == 2


def test_engine_greedy_matches_manual_forward(small_served_model):
    """One request, greedy: engine output == argmax rollout via forward."""
    cfg, params = small_served_model
    eng = Engine(cfg, params, max_len=48, max_batch=2)
    prompt = list(range(1, 9))
    req = eng.submit(prompt, max_new_tokens=4)
    eng.run_until_idle()
    assert req.done and len(req.output) == 4

    toks = list(prompt)
    for _ in range(4):
        logits, _, _ = T.forward(cfg, params,
                                 {"tokens": jnp.asarray([toks], jnp.int32)},
                                 mode="train")
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.output == toks[len(prompt):]


def test_engine_batches_mixed_lengths(small_served_model):
    cfg, params = small_served_model
    eng = Engine(cfg, params, max_len=64, max_batch=4)
    reqs = [eng.submit(list(range(1, 1 + n)), max_new_tokens=3)
            for n in (5, 5, 9, 9, 5)]
    eng.run_until_idle()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == 3 for r in reqs)
    # greedy decode is batch-invariant: same-prompt requests agree
    assert reqs[0].output == reqs[1].output == reqs[4].output


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------
def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ag = f32[16,256]{1,0} all-gather(%x), replica_groups={}
  %ar.1 = bf16[8]{0} all-reduce-start(%y), to_apply=%sum
  %ar.done = bf16[8]{0} all-reduce-done(%ar.1)
  %rs = (f32[4,4]{1,0}, f32[2]{0}) reduce-scatter(%a, %b)
  ROOT %cp = u8[100]{0} collective-permute(%z)
"""
    b = collective_bytes(hlo)
    assert b["all-gather"] == 16 * 256 * 4
    assert b["all-reduce"] == 8 * 2            # start counted once
    assert b["reduce-scatter"] == 4 * 4 * 4 + 2 * 4
    assert b["collective-permute"] == 100
    assert b["total"] == sum(v for k, v in b.items() if k != "total")
    c = collective_counts(hlo)
    assert c["all-gather"] == 1 and c["all-reduce"] == 1
