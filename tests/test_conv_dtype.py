"""bf16-storage / fp32-accumulate conv path: policy resolution, kernel
parity at relaxed tolerance on every AlexNet/VGG16 conv (+ fused pool
triple) shape, planner VMEM headroom, boundary-payload serialization, and
the dtype-aware cost model steering NSGA-II/TOPSIS.

Everything runs in interpret mode on CPU; full-resolution shapes whose
conv exceeds ~2e8 MACs are marked ``slow`` (tier-1 runs ``-m "not slow"``)
but still pass under a plain ``pytest`` run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PAPER_ENV_J6, evaluate_objectives, feasible_mask,
                        latency_terms, smartsplit_exhaustive)
from repro.core.dtype_policy import (CONV_DTYPES, conv_dtype, dtype_bytes,
                                     policy_jnp_dtype)
from repro.kernels import ops, ref
from repro.kernels.conv2d import DEFAULT_VMEM_BUDGET, plan_conv
from repro.models import cnn
from repro.models.profiles import cnn_profile

KEY = jax.random.PRNGKey(0)

# bf16 stores ~8 mantissa bits: with the fp32 accumulator the error is
# input/weight rounding only, well inside 2e-2 for O(1) activations.
BF16_TOL = dict(rtol=2e-2, atol=2e-2)


def _assert_bf16_close(got, want):
    """2e-2 max-abs in units of the output scale (relative where the
    reduction makes activations O(10): a near-zero element of a 3456-term
    dot sees the other elements' rounding without their magnitude)."""
    got = np.asarray(got, np.float32)
    want = np.asarray(want)
    scale = max(1.0, float(np.max(np.abs(want))))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2 * scale)


def _inputs(n, cin, hw, cout, k, scale=0.3):
    x = jax.random.normal(KEY, (n, cin, hw, hw)) * scale
    w = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (cout, cin, k, k)) * 0.2
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (cout,)) * 0.1
    return x, w, b


def _ref_fp32(x, w, b, *, stride, pad, act, pool_k=0, pool_s=0):
    y = ref.conv2d_ref(x, w, stride=stride, pad=pad, bias=b, activation=act)
    if pool_k:
        y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                                  (1, 1, pool_k, pool_k),
                                  (1, 1, pool_s, pool_s), "VALID")
    return y


# ---------------------------------------------------------------------------
# Policy resolution
# ---------------------------------------------------------------------------
def test_dtype_env_and_arg_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_CONV_DTYPE", raising=False)
    assert conv_dtype() == "fp32"
    monkeypatch.setenv("REPRO_CONV_DTYPE", "bf16")
    assert conv_dtype() == "bf16"
    assert conv_dtype("fp32") == "fp32"       # explicit arg wins
    monkeypatch.setenv("REPRO_CONV_DTYPE", "fp8-magic")
    with pytest.raises(ValueError):
        conv_dtype()
    with pytest.raises(ValueError):
        conv_dtype("int4")


def test_dtype_bytes_and_jnp_dtype():
    assert [dtype_bytes(d) for d in CONV_DTYPES] == [4, 2]
    assert policy_jnp_dtype("fp32") == jnp.float32
    assert policy_jnp_dtype("bf16") == jnp.bfloat16


# ---------------------------------------------------------------------------
# Kernel parity: every AlexNet/VGG16 conv (+ fused pool triple) shape
# ---------------------------------------------------------------------------
def _conv_specs():
    """Every AlexNet/VGG16 conv (+ fused pool triple) shape, from the same
    enumeration the dtype-sweep benchmark uses."""
    from benchmarks.kernels_bench import model_conv_specs
    return [s for m in ("alexnet", "vgg16") for s in model_conv_specs(m)]


def _shape_params():
    params = []
    for name, cin, hw, cout, k, s, p, act, pk, ps in _conv_specs():
        macs = k * k * cin * cout * hw * hw
        marks = [pytest.mark.slow] if macs > 2e8 else []
        params.append(pytest.param(
            (cin, hw, cout, k, s, p, act, pk, ps), marks=marks,
            id=f"{name}-{cin}x{hw}-{cout}c{k}s{s}p{pk}_{ps}"))
    return params


@pytest.mark.parametrize("spec", _shape_params())
def test_bf16_parity_model_shapes(spec):
    """Acceptance: bf16 storage matches the fp32 XLA reference within
    2e-2 max-abs on every AlexNet/VGG16 conv and fused pool-triple shape,
    and the bf16 launch returns bfloat16 storage."""
    cin, hw, cout, k, s, p, act, pk, ps = spec
    x, w, b = _inputs(1, cin, hw, cout, k)
    got = ops.conv2d(x, w, stride=s, pad=p, bias=b, activation=act,
                     pool_k=pk, pool_s=ps, dtype="bf16")
    assert got.dtype == jnp.bfloat16
    want = _ref_fp32(x, w, b, stride=s, pad=p, act=act, pool_k=pk,
                     pool_s=ps)
    assert got.shape == want.shape
    _assert_bf16_close(got, want)


@pytest.mark.parametrize("k,stride,pad,pk,ps", [
    (3, 1, 1, 0, 0), (3, 1, 1, 2, 2), (5, 1, 2, 3, 2), (11, 4, 2, 3, 2),
])
def test_bf16_parity_geometry_small(k, stride, pad, pk, ps):
    """The paper models' distinct conv/pool geometries at small channels
    and resolution, so tier-1 covers the bf16 halo/pool path cheaply."""
    hw = 31 if k > 5 else 23
    x, w, b = _inputs(2, 6, hw, 8, k, scale=0.4)
    got = ops.conv2d(x, w, stride=stride, pad=pad, bias=b,
                     activation="relu", pool_k=pk, pool_s=ps, dtype="bf16")
    want = _ref_fp32(x, w, b, stride=stride, pad=pad, act="relu",
                     pool_k=pk, pool_s=ps)
    np.testing.assert_allclose(np.asarray(got.astype(jnp.float32)),
                               np.asarray(want), **BF16_TOL)


# ---------------------------------------------------------------------------
# Planner: bf16 buys VMEM headroom (bigger tiles, fewer launches)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("spec",
                         [pytest.param(s, id=s[0]) for s in _conv_specs()])
def test_planner_bf16_headroom(spec):
    """Acceptance: for every AlexNet/VGG16 conv shape the bf16 plan fits
    the budget with a tile *area* >= the fp32 plan's and no more launches.
    (Since the joint tiling search the headroom invariant is 2-D: bf16 may
    trade tile_h for a wider tile_w -- e.g. full-width rows vs the fp32
    plan's square tiles -- but never tiles finer overall.)"""
    name, cin, hw, cout, k, s, p, act, pk, ps = spec
    plans = {}
    for nbytes in (4, 2):
        plans[nbytes] = plan_conv((1, cin, hw, hw), (cout, cin, k, k),
                                  stride=s, pad=p, pool_k=pk, pool_s=ps,
                                  dtype_bytes=nbytes)
        assert plans[nbytes].vmem_bytes <= DEFAULT_VMEM_BUDGET, (name,
                                                                 nbytes)
    assert plans[2].tile_h * plans[2].tile_w \
        >= plans[4].tile_h * plans[4].tile_w, name
    assert plans[2].launches <= plans[4].launches, name


def test_planner_bf16_fewer_launches_vgg16_early():
    """Acceptance: on the VGG16 early layers the doubled headroom must
    actually reduce launch counts, and the same-tile VMEM saving is at
    least 1.5x (the fp32 accumulator caps it below 2x)."""
    from benchmarks.kernels_bench import dtype_plan_stats, model_conv_specs
    early = model_conv_specs("vgg16")[:3]          # conv1-conv3
    reduced = []
    for name, cin, hw, cout, k, s, p, act, pk, ps in early:
        stats = dtype_plan_stats(cin, hw, cout, k, s, p, pk, ps)
        assert stats["vmem_per_tile_ratio"] >= 1.5, (name, stats)
        assert stats["bf16"]["launches"] <= stats["fp32"]["launches"]
        reduced.append(stats["bf16"]["launches"] < stats["fp32"]["launches"])
    assert any(reduced), "bf16 reduced no VGG16 early-layer launch count"


def test_conv2d_passes_storage_itemsize_to_planner(monkeypatch):
    """ops.conv2d under bf16 must hand the planner 2-byte elements -- the
    executed grid uses the bf16 plan, not the fp32 one (observed via a
    plan_conv spy; the shape is unique so the jit cache cannot serve a
    stale trace that skips planning)."""
    from repro.kernels import conv2d as conv2d_mod
    seen = []
    real_plan = conv2d_mod.plan_conv

    def spy(x_shape, w_shape, **kw):
        seen.append(kw.get("dtype_bytes"))
        return real_plan(x_shape, w_shape, **kw)

    monkeypatch.setattr(conv2d_mod, "plan_conv", spy)
    x, w, b = _inputs(1, 8, 61, 8, 3)
    got = ops.conv2d(x, w, stride=1, pad=1, bias=b, dtype="bf16")
    assert seen and seen[-1] == 2
    want = _ref_fp32(x, w, b, stride=1, pad=1, act=None)
    np.testing.assert_allclose(np.asarray(got.astype(jnp.float32)),
                               np.asarray(want), **BF16_TOL)
    # and the headroom is real for a shape the fp32 plan cannot tile as
    # coarsely
    p32 = plan_conv((1, 64, 224, 224), (64, 64, 3, 3), stride=1, pad=1,
                    dtype_bytes=4)
    p16 = plan_conv((1, 64, 224, 224), (64, 64, 3, 3), stride=1, pad=1,
                    dtype_bytes=2)
    assert p16.tile_h > p32.tile_h and p16.n_h_blocks < p32.n_h_blocks


# ---------------------------------------------------------------------------
# Model walk + split boundary serialization
# ---------------------------------------------------------------------------
_TINY = [cnn.conv(8, 3, 1, 1), cnn.relu(), cnn.maxpool(2, 2),
         cnn.conv(16, 3, 2, 1), cnn.relu6(),
         cnn.conv(16, 1, 1, 0),
         cnn.avgpool(2), cnn.linear(10)]
_TINY_IN = (3, 16, 16)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_apply_cnn_bf16_matches_fp32(backend):
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TINY, _TINY_IN)
    x = jax.random.normal(KEY, (2,) + _TINY_IN) * 0.5
    want = cnn.apply_cnn(_TINY, params, x, backend=backend)
    got = cnn.apply_cnn(_TINY, params, x, backend=backend, dtype="bf16")
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got.astype(jnp.float32)),
                               np.asarray(want), rtol=5e-2, atol=5e-2)


def test_backends_agree_under_bf16():
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TINY, _TINY_IN)
    x = jax.random.normal(KEY, (2,) + _TINY_IN) * 0.5
    a = cnn.apply_cnn(_TINY, params, x, backend="xla", dtype="bf16")
    b = cnn.apply_cnn(_TINY, params, x, backend="pallas", dtype="bf16")
    np.testing.assert_allclose(np.asarray(a.astype(jnp.float32)),
                               np.asarray(b.astype(jnp.float32)),
                               rtol=2e-2, atol=2e-2)


def test_env_var_routes_dtype(monkeypatch):
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TINY, _TINY_IN)
    x = jax.random.normal(KEY, (1,) + _TINY_IN) * 0.5
    monkeypatch.delenv("REPRO_CONV_DTYPE", raising=False)
    assert cnn.apply_cnn(_TINY, params, x).dtype == jnp.float32
    monkeypatch.setenv("REPRO_CONV_DTYPE", "bf16")
    assert cnn.apply_cnn(_TINY, params, x).dtype == jnp.bfloat16


@pytest.mark.parametrize("split", range(1, len(_TINY)))
def test_split_boundary_serialized_in_policy_dtype(split):
    """Acceptance: under bf16 the boundary payload crosses the link as
    bfloat16 with exactly the byte count the dtype-aware profile charges,
    and the split logits still match the fp32 monolithic run."""
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TINY, _TINY_IN)
    x = jax.random.normal(KEY, (1,) + _TINY_IN) * 0.5
    full = cnn.apply_cnn(_TINY, params, x)                # fp32 reference
    logits, boundary = cnn.apply_split(_TINY, params, x, split,
                                       backend="pallas", dtype="bf16")
    assert boundary.dtype == jnp.bfloat16
    lx, bx = cnn.apply_split(_TINY, params, x, split, backend="xla",
                             dtype="bf16")
    assert bx.dtype == jnp.bfloat16 and bx.shape == boundary.shape
    np.testing.assert_allclose(np.asarray(logits.astype(jnp.float32)),
                               np.asarray(full), rtol=5e-2, atol=5e-2)


def test_coc_split_uploads_policy_dtype_input():
    """Degenerate l1=0 (COC): the boundary IS the input, and it must be
    serialized in the policy dtype with exactly the profile's input_bytes
    -- the storage invariant starts before the first layer."""
    in_shape = (3, 64, 64)
    layers = cnn.CNN_MODELS["alexnet"][:4]
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers, in_shape)
    x = jax.random.normal(KEY, (1,) + in_shape) * 0.3
    _, boundary = cnn.apply_split(layers, params, x, 0, dtype="bf16")
    assert boundary.dtype == jnp.bfloat16
    p16 = cnn_profile("alexnet", in_shape=in_shape, dtype="bf16")
    assert boundary.size * boundary.dtype.itemsize == p16.boundary()[0]


def test_split_boundary_bytes_match_bf16_profile():
    """Execution vs analytic profile: boundary.size * 2 == I|l1 at bf16,
    half the fp32 figure, on a real paper model prefix."""
    layers = cnn.CNN_MODELS["alexnet"]
    in_shape = (3, 64, 64)
    params = cnn.init_cnn(jax.random.PRNGKey(0), layers[:4], in_shape)
    x = jax.random.normal(KEY, (1,) + in_shape) * 0.3
    for l1 in (1, 3):
        _, boundary = cnn.apply_split(layers[:4], params, x, l1,
                                      dtype="bf16")
        p16 = cnn_profile("alexnet", in_shape=in_shape, dtype="bf16")
        p32 = cnn_profile("alexnet", in_shape=in_shape, dtype="fp32")
        assert boundary.dtype == jnp.bfloat16
        assert boundary.size * 2 == p16.boundary()[l1]
        assert 2 * p16.boundary()[l1] == p32.boundary()[l1]


# ---------------------------------------------------------------------------
# Dtype-aware cost model -> optimiser
# ---------------------------------------------------------------------------
def test_profile_terms_scale_with_dtype():
    p32 = cnn_profile("vgg16")
    p16 = cnn_profile("vgg16", dtype="bf16")
    assert (p32.dtype, p16.dtype) == ("fp32", "bf16")
    np.testing.assert_allclose(p16.cum_mem(), p32.cum_mem() * 0.5)
    np.testing.assert_allclose(p16.boundary(), p32.boundary() * 0.5)
    np.testing.assert_allclose(p16.cum_flops(), p32.cum_flops())
    # with_dtype round-trips between the two profiles
    np.testing.assert_allclose(p32.with_dtype("bf16").boundary(),
                               p16.boundary())
    np.testing.assert_allclose(p16.with_dtype("fp32").cum_mem(),
                               p32.cum_mem())


def test_with_dtype_keeps_token_input_bytes_fixed():
    """Transformer profiles upload int32 token ids at l1=0: re-profiling
    under another storage policy must rescale weights/activations but
    leave the policy-independent input payload alone."""
    from repro.configs import all_configs
    from repro.models.profiles import transformer_profile
    cfg = all_configs()["qwen3-4b"].reduced()
    prof = transformer_profile(cfg, seq_len=8, batch=2, mode="prefill")
    assert prof.dtype == "bf16" and not prof.input_follows_dtype
    up = prof.with_dtype("fp32")
    assert up.input_bytes == prof.input_bytes       # token ids unchanged
    np.testing.assert_allclose(up.cum_mem(), prof.cum_mem() * 2)
    np.testing.assert_allclose(up.boundary()[1:], prof.boundary()[1:] * 2)


def test_transfer_and_memory_objectives_scale():
    """core/costs: the upload-latency and client-memory terms (the two
    byte-dominated objectives) halve under bf16."""
    p32 = cnn_profile("vgg16")
    p16 = p32.with_dtype("bf16")
    _, up32, _, _ = latency_terms(p32, PAPER_ENV_J6)
    _, up16, _, _ = latency_terms(p16, PAPER_ENV_J6)
    np.testing.assert_allclose(up16, up32 * 0.5)
    F32 = evaluate_objectives(p32, PAPER_ENV_J6)
    F16 = evaluate_objectives(p16, PAPER_ENV_J6)
    np.testing.assert_allclose(F16[:, 2], F32[:, 2] * 0.5)
    assert np.all(F16[1:-1, 0] < F32[1:-1, 0])      # latency strictly drops


def test_optimizer_picks_different_split_under_bf16():
    """Acceptance: with a client memory budget that binds at fp32, the
    bf16 policy unlocks later splits and NSGA-II/TOPSIS (exhaustive
    ground truth) picks a different split index with a better memory
    objective."""
    p32 = cnn_profile("vgg16")
    p16 = p32.with_dtype("bf16")
    free = smartsplit_exhaustive(p32, PAPER_ENV_J6)
    mem_free = evaluate_objectives(p32, PAPER_ENV_J6)[free.split_index, 2]
    client = dataclasses.replace(PAPER_ENV_J6.client,
                                 memory_budget=mem_free * 0.5)
    hw = dataclasses.replace(PAPER_ENV_J6, client=client)
    s32 = smartsplit_exhaustive(p32, hw)
    s16 = smartsplit_exhaustive(p16, hw)
    assert feasible_mask(p16, hw).sum() > feasible_mask(p32, hw).sum()
    assert s16.split_index != s32.split_index
    assert s16.split_index > s32.split_index      # deeper on-device prefix
    assert s16.objectives[2] <= hw.client.memory_budget


# ---------------------------------------------------------------------------
# Bench smoke contract (keeps the CI bench gate honest)
# ---------------------------------------------------------------------------
def test_dtype_sweep_smoke_emits_artifact(tmp_path, monkeypatch):
    from benchmarks import common, kernels_bench
    monkeypatch.setattr(common, "OUT_DIR", str(tmp_path))
    rows = kernels_bench.dtype_sweep_report(smoke=True)
    assert any(name == "kernels.dtype_sweep.json" for name, _, _ in rows)
    import json
    with open(tmp_path / "BENCH_dtype_sweep_smoke.json") as f:
        payload = json.load(f)
    assert payload["smoke"] is True
    for e in payload["entries"]:
        assert e["vmem_per_tile_ratio"] >= 1.5
        assert e["max_abs_err_bf16"] < 2e-2
        assert {"tile_h", "launches", "vmem_bytes_per_tile"} \
            <= set(e["fp32"])
