"""Dry-run pipeline integration test on a small host-device mesh: exercises
param/batch/cache structs, lowering, compile, cost extraction and the
loop-cost extrapolation for one arch of each loop depth.  Subprocess with 8
devices; the production 512-device sweep runs via launch/dryrun.py."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax
    from repro.configs import all_configs
    from repro.configs.base import InputShape
    from repro.launch import dryrun as DR

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    ok = []
    for arch, seq in [("@A1@", 64), ("@A2@", 64), ("@A3@", 64)]:
        cfg0 = all_configs()[arch]
        cfg = dataclasses.replace(
            cfg0.reduced(), num_layers=4,
            attn_every=2 if cfg0.attn_every else 0, name=arch)
        shape = InputShape("t", seq, 8, "@MODE@")
        rec = DR.lower_cell(cfg, shape, mesh, "test-mesh")
        assert rec["cost"]["flops"] > 0
        assert rec["model_flops"] > 0
        # extrapolated totals exceed the raw scan-undercounted totals
        assert rec["cost"]["flops"] >= rec["cost_scan_raw"]["flops"] * 0.99
        ok.append(arch)
    print("DRYRUN_OK", ok)
""")


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.parametrize("mode", [
    # the train cell compiles backward passes for 3 archs (~2 min on CPU);
    # decode exercises the same lower/extrapolate pipeline in ~20 s
    pytest.param("train", marks=pytest.mark.slow),
    "decode",
])
def test_dryrun_cells_small_mesh(mode):
    """depth-1 (attn), depth-2 (rwkv), depth-3 (zamba) archs through the
    full lower/compile/extrapolate pipeline."""
    script = SCRIPT.replace("@A1@", "qwen3-4b") \
        .replace("@A2@", "rwkv6-7b").replace("@A3@", "zamba2-7b") \
        .replace("@MODE@", mode)
    out = _run(script)
    assert "DRYRUN_OK" in out


@pytest.mark.slow
def test_extrapolation_exactness_linear():
    """On a depth-1 arch the extrapolation must reproduce the true FLOPs of
    an unrolled model exactly: compile L=6 unrolled as ground truth and
    compare with extrapolation from L=2/L=4."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax
        from repro.configs import all_configs
        from repro.configs.base import InputShape
        from repro.launch import dryrun as DR

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = dataclasses.replace(all_configs()["phi3-mini-3.8b"].reduced(),
                                  num_layers=6, name="exact-test")
        shape = InputShape("t", 64, 8, "train")
        # ground truth: fully unrolled 6-layer model, no loops at all
        truth = DR._measure(cfg, shape, mesh, unroll_layers=True,
                            scan_unroll=1)["flops"]
        rec = DR.lower_cell(cfg, shape, mesh, "test-mesh")
        err = abs(rec["cost"]["flops"] - truth) / truth
        assert err < 0.02, (rec["cost"]["flops"], truth)
        print("EXACT_OK", err)
    """)
    out = _run(script)
    assert "EXACT_OK" in out
