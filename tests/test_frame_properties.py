"""Multipart framing edge cases: ``pack_frames`` / ``unpack_frames``.

The int8 boundary codec rides these for its (scales, data) payloads, so
the framing layer must be exact at the edges: zero-length parts, an
empty part tuple, label-count mismatches, and buffers truncated inside
a part header must all either round-trip bit-for-bit or raise a
``FrameError`` naming the damage -- never return partial bytes.

Deterministic cases run everywhere; the randomised round-trip and
truncation sweeps additionally run where ``hypothesis`` (dev-only dep)
is installed."""
import struct

import pytest

from repro.core.costs import MULTIPART_BASE_BYTES, PART_HEADER_BYTES
from repro.runtime import FrameError, pack_frames, unpack_frames

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Deterministic edge cases
# ---------------------------------------------------------------------------
def test_round_trip_basic():
    parts = (b"scales", b"data" * 100)
    assert unpack_frames(pack_frames(*parts)) == parts
    assert unpack_frames(pack_frames(*parts),
                         labels=("scales", "data")) == parts


def test_zero_length_parts_round_trip():
    # empty parts are legal payloads (e.g. a 0-element scales vector);
    # each still carries its own header + crc32 of b""
    for parts in ((b"",), (b"", b""), (b"", b"x", b"")):
        buf = pack_frames(*parts)
        assert len(buf) == MULTIPART_BASE_BYTES \
            + len(parts) * PART_HEADER_BYTES + sum(len(p) for p in parts)
        assert unpack_frames(buf) == parts


def test_empty_tuple_round_trips():
    # zero parts: just the 4-byte count header
    buf = pack_frames()
    assert len(buf) == MULTIPART_BASE_BYTES
    assert unpack_frames(buf) == ()
    # ...but any trailing garbage after "0 parts" is structural damage
    with pytest.raises(FrameError) as ei:
        unpack_frames(buf + b"\x00")
    assert ei.value.part == "header"


def test_label_count_mismatch_is_header_damage():
    buf = pack_frames(b"a", b"b")
    with pytest.raises(FrameError) as ei:
        unpack_frames(buf, labels=("only-one",))
    assert ei.value.part == "header"
    with pytest.raises(FrameError) as ei:
        unpack_frames(buf, labels=("x", "y", "z"))
    assert ei.value.part == "header"
    # no labels = no count check; extra parts get positional names
    assert unpack_frames(buf) == (b"a", b"b")


def test_truncation_inside_final_part_header():
    # cut the buffer mid-way through the LAST part's (length, crc) header:
    # the part count promises 2 parts but part 1's header is short
    buf = pack_frames(b"abc", b"defg")
    last_header_at = MULTIPART_BASE_BYTES + PART_HEADER_BYTES + 3
    for cut in range(last_header_at + 1,
                     last_header_at + PART_HEADER_BYTES):
        with pytest.raises(FrameError) as ei:
            unpack_frames(buf[:cut])
        assert ei.value.part == "header"


def test_truncation_inside_part_payload():
    buf = pack_frames(b"abc", b"defg")
    with pytest.raises(FrameError) as ei:
        unpack_frames(buf[:-1])     # last payload byte gone
    assert ei.value.part == "header"


def test_buffer_shorter_than_count_header():
    for n in range(MULTIPART_BASE_BYTES):
        with pytest.raises(FrameError) as ei:
            unpack_frames(b"\x01" * n)
        assert ei.value.part == "header"


def test_corrupt_part_is_attributed_by_label():
    buf = bytearray(pack_frames(b"scales-bytes", b"data-bytes"))
    buf[MULTIPART_BASE_BYTES + PART_HEADER_BYTES] ^= 0xFF  # part 0 payload
    with pytest.raises(FrameError) as ei:
        unpack_frames(bytes(buf), labels=("scales", "data"))
    assert ei.value.part == "scales"
    with pytest.raises(FrameError) as ei:
        unpack_frames(bytes(buf))
    assert ei.value.part == "part0"


def test_lying_part_count_is_header_damage():
    # inflate the count field past the real part list
    buf = bytearray(pack_frames(b"abc"))
    struct.pack_into("<I", buf, 0, 2)
    with pytest.raises(FrameError) as ei:
        unpack_frames(bytes(buf))
    assert ei.value.part == "header"


# ---------------------------------------------------------------------------
# Randomised sweeps (hypothesis, when available)
# ---------------------------------------------------------------------------
if HAS_HYPOTHESIS:
    parts_strategy = st.lists(st.binary(min_size=0, max_size=64),
                              min_size=0, max_size=5).map(tuple)

    @settings(max_examples=200, deadline=None)
    @given(parts=parts_strategy)
    def test_pack_unpack_round_trip_property(parts):
        assert unpack_frames(pack_frames(*parts)) == parts

    @settings(max_examples=200, deadline=None)
    @given(parts=st.lists(st.binary(min_size=0, max_size=32),
                          min_size=1, max_size=4).map(tuple),
           data=st.data())
    def test_any_truncation_raises_never_partial(parts, data):
        buf = pack_frames(*parts)
        cut = data.draw(st.integers(min_value=0, max_value=len(buf) - 1))
        with pytest.raises(FrameError):
            unpack_frames(buf[:cut])

    @settings(max_examples=200, deadline=None)
    @given(parts=st.lists(st.binary(min_size=1, max_size=32),
                          min_size=1, max_size=4).map(tuple),
           data=st.data())
    def test_any_single_byte_flip_is_caught(parts, data):
        buf = bytearray(pack_frames(*parts))
        pos = data.draw(st.integers(min_value=0, max_value=len(buf) - 1))
        buf[pos] ^= data.draw(st.integers(min_value=1, max_value=255))
        try:
            out = unpack_frames(bytes(buf))
        except FrameError:
            return                      # caught and attributed: good
        # a flip the checksums cannot see must still round-trip the
        # payload bytes exactly (possible only if it hit a crc field in
        # a way that... it can't: crc32 mismatches on any payload flip,
        # so an accepted buffer must equal the original parts)
        assert out == parts
