"""Pallas kernel validation (interpret mode on CPU): shape/dtype sweeps
asserting allclose against the pure-jnp oracles in kernels/ref.py, plus the
integration paths in kernels/ops.py (GQA wrapper, padding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.conv2d import conv2d
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba2_ssd import mamba2_ssd
from repro.kernels.rwkv6_wkv import rwkv6_wkv

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,sq,sk,hd,causal,bq,bk", [
    (2, 128, 128, 64, True, 64, 64),
    (1, 128, 128, 128, True, 128, 128),   # full-tile blocks, MXU head dim
    (2, 128, 256, 64, False, 64, 64),     # cross-attention style
    (1, 64, 256, 32, True, 64, 128),      # decode-ish: fewer q than k
    (2, 128, 128, 80, True, 64, 64),      # non-128 head dim (phi3's 96 kin)
    # original oversized variants: multi-q-block at hd=128, deeper decode
    # k-span, and non-power-of-two extents -- slow, not deleted
    pytest.param(1, 256, 256, 128, True, 128, 128,
                 marks=pytest.mark.slow),
    pytest.param(1, 64, 384, 32, True, 64, 128, marks=pytest.mark.slow),
    pytest.param(3, 192, 192, 80, True, 64, 64, marks=pytest.mark.slow),
])
def test_flash_attention_sweep(bh, sq, sk, hd, causal, bq, bk, dtype):
    q = (jax.random.normal(KEY, (bh, sq, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (bh, sk, hd))
         * 0.3).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(KEY, 2), (bh, sk, hd))
         * 0.3).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_gqa_wrapper_matches_layer_attention():
    """ops.flash_attention_gqa == the model's einsum attention (no cache)."""
    B, S, H, KV, hd = 2, 128, 8, 2, 64
    q = jax.random.normal(KEY, (B, S, H, hd)) * 0.3
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, KV, hd)) * 0.3
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KV, hd)) * 0.3
    out = ops.flash_attention_gqa(q, k, v, causal=True, block_q=64,
                                  block_k=64)
    # reference via repeat + dense attention
    g = H // KV
    kb = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vb = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    want = ref.attention_ref(qf, kb, vb, causal=True)
    want = want.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Conv2d
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,cin,cout,hw,k,stride,pad", [
    (1, 3, 16, 32, 3, 1, 1),
    (2, 8, 32, 28, 5, 1, 2),
    (1, 3, 64, 19, 11, 4, 2),     # AlexNet conv1 geometry (shrunk H/W:
                                  # parity is shape-independent, K=11 is
                                  # the expensive unrolled part)
    (2, 16, 16, 16, 1, 1, 0),     # pointwise
    (1, 4, 8, 20, 3, 2, 1),       # strided
])
def test_conv2d_sweep(n, cin, cout, hw, k, stride, pad, dtype):
    x = (jax.random.normal(KEY, (n, cin, hw, hw)) * 0.5).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(KEY, 1), (cout, cin, k, k))
         * 0.2).astype(dtype)
    out = conv2d(x, w, stride=stride, pad=pad, block_co=min(cout, 16))
    want = ref.conv2d_ref(x, w, stride=stride, pad=pad)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_conv2d_matches_cnn_layer():
    """Kernel == the model's lax conv on a real AlexNet layer shape."""
    from repro.models import cnn
    layer = cnn.ALEXNET[3]            # conv(192, 5, 1, 2)
    params = cnn.init_layer(jax.random.PRNGKey(0), layer, (64, 27, 27))
    x = jax.random.normal(KEY, (1, 64, 27, 27)) * 0.3
    want = cnn.apply_layer(layer, params, x)
    got = ops.conv2d(x, params["w"], stride=1, pad=2) \
        + params["b"][None, :, None, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Pallas execution-mode env: resolved at call time, not import time
# ---------------------------------------------------------------------------
def test_pallas_compile_env_resolved_at_call_time(monkeypatch):
    """Setting REPRO_PALLAS_COMPILE *after* import must change the mode the
    next kernel call requests (the old module-constant INTERPRET froze the
    value at import).  The spy forces interpret execution so the test runs
    on CPU while still observing what the wrapper asked for."""
    requested = []
    real = ops._conv.conv2d

    def spy(*args, **kw):
        requested.append(kw["interpret"])
        kw["interpret"] = True
        return real(*args, **kw)

    monkeypatch.setattr(ops._conv, "conv2d", spy)
    # distinctive shape so no earlier test's jit cache entry can absorb the
    # first (interpret=True) trace
    x = jax.random.normal(KEY, (1, 5, 9, 9)) * 0.3
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (7, 5, 3, 3)) * 0.2

    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    assert ops.interpret_mode() is True
    ops.conv2d(x, w, stride=1, pad=1)
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    assert ops.interpret_mode() is False
    ops.conv2d(x, w, stride=1, pad=1)   # same shapes: must still retrace
    # interpret is a static jit arg, so the compile-mode call cannot have
    # silently reused the interpret-mode executable
    assert requested == [True, False]


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,hd,bt", [
    (2, 128, 2, 32, 32),
    (1, 96, 4, 64, 32),
    (3, 64, 1, 16, 64),
])
def test_rwkv6_wkv_sweep(b, t, h, hd, bt, dtype):
    r = (jax.random.normal(KEY, (b, t, h, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h, hd))
         * 0.3).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, h, hd))
         * 0.3).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(KEY, 3),
                                          (b, t, h, hd))) * 0.5
         + 0.45).astype(dtype)
    u = (jax.random.normal(jax.random.fold_in(KEY, 4), (h, hd))
         * 0.1).astype(dtype)
    out = rwkv6_wkv(r, k, v, w, u, block_t=bt)
    want, _ = ref.rwkv6_wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_rwkv6_ops_padding():
    """T not a block multiple: ops pads with identity decay."""
    b, t, h, hd = 1, 50, 2, 16
    mk = lambda i: jax.random.normal(jax.random.fold_in(KEY, i),
                                     (b, t, h, hd)) * 0.3
    w = jax.nn.sigmoid(mk(3)) * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(KEY, 4), (h, hd)) * 0.1
    out = ops.rwkv6_wkv(mk(0), mk(1), mk(2), w, u, block_t=32)
    want, _ = ref.rwkv6_wkv_ref(mk(0), mk(1), mk(2), w, u)
    assert out.shape == (b, t, h, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,hp,ds,chunk", [
    (2, 128, 2, 16, 8, 32),
    (1, 64, 4, 32, 16, 64),
    (2, 96, 1, 64, 64, 32),       # zamba2-like head/state dims
])
def test_mamba2_ssd_sweep(b, t, h, hp, ds, chunk, dtype):
    x = (jax.random.normal(KEY, (b, t, h, hp)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    B = (jax.random.normal(jax.random.fold_in(KEY, 3), (b, t, h, ds))
         * 0.4).astype(dtype)
    C = (jax.random.normal(jax.random.fold_in(KEY, 4), (b, t, h, ds))
         * 0.4).astype(dtype)
    out = mamba2_ssd(x, dt.astype(dtype), A, B, C, chunk=chunk)
    want, _ = ref.mamba2_ssd_ref(x, dt, A, B, C)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_mamba2_layer_matches_kernel_path():
    """The model's chunked-jnp Mamba2 inner scan and the Pallas SSD kernel
    agree on the same (x, dt, A, B, C) inputs."""
    b, t, h, hp, ds = 1, 64, 2, 16, 8
    x = jax.random.normal(KEY, (b, t, h, hp)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1),
                                           (b, t, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(KEY, 3), (b, t, h, ds)) * 0.4
    C = jax.random.normal(jax.random.fold_in(KEY, 4), (b, t, h, ds)) * 0.4
    got = ops.mamba2_ssd(x, dt, A, B, C, chunk=32)
    want, _ = ref.mamba2_ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
