"""Spatially-tiled fused conv2d kernel: parity sweeps, VMEM planning, and
the model-layer shapes (AlexNet / VGG16 / MobileNetV2) the seed kernel
could not hold in VMEM.

Everything runs the kernel in interpret mode on CPU; tests on the full
224x224 model layers are marked ``slow`` (tier-1 runs ``-m "not slow"``,
see ROADMAP.md) but still pass under a plain ``pytest`` run."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.conv2d import (DEFAULT_VMEM_BUDGET, VMEM_LIMIT_BYTES,
                                  choose_tile_h, conv2d, conv_vmem_bytes,
                                  plan_conv, search_enabled, tile_w_override)
from repro.models import cnn

KEY = jax.random.PRNGKey(0)


def _inputs(n, cin, hw, cout, k, groups=1, scale=0.4):
    x = jax.random.normal(KEY, (n, cin, hw, hw)) * scale
    w = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (cout, cin // groups, k, k)) * 0.2
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (cout,)) * 0.1
    return x, w, b


# ---------------------------------------------------------------------------
# Parity sweep: stride x pad x K x groups (ISSUE-mandated grid)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("stride,pad,k,depthwise", [
    # full stride x pad product where the halo arithmetic interacts (K 3/5);
    # K=1 (no halo) and K=11 (costly unrolled trace) get corner spot checks
    *[(s, p, k, g) for s, p, k, g in itertools.product(
        (1, 2, 4), (0, 1, 2, 3), (3, 5), (False, True))],
    *[(s, p, 1, g) for s, p, g in itertools.product(
        (1, 2, 4), (0, 1), (False, True))],
    *[(s, p, 11, g) for s, p, g in itertools.product(
        (1, 4), (0, 2), (False, True))],
])
def test_conv2d_tiled_sweep(stride, pad, k, depthwise):
    cin = 8
    cout = cin if depthwise else 16
    groups = cin if depthwise else 1
    hw = 23
    if hw + 2 * pad < k:
        pytest.skip("kernel larger than padded input")
    x, w, b = _inputs(1, cin, hw, cout, k, groups)
    got = conv2d(x, w, stride=stride, pad=pad, bias=b, activation="relu",
                 groups=groups)
    want = ref.conv2d_ref(x, w, stride=stride, pad=pad, bias=b,
                          activation="relu", groups=groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("tile_h", [1, 3, 5, 7, 13])
def test_conv2d_remainder_tiles(tile_h):
    """h_out = 14 is not a multiple of most tile heights: the padded
    remainder tile must not leak into the sliced output."""
    x, w, b = _inputs(2, 6, 14, 12, 3)
    got = conv2d(x, w, stride=1, pad=1, bias=b, tile_h=tile_h)
    want = ref.conv2d_ref(x, w, stride=1, pad=1, bias=b)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_grouped_intermediate():
    """1 < groups < Cin (ResNeXt-style), group-aligned channel blocks."""
    x, w, b = _inputs(1, 16, 18, 32, 3, groups=4)
    got = conv2d(x, w, stride=2, pad=1, bias=b, groups=4)
    want = ref.conv2d_ref(x, w, stride=2, pad=1, bias=b, groups=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv2d_fused_epilogue_matches_unfused():
    """Fused bias+activation epilogue == unfused kernel + XLA epilogue."""
    x, w, b = _inputs(1, 8, 20, 16, 3)
    plain = conv2d(x, w, stride=1, pad=1)
    for act, fn in (("relu", jax.nn.relu),
                    ("relu6", lambda y: jnp.clip(y, 0.0, 6.0))):
        fused = conv2d(x, w, stride=1, pad=1, bias=b, activation=act)
        unfused = fn(plain + b[None, :, None, None])
        np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                                   rtol=1e-5, atol=1e-5)


def test_conv2d_rejects_unknown_activation():
    x, w, _ = _inputs(1, 4, 8, 4, 3)
    with pytest.raises(ValueError):
        conv2d(x, w, activation="gelu")


# ---------------------------------------------------------------------------
# VMEM budget estimator / tile planner
# ---------------------------------------------------------------------------
def test_choose_tile_h_fits_budget():
    kw = dict(cin_block=64, block_co=64, w_in=226, w_out=224, K=3, stride=1,
              cin_per_group=64)
    t = choose_tile_h(224, budget=DEFAULT_VMEM_BUDGET, **kw)
    assert 1 <= t <= 224
    assert conv_vmem_bytes(tile_h=t, **kw) <= DEFAULT_VMEM_BUDGET
    # one more output row per tile must overflow the budget at the
    # originally-selected maximum (before the waste-minimising shrink)
    n_blocks = -(-224 // t)
    if n_blocks > 1:
        t_prev = -(-224 // (n_blocks - 1))
        assert conv_vmem_bytes(tile_h=t_prev, **kw) > DEFAULT_VMEM_BUDGET


def test_plan_conv_rejects_kernel_larger_than_input():
    """K > padded H must name the geometry, not blame the VMEM budget."""
    with pytest.raises(ValueError, match="geometry"):
        plan_conv((1, 4, 3, 3), (8, 4, 5, 5), stride=1, pad=0)


def test_choose_tile_h_raises_when_one_row_too_big():
    with pytest.raises(ValueError):
        choose_tile_h(64, cin_block=4096, block_co=256, w_in=4096,
                      w_out=4096, K=3, stride=1, cin_per_group=4096,
                      budget=1 << 20)


def test_vmem_estimate_pooled_epilogue_terms():
    """With a fused maxpool the streamed output tile shrinks (pooled
    footprint) while the fp32 accumulator grows to span the conv rows
    feeding the pool windows -- both terms must show up in the estimate."""
    kw = dict(cin_block=64, block_co=64, w_in=114, w_out=112, K=3, stride=1,
              cin_per_group=64)
    unfused = conv_vmem_bytes(tile_h=8, **kw)
    fused = conv_vmem_bytes(tile_h=8, pool_k=2, pool_s=2, **kw)
    # 8 pooled rows need 16 conv rows: bigger input tile + accumulator ...
    assert fused > unfused
    # ... but per *conv row covered*, fusion is cheaper than two unfused
    # tiles of 8 rows, because the pooled output block is 4x smaller
    assert fused < 2 * unfused


def test_choose_tile_h_pool_aware():
    """Pooled tiling: the returned tile is in pooled rows, its estimate
    fits the budget, and the implied conv-row span stays pool-aligned."""
    kw = dict(cin_block=64, block_co=64, w_in=226, w_out=224, K=3, stride=1,
              cin_per_group=64, pool_k=2, pool_s=2)
    p_out = (224 - 2) // 2 + 1
    t = choose_tile_h(p_out, budget=DEFAULT_VMEM_BUDGET, **kw)
    assert 1 <= t <= p_out
    assert conv_vmem_bytes(tile_h=t, **kw) <= DEFAULT_VMEM_BUDGET
    plan = plan_conv((1, 64, 224, 224), (64, 64, 3, 3), stride=1, pad=1,
                     pool_k=2, pool_s=2)
    assert plan.tile_h == t and plan.p_out == p_out
    assert plan.tile_conv_h == (t - 1) * 2 + 2
    assert plan.tile_in_h == plan.tile_conv_h + 2   # K-1 halo rows


def test_vmem_estimate_monotone_in_tile_h():
    kw = dict(cin_block=32, block_co=32, w_in=100, w_out=98, K=3, stride=1,
              cin_per_group=32)
    est = [conv_vmem_bytes(tile_h=t, **kw) for t in range(1, 30)]
    assert all(a < b for a, b in zip(est, est[1:]))


def test_plan_conv_seed_buster_shape():
    """VGG16 conv2 (64ch @ 224x224): the shape the seed kernel could not
    stage -- whole-image staging needs ~26 MB; the plan must fit 16 MB."""
    whole_image = conv_vmem_bytes(cin_block=64, block_co=64, tile_h=224,
                                  w_in=226, w_out=224, K=3, stride=1,
                                  cin_per_group=64)
    assert whole_image > VMEM_LIMIT_BYTES
    plan = plan_conv((1, 64, 224, 224), (64, 64, 3, 3), stride=1, pad=1)
    assert plan.vmem_bytes <= DEFAULT_VMEM_BUDGET < VMEM_LIMIT_BYTES
    assert plan.n_h_blocks > 1


# ---------------------------------------------------------------------------
# Every conv layer shape of the paper's models
# ---------------------------------------------------------------------------
def _model_conv_shapes(name):
    """Unique (cin, hw, cout, k, stride, pad, groups, act) for every conv
    executed by the model, including the convs inside invres blocks."""
    layers = cnn.CNN_MODELS[name]
    shape = cnn.INPUT_SHAPE
    seen, out = set(), []
    for i, l in enumerate(layers):
        if l.kind == "conv":
            nxt = layers[i + 1].kind if i + 1 < len(layers) else ""
            act = nxt if nxt in ("relu", "relu6") else None
            spec = (shape[0], shape[1], l.cout, l.ksize, l.stride, l.pad,
                    1, act)
            if spec not in seen:
                seen.add(spec)
                out.append(spec)
        elif l.kind == "invres":
            cin, h, _ = shape
            hidden = cin * l.expand
            oh = (h + 2 - 3) // l.stride + 1
            for spec in ((cin, h, hidden, 1, 1, 0, 1, "relu6"),
                         (hidden, h, hidden, 3, l.stride, 1, hidden,
                          "relu6"),
                         (hidden, oh, l.cout, 1, 1, 0, 1, None)):
                if l.expand == 1 and spec[3] == 1 and spec[7] == "relu6":
                    continue        # no expand conv when t == 1
                if spec not in seen:
                    seen.add(spec)
                    out.append(spec)
        shape = cnn.layer_out_shape(l, shape)
    return out


def _shape_params():
    params = []
    for model in ("alexnet", "vgg16", "mobilenetv2"):
        for spec in _model_conv_shapes(model):
            cin, hw, cout, k, stride, pad, groups, act = spec
            macs = k * k * cin // groups * cout * hw * hw
            marks = [pytest.mark.slow] if macs > 2e8 else []
            params.append(pytest.param(
                model, spec, marks=marks,
                id=f"{model}-{cin}x{hw}-{cout}c{k}s{stride}g{groups}"))
    return params


@pytest.mark.parametrize("model,spec", _shape_params())
def test_model_layer_parity_and_vmem(model, spec):
    """Acceptance: the tiled kernel matches ref.conv2d_ref (atol 1e-4) on
    every conv layer of AlexNet/VGG16/MobileNetV2 with the per-tile VMEM
    estimate < 16 MB, and the fused conv+bias+act epilogue matches the
    unfused XLA sequence."""
    cin, hw, cout, k, stride, pad, groups, act = spec
    x, w, b = _inputs(1, cin, hw, cout, k, groups, scale=0.3)
    plan = plan_conv(x.shape, w.shape, stride=stride, pad=pad, groups=groups)
    assert plan.vmem_bytes < VMEM_LIMIT_BYTES, plan
    got = conv2d(x, w, stride=stride, pad=pad, bias=b, activation=act,
                 groups=groups)
    want = ref.conv2d_ref(x, w, stride=stride, pad=pad, bias=b,
                          activation=act, groups=groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Model-layer integration: backend switch + conv->relu fusion walk
# ---------------------------------------------------------------------------
_TINY = [cnn.conv(8, 3, 1, 1), cnn.relu(), cnn.maxpool(2, 2),
         cnn.conv(16, 3, 2, 1), cnn.relu6(),
         cnn.conv(16, 1, 1, 0),            # conv NOT followed by activation
         cnn.avgpool(2), cnn.linear(10)]
_TINY_IN = (3, 16, 16)


def test_backend_env_and_arg_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_CONV_BACKEND", raising=False)
    assert cnn.conv_backend() == "xla"
    monkeypatch.setenv("REPRO_CONV_BACKEND", "pallas")
    assert cnn.conv_backend() == "pallas"
    assert cnn.conv_backend("xla") == "xla"   # explicit arg wins
    monkeypatch.setenv("REPRO_CONV_BACKEND", "tpu-magic")
    with pytest.raises(ValueError):
        cnn.conv_backend()


def test_tiny_cnn_backends_agree():
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TINY, _TINY_IN)
    x = jax.random.normal(KEY, (2,) + _TINY_IN) * 0.5
    want = cnn.apply_cnn(_TINY, params, x, backend="xla")
    got = cnn.apply_cnn(_TINY, params, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("split", range(1, len(_TINY)))
def test_tiny_cnn_split_boundary_not_fused_across(split):
    """A split between a conv and its activation must hand the *pre-
    activation* payload across the link -- the fusion walk may only fuse
    pairs wholly on one side."""
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TINY, _TINY_IN)
    x = jax.random.normal(KEY, (1,) + _TINY_IN) * 0.5
    lx, bx = cnn.apply_split(_TINY, params, x, split, backend="xla")
    lp, bp = cnn.apply_split(_TINY, params, x, split, backend="pallas")
    np.testing.assert_allclose(np.asarray(bp), np.asarray(bx),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lx),
                               rtol=1e-4, atol=1e-4)


def test_env_var_routes_apply_cnn(monkeypatch):
    """REPRO_CONV_BACKEND=pallas changes the executed path (and agrees)."""
    params = cnn.init_cnn(jax.random.PRNGKey(3), _TINY, _TINY_IN)
    x = jax.random.normal(KEY, (1,) + _TINY_IN) * 0.5
    monkeypatch.delenv("REPRO_CONV_BACKEND", raising=False)
    want = cnn.apply_cnn(_TINY, params, x)
    monkeypatch.setenv("REPRO_CONV_BACKEND", "pallas")
    got = cnn.apply_cnn(_TINY, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Column (W-axis) tiling + the joint (block_co, tile_h, tile_w) search
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("tile_w", [1, 3, 5, 8, 14])
def test_conv2d_column_remainder_tiles(tile_w):
    """w_out = 14 is not a multiple of most tile widths: the padded
    remainder column tile must not leak into the sliced output."""
    x, w, b = _inputs(2, 6, 14, 12, 3)
    got = conv2d(x, w, stride=1, pad=1, bias=b, tile_h=5, tile_w=tile_w)
    want = ref.conv2d_ref(x, w, stride=1, pad=1, bias=b)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("pk,ps", [(2, 2), (3, 2)])
@pytest.mark.parametrize("tile_w", [1, 2, 3])
def test_pooled_column_tiles_land_on_window_starts(pk, ps, tile_w):
    """With a fused maxpool, tile_w counts *pooled* columns: consecutive
    column tiles must advance by whole pool windows (including the
    overlapping pk > ps case), matching the XLA reference exactly."""
    x, w, b = _inputs(2, 6, 17, 12, 3)
    got = conv2d(x, w, stride=1, pad=1, bias=b, activation="relu",
                 pool_k=pk, pool_s=ps, tile_h=2, tile_w=tile_w)
    y = ref.conv2d_ref(x, w, stride=1, pad=1, bias=b, activation="relu")
    want = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max,
                                 (1, 1, pk, pk), (1, 1, ps, ps), "VALID")
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_wide_row_greedy_raises_search_runs():
    """A row too wide for the budget: the legacy greedy planner must
    raise (the old 'W-axis tiling not implemented' wall) while the search
    splits columns, executes, and matches the reference.  A tiny budget
    stands in for the 12 MiB wall so the test stays fast -- the real
    full-budget strip shapes run in benchmarks/kernels_bench.py."""
    x, w, b = _inputs(1, 8, 12, 16, 3)
    x = jnp.concatenate([x] * 8, axis=3)            # 12 x 96 strip
    budget = 40 * 1024
    with pytest.raises(ValueError, match="single output row"):
        plan_conv(x.shape, w.shape, stride=1, pad=1, vmem_budget=budget,
                  search=False)
    plan = plan_conv(x.shape, w.shape, stride=1, pad=1, vmem_budget=budget)
    assert plan.searched and plan.n_w_blocks > 1
    assert plan.vmem_bytes <= budget
    got = conv2d(x, w, stride=1, pad=1, bias=b, activation="relu",
                 vmem_budget=budget)
    want = ref.conv2d_ref(x, w, stride=1, pad=1, bias=b, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_search_launches_never_exceed_greedy_on_paper_shapes():
    """Acceptance: on every AlexNet/VGG16 conv shape (fp32 and bf16) the
    joint search needs <= the greedy planner's launches, with a strict
    reduction on at least two VGG16 layers (planning only, so the full
    sweep stays in tier-1)."""
    from benchmarks.kernels_bench import model_conv_specs
    strict_vgg16 = 0
    for model in ("alexnet", "vgg16"):
        for name, cin, hw, cout, k, s, p, act, pk, ps in \
                model_conv_specs(model):
            for nbytes in (4, 2):
                args = dict(stride=s, pad=p, pool_k=pk, pool_s=ps,
                            dtype_bytes=nbytes)
                greedy = plan_conv((1, cin, hw, hw), (cout, cin, k, k),
                                   search=False, **args)
                searched = plan_conv((1, cin, hw, hw), (cout, cin, k, k),
                                     search=True, **args)
                assert searched.launches <= greedy.launches, (name, nbytes)
                assert searched.vmem_bytes <= DEFAULT_VMEM_BUDGET
                if model == "vgg16" and nbytes == 4 \
                        and searched.launches < greedy.launches:
                    strict_vgg16 += 1
    assert strict_vgg16 >= 2


def test_search_cost_at_most_greedy_cost():
    """The greedy point is in the search space, so the searched plan's
    cost-model bytes can never exceed greedy's."""
    for shape, wshape, kw in [
            ((1, 64, 224, 224), (64, 64, 3, 3), dict(stride=1, pad=1)),
            ((1, 64, 27, 27), (192, 64, 5, 5),
             dict(stride=1, pad=2, pool_k=3, pool_s=2)),
            ((2, 16, 33, 65), (48, 16, 3, 3), dict(stride=2, pad=1))]:
        g = plan_conv(shape, wshape, search=False, **kw)
        s = plan_conv(shape, wshape, search=True, **kw)
        assert s.cost_bytes <= g.cost_bytes


def test_choose_tile_h_bisection_matches_linear_scan():
    """The bisected max-fit tile must equal the legacy O(512) downward
    scan's result (the estimate is monotone, so both find the largest
    fitting tile, then apply the same waste-minimising shrink)."""
    for budget in (DEFAULT_VMEM_BUDGET, 4 * 1024 * 1024, 2 * 1024 * 1024):
        for pool in ((0, 1), (2, 2), (3, 2)):
            kw = dict(cin_block=64, block_co=64, w_in=226, w_out=224, K=3,
                      stride=1, cin_per_group=64, pool_k=pool[0],
                      pool_s=pool[1])
            h_out = 224 if not pool[0] else (224 - pool[0]) // pool[1] + 1
            got = choose_tile_h(h_out, budget=budget, **kw)
            scan = next((t for t in range(min(h_out, 512), 0, -1)
                         if conv_vmem_bytes(tile_h=t, **kw) <= budget), 0)
            assert scan, "budget too small for the linear-scan oracle"
            n_blocks = -(-h_out // scan)
            assert got == -(-h_out // n_blocks)


def test_plan_env_knobs(monkeypatch):
    """REPRO_CONV_SEARCH=0 reproduces the greedy plan; REPRO_CONV_TILE_W
    pins the column tile; malformed values raise with the var named."""
    shape, wshape = (1, 64, 56, 56), (256, 64, 3, 3)
    monkeypatch.delenv("REPRO_CONV_SEARCH", raising=False)
    monkeypatch.delenv("REPRO_CONV_TILE_W", raising=False)
    assert search_enabled() and tile_w_override() == 0
    default = plan_conv(shape, wshape, stride=1, pad=1)
    assert default.searched
    monkeypatch.setenv("REPRO_CONV_SEARCH", "0")
    greedy_env = plan_conv(shape, wshape, stride=1, pad=1)
    assert greedy_env == plan_conv(shape, wshape, stride=1, pad=1,
                                   search=False)
    assert not greedy_env.searched
    assert plan_conv(shape, wshape, stride=1, pad=1,
                     search=True).searched    # explicit arg beats env
    monkeypatch.delenv("REPRO_CONV_SEARCH", raising=False)
    monkeypatch.setenv("REPRO_CONV_TILE_W", "14")
    pinned = plan_conv(shape, wshape, stride=1, pad=1)
    assert pinned.tile_w == 14 and pinned.n_w_blocks == 4
    assert plan_conv(shape, wshape, stride=1, pad=1,
                     tile_w=28).tile_w == 28  # explicit arg beats env
    monkeypatch.setenv("REPRO_CONV_SEARCH", "maybe")
    with pytest.raises(ValueError, match="REPRO_CONV_SEARCH"):
        plan_conv(shape, wshape, stride=1, pad=1)
    monkeypatch.delenv("REPRO_CONV_SEARCH", raising=False)
    monkeypatch.setenv("REPRO_CONV_TILE_W", "wide")
    with pytest.raises(ValueError, match="REPRO_CONV_TILE_W"):
        plan_conv(shape, wshape, stride=1, pad=1)


def test_env_tile_w_routes_through_ops(monkeypatch):
    """The ops-layer jit must not serve a stale grid when the env knobs
    flip between calls: pin a column tile via REPRO_CONV_TILE_W and check
    the executed kernel still matches the reference."""
    x, w, b = _inputs(1, 6, 20, 8, 3)
    from repro.kernels import ops
    want = ref.conv2d_ref(x, w, stride=1, pad=1, bias=b, activation="relu")
    monkeypatch.setenv("REPRO_CONV_TILE_W", "7")
    got = ops.conv2d(x, w, stride=1, pad=1, bias=b, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    monkeypatch.setenv("REPRO_CONV_SEARCH", "0")
    monkeypatch.delenv("REPRO_CONV_TILE_W", raising=False)
    got = ops.conv2d(x, w, stride=1, pad=1, bias=b, activation="relu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_conv_plans_matches_fusion_walk_geometry():
    """cnn.conv_plans plans each conv exactly as the pallas walk launches
    it: triple-heading convs carry their fused pool window, and the plan
    matches a direct plan_conv call with the same geometry."""
    layers = cnn.CNN_MODELS["alexnet"]
    plans = dict(cnn.conv_plans(layers))
    triples = {t[0]: t for t in cnn.conv_pool_triples(layers)}
    shape = cnn.INPUT_SHAPE
    n_convs = 0
    for i, l in enumerate(layers):
        if l.kind == "conv":
            n_convs += 1
            plan = plans[i]
            pk = triples[i][-2] if i in triples else 0
            assert plan.pool_k == pk
            want = plan_conv((1,) + shape,
                             (l.cout, shape[0], l.ksize, l.ksize),
                             stride=l.stride, pad=l.pad, pool_k=pk,
                             pool_s=triples[i][-1] if i in triples else 0)
            assert plan == want
        shape = cnn.layer_out_shape(l, shape)
    assert len(plans) == n_convs
    # dtype plumbing: bf16 plans never need more launches
    plans16 = dict(cnn.conv_plans(layers, dtype="bf16"))
    assert all(plans16[i].launches <= plans[i].launches for i in plans)


@pytest.mark.slow
@pytest.mark.parametrize("name,cin,H,W,cout,k,s,p,pk,ps", [
    ("strip7680", 64, 16, 7680, 64, 3, 1, 1, 0, 0),
    ("strip6144_pool", 64, 17, 6144, 64, 3, 1, 1, 2, 2),
])
def test_wide_strip_full_budget_parity(name, cin, H, W, cout, k, s, p,
                                       pk, ps):
    """Acceptance: panoramic strips whose single output row overflows the
    default 12 MiB budget (ValueError on main) run on the pallas backend
    and match ref.conv2d_ref at the established tolerances."""
    x = jax.random.normal(KEY, (1, cin, H, W)) * 0.3
    w = jax.random.normal(jax.random.fold_in(KEY, 1),
                          (cout, cin, k, k)) * 0.2
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (cout,)) * 0.1
    with pytest.raises(ValueError, match="single output row"):
        plan_conv(x.shape, w.shape, stride=s, pad=p, pool_k=pk, pool_s=ps,
                  search=False)
    plan = plan_conv(x.shape, w.shape, stride=s, pad=p, pool_k=pk,
                     pool_s=ps)
    assert plan.n_w_blocks > 1
    assert plan.vmem_bytes <= DEFAULT_VMEM_BUDGET
    got = conv2d(x, w, stride=s, pad=p, bias=b, activation="relu",
                 pool_k=pk, pool_s=ps)
    want = ref.conv2d_ref(x, w, stride=s, pad=p, bias=b, activation="relu")
    if pk:
        want = jax.lax.reduce_window(want, -jnp.inf, jax.lax.max,
                                     (1, 1, pk, pk), (1, 1, ps, ps),
                                     "VALID")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@pytest.mark.parametrize("model", ["alexnet", "mobilenetv2"])
def test_end_to_end_backend_parity_224(model):
    """Acceptance: full 224x224 batch-1 forward, pallas vs xla to 1e-3."""
    layers = cnn.CNN_MODELS[model]
    params = cnn.init_cnn(jax.random.PRNGKey(1), layers)
    x = jax.random.normal(KEY, (1,) + cnn.INPUT_SHAPE) * 0.5
    want = cnn.apply_cnn(layers, params, x, backend="xla")
    got = cnn.apply_cnn(layers, params, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
