"""Hypothesis property tests for the int8 boundary quantization codec.

Headline invariants, for ANY float tensor:

* dequantize(quantize(x)) is within half a quantization step of x, per
  channel (the symmetric-absmax error bound the cost model's accuracy
  story rests on);
* values already on a channel's quantization grid survive the round trip
  exactly;
* all-zero channels are safe (scale 1.0, exact zeros back);
* float wire formats (fp32, and bf16 on bf16-stored tensors) round-trip
  bit-identically -- the wire tier is invisible unless it re-encodes.

Kept separate from tests/test_wire_quant.py so environments without
``hypothesis`` (dev-only dependency) still run the deterministic suite."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.quant import (boundary_roundtrip,  # noqa: E402
                                 dequantize_jnp, quantize_jnp)

# Bounded, finite floats: the boundary activations the codec ever sees
# (post conv/relu/pool), not inf/nan adversaria.
ELEMS = st.floats(min_value=-1e4, max_value=1e4, width=32)


def _tensors(min_c=1, max_c=6, max_n=8):
    """(C, N) float32 arrays: channel-major boundary slabs."""
    return st.tuples(
        st.integers(min_c, max_c), st.integers(1, max_n)).flatmap(
        lambda cn: st.lists(
            st.lists(ELEMS, min_size=cn[1], max_size=cn[1]),
            min_size=cn[0], max_size=cn[0])).map(
        lambda rows: np.asarray(rows, np.float32))


@settings(max_examples=60, deadline=None)
@given(_tensors())
def test_roundtrip_error_within_half_step(x):
    xj = jnp.asarray(x)[None]                      # (1, C, N): channel axis 1
    q, scales = quantize_jnp(xj, axis=1)
    y = np.asarray(dequantize_jnp(q, scales, axis=1))[0]
    s = np.asarray(scales)
    # |dequant - x| <= scale/2 per channel (+ float slack)
    err = np.abs(y - x).max(axis=1)
    assert np.all(err <= s / 2 + 1e-4 * np.maximum(s, 1.0))


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_grid_values_survive_exactly(c, n, seed):
    # a tensor already on the quantization grid: k * scale with |k| <= 127
    # and one k = 127 per channel, so the recomputed absmax/127 recovers
    # the scale (up to 1 ulp) and every point rounds back to its own k
    rng = np.random.default_rng(seed)
    scales = np.exp(rng.uniform(-6, 6, size=c)).astype(np.float32)
    k = rng.integers(-127, 128, size=(c, n)).astype(np.float32)
    k[:, 0] = 127.0
    grid = (k * scales[:, None]).astype(np.float32)
    y = np.asarray(boundary_roundtrip(jnp.asarray(grid)[None], "int8"))[0]
    np.testing.assert_allclose(y, grid, rtol=1e-5, atol=0)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 16))
def test_zero_channels_are_safe(c, n):
    x = jnp.zeros((1, c, n), jnp.float32)
    q, scales = quantize_jnp(x, axis=1)
    np.testing.assert_array_equal(np.asarray(scales), np.ones(c))
    np.testing.assert_array_equal(
        np.asarray(dequantize_jnp(q, scales, axis=1)), 0.0)


@settings(max_examples=60, deadline=None)
@given(_tensors())
def test_float_wire_roundtrips_bit_identical(x):
    xj = jnp.asarray(x)[None]
    np.testing.assert_array_equal(
        np.asarray(boundary_roundtrip(xj, "fp32")), np.asarray(xj))
    xb = xj.astype(jnp.bfloat16)
    got = boundary_roundtrip(xb, "bf16")
    np.testing.assert_array_equal(
        np.asarray(got.astype(jnp.float32)),
        np.asarray(xb.astype(jnp.float32)))
