"""Serving-engine accounting: token counts and monotonic latency stats.

Pins the two satellite fixes: (1) ``stats["tokens"]`` counts the
prefill-sampled first token (previously it drifted from
``sum(len(r.output))`` by one per request), and (2) request timing uses
``time.perf_counter()`` (monotonic) with p50/p99 surfaced in
``Engine.stats``."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_configs
from repro.models import transformer as T
from repro.serving.engine import Engine


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(all_configs()["qwen3-4b"].reduced(),
                              vocab_size=128, name="stats-test")
    params = T.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def test_tokens_stat_matches_outputs_exactly(served):
    """tokens == sum(len(r.output)) -- the prefill-sampled first token is
    output and must be counted."""
    cfg, params = served
    eng = Engine(cfg, params, max_len=64, max_batch=4)
    reqs = [eng.submit(list(range(1, 1 + n)), max_new_tokens=m)
            for n, m in ((5, 3), (5, 1), (9, 4), (9, 2), (5, 3))]
    eng.run_until_idle()
    assert all(r.done for r in reqs)
    produced = sum(len(r.output) for r in reqs)
    assert eng.stats["tokens"] == produced
    # max_new_tokens=1 is the pure-prefill edge: exactly one token, and
    # it is counted
    assert len(reqs[1].output) == 1


def test_request_timing_is_perf_counter_based(served):
    """enqueue/finish stamps come from the perf_counter timeline (not the
    epoch): both sit inside a perf_counter bracket around the run, and
    per-request latency is non-negative."""
    cfg, params = served
    eng = Engine(cfg, params, max_len=48, max_batch=2)
    t_before = time.perf_counter()
    req = eng.submit(list(range(1, 7)), max_new_tokens=2)
    eng.run_until_idle()
    t_after = time.perf_counter()
    assert t_before <= req.enqueue_t <= req.finish_t <= t_after
    # epoch seconds (time.time()) are ~1.7e9; perf_counter is not
    assert req.enqueue_t < 1e9


def test_latency_percentiles_surfaced(served):
    cfg, params = served
    eng = Engine(cfg, params, max_len=64, max_batch=2)
    reqs = [eng.submit(list(range(1, 6)), max_new_tokens=2)
            for _ in range(5)]
    eng.run_until_idle()
    assert all(r.done for r in reqs)
    p50 = eng.stats["latency_p50_s"]
    p99 = eng.stats["latency_p99_s"]
    assert 0.0 < p50 <= p99
    # every individual latency is bounded by the stats' sample
    lats = [r.finish_t - r.enqueue_t for r in reqs]
    assert p99 <= max(lats) + 1e-9
