"""Expert-parallel MoE (models/moe_ep.py): exactness vs the baseline
dispatch, gradient agreement, and fallback behaviour.  Runs in a
subprocess (needs 8 host devices)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import all_configs
    from repro.models import layers as L
    from repro.models import moe_ep

    cfg = dataclasses.replace(all_configs()["granite-moe-3b-a800m"].reduced(),
                              moe_capacity_factor=8.0)
    params = L.init_moe_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32) * 0.5
    y_base, aux_base = jax.jit(lambda p, x: L.moe(cfg, p, x))(params, x)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    moe_ep.EP_MESH = mesh
    assert moe_ep.ep_enabled(cfg, x.shape)
    y_ep, aux_ep = jax.jit(lambda p, x: L.moe(cfg, p, x))(params, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_base),
                               rtol=1e-5, atol=1e-5)
    # aux: per-shard estimate of the balance loss (documented approximation)
    assert abs(float(aux_ep) - float(aux_base)) < 0.05

    def loss(p, x):
        y, _ = L.moe(cfg, p, x)
        return (y ** 2).sum()
    g_ep = jax.jit(jax.grad(loss))(params, x)
    moe_ep.EP_MESH = None
    g_base = jax.grad(loss)(params, x)
    for a, b in zip(jax.tree.leaves(g_base), jax.tree.leaves(g_ep)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)

    # fallback: token count not divisible by the model axis -> baseline path
    moe_ep.EP_MESH = mesh
    assert not moe_ep.ep_enabled(cfg, (2, 3, cfg.d_model))
    # capacity drops under EP stay bounded with default cf
    cfg2 = dataclasses.replace(cfg, moe_capacity_factor=1.25)
    y2, _ = jax.jit(lambda p, x: L.moe(cfg2, p, x))(params, x)
    assert bool(jnp.isfinite(y2).all())
    moe_ep.EP_MESH = None
    print("MOE_EP_OK")
""")


def test_moe_ep_exact_and_grads():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MOE_EP_OK" in out.stdout
