"""Shared benchmark utilities: timing, CSV rows, output locations."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def ensure_out(sub: str = "") -> str:
    d = os.path.join(OUT_DIR, sub) if sub else OUT_DIR
    os.makedirs(d, exist_ok=True)
    return d


def time_us(fn: Callable[[], object], *, repeats: int = 5,
            warmup: int = 1) -> float:
    """Median wall-time of fn() in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def emit(rows: list[tuple], header: bool = False) -> None:
    """Print ``name,us_per_call,derived`` CSV rows (the harness contract)."""
    if header:
        print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")


def save_json(sub: str, name: str, obj) -> str:
    d = ensure_out(sub)
    path = os.path.join(d, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=str)
    return path
