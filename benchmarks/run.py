"""Benchmark orchestrator. One section per paper table/figure plus the
beyond-paper roofline/kernel/TPU-split reports.

Prints ``name,us_per_call,derived`` CSV (the harness contract); full
artefacts are written to benchmarks/out/.

Usage: ``python benchmarks/run.py [section] [--smoke]``.  ``--smoke`` runs
one tiny shape per kernel family in interpret mode (seconds, not minutes)
so CI can gate the bench path itself; sections without a smoke variant are
skipped in that mode.
"""
from __future__ import annotations

import inspect
import sys

from benchmarks.common import emit


def main() -> None:
    argv = sys.argv[1:]
    smoke = "--smoke" in argv
    argv = [a for a in argv if a != "--smoke"]
    only = argv[0] if argv else None
    sections = {}

    from benchmarks import paper_tables
    sections["paper"] = paper_tables.run_all

    try:
        from benchmarks import kernels_bench
        sections["kernels"] = kernels_bench.run_all
    except ImportError:
        pass
    try:
        from benchmarks import roofline_report
        sections["roofline"] = roofline_report.run_all
    except ImportError:
        pass
    try:
        from benchmarks import tpu_split
        sections["tpu_split"] = tpu_split.run_all
    except ImportError:
        pass
    try:
        from benchmarks import multicut_bench
        sections["multicut"] = multicut_bench.run_all
    except ImportError:
        pass
    try:
        from benchmarks import robustness_bench
        sections["robustness"] = robustness_bench.run_all
    except ImportError:
        pass
    try:
        from benchmarks import boundary_quant_bench
        sections["boundary_quant"] = boundary_quant_bench.run_all
    except ImportError:
        pass
    try:
        from benchmarks import serving_bench
        sections["serving"] = serving_bench.run_all
    except ImportError:
        pass
    try:
        from benchmarks import tier_faults_bench
        sections["tier_faults"] = tier_faults_bench.run_all
    except ImportError:
        pass

    emit([], header=True)
    ran = []
    for name, fn in sections.items():
        if only and name != only:
            continue
        has_smoke = "smoke" in inspect.signature(fn).parameters
        if smoke:
            if has_smoke:
                emit(fn(smoke=True))
                ran.append(name)
            continue
        emit(fn())
        ran.append(name)

    if "kernels" in ran:
        # headline artifact: aggregate this run's kernel JSONs into the
        # one canonical series (BENCH_kernel_summary{_smoke}.json) the
        # perf trajectory tracks across PRs
        from benchmarks import kernels_bench
        emit(kernels_bench.kernel_summary_report(smoke=smoke))


if __name__ == "__main__":
    main()
