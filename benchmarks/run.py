"""Benchmark orchestrator. One section per paper table/figure plus the
beyond-paper roofline/kernel/TPU-split reports.

Prints ``name,us_per_call,derived`` CSV (the harness contract); full
artefacts are written to benchmarks/out/."""
from __future__ import annotations

import sys

from benchmarks.common import emit


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    sections = {}

    from benchmarks import paper_tables
    sections["paper"] = paper_tables.run_all

    try:
        from benchmarks import kernels_bench
        sections["kernels"] = kernels_bench.run_all
    except ImportError:
        pass
    try:
        from benchmarks import roofline_report
        sections["roofline"] = roofline_report.run_all
    except ImportError:
        pass
    try:
        from benchmarks import tpu_split
        sections["tpu_split"] = tpu_split.run_all
    except ImportError:
        pass
    try:
        from benchmarks import multicut_bench
        sections["multicut"] = multicut_bench.run_all
    except ImportError:
        pass

    emit([], header=True)
    for name, fn in sections.items():
        if only and name != only:
            continue
        emit(fn())


if __name__ == "__main__":
    main()
