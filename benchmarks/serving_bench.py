"""Serving throughput: the CNN split-serving engine under offered load.

Drives ``serving.cnn_engine.CnnServingEngine`` with Poisson-ish request
streams (deterministic seeded arrivals) at several offered loads and
measures, on the virtual clock:

* requests/sec and p50/p99 end-to-end latency, **pipelined vs
  sequential** execution -- the headline: cross-request pipelining keeps
  client, link, and server tiers concurrently busy, so throughput rises
  well before latency does;
* the same pair under a 30%-drop fault profile -- throughput under
  chaos, riding the runtime's retry/merge/re-pick ladder;
* a bit-identity audit on the fault-free cells: every served request's
  logits must equal ``apply_split`` of that sample alone (the engine's
  one-request-one-microbatch contract).

Writes ``BENCH_serving.json`` (``BENCH_serving_smoke.json`` with
``--smoke``) to benchmarks/out/ and prints the harness CSV rows.
Virtual-clock timing means the numbers are schedules, not machine noise
-- stable across hosts.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json, time_us
from repro.core.hardware import paper_chain
from repro.models import cnn as cnn_lib
from repro.models.cnn import apply_split
from repro.runtime.faults import FaultSpec, FaultyLink, VirtualClock
from repro.runtime.transfer import RetryPolicy
from repro.serving.cnn_engine import CnnServingEngine

MODEL = "alexnet"
# Per-hop wire times on paper_chain(3) are ~ms; the default 5 s timeout
# would make every 30%-drop retry catastrophic.  Budget ~5 attempts
# with a timeout that caps a lost attempt at a few wire times.
POLICY = RetryPolicy(max_attempts=5, timeout_s=0.25, backoff_base_s=0.01)
IN_SHAPE = (3, 64, 64)
TIERS = 3
DROP_RATE = 0.3
# offered load as a multiple of one batch-4 request's service rate
LOADS = (0.5, 1.0, 2.0)
LOADS_SMOKE = (1.0,)
N_REQUESTS = 64
N_REQUESTS_SMOKE = 16


def _params():
    layers = cnn_lib.CNN_MODELS[MODEL]
    return layers, cnn_lib.init_cnn(jax.random.PRNGKey(0), layers,
                                    in_shape=IN_SHAPE)


def _links(drop: float, seed: int = 0) -> list[FaultyLink]:
    hw = paper_chain(TIERS)
    clock = VirtualClock()
    faults = FaultSpec(drop_rate=drop) if drop else FaultSpec()
    return [FaultyLink(link.bandwidth, faults=faults, seed=seed + k,
                       clock=clock)
            for k, link in enumerate(hw.links)]


def _service_rate(params) -> float:
    """Served requests/sec of one isolated batch-4 pipelined pass --
    the normalizer that turns LOADS into arrival rates."""
    layers, p = params
    eng = CnnServingEngine({MODEL: (layers, p)}, hw=paper_chain(TIERS),
                           max_batch=4, pipelined=True, policy=POLICY)
    rng = np.random.default_rng(7)
    for _ in range(4):
        eng.submit(rng.normal(size=IN_SHAPE).astype(np.float32), at=0.0)
    eng.run_until_idle()
    return eng.stats()["requests_per_s"]


def _drive(params, *, pipelined: bool, drop: float, rate: float,
           n_requests: int, seed: int = 0) -> dict:
    layers, p = params
    eng = CnnServingEngine(
        {MODEL: (layers, p)}, hw=paper_chain(TIERS), max_batch=4,
        max_queue=max(64, n_requests), pipelined=pipelined,
        links=_links(drop, seed=seed), policy=POLICY, jitter_seed=seed)
    rng = np.random.default_rng(seed)
    # exponential inter-arrivals at the offered rate (seeded: the same
    # stream hits the pipelined and sequential engines)
    t = 0.0
    arrivals = []
    for _ in range(n_requests):
        t += rng.exponential(1.0 / rate)
        arrivals.append(t)
    xs = [rng.normal(size=IN_SHAPE).astype(np.float32)
          for _ in range(n_requests)]
    reqs = [eng.submit(x, at=a) for x, a in zip(xs, arrivals)]
    eng.run_until_idle()
    s = eng.stats()
    return {"stats": s, "requests": reqs, "samples": xs, "engine": eng}


def _bit_identity(run: dict) -> bool:
    """Every served request's logits == apply_split of that sample alone
    at the engine's chosen first cut (fault-free path only)."""
    eng = run["engine"]
    layers, p = next(iter(eng._models.values()))
    ok = True
    for req, x in zip(run["requests"], run["samples"]):
        if req.status != "served":
            continue
        cuts = eng._buckets[req.bucket].rt.plan.cuts
        ref, _ = apply_split(layers, p, x[None], cuts[0] if cuts else 0)
        ok = ok and bool(jnp.array_equal(req.logits, ref[0]))
    return ok


def run_all(smoke: bool = False) -> list[tuple]:
    loads = LOADS_SMOKE if smoke else LOADS
    n_req = N_REQUESTS_SMOKE if smoke else N_REQUESTS
    params = _params()
    base_rate = _service_rate(params)
    cells = []

    def build():
        for profile, drop in (("clean", 0.0), ("drop30", DROP_RATE)):
            for load in loads:
                rate = base_rate * load
                pair = {}
                for mode, pipelined in (("pipelined", True),
                                        ("sequential", False)):
                    run = _drive(params, pipelined=pipelined, drop=drop,
                                 rate=rate, n_requests=n_req)
                    s = run["stats"]
                    pair[mode] = {
                        "requests_per_s": s["requests_per_s"],
                        "latency_p50_s": s["latency_p50_s"],
                        "latency_p99_s": s["latency_p99_s"],
                        "served": s["served"],
                        "failed": s["failed"],
                        "queue_shed": s["queue_shed"],
                        "deadline_pre_dispatch":
                            s["deadline_pre_dispatch"],
                        "deadline_mid_flight": s["deadline_mid_flight"],
                        "batches": s["batches"],
                        "avg_batch_size": s["avg_batch_size"],
                        "repicks": s["repicks"],
                        "merges": s["merges"],
                        "hop_goodput_Bps": [h["goodput_Bps"]
                                            for h in s["hops"]],
                    }
                    if drop == 0.0 and pipelined:
                        # the serving path's contract; the sequential
                        # baseline fuses batches (different last-ulp)
                        pair[mode]["bit_identical"] = _bit_identity(run)
                seq_rps = pair["sequential"]["requests_per_s"]
                cells.append({
                    "model": MODEL, "tiers": TIERS, "profile": profile,
                    "offered_load": load, "offered_rate_rps": rate,
                    "n_requests": n_req,
                    "pipelined": pair["pipelined"],
                    "sequential": pair["sequential"],
                    "pipeline_speedup":
                        pair["pipelined"]["requests_per_s"] / seq_rps
                        if seq_rps > 0 else float("inf"),
                })

    us = time_us(build, repeats=1, warmup=0)
    out = {"model": MODEL, "in_shape": list(IN_SHAPE), "tiers": TIERS,
           "max_batch": 4, "base_service_rate_rps": base_rate,
           "drop_rate": DROP_RATE, "cells": cells}
    name = "BENCH_serving_smoke.json" if smoke else "BENCH_serving.json"
    path = save_json("", name, out)
    rows = []
    for c in cells:
        pi = c["pipelined"]
        derived = (f"rps={pi['requests_per_s']:.1f}"
                   f" p50={pi['latency_p50_s']:.4f}s"
                   f" p99={pi['latency_p99_s']:.4f}s"
                   f" speedup={c['pipeline_speedup']:.2f}x"
                   f" served={pi['served']}/{c['n_requests']}")
        if "bit_identical" in pi:
            derived += f" bitid={pi['bit_identical']}"
        if c["profile"] != "clean":
            derived += f" repicks={pi['repicks']} merges={pi['merges']}"
        rows.append((
            f"serving/{c['model']}.chain{c['tiers']}.{c['profile']}"
            f".load{c['offered_load']:g}",
            round(pi["latency_p50_s"] * 1e6, 1), derived))
    clean = [c for c in cells if c["profile"] == "clean"]
    min_speedup = min(c["pipeline_speedup"] for c in clean)
    bit_ok = all(c["pipelined"].get("bit_identical") for c in clean)
    rows.append((f"serving/summary[{len(cells)}cells]", round(us, 1),
                 f"min_clean_speedup={min_speedup:.2f}x"
                 f" bitid={bit_ok} -> {path}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    from benchmarks.common import emit
    emit([], header=True)
    emit(run_all(smoke=args.smoke))


if __name__ == "__main__":
    sys.exit(main())
