"""Roofline report (deliverable g): reads the dry-run JSON artefacts and
emits the three-term roofline per (arch x shape x mesh), the dominant
bottleneck, and the useful-FLOPs ratio.  Also prints the formatted table
consumed by EXPERIMENTS.md section Roofline."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_json
from repro.analysis.roofline import Roofline, format_table, from_record

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "out", "dryrun")


def load_records(mesh: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "skipped" in rec or "error" in rec:
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        recs.append(rec)
    return recs


def rooflines(mesh: str = "single16x16") -> list[Roofline]:
    return [from_record(r) for r in load_records(mesh)]


def run_all() -> list[tuple]:
    rows = []
    table = []
    for mesh in ("single16x16", "multi2x16x16"):
        rls = rooflines(mesh)
        for r in rls:
            key = f"roofline.{mesh}.{r.arch}.{r.shape}"
            rows.append((f"{key}.bound_s", None, f"{r.bound_s:.5f}"))
            rows.append((f"{key}.dominant", None, r.dominant))
            rows.append((f"{key}.useful_ratio", None,
                         f"{r.useful_ratio:.3f}"))
            rows.append((f"{key}.gb_per_device", None,
                         f"{r.bytes_per_device / 2**30:.2f}"))
            table.append({
                "arch": r.arch, "shape": r.shape, "mesh": r.mesh,
                "compute_s": r.compute_s, "memory_s": r.memory_s,
                "collective_s": r.collective_s, "dominant": r.dominant,
                "useful_ratio": r.useful_ratio,
                "gb_per_device": r.bytes_per_device / 2**30,
                "fits_hbm": r.hbm_budget_ok,
            })
        if rls:
            print(f"\n== roofline ({mesh}) ==")
            print(format_table(rls))
    skips = [json.load(open(p)) for p in
             sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
             if "skipped" in json.load(open(p))]
    for s in skips:
        rows.append((f"roofline.{s['mesh']}.{s['arch']}.{s['shape']}.skip",
                     None, s["skipped"]))
    save_json("", "roofline_table.json", table)
    return rows
