"""Beyond-paper: SmartSplit plans for the assigned transformer
architectures on the TPU edge+cloud two-tier profile.

For each decoder arch x serving mode, plan the split with the full
Algorithm 1 (GA front + TOPSIS) and report the chosen boundary, the
objective triple, and how it compares against the LBO/EBO/COS/COC
baselines -- the paper's Table II transplanted to the TPU fleet."""
from __future__ import annotations

from benchmarks.common import save_json
from repro.configs import all_configs
from repro.core import (ALGORITHMS, TPU_EDGE_CLOUD, evaluate_objectives,
                        smartsplit_exhaustive)
from repro.models.profiles import transformer_profile

MODES = [("prefill", 32768, 8), ("decode", 32768, 32)]


def run_all() -> list[tuple]:
    rows = []
    art = {}
    for arch, cfg in sorted(all_configs().items()):
        if cfg.is_encoder:
            continue
        art[arch] = {}
        for mode, seq, batch in MODES:
            prof = transformer_profile(cfg, seq_len=seq, batch=batch,
                                       mode=mode)
            plan = smartsplit_exhaustive(prof, TPU_EDGE_CLOUD)
            F = evaluate_objectives(prof, TPU_EDGE_CLOUD)
            entry = {"l1": plan.split_index, "L": prof.num_layers,
                     "latency_s": plan.objectives[0],
                     "energy_j": plan.objectives[1],
                     "edge_mem_gb": plan.objectives[2] / 2**30,
                     "pareto_size": len(plan.pareto_indices)}
            for alg in ("LBO", "EBO", "COS", "COC"):
                entry[alg] = int(ALGORITHMS[alg](prof, TPU_EDGE_CLOUD))
            art[arch][mode] = entry
            rows.append((f"tpu_split.{arch}.{mode}.l1", None,
                         f"{plan.split_index}/{prof.num_layers}"))
            rows.append((f"tpu_split.{arch}.{mode}.latency_s", None,
                         f"{plan.objectives[0]:.4f}"))
    save_json("", "tpu_split.json", art)
    return rows
