"""Quantized boundary streaming bench: fp32/bf16/int8 wire formats over
the paper CNNs.

Two halves per model:

* **modelled** (always the paper's 224 px shapes, batch 1): per-split
  wire bytes under each format (``ModelProfile.wire_boundary`` -- int8 =
  payload + fp32 per-channel scales + multipart framing), the resulting
  upload latency/energy deltas on the paper's J6 environment, and where
  NSGA-II/TOPSIS moves the split when it prices each wire format
  (``smartsplit(wire=...)``).
* **executed** (96 px in smoke so CI finishes in seconds, 224 px full):
  ``apply_split(wire=...)`` end to end at the int8-planned split --
  top-1 agreement and max-abs logits error against the fp32 wire, plus
  the fused quantize kernel's wall time on the real boundary activation.

Headline artifact: ``benchmarks/out/BENCH_boundary_quant{_smoke}.json``
with the min int8-vs-fp32 wire-bytes reduction across every paper split
(the >= 3.5x acceptance series).

CLI: ``python -m benchmarks.boundary_quant_bench [--smoke]``.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json, time_us
from repro.core import PAPER_ENV_J6, latency_terms, smartsplit, total_energy
from repro.kernels.quant import quantize_boundary
from repro.models import cnn as cnn_lib
from repro.models.profiles import cnn_profile

MODELS = ("alexnet", "vgg16", "mobilenetv2")
WIRES = ("fp32", "bf16", "int8")


def modelled_section(model: str) -> dict:
    """Wire-byte / objective / split-movement model at the paper shapes."""
    hw = PAPER_ENV_J6
    prof = cnn_profile(model)           # 224 px, batch 1, fp32 storage
    wb = {w: prof.wire_boundary(w) for w in WIRES}
    live = wb["fp32"] > 0               # splits with a non-empty boundary
    reduction = wb["fp32"][live] / wb["int8"][live]
    out = {
        "model": model,
        "num_splits": int(live.sum()),
        "min_int8_reduction": float(reduction.min()),
        "mean_int8_reduction": float(reduction.mean()),
        "wire": {},
        "splits": {},
    }
    t_up_fp32 = latency_terms(prof, hw, wire="fp32")[1]
    en_fp32 = total_energy(prof, hw, wire="fp32")
    l_fp32 = smartsplit(prof, hw, wire="fp32").split_index
    for w in WIRES:
        plan = smartsplit(prof, hw, wire=w)
        l1 = plan.split_index
        lat, en, mem = plan.objectives
        t_up = latency_terms(prof, hw, wire=w)[1]
        out["splits"][w] = l1
        out["wire"][w] = {
            "split_index": l1,
            "latency_s": float(lat), "energy_j": float(en),
            "client_mem_bytes": float(mem),
            "boundary_wire_bytes": float(wb[w][l1]),
            "upload_s": float(t_up[l1]),
            # deltas at the fp32-planned split: same placement, new wire
            "upload_delta_s_at_fp32_split":
                float(t_up[l_fp32] - t_up_fp32[l_fp32]),
            "energy_delta_j_at_fp32_split":
                float(total_energy(prof, hw, wire=w)[l_fp32]
                      - en_fp32[l_fp32]),
        }
    return out


def executed_section(model: str, in_shape: tuple, batch: int = 2) -> dict:
    """End-to-end ``apply_split(wire=...)`` accuracy + quantize timing."""
    hw = PAPER_ENV_J6
    prof = cnn_profile(model, in_shape=in_shape)
    plan = smartsplit(prof, hw, wire="int8")
    l1 = plan.split_index
    layers = cnn_lib.CNN_MODELS[model]
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), layers, in_shape)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(batch,) + in_shape), jnp.float32)
    ref, boundary = cnn_lib.apply_split(layers, params, x, l1, wire="fp32")
    ref_top1 = np.asarray(jnp.argmax(ref, axis=-1))
    us_q = time_us(lambda: jax.block_until_ready(
        quantize_boundary(boundary)), repeats=3)
    out = {"model": model, "in_shape": list(in_shape), "batch": batch,
           "split_index": l1,
           "boundary_shape": [int(d) for d in boundary.shape],
           "quantize_us": us_q, "wire": {}}
    for w in WIRES:
        logits, _ = cnn_lib.apply_split(layers, params, x, l1, wire=w)
        top1 = np.asarray(jnp.argmax(logits, axis=-1))
        out["wire"][w] = {
            "top1_agreement": float(np.mean(top1 == ref_top1)),
            "max_abs_err": float(jnp.max(jnp.abs(logits - ref))),
        }
    return out


def run_all(smoke: bool = False) -> list[tuple]:
    """Bench-contract entry: returns ``(name, us, derived)`` rows and
    writes BENCH_boundary_quant{_smoke}.json."""
    exec_shape = (3, 96, 96) if smoke else cnn_lib.INPUT_SHAPE
    rows, models = [], {}
    for model in MODELS:
        m = modelled_section(model)
        m["executed"] = executed_section(model, exec_shape)
        models[model] = m
        i8 = m["wire"]["int8"]
        e8 = m["executed"]["wire"]["int8"]
        rows.append((
            f"boundary_quant/{model}.int8",
            m["executed"]["quantize_us"],
            f"min_reduction={m['min_int8_reduction']:.2f}x"
            f" split={m['splits']['fp32']}->{m['splits']['int8']}"
            f" upload_delta={i8['upload_delta_s_at_fp32_split']:.2e}s"
            f" top1_agree={e8['top1_agreement']:.3f}"
            f" max_abs_err={e8['max_abs_err']:.3e}"))
    totals = {
        "min_int8_reduction": min(m["min_int8_reduction"]
                                  for m in models.values()),
        "min_top1_agreement_int8": min(
            m["executed"]["wire"]["int8"]["top1_agreement"]
            for m in models.values()),
        "max_abs_err_int8": max(
            m["executed"]["wire"]["int8"]["max_abs_err"]
            for m in models.values()),
        "split_moves_int8": sum(
            m["splits"]["int8"] != m["splits"]["fp32"]
            for m in models.values()),
    }
    name = "BENCH_boundary_quant_smoke.json" if smoke \
        else "BENCH_boundary_quant.json"
    path = save_json("", name, {
        "bench": "boundary_quant", "smoke": smoke,
        "hardware": "paper-j6", "modelled_in_shape": list(cnn_lib.INPUT_SHAPE),
        "executed_in_shape": list(exec_shape),
        "models": models, "totals": totals})
    rows.append((
        f"boundary_quant/totals[{len(models)}models]", None,
        f"min_reduction={totals['min_int8_reduction']:.2f}x"
        f" min_top1={totals['min_top1_agreement_int8']:.3f}"
        f" split_moves={totals['split_moves_int8']} -> {path}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    emit([], header=True)
    emit(run_all(smoke=args.smoke))


if __name__ == "__main__":
    sys.exit(main())
