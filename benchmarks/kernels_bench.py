"""Kernel microbenchmarks: wall time (interpret mode on CPU -- relative
numbers only; on TPU pass REPRO_PALLAS_COMPILE=1) plus the analytic MXU
utilisation each BlockSpec tiling would claim on v5e."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import save_json, time_us
from repro.core.hardware import V5E_PEAK_FLOPS_BF16
from repro.kernels import ops, ref
from repro.kernels.conv2d import conv_vmem_bytes, plan_conv


def _pool_triples(model: str) -> list[tuple]:
    """(name, cin, hw, cout, K, stride, pad, act, pool_k, pool_s) for every
    conv->relu->maxpool triple the model executes at 224 px (enumeration
    shared with the fusion walk via cnn.conv_pool_triples)."""
    from repro.models import cnn
    layers = cnn.CNN_MODELS[model]
    conv_ordinal = {i: n + 1 for n, i in enumerate(
        i for i, l in enumerate(layers) if l.kind == "conv")}
    return [(f"{model}_conv{conv_ordinal[i]}", cin, hw, cout, K, s, p,
             act, pk, ps)
            for i, cin, hw, cout, K, s, p, act, pk, ps
            in cnn.conv_pool_triples(layers)]


def conv_fusion_report() -> list[tuple]:
    """Fused conv+relu+maxpool triple vs the unfused two-launch path for
    every AlexNet/VGG16 pool triple: interpret-mode wall time (relative
    only -- compile on TPU for real numbers), predicted per-tile VMEM, and
    the analytic HBM-traffic proxy fusion removes (the conv activation
    write + re-read).  Emits BENCH_conv_fusion.json so the perf trajectory
    records launch counts and bandwidth proxies over time."""
    rows, triples = [], []
    key = jax.random.PRNGKey(42)
    for model in ("alexnet", "vgg16"):
        for name, cin, hw, cout, K, s, p, act, pk, ps in \
                _pool_triples(model):
            x = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
            w = jax.random.normal(jax.random.fold_in(key, 1),
                                  (cout, cin, K, K), jnp.float32) * 0.1
            b = jax.random.normal(jax.random.fold_in(key, 2),
                                  (cout,), jnp.float32) * 0.1
            plan = plan_conv(x.shape, w.shape, stride=s, pad=p,
                             pool_k=pk, pool_s=ps)
            us_f = time_us(lambda: jax.block_until_ready(
                ops.conv2d(x, w, stride=s, pad=p, bias=b, activation=act,
                           pool_k=pk, pool_s=ps)), repeats=3)
            pool = jax.jit(lambda y: jax.lax.reduce_window(
                y, -jnp.inf, jax.lax.max, (1, 1, pk, pk), (1, 1, ps, ps),
                "VALID"))
            us_u = time_us(lambda: jax.block_until_ready(pool(
                ops.conv2d(x, w, stride=s, pad=p, bias=b,
                           activation=act))), repeats=3)
            jx = jax.jit(lambda a, c, d: pool(ref.conv2d_ref(
                a, c, stride=s, pad=p, bias=d, activation=act)))
            us_x = time_us(lambda: jax.block_until_ready(jx(x, w, b)),
                           repeats=3)
            # bandwidth proxy: the unfused path writes the conv activation
            # to HBM and reads it back for the pool; fusion removes both
            act_b = 4 * cout * plan.h_out * plan.w_out
            pooled_b = 4 * cout * plan.p_out * plan.pw_out
            in_b = 4 * cin * hw * hw
            w_b = 4 * cout * cin * K * K
            rows.append((
                f"kernels.conv_fusion.{name}_pool{pk}s{ps}", us_f,
                f"unfused_us={us_u:.1f} tile_h={plan.tile_h} "
                f"vmem_bytes={plan.vmem_bytes} "
                f"act_hbm_bytes_avoided={2 * act_b}"))
            triples.append({
                "name": name, "model": model,
                "shape": {"cin": cin, "hw": hw, "cout": cout, "K": K,
                          "stride": s, "pad": p, "pool_k": pk,
                          "pool_s": ps},
                "fused_us": us_f, "unfused_us": us_u, "xla_us": us_x,
                "launches_fused": 1,          # one pallas_call, pool inside
                "launches_unfused": 2,        # pallas_call + reduce_window
                "ops_seed": 4,                # conv, bias, relu, pool
                "tile_h": plan.tile_h, "tile_conv_h": plan.tile_conv_h,
                "vmem_bytes": plan.vmem_bytes,
                "hbm_bytes_fused": in_b + w_b + pooled_b,
                "hbm_bytes_unfused": in_b + w_b + pooled_b + 2 * act_b,
                "act_hbm_bytes_avoided": 2 * act_b,
            })
    path = save_json("", "BENCH_conv_fusion.json", {
        "triples": triples,
        "totals": {
            "n_triples": len(triples),
            "launches_fused": sum(t["launches_fused"] for t in triples),
            "launches_unfused": sum(t["launches_unfused"] for t in triples),
            "hbm_bytes_saved": sum(t["act_hbm_bytes_avoided"]
                                   for t in triples),
        }})
    rows.append(("kernels.conv_fusion.json", None, path))
    return rows


def model_conv_specs(model: str) -> list[tuple]:
    """(name, cin, hw, cout, K, stride, pad, act, pool_k, pool_s) for every
    conv paper-layer the model executes at 224 px.  ``pool_k/pool_s`` are
    non-zero when the conv heads a conv->relu->maxpool triple that the
    pallas backend fuses into one launch (``cnn.conv_pool_triples``)."""
    from repro.models import cnn
    layers = cnn.CNN_MODELS[model]
    triples = {t[0]: t for t in cnn.conv_pool_triples(layers)}
    shape = cnn.INPUT_SHAPE
    out, n = [], 0
    for i, l in enumerate(layers):
        if l.kind == "conv":
            n += 1
            nxt = layers[i + 1].kind if i + 1 < len(layers) else ""
            act = nxt if nxt in ("relu", "relu6") else None
            pk, ps = (triples[i][-2], triples[i][-1]) if i in triples \
                else (0, 0)
            out.append((f"{model}_conv{n}", shape[0], shape[1], l.cout,
                        l.ksize, l.stride, l.pad, act, pk, ps))
        shape = cnn.layer_out_shape(l, shape)
    return out


def dtype_plan_stats(cin: int, hw: int, cout: int, K: int, stride: int,
                     pad: int, pool_k: int = 0, pool_s: int = 0,
                     batch: int = 1) -> dict:
    """fp32-vs-bf16 planner comparison for one conv (+fused pool) shape.

    Three numbers matter: VMEM per tile at the *same* tile geometry (the
    apples-to-apples storage saving -- the fp32 accumulator stays, so the
    ratio is < 2x), the ``tile_h`` the planner buys back with the freed
    headroom, and the launch count that falls out of the bigger tiles."""
    x_shape = (batch, cin, hw, hw)
    w_shape = (cout, cin, K, K)
    plans = {}
    stats = {}
    for policy, nbytes in (("fp32", 4), ("bf16", 2)):
        plan = plan_conv(x_shape, w_shape, stride=stride, pad=pad,
                         pool_k=pool_k, pool_s=pool_s, dtype_bytes=nbytes)
        plans[policy] = plan
        stats[policy] = {
            "tile_h": plan.tile_h, "n_h_blocks": plan.n_h_blocks,
            "launches": batch * (cout // plan.block_co) * plan.n_h_blocks,
            "vmem_bytes_per_tile": plan.vmem_bytes,
            "out_bytes": batch * cout * plan.p_out * plan.pw_out * nbytes,
        }
    p32 = plans["fp32"]
    same_tile = conv_vmem_bytes(
        cin_block=p32.cin_block, block_co=p32.block_co, tile_h=p32.tile_h,
        w_in=hw + 2 * pad, w_out=p32.w_out, K=K, stride=stride,
        cin_per_group=cin, dtype_bytes=2, pool_k=p32.pool_k,
        pool_s=p32.pool_s)
    stats["vmem_bytes_bf16_at_fp32_tile"] = same_tile
    stats["vmem_per_tile_ratio"] = p32.vmem_bytes / same_tile
    stats["launch_ratio"] = (stats["fp32"]["launches"]
                             / stats["bf16"]["launches"])
    stats["transfer_bytes_ratio"] = (stats["fp32"]["out_bytes"]
                                     / stats["bf16"]["out_bytes"])
    return stats


_SMOKE_CONV_SPECS = [
    # one tiny shape per conv family: plain conv+relu, fused pool triple
    ("smoke_conv", 8, 16, 16, 3, 1, 1, "relu", 0, 0),
    ("smoke_triple", 8, 16, 16, 3, 1, 1, "relu", 2, 2),
]


def dtype_sweep_report(smoke: bool = False) -> list[tuple]:
    """fp32 vs bf16 storage for every AlexNet/VGG16 conv (+fused pool
    triple) shape: planner stats (VMEM per tile, tile_h, launch counts),
    interpret-mode wall time, and max-abs error of the bf16 kernel against
    the fp32 XLA reference.  Emits BENCH_dtype_sweep.json.

    ``smoke`` runs one tiny shape per family so CI can exercise the whole
    bench path (planning, execution, JSON emission) in seconds."""
    key = jax.random.PRNGKey(7)
    specs = _SMOKE_CONV_SPECS if smoke else [
        s for m in ("alexnet", "vgg16") for s in model_conv_specs(m)]
    rows, entries = [], []
    for name, cin, hw, cout, K, s, p, act, pk, ps in specs:
        stats = dtype_plan_stats(cin, hw, cout, K, s, p, pk, ps)
        x = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
        w = jax.random.normal(jax.random.fold_in(key, 1),
                              (cout, cin, K, K), jnp.float32) * 0.1
        b = jax.random.normal(jax.random.fold_in(key, 2),
                              (cout,), jnp.float32) * 0.1
        want = ref.conv2d_ref(x, w, stride=s, pad=p, bias=b, activation=act)
        if pk:
            want = jax.lax.reduce_window(
                want, -jnp.inf, jax.lax.max, (1, 1, pk, pk),
                (1, 1, ps, ps), "VALID")
        want = jax.block_until_ready(want)
        macs = K * K * cin * cout * hw * hw
        repeats = 1 if macs > 5e8 else 3
        us, err = {}, {}
        for policy in ("fp32", "bf16"):
            def run(policy=policy):
                return jax.block_until_ready(ops.conv2d(
                    x, w, stride=s, pad=p, bias=b, activation=act,
                    pool_k=pk, pool_s=ps, dtype=policy))
            got = run().astype(jnp.float32)      # doubles as the warmup
            us[policy] = time_us(run, repeats=repeats, warmup=0)
            err[policy] = float(jnp.max(jnp.abs(got - want)))
        denom = float(jnp.max(jnp.abs(want)))
        entries.append({
            "name": name,
            "shape": {"cin": cin, "hw": hw, "cout": cout, "K": K,
                      "stride": s, "pad": p, "act": act,
                      "pool_k": pk, "pool_s": ps},
            **stats,
            "fp32_us": us["fp32"], "bf16_us": us["bf16"],
            "max_abs_err_fp32": err["fp32"],
            "max_abs_err_bf16": err["bf16"],
            "max_rel_err_bf16": err["bf16"] / denom if denom else 0.0,
        })
        rows.append((
            f"kernels.dtype_sweep.{name}", us["bf16"],
            f"fp32_us={us['fp32']:.1f} "
            f"tile_h={stats['fp32']['tile_h']}->{stats['bf16']['tile_h']} "
            f"launches={stats['fp32']['launches']}->"
            f"{stats['bf16']['launches']} "
            f"vmem_ratio={stats['vmem_per_tile_ratio']:.2f} "
            f"max_abs_err={err['bf16']:.3e}"))
    fname = "BENCH_dtype_sweep_smoke.json" if smoke \
        else "BENCH_dtype_sweep.json"
    path = save_json("", fname, {
        "smoke": smoke,
        "entries": entries,
        "totals": {
            "n_shapes": len(entries),
            "launches_fp32": sum(e["fp32"]["launches"] for e in entries),
            "launches_bf16": sum(e["bf16"]["launches"] for e in entries),
            "min_vmem_per_tile_ratio": min(
                e["vmem_per_tile_ratio"] for e in entries),
            "max_abs_err_bf16": max(
                e["max_abs_err_bf16"] for e in entries),
        }})
    rows.append(("kernels.dtype_sweep.json", None, path))
    return rows


def run_smoke() -> list[tuple]:
    """One tiny shape per kernel family, in seconds: the CI bench-smoke
    gate that keeps the bench path itself from rotting."""
    rows = []
    key = jax.random.PRNGKey(0)

    # conv family (tiled kernel + fused triple + dtype sweep JSON)
    rows += dtype_sweep_report(smoke=True)

    # flash attention: one 128-token tile pair
    B, S, H, KV, hd = 1, 128, 2, 1, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd),
                          jnp.float32) * 0.3
    us = time_us(lambda: jax.block_until_ready(
        ops.flash_attention_gqa(q, k, v, block_q=64, block_k=64)),
        repeats=1)
    rows.append(("kernels.smoke.flash_attention.128x64", us, "interpret"))

    # rwkv6 wkv: 32 tokens x 1 head
    r = jax.random.normal(key, (1, 32, 1, 32)) * 0.3
    kk = jax.random.normal(jax.random.fold_in(key, 4), (1, 32, 1, 32)) * 0.3
    vv = jax.random.normal(jax.random.fold_in(key, 5), (1, 32, 1, 32)) * 0.3
    ww = jax.nn.sigmoid(
        jax.random.normal(jax.random.fold_in(key, 6), (1, 32, 1, 32))) \
        * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(key, 7), (1, 32)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.rwkv6_wkv(r, kk, vv, ww, u, block_t=16)), repeats=1)
    rows.append(("kernels.smoke.rwkv6_wkv.32tok", us, "interpret"))

    # mamba2 ssd: 64 tokens
    x2 = jax.random.normal(key, (1, 64, 1, 16)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 8),
                                           (1, 64, 1)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (1,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 10), (1, 64, 1, 8)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(key, 11), (1, 64, 1, 8)) * 0.4
    us = time_us(lambda: jax.block_until_ready(
        ops.mamba2_ssd(x2, dt, A, Bm, Cm, chunk=32)), repeats=1)
    rows.append(("kernels.smoke.mamba2_ssd.64tok", us, "interpret"))
    return rows


def run_all(smoke: bool = False) -> list[tuple]:
    if smoke:
        return run_smoke()
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: seq 512, hd 128 (MXU-aligned)
    B, S, H, KV, hd = 1, 512, 4, 2, 128
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd),
                          jnp.float32) * 0.3
    us = time_us(lambda: jax.block_until_ready(
        ops.flash_attention_gqa(q, k, v)), repeats=3)
    flops = 2 * B * H * S * S * hd * 2 / 2        # causal halves the work
    rows.append(("kernels.flash_attention.512x128", us,
                 f"analytic_v5e_us={flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))

    # reference attention for the same shape (oracle cost)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k, H // KV, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = jnp.repeat(v, H // KV, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    jref = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c))
    us = time_us(lambda: jax.block_until_ready(jref(qf, kf, vf)), repeats=3)
    rows.append(("kernels.attention_ref.512x128", us, "xla_dense"))

    # conv2d: AlexNet conv2 shape
    x = jax.random.normal(key, (1, 64, 27, 27), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 3), (192, 64, 5, 5),
                          jnp.float32) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.conv2d(x, w, stride=1, pad=2)), repeats=3)
    flops = 2 * 25 * 64 * 192 * 27 * 27
    rows.append(("kernels.conv2d.alexnet_conv2", us,
                 f"analytic_v5e_us={flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))
    jconv = jax.jit(lambda a, b: ref.conv2d_ref(a, b, stride=1, pad=2))
    us = time_us(lambda: jax.block_until_ready(jconv(x, w)), repeats=3)
    rows.append(("kernels.conv2d_ref.alexnet_conv2", us, "xla_conv"))

    # fused conv+bias+relu: one tiled-kernel launch where the seed path
    # needed three ops (conv kernel, XLA bias broadcast, XLA relu)
    bias = jax.random.normal(jax.random.fold_in(key, 12), (192,)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.conv2d(x, w, stride=1, pad=2, bias=bias, activation="relu")),
        repeats=3)
    rows.append(("kernels.conv2d_fused.alexnet_conv2", us,
                 "1_launch_vs_seed_3_ops"))
    jseed = jax.jit(lambda a, b, c: jax.nn.relu(
        ref.conv2d_ref(a, b, stride=1, pad=2) + c[None, :, None, None]))
    us = time_us(lambda: jax.block_until_ready(jseed(x, w, bias)), repeats=3)
    rows.append(("kernels.conv2d_unfused3.alexnet_conv2", us,
                 "xla_conv+bias+relu"))

    # the VMEM-busting shapes the seed kernel (whole-image staging) could
    # not hold in a 16 MB core: VGG16 conv1-conv3 + MobileNetV2 dw convs
    conv_shapes = [  # name, cin, hw, cout, K, stride, pad, groups
        ("vgg16_conv1", 3, 224, 64, 3, 1, 1, 1),
        ("vgg16_conv2", 64, 224, 64, 3, 1, 1, 1),
        ("vgg16_conv3", 64, 112, 128, 3, 1, 1, 1),
        ("mbv2_dw_s2_96", 96, 112, 96, 3, 2, 1, 96),
        ("mbv2_dw_s1_384", 384, 14, 384, 3, 1, 1, 384),
    ]
    for name, cin, hw, cout, K, s, p, g in conv_shapes:
        xc = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
        wc = jax.random.normal(jax.random.fold_in(key, 13),
                               (cout, cin // g, K, K), jnp.float32) * 0.1
        bc = jax.random.normal(jax.random.fold_in(key, 14),
                               (cout,), jnp.float32) * 0.1
        plan = plan_conv(xc.shape, wc.shape, stride=s, pad=p, groups=g)
        us = time_us(lambda: jax.block_until_ready(
            ops.conv2d(xc, wc, stride=s, pad=p, bias=bc,
                       activation="relu", groups=g)), repeats=3)
        h_out = (hw + 2 * p - K) // s + 1
        flops = 2 * K * K * (cin // g) * cout * h_out * h_out
        rows.append((f"kernels.conv2d_tiled.{name}", us,
                     f"tile_h={plan.tile_h} vmem_bytes={plan.vmem_bytes} "
                     f"analytic_v5e_us="
                     f"{flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))
        jc = jax.jit(functools.partial(ref.conv2d_ref, stride=s, pad=p,
                                       bias=bc, activation="relu", groups=g))
        us = time_us(lambda: jax.block_until_ready(jc(xc, wc)), repeats=3)
        rows.append((f"kernels.conv2d_ref.{name}", us, "xla_conv"))

    # fused conv+relu+maxpool triples (AlexNet/VGG16) + BENCH_conv_fusion
    rows += conv_fusion_report()

    # fp32 vs bf16 storage sweep (planner + parity) + BENCH_dtype_sweep
    rows += dtype_sweep_report()

    # rwkv6 wkv: 64 tokens x 2 heads
    b, t, h, hd2 = 1, 64, 2, 64
    r = jax.random.normal(key, (b, t, h, hd2)) * 0.3
    kk = jax.random.normal(jax.random.fold_in(key, 4), (b, t, h, hd2)) * 0.3
    vv = jax.random.normal(jax.random.fold_in(key, 5), (b, t, h, hd2)) * 0.3
    ww = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 6),
                                          (b, t, h, hd2))) * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(key, 7), (h, hd2)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.rwkv6_wkv(r, kk, vv, ww, u, block_t=32)), repeats=3)
    rows.append(("kernels.rwkv6_wkv.64tok", us, "interpret"))

    # mamba2 ssd: 128 tokens
    x2 = jax.random.normal(key, (1, 128, 2, 32)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 8),
                                           (1, 128, 2)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (2,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 10), (1, 128, 2, 16)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(key, 11), (1, 128, 2, 16)) * 0.4
    us = time_us(lambda: jax.block_until_ready(
        ops.mamba2_ssd(x2, dt, A, Bm, Cm, chunk=64)), repeats=3)
    rows.append(("kernels.mamba2_ssd.128tok", us, "interpret"))
    return rows
