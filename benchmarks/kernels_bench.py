"""Kernel microbenchmarks: wall time (interpret mode on CPU -- relative
numbers only; on TPU pass REPRO_PALLAS_COMPILE=1) plus the analytic MXU
utilisation each BlockSpec tiling would claim on v5e."""
from __future__ import annotations

import functools
import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import ensure_out, save_json, time_us
from repro.core.costs import INT8_FRAME_OVERHEAD_BYTES, WIRE_SCALE_BYTES
from repro.core.hardware import V5E_PEAK_FLOPS_BF16
from repro.kernels import conv2d as conv2d_mod
from repro.kernels import ops, ref
from repro.kernels.conv2d import conv_vmem_bytes, plan_conv


def _pool_triples(model: str) -> list[tuple]:
    """(name, cin, hw, cout, K, stride, pad, act, pool_k, pool_s) for every
    conv->relu->maxpool triple the model executes at 224 px (enumeration
    shared with the fusion walk via cnn.conv_pool_triples)."""
    from repro.models import cnn
    layers = cnn.CNN_MODELS[model]
    conv_ordinal = {i: n + 1 for n, i in enumerate(
        i for i, l in enumerate(layers) if l.kind == "conv")}
    return [(f"{model}_conv{conv_ordinal[i]}", cin, hw, cout, K, s, p,
             act, pk, ps)
            for i, cin, hw, cout, K, s, p, act, pk, ps
            in cnn.conv_pool_triples(layers)]


def conv_fusion_report() -> list[tuple]:
    """Fused conv+relu+maxpool triple vs the unfused two-launch path for
    every AlexNet/VGG16 pool triple: interpret-mode wall time (relative
    only -- compile on TPU for real numbers), predicted per-tile VMEM, and
    the analytic HBM-traffic proxy fusion removes (the conv activation
    write + re-read).  Emits BENCH_conv_fusion.json so the perf trajectory
    records launch counts and bandwidth proxies over time."""
    rows, triples = [], []
    key = jax.random.PRNGKey(42)
    for model in ("alexnet", "vgg16"):
        for name, cin, hw, cout, K, s, p, act, pk, ps in \
                _pool_triples(model):
            x = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
            w = jax.random.normal(jax.random.fold_in(key, 1),
                                  (cout, cin, K, K), jnp.float32) * 0.1
            b = jax.random.normal(jax.random.fold_in(key, 2),
                                  (cout,), jnp.float32) * 0.1
            plan = plan_conv(x.shape, w.shape, stride=s, pad=p,
                             pool_k=pk, pool_s=ps)
            us_f = time_us(lambda: jax.block_until_ready(
                ops.conv2d(x, w, stride=s, pad=p, bias=b, activation=act,
                           pool_k=pk, pool_s=ps)), repeats=3)
            pool = jax.jit(lambda y: jax.lax.reduce_window(
                y, -jnp.inf, jax.lax.max, (1, 1, pk, pk), (1, 1, ps, ps),
                "VALID"))
            us_u = time_us(lambda: jax.block_until_ready(pool(
                ops.conv2d(x, w, stride=s, pad=p, bias=b,
                           activation=act))), repeats=3)
            jx = jax.jit(lambda a, c, d: pool(ref.conv2d_ref(
                a, c, stride=s, pad=p, bias=d, activation=act)))
            us_x = time_us(lambda: jax.block_until_ready(jx(x, w, b)),
                           repeats=3)
            # bandwidth proxy: the unfused path writes the conv activation
            # to HBM and reads it back for the pool; fusion removes both
            act_b = 4 * cout * plan.h_out * plan.w_out
            pooled_b = 4 * cout * plan.p_out * plan.pw_out
            in_b = 4 * cin * hw * hw
            w_b = 4 * cout * cin * K * K
            rows.append((
                f"kernels.conv_fusion.{name}_pool{pk}s{ps}", us_f,
                f"unfused_us={us_u:.1f} tile_h={plan.tile_h} "
                f"vmem_bytes={plan.vmem_bytes} "
                f"act_hbm_bytes_avoided={2 * act_b}"))
            triples.append({
                "name": name, "model": model,
                "shape": {"cin": cin, "hw": hw, "cout": cout, "K": K,
                          "stride": s, "pad": p, "pool_k": pk,
                          "pool_s": ps},
                "fused_us": us_f, "unfused_us": us_u, "xla_us": us_x,
                "launches_fused": 1,          # one pallas_call, pool inside
                "launches_unfused": 2,        # pallas_call + reduce_window
                "ops_seed": 4,                # conv, bias, relu, pool
                "tile_h": plan.tile_h, "tile_conv_h": plan.tile_conv_h,
                "vmem_bytes": plan.vmem_bytes,
                "hbm_bytes_fused": in_b + w_b + pooled_b,
                "hbm_bytes_unfused": in_b + w_b + pooled_b + 2 * act_b,
                "act_hbm_bytes_avoided": 2 * act_b,
            })
    path = save_json("", "BENCH_conv_fusion.json", {
        "triples": triples,
        "totals": {
            "n_triples": len(triples),
            "launches_fused": sum(t["launches_fused"] for t in triples),
            "launches_unfused": sum(t["launches_unfused"] for t in triples),
            "hbm_bytes_saved": sum(t["act_hbm_bytes_avoided"]
                                   for t in triples),
        }})
    rows.append(("kernels.conv_fusion.json", None, path))
    return rows


def model_conv_specs(model: str) -> list[tuple]:
    """(name, cin, hw, cout, K, stride, pad, act, pool_k, pool_s) for every
    conv paper-layer the model executes at 224 px.  ``pool_k/pool_s`` are
    non-zero when the conv heads a conv->relu->maxpool triple that the
    pallas backend fuses into one launch (``cnn.conv_pool_triples``)."""
    from repro.models import cnn
    layers = cnn.CNN_MODELS[model]
    triples = {t[0]: t for t in cnn.conv_pool_triples(layers)}
    shape = cnn.INPUT_SHAPE
    out, n = [], 0
    for i, l in enumerate(layers):
        if l.kind == "conv":
            n += 1
            nxt = layers[i + 1].kind if i + 1 < len(layers) else ""
            act = nxt if nxt in ("relu", "relu6") else None
            pk, ps = (triples[i][-2], triples[i][-1]) if i in triples \
                else (0, 0)
            out.append((f"{model}_conv{n}", shape[0], shape[1], l.cout,
                        l.ksize, l.stride, l.pad, act, pk, ps))
        shape = cnn.layer_out_shape(l, shape)
    return out


def dtype_plan_stats(cin: int, hw: int, cout: int, K: int, stride: int,
                     pad: int, pool_k: int = 0, pool_s: int = 0,
                     batch: int = 1) -> dict:
    """fp32-vs-bf16 planner comparison for one conv (+fused pool) shape.

    Three numbers matter: VMEM per tile at the *same* tile geometry (the
    apples-to-apples storage saving -- the fp32 accumulator stays, so the
    ratio is < 2x), the ``tile_h`` the planner buys back with the freed
    headroom, and the launch count that falls out of the bigger tiles."""
    x_shape = (batch, cin, hw, hw)
    w_shape = (cout, cin, K, K)
    plans = {}
    stats = {}
    for policy, nbytes in (("fp32", 4), ("bf16", 2)):
        plan = plan_conv(x_shape, w_shape, stride=stride, pad=pad,
                         pool_k=pool_k, pool_s=pool_s, dtype_bytes=nbytes)
        plans[policy] = plan
        stats[policy] = {
            "tile_h": plan.tile_h, "tile_w": plan.tile_w,
            "n_h_blocks": plan.n_h_blocks, "n_w_blocks": plan.n_w_blocks,
            "launches": plan.launches,
            "vmem_bytes_per_tile": plan.vmem_bytes,
            "out_bytes": batch * cout * plan.p_out * plan.pw_out * nbytes,
        }
    p32 = plans["fp32"]
    same_tile = conv_vmem_bytes(
        cin_block=p32.cin_block, block_co=p32.block_co, tile_h=p32.tile_h,
        w_in=hw + 2 * pad, w_out=p32.w_out, K=K, stride=stride,
        cin_per_group=cin, dtype_bytes=2, pool_k=p32.pool_k,
        pool_s=p32.pool_s,
        tile_w=p32.tile_w if p32.n_w_blocks > 1 else 0)
    stats["vmem_bytes_bf16_at_fp32_tile"] = same_tile
    stats["vmem_per_tile_ratio"] = p32.vmem_bytes / same_tile
    stats["launch_ratio"] = (stats["fp32"]["launches"]
                             / stats["bf16"]["launches"])
    stats["transfer_bytes_ratio"] = (stats["fp32"]["out_bytes"]
                                     / stats["bf16"]["out_bytes"])
    return stats


_SMOKE_CONV_SPECS = [
    # one tiny shape per conv family: plain conv+relu, fused pool triple
    ("smoke_conv", 8, 16, 16, 3, 1, 1, "relu", 0, 0),
    ("smoke_triple", 8, 16, 16, 3, 1, 1, "relu", 2, 2),
]


def dtype_sweep_report(smoke: bool = False) -> list[tuple]:
    """fp32 vs bf16 storage for every AlexNet/VGG16 conv (+fused pool
    triple) shape: planner stats (VMEM per tile, tile_h, launch counts),
    interpret-mode wall time, and max-abs error of the bf16 kernel against
    the fp32 XLA reference.  Emits BENCH_dtype_sweep.json.

    ``smoke`` runs one tiny shape per family so CI can exercise the whole
    bench path (planning, execution, JSON emission) in seconds."""
    key = jax.random.PRNGKey(7)
    specs = _SMOKE_CONV_SPECS if smoke else [
        s for m in ("alexnet", "vgg16") for s in model_conv_specs(m)]
    rows, entries = [], []
    for name, cin, hw, cout, K, s, p, act, pk, ps in specs:
        stats = dtype_plan_stats(cin, hw, cout, K, s, p, pk, ps)
        x = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
        w = jax.random.normal(jax.random.fold_in(key, 1),
                              (cout, cin, K, K), jnp.float32) * 0.1
        b = jax.random.normal(jax.random.fold_in(key, 2),
                              (cout,), jnp.float32) * 0.1
        want = ref.conv2d_ref(x, w, stride=s, pad=p, bias=b, activation=act)
        if pk:
            want = jax.lax.reduce_window(
                want, -jnp.inf, jax.lax.max, (1, 1, pk, pk),
                (1, 1, ps, ps), "VALID")
        want = jax.block_until_ready(want)
        macs = K * K * cin * cout * hw * hw
        repeats = 1 if macs > 5e8 else 3
        us, err = {}, {}
        for policy in ("fp32", "bf16"):
            def run(policy=policy):
                return jax.block_until_ready(ops.conv2d(
                    x, w, stride=s, pad=p, bias=b, activation=act,
                    pool_k=pk, pool_s=ps, dtype=policy))
            got = run().astype(jnp.float32)      # doubles as the warmup
            us[policy] = time_us(run, repeats=repeats, warmup=0)
            err[policy] = float(jnp.max(jnp.abs(got - want)))
        denom = float(jnp.max(jnp.abs(want)))
        # wire column: this activation shipped as the split boundary --
        # int8 = 1 byte/elem + per-channel fp32 scales + two-part framing
        out_elems = stats["fp32"]["out_bytes"] // 4
        wire_fp32 = stats["fp32"]["out_bytes"]
        wire_int8 = out_elems + WIRE_SCALE_BYTES * cout \
            + INT8_FRAME_OVERHEAD_BYTES
        entries.append({
            "name": name,
            "shape": {"cin": cin, "hw": hw, "cout": cout, "K": K,
                      "stride": s, "pad": p, "act": act,
                      "pool_k": pk, "pool_s": ps},
            **stats,
            "fp32_us": us["fp32"], "bf16_us": us["bf16"],
            "max_abs_err_fp32": err["fp32"],
            "max_abs_err_bf16": err["bf16"],
            "max_rel_err_bf16": err["bf16"] / denom if denom else 0.0,
            "wire_bytes_fp32": wire_fp32,
            "wire_bytes_int8": wire_int8,
            "wire_int8_reduction": wire_fp32 / wire_int8,
        })
        rows.append((
            f"kernels.dtype_sweep.{name}", us["bf16"],
            f"fp32_us={us['fp32']:.1f} "
            f"tile_h={stats['fp32']['tile_h']}->{stats['bf16']['tile_h']} "
            f"launches={stats['fp32']['launches']}->"
            f"{stats['bf16']['launches']} "
            f"vmem_ratio={stats['vmem_per_tile_ratio']:.2f} "
            f"max_abs_err={err['bf16']:.3e}"))
    fname = "BENCH_dtype_sweep_smoke.json" if smoke \
        else "BENCH_dtype_sweep.json"
    path = save_json("", fname, {
        "smoke": smoke,
        "entries": entries,
        "totals": {
            "n_shapes": len(entries),
            "launches_fp32": sum(e["fp32"]["launches"] for e in entries),
            "launches_bf16": sum(e["bf16"]["launches"] for e in entries),
            "min_vmem_per_tile_ratio": min(
                e["vmem_per_tile_ratio"] for e in entries),
            "max_abs_err_bf16": max(
                e["max_abs_err_bf16"] for e in entries),
            "wire_bytes_fp32": sum(e["wire_bytes_fp32"] for e in entries),
            "wire_bytes_int8": sum(e["wire_bytes_int8"] for e in entries),
            "min_wire_int8_reduction": min(
                e["wire_int8_reduction"] for e in entries),
        }})
    rows.append(("kernels.dtype_sweep.json", None, path))
    return rows


def _plan_stats(plan) -> dict:
    """The comparable numbers of one ConvPlan for the tiling JSONs."""
    return {"block_co": plan.block_co, "tile_h": plan.tile_h,
            "tile_w": plan.tile_w, "n_h_blocks": plan.n_h_blocks,
            "n_w_blocks": plan.n_w_blocks, "launches": plan.launches,
            "vmem_bytes": plan.vmem_bytes, "cost_bytes": plan.cost_bytes}


# Wide-input client workloads (1080p camera frame, panoramic strips) the
# paper's smartphone setting implies.  The two *_row_buster strips keep H
# small so interpret mode stays tractable, but their single output row
# overflows the 12 MiB budget: ValueError on the greedy planner, runnable
# only with column tiles.
_WIDE_SPECS = [
    # name, cin, H, W, cout, K, stride, pad, act, pool_k, pool_s
    ("hd1080_conv1", 3, 1080, 1920, 64, 3, 1, 1, "relu", 0, 0),
    ("pano512x2048_conv1", 3, 512, 2048, 64, 11, 4, 2, "relu", 3, 2),
    ("strip7680_row_buster", 64, 16, 7680, 64, 3, 1, 1, "relu", 0, 0),
    ("strip6144_pool_row_buster", 64, 17, 6144, 64, 3, 1, 1, "relu", 2, 2),
]

# Smoke twins: one wide shape per conv family (plain conv, fused pool
# triple) shrunk so CI exercises column tiling in seconds.  The tiny
# explicit VMEM budget is what makes a 96-px row "wide": the greedy
# row-only planner raises on it, the search splits columns.
_SMOKE_WIDE_BUDGET = 40 * 1024
_SMOKE_WIDE_SPECS = [
    ("smoke_wide_conv", 8, 12, 96, 16, 3, 1, 1, "relu", 0, 0),
    ("smoke_wide_triple", 8, 13, 96, 16, 3, 1, 1, "relu", 2, 2),
]


def tiling_search_report(smoke: bool = False) -> list[tuple]:
    """Greedy-vs-joint-search planner comparison plus the wide-input sweep.

    Full mode: every AlexNet/VGG16/MobileNetV2 conv shape at fp32 and
    bf16 -- launch counts, per-tile VMEM, cost-model bytes, and
    interpret-mode wall time (relative only) for both planners -- plus
    the ``_WIDE_SPECS`` high-resolution shapes, recording which ones the
    greedy planner rejects outright and the parity of the column-tiled
    kernel against ``ref.conv2d_ref``.  Smoke mode runs the two tiny
    wide shapes under a 40 KiB budget so CI exercises column tiling on
    every push.  Emits BENCH_tiling_search{_smoke}.json."""
    key = jax.random.PRNGKey(11)
    rows, entries, wide = [], [], []
    if not smoke:
        specs = [s for m in ("alexnet", "vgg16", "mobilenetv2")
                 for s in model_conv_specs(m)]
        for name, cin, hw, cout, K, s, p, act, pk, ps in specs:
            x = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
            w = jax.random.normal(jax.random.fold_in(key, 1),
                                  (cout, cin, K, K), jnp.float32) * 0.1
            b = jax.random.normal(jax.random.fold_in(key, 2),
                                  (cout,), jnp.float32) * 0.1
            entry = {"name": name,
                     "shape": {"cin": cin, "hw": hw, "cout": cout, "K": K,
                               "stride": s, "pad": p, "act": act,
                               "pool_k": pk, "pool_s": ps}}
            for policy, nbytes in (("fp32", 4), ("bf16", 2)):
                cmp, plans = {}, {}
                for mode, searched in (("greedy", False), ("search", True)):
                    plans[mode] = _plan_stats(plan_conv(
                        (1, cin, hw, hw), (cout, cin, K, K),
                        stride=s, pad=p, pool_k=pk, pool_s=ps,
                        dtype_bytes=nbytes, search=searched))
                    st = dict(plans[mode])
                    if mode == "search" and plans["search"] == \
                            plans["greedy"]:
                        # identical plan: reuse the greedy measurement
                        st["us"] = cmp["greedy"]["us"]
                    else:
                        st["us"] = time_us(
                            lambda se=searched, po=policy:
                            jax.block_until_ready(ops.conv2d(
                                x, w, stride=s, pad=p, bias=b,
                                activation=act, pool_k=pk, pool_s=ps,
                                dtype=po, search=se)),
                            repeats=1)
                    cmp[mode] = st
                entry[policy] = cmp
            entries.append(entry)
            f32 = entry["fp32"]
            rows.append((
                f"kernels.tiling_search.{name}", f32["search"]["us"],
                f"greedy_us={f32['greedy']['us']:.1f} "
                f"launches={f32['greedy']['launches']}->"
                f"{f32['search']['launches']} "
                f"tile={f32['search']['tile_h']}x{f32['search']['tile_w']} "
                f"bc={f32['search']['block_co']}"))

    wide_specs = _SMOKE_WIDE_SPECS if smoke else _WIDE_SPECS
    budget = _SMOKE_WIDE_BUDGET if smoke \
        else conv2d_mod.DEFAULT_VMEM_BUDGET
    for name, cin, H, W, cout, K, s, p, act, pk, ps in wide_specs:
        x = jax.random.normal(key, (1, cin, H, W), jnp.float32) * 0.3
        w = jax.random.normal(jax.random.fold_in(key, 3),
                              (cout, cin, K, K), jnp.float32) * 0.1
        b = jax.random.normal(jax.random.fold_in(key, 4),
                              (cout,), jnp.float32) * 0.1
        entry = {"name": name,
                 "shape": {"cin": cin, "H": H, "W": W, "cout": cout,
                           "K": K, "stride": s, "pad": p, "act": act,
                           "pool_k": pk, "pool_s": ps},
                 "vmem_budget": budget}
        try:
            entry["greedy_fp32"] = _plan_stats(plan_conv(
                x.shape, w.shape, stride=s, pad=p, pool_k=pk, pool_s=ps,
                vmem_budget=budget, search=False))
        except ValueError as e:
            entry["greedy_fp32"] = {"error": str(e)}
        for policy, nbytes in (("fp32", 4), ("bf16", 2)):
            entry[f"search_{policy}"] = _plan_stats(plan_conv(
                x.shape, w.shape, stride=s, pad=p, pool_k=pk, pool_s=ps,
                dtype_bytes=nbytes, vmem_budget=budget, search=True))
        # execute the searched fp32 plan once (interpret mode is slow on
        # these shapes): the same run provides the timing and the parity
        got = None

        def run_wide():
            nonlocal got
            got = jax.block_until_ready(conv2d_mod.conv2d(
                x, w, stride=s, pad=p, bias=b, activation=act,
                pool_k=pk, pool_s=ps, vmem_budget=budget, search=True))

        us = time_us(run_wide, repeats=1, warmup=0)
        want = ref.conv2d_ref(x, w, stride=s, pad=p, bias=b,
                              activation=act)
        if pk:
            want = jax.lax.reduce_window(
                want, -jnp.inf, jax.lax.max, (1, 1, pk, pk),
                (1, 1, ps, ps), "VALID")
        entry["us"] = us
        entry["max_abs_err"] = float(jnp.max(jnp.abs(got - want)))
        wide.append(entry)
        sp = entry["search_fp32"]
        rows.append((
            f"kernels.tiling_search.wide.{name}", us,
            f"greedy={'raises' if 'error' in entry['greedy_fp32'] else 'ok'}"
            f" grid={sp['n_h_blocks']}x{sp['n_w_blocks']}"
            f" tile={sp['tile_h']}x{sp['tile_w']}"
            f" max_abs_err={entry['max_abs_err']:.3e}"))

    fname = "BENCH_tiling_search_smoke.json" if smoke \
        else "BENCH_tiling_search.json"
    totals = {"n_shapes": len(entries), "n_wide": len(wide),
              "wide_greedy_rejected": sum(
                  1 for e in wide if "error" in e["greedy_fp32"]),
              "max_wide_abs_err": max(
                  (e["max_abs_err"] for e in wide), default=0.0)}
    for policy in ("fp32", "bf16"):
        totals[f"launches_greedy_{policy}"] = sum(
            e[policy]["greedy"]["launches"] for e in entries)
        totals[f"launches_search_{policy}"] = sum(
            e[policy]["search"]["launches"] for e in entries)
        totals[f"n_reduced_{policy}"] = sum(
            e[policy]["search"]["launches"] < e[policy]["greedy"]["launches"]
            for e in entries)
    path = save_json("", fname, {"smoke": smoke, "entries": entries,
                                 "wide": wide, "totals": totals})
    rows.append(("kernels.tiling_search.json", None, path))
    return rows


def kernel_summary_report(smoke: bool = False) -> list[tuple]:
    """Aggregate the kernel JSON artefacts of this run into one stable
    headline series, BENCH_kernel_summary{_smoke}.json: total launches
    (greedy vs search, fp32 vs bf16), max per-tile VMEM, fused-vs-unfused
    and dtype aggregates.  Sections whose artefact is absent (e.g. the
    fusion report has no smoke variant) are skipped, so the summary is
    emittable from both the full bench and the CI smoke gate."""
    sfx = "_smoke" if smoke else ""
    out_dir = ensure_out("")

    def load(name):
        p = os.path.join(out_dir, name)
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return json.load(f)

    summary = {"smoke": smoke, "sections": {}}
    fusion = load("BENCH_conv_fusion.json") if not smoke else None
    if fusion:
        ratios = sorted(t["unfused_us"] / t["fused_us"]
                        for t in fusion["triples"] if t["fused_us"])
        summary["sections"]["conv_fusion"] = {
            **fusion["totals"],
            "median_unfused_over_fused_us": ratios[len(ratios) // 2],
        }
    dtype = load(f"BENCH_dtype_sweep{sfx}.json")
    if dtype:
        summary["sections"]["dtype_sweep"] = dict(dtype["totals"])
    tiling = load(f"BENCH_tiling_search{sfx}.json")
    if tiling:
        sec = dict(tiling["totals"])
        vmems = [e[p]["search"]["vmem_bytes"]
                 for e in tiling["entries"] for p in ("fp32", "bf16")] + \
                [e["search_fp32"]["vmem_bytes"] for e in tiling["wide"]]
        sec["max_vmem_bytes_per_tile"] = max(vmems, default=0)
        summary["sections"]["tiling_search"] = sec
    quant = load(f"BENCH_boundary_quant{sfx}.json")
    if quant:
        summary["sections"]["boundary_quant"] = dict(quant["totals"])
    head = {}
    ts = summary["sections"].get("tiling_search", {})
    if ts:
        head["total_launches_greedy_fp32"] = ts.get("launches_greedy_fp32")
        head["total_launches_search_fp32"] = ts.get("launches_search_fp32")
        head["total_launches_search_bf16"] = ts.get("launches_search_bf16")
        head["max_vmem_bytes_per_tile"] = ts.get("max_vmem_bytes_per_tile")
        head["wide_shapes_unlocked"] = ts.get("wide_greedy_rejected")
    ds = summary["sections"].get("dtype_sweep", {})
    if "wire_bytes_int8" in ds:
        head["wire_bytes_fp32"] = ds["wire_bytes_fp32"]
        head["wire_bytes_int8"] = ds["wire_bytes_int8"]
    bq = summary["sections"].get("boundary_quant", {})
    if bq:
        head["min_boundary_int8_reduction"] = bq.get("min_int8_reduction")
        head["min_top1_agreement_int8"] = bq.get("min_top1_agreement_int8")
    summary["headline"] = head
    path = save_json("", f"BENCH_kernel_summary{sfx}.json", summary)
    return [("kernels.summary.json", None, path)]


def run_smoke() -> list[tuple]:
    """One tiny shape per kernel family, in seconds: the CI bench-smoke
    gate that keeps the bench path itself from rotting."""
    rows = []
    key = jax.random.PRNGKey(0)

    # conv family (tiled kernel + fused triple + dtype sweep JSON)
    rows += dtype_sweep_report(smoke=True)

    # wide-input column tiling (one shape per conv family, tiny budget)
    rows += tiling_search_report(smoke=True)

    # boundary quantize: one AlexNet-pool5-sized activation
    from repro.kernels.quant import quantize_boundary
    xq = jax.random.normal(key, (1, 256, 6, 6), jnp.float32)
    us = time_us(lambda: jax.block_until_ready(quantize_boundary(xq)),
                 repeats=1)
    rows.append(("kernels.smoke.quantize_boundary.256x6x6", us,
                 "per-channel int8 + fp32 scales"))

    # flash attention: one 128-token tile pair
    B, S, H, KV, hd = 1, 128, 2, 1, 64
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd),
                          jnp.float32) * 0.3
    us = time_us(lambda: jax.block_until_ready(
        ops.flash_attention_gqa(q, k, v, block_q=64, block_k=64)),
        repeats=1)
    rows.append(("kernels.smoke.flash_attention.128x64", us, "interpret"))

    # rwkv6 wkv: 32 tokens x 1 head
    r = jax.random.normal(key, (1, 32, 1, 32)) * 0.3
    kk = jax.random.normal(jax.random.fold_in(key, 4), (1, 32, 1, 32)) * 0.3
    vv = jax.random.normal(jax.random.fold_in(key, 5), (1, 32, 1, 32)) * 0.3
    ww = jax.nn.sigmoid(
        jax.random.normal(jax.random.fold_in(key, 6), (1, 32, 1, 32))) \
        * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(key, 7), (1, 32)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.rwkv6_wkv(r, kk, vv, ww, u, block_t=16)), repeats=1)
    rows.append(("kernels.smoke.rwkv6_wkv.32tok", us, "interpret"))

    # mamba2 ssd: 64 tokens
    x2 = jax.random.normal(key, (1, 64, 1, 16)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 8),
                                           (1, 64, 1)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (1,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 10), (1, 64, 1, 8)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(key, 11), (1, 64, 1, 8)) * 0.4
    us = time_us(lambda: jax.block_until_ready(
        ops.mamba2_ssd(x2, dt, A, Bm, Cm, chunk=32)), repeats=1)
    rows.append(("kernels.smoke.mamba2_ssd.64tok", us, "interpret"))
    return rows


def run_all(smoke: bool = False) -> list[tuple]:
    if smoke:
        return run_smoke()
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: seq 512, hd 128 (MXU-aligned)
    B, S, H, KV, hd = 1, 512, 4, 2, 128
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd),
                          jnp.float32) * 0.3
    us = time_us(lambda: jax.block_until_ready(
        ops.flash_attention_gqa(q, k, v)), repeats=3)
    flops = 2 * B * H * S * S * hd * 2 / 2        # causal halves the work
    rows.append(("kernels.flash_attention.512x128", us,
                 f"analytic_v5e_us={flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))

    # reference attention for the same shape (oracle cost)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k, H // KV, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = jnp.repeat(v, H // KV, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    jref = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c))
    us = time_us(lambda: jax.block_until_ready(jref(qf, kf, vf)), repeats=3)
    rows.append(("kernels.attention_ref.512x128", us, "xla_dense"))

    # conv2d: AlexNet conv2 shape
    x = jax.random.normal(key, (1, 64, 27, 27), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 3), (192, 64, 5, 5),
                          jnp.float32) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.conv2d(x, w, stride=1, pad=2)), repeats=3)
    flops = 2 * 25 * 64 * 192 * 27 * 27
    rows.append(("kernels.conv2d.alexnet_conv2", us,
                 f"analytic_v5e_us={flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))
    jconv = jax.jit(lambda a, b: ref.conv2d_ref(a, b, stride=1, pad=2))
    us = time_us(lambda: jax.block_until_ready(jconv(x, w)), repeats=3)
    rows.append(("kernels.conv2d_ref.alexnet_conv2", us, "xla_conv"))

    # fused conv+bias+relu: one tiled-kernel launch where the seed path
    # needed three ops (conv kernel, XLA bias broadcast, XLA relu)
    bias = jax.random.normal(jax.random.fold_in(key, 12), (192,)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.conv2d(x, w, stride=1, pad=2, bias=bias, activation="relu")),
        repeats=3)
    rows.append(("kernels.conv2d_fused.alexnet_conv2", us,
                 "1_launch_vs_seed_3_ops"))
    jseed = jax.jit(lambda a, b, c: jax.nn.relu(
        ref.conv2d_ref(a, b, stride=1, pad=2) + c[None, :, None, None]))
    us = time_us(lambda: jax.block_until_ready(jseed(x, w, bias)), repeats=3)
    rows.append(("kernels.conv2d_unfused3.alexnet_conv2", us,
                 "xla_conv+bias+relu"))

    # the VMEM-busting shapes the seed kernel (whole-image staging) could
    # not hold in a 16 MB core: VGG16 conv1-conv3 + MobileNetV2 dw convs
    conv_shapes = [  # name, cin, hw, cout, K, stride, pad, groups
        ("vgg16_conv1", 3, 224, 64, 3, 1, 1, 1),
        ("vgg16_conv2", 64, 224, 64, 3, 1, 1, 1),
        ("vgg16_conv3", 64, 112, 128, 3, 1, 1, 1),
        ("mbv2_dw_s2_96", 96, 112, 96, 3, 2, 1, 96),
        ("mbv2_dw_s1_384", 384, 14, 384, 3, 1, 1, 384),
    ]
    for name, cin, hw, cout, K, s, p, g in conv_shapes:
        xc = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
        wc = jax.random.normal(jax.random.fold_in(key, 13),
                               (cout, cin // g, K, K), jnp.float32) * 0.1
        bc = jax.random.normal(jax.random.fold_in(key, 14),
                               (cout,), jnp.float32) * 0.1
        plan = plan_conv(xc.shape, wc.shape, stride=s, pad=p, groups=g)
        us = time_us(lambda: jax.block_until_ready(
            ops.conv2d(xc, wc, stride=s, pad=p, bias=bc,
                       activation="relu", groups=g)), repeats=3)
        h_out = (hw + 2 * p - K) // s + 1
        flops = 2 * K * K * (cin // g) * cout * h_out * h_out
        rows.append((f"kernels.conv2d_tiled.{name}", us,
                     f"tile_h={plan.tile_h} vmem_bytes={plan.vmem_bytes} "
                     f"analytic_v5e_us="
                     f"{flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))
        jc = jax.jit(functools.partial(ref.conv2d_ref, stride=s, pad=p,
                                       bias=bc, activation="relu", groups=g))
        us = time_us(lambda: jax.block_until_ready(jc(xc, wc)), repeats=3)
        rows.append((f"kernels.conv2d_ref.{name}", us, "xla_conv"))

    # fused conv+relu+maxpool triples (AlexNet/VGG16) + BENCH_conv_fusion
    rows += conv_fusion_report()

    # fp32 vs bf16 storage sweep (planner + parity) + BENCH_dtype_sweep
    rows += dtype_sweep_report()

    # greedy-vs-search tiling + wide-input sweep + BENCH_tiling_search
    rows += tiling_search_report()

    # boundary quantize at the paper splits: AlexNet pool5 (flat
    # scale-heavy boundary) and VGG16 pool4 (bulk 512-channel map)
    from repro.kernels.quant import quantize_boundary
    for qname, qshape in (("alexnet_pool5", (1, 256, 6, 6)),
                          ("vgg16_pool4", (1, 512, 28, 28))):
        xq = jax.random.normal(key, qshape, jnp.float32)
        us = time_us(lambda: jax.block_until_ready(quantize_boundary(xq)),
                     repeats=3)
        rows.append((f"kernels.quantize_boundary.{qname}", us,
                     "per-channel int8 + fp32 scales"))

    # rwkv6 wkv: 64 tokens x 2 heads
    b, t, h, hd2 = 1, 64, 2, 64
    r = jax.random.normal(key, (b, t, h, hd2)) * 0.3
    kk = jax.random.normal(jax.random.fold_in(key, 4), (b, t, h, hd2)) * 0.3
    vv = jax.random.normal(jax.random.fold_in(key, 5), (b, t, h, hd2)) * 0.3
    ww = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 6),
                                          (b, t, h, hd2))) * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(key, 7), (h, hd2)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.rwkv6_wkv(r, kk, vv, ww, u, block_t=32)), repeats=3)
    rows.append(("kernels.rwkv6_wkv.64tok", us, "interpret"))

    # mamba2 ssd: 128 tokens
    x2 = jax.random.normal(key, (1, 128, 2, 32)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 8),
                                           (1, 128, 2)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (2,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 10), (1, 128, 2, 16)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(key, 11), (1, 128, 2, 16)) * 0.4
    us = time_us(lambda: jax.block_until_ready(
        ops.mamba2_ssd(x2, dt, A, Bm, Cm, chunk=64)), repeats=3)
    rows.append(("kernels.mamba2_ssd.128tok", us, "interpret"))
    return rows
