"""Kernel microbenchmarks: wall time (interpret mode on CPU -- relative
numbers only; on TPU pass REPRO_PALLAS_COMPILE=1) plus the analytic MXU
utilisation each BlockSpec tiling would claim on v5e."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import time_us
from repro.core.hardware import V5E_PEAK_FLOPS_BF16
from repro.kernels import ops, ref
from repro.kernels.conv2d import plan_conv


def run_all() -> list[tuple]:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention: seq 512, hd 128 (MXU-aligned)
    B, S, H, KV, hd = 1, 512, 4, 2, 128
    q = jax.random.normal(key, (B, S, H, hd), jnp.float32) * 0.3
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd),
                          jnp.float32) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd),
                          jnp.float32) * 0.3
    us = time_us(lambda: jax.block_until_ready(
        ops.flash_attention_gqa(q, k, v)), repeats=3)
    flops = 2 * B * H * S * S * hd * 2 / 2        # causal halves the work
    rows.append(("kernels.flash_attention.512x128", us,
                 f"analytic_v5e_us={flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))

    # reference attention for the same shape (oracle cost)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = jnp.repeat(k, H // KV, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = jnp.repeat(v, H // KV, 2).transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    jref = jax.jit(lambda a, b, c: ref.attention_ref(a, b, c))
    us = time_us(lambda: jax.block_until_ready(jref(qf, kf, vf)), repeats=3)
    rows.append(("kernels.attention_ref.512x128", us, "xla_dense"))

    # conv2d: AlexNet conv2 shape
    x = jax.random.normal(key, (1, 64, 27, 27), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 3), (192, 64, 5, 5),
                          jnp.float32) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.conv2d(x, w, stride=1, pad=2)), repeats=3)
    flops = 2 * 25 * 64 * 192 * 27 * 27
    rows.append(("kernels.conv2d.alexnet_conv2", us,
                 f"analytic_v5e_us={flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))
    jconv = jax.jit(lambda a, b: ref.conv2d_ref(a, b, stride=1, pad=2))
    us = time_us(lambda: jax.block_until_ready(jconv(x, w)), repeats=3)
    rows.append(("kernels.conv2d_ref.alexnet_conv2", us, "xla_conv"))

    # fused conv+bias+relu: one tiled-kernel launch where the seed path
    # needed three ops (conv kernel, XLA bias broadcast, XLA relu)
    bias = jax.random.normal(jax.random.fold_in(key, 12), (192,)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.conv2d(x, w, stride=1, pad=2, bias=bias, activation="relu")),
        repeats=3)
    rows.append(("kernels.conv2d_fused.alexnet_conv2", us,
                 "1_launch_vs_seed_3_ops"))
    jseed = jax.jit(lambda a, b, c: jax.nn.relu(
        ref.conv2d_ref(a, b, stride=1, pad=2) + c[None, :, None, None]))
    us = time_us(lambda: jax.block_until_ready(jseed(x, w, bias)), repeats=3)
    rows.append(("kernels.conv2d_unfused3.alexnet_conv2", us,
                 "xla_conv+bias+relu"))

    # the VMEM-busting shapes the seed kernel (whole-image staging) could
    # not hold in a 16 MB core: VGG16 conv1-conv3 + MobileNetV2 dw convs
    conv_shapes = [  # name, cin, hw, cout, K, stride, pad, groups
        ("vgg16_conv1", 3, 224, 64, 3, 1, 1, 1),
        ("vgg16_conv2", 64, 224, 64, 3, 1, 1, 1),
        ("vgg16_conv3", 64, 112, 128, 3, 1, 1, 1),
        ("mbv2_dw_s2_96", 96, 112, 96, 3, 2, 1, 96),
        ("mbv2_dw_s1_384", 384, 14, 384, 3, 1, 1, 384),
    ]
    for name, cin, hw, cout, K, s, p, g in conv_shapes:
        xc = jax.random.normal(key, (1, cin, hw, hw), jnp.float32) * 0.3
        wc = jax.random.normal(jax.random.fold_in(key, 13),
                               (cout, cin // g, K, K), jnp.float32) * 0.1
        bc = jax.random.normal(jax.random.fold_in(key, 14),
                               (cout,), jnp.float32) * 0.1
        plan = plan_conv(xc.shape, wc.shape, stride=s, pad=p, groups=g)
        us = time_us(lambda: jax.block_until_ready(
            ops.conv2d(xc, wc, stride=s, pad=p, bias=bc,
                       activation="relu", groups=g)), repeats=3)
        h_out = (hw + 2 * p - K) // s + 1
        flops = 2 * K * K * (cin // g) * cout * h_out * h_out
        rows.append((f"kernels.conv2d_tiled.{name}", us,
                     f"tile_h={plan.tile_h} vmem_bytes={plan.vmem_bytes} "
                     f"analytic_v5e_us="
                     f"{flops / V5E_PEAK_FLOPS_BF16 * 1e6:.2f}"))
        jc = jax.jit(functools.partial(ref.conv2d_ref, stride=s, pad=p,
                                       bias=bc, activation="relu", groups=g))
        us = time_us(lambda: jax.block_until_ready(jc(xc, wc)), repeats=3)
        rows.append((f"kernels.conv2d_ref.{name}", us, "xla_conv"))

    # rwkv6 wkv: 64 tokens x 2 heads
    b, t, h, hd2 = 1, 64, 2, 64
    r = jax.random.normal(key, (b, t, h, hd2)) * 0.3
    kk = jax.random.normal(jax.random.fold_in(key, 4), (b, t, h, hd2)) * 0.3
    vv = jax.random.normal(jax.random.fold_in(key, 5), (b, t, h, hd2)) * 0.3
    ww = jax.nn.sigmoid(jax.random.normal(jax.random.fold_in(key, 6),
                                          (b, t, h, hd2))) * 0.5 + 0.45
    u = jax.random.normal(jax.random.fold_in(key, 7), (h, hd2)) * 0.1
    us = time_us(lambda: jax.block_until_ready(
        ops.rwkv6_wkv(r, kk, vv, ww, u, block_t=32)), repeats=3)
    rows.append(("kernels.rwkv6_wkv.64tok", us, "interpret"))

    # mamba2 ssd: 128 tokens
    x2 = jax.random.normal(key, (1, 128, 2, 32)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 8),
                                           (1, 128, 2)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 9), (2,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 10), (1, 128, 2, 16)) * 0.4
    Cm = jax.random.normal(jax.random.fold_in(key, 11), (1, 128, 2, 16)) * 0.4
    us = time_us(lambda: jax.block_until_ready(
        ops.mamba2_ssd(x2, dt, A, Bm, Cm, chunk=64)), repeats=3)
    rows.append(("kernels.mamba2_ssd.128tok", us, "interpret"))
    return rows
