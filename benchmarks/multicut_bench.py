"""Beyond-paper: K-cut chain splits (edge accelerator -> edge pod ->
regional -> core).  Reports the GA plan vs brute force (where tractable)
and the GA's advantage as K grows; the smoke variant plans the paper's
CNN chains (``smartsplit_chain``) and prices microbatch pipelining.

Artifacts: ``benchmarks/out/BENCH_multicut{_smoke}.json``."""
from __future__ import annotations

import itertools
import time

import numpy as np

from benchmarks.common import save_json
from repro.core import paper_chain, smartsplit_chain
from repro.core.hardware import DCN_LINK, tpu_pod_tier
from repro.core.multicut import (ChainHardware, evaluate_multicut,
                                 smartsplit_multicut)
from repro.core.nsga2 import NSGA2Config
from repro.core.pareto import exhaustive_pareto
from repro.core.topsis import topsis_select
from repro.models.profiles import cnn_profile, transformer_profile


def _chain(K: int) -> ChainHardware:
    tiers = tuple(tpu_pod_tier(f"tier{k}", chips=4 * 4**k)
                  for k in range(K))
    return ChainHardware(tiers=tiers, links=tuple([DCN_LINK] * (K - 1)))


def run_smoke() -> list[tuple]:
    """CI-sized variant: exhaustive chain plans for the paper CNN on the
    phone->edge->core environment, priced at M=1 vs M=4 microbatches."""
    rows = []
    art = {}
    prof = cnn_profile("alexnet", batch=4, in_shape=(3, 96, 96))
    for K in (2, 3):
        hw = paper_chain(K)
        t0 = time.time()
        plan = smartsplit_chain(prof, hw)
        wall_s = time.time() - t0
        plan_m4 = smartsplit_chain(prof, hw, microbatches=4)
        entry = {"cuts": list(plan.cuts), "tiers": list(plan.tiers),
                 "latency_s": plan.objectives[0],
                 "energy_j": plan.objectives[1],
                 "device_mem_bytes": plan.objectives[2],
                 "m4_cuts": list(plan_m4.cuts),
                 "m4_latency_s": plan_m4.objectives[0],
                 "pipeline_speedup": plan.objectives[0]
                 / max(plan_m4.objectives[0], 1e-12),
                 "wall_s": round(wall_s, 3)}
        art[f"K={K}"] = entry
        rows.append((f"multicut/smoke.alexnet.K{K}.cuts", None,
                     "/".join(map(str, plan.cuts)) or "none"))
        rows.append((f"multicut/smoke.alexnet.K{K}.latency_s",
                     plan.objectives[0] * 1e6,
                     f"m1={plan.objectives[0]:.5f}s"
                     f" m4={plan_m4.objectives[0]:.5f}s"
                     f" speedup={entry['pipeline_speedup']:.3f}x"))
    path = save_json("", "BENCH_multicut_smoke.json", art)
    rows.append(("multicut/smoke.artifact", None, str(path)))
    return rows


def run_all(smoke: bool = False) -> list[tuple]:
    if smoke:
        return run_smoke()
    rows = []
    art = {}
    from repro.configs import all_configs
    prof = transformer_profile(all_configs()["internvl2-76b"],
                               seq_len=8192, batch=8, mode="prefill")
    for K in (2, 3, 4, 6):
        hw = _chain(K)
        t0 = time.time()
        plan = smartsplit_multicut(
            prof, hw, NSGA2Config(pop_size=128, generations=80, seed=0))
        ga_s = time.time() - t0
        entry = {"cuts": list(plan.cuts),
                 "latency_s": plan.objectives[0],
                 "energy_j": plan.objectives[1],
                 "peak_mem_frac": plan.objectives[2],
                 "ga_wall_s": round(ga_s, 2)}
        # brute force for small K (L=80: K=3 -> 3k pts, K=4 -> 80k pts)
        L = prof.num_layers
        if K <= 4:
            cands = np.array(list(
                itertools.combinations(range(1, L), K - 1)), np.int64)
            t0 = time.time()
            F = evaluate_multicut(prof, hw, cands)
            front = exhaustive_pareto(F)
            pick = topsis_select(F[front])
            entry["bruteforce_latency_s"] = float(F[front][pick][0])
            entry["bruteforce_wall_s"] = round(time.time() - t0, 2)
            entry["ga_vs_bf_latency"] = round(
                plan.objectives[0] / max(F[front][pick][0], 1e-12), 4)
        art[f"K={K}"] = entry
        rows.append((f"multicut.internvl2.K{K}.cuts", None,
                     "/".join(map(str, plan.cuts))))
        rows.append((f"multicut.internvl2.K{K}.latency_s", ga_s * 1e6,
                     f"{plan.objectives[0]:.5f}"))
    save_json("", "BENCH_multicut.json", art)
    return rows
