"""Chaos harness: fault-rate x model x dtype robustness sweep.

Runs the fault-tolerant split runtime (``repro.runtime``) against seeded
flaky-link profiles and measures what the recovery machinery costs and
whether it ever loses a request: per cell we record success rate, added
link latency (p50/p99 of virtual link time beyond the ideal fault-free
transfer), wire amplification (retransmitted bytes), recovery counts
(retries, device fallbacks, Pareto-front re-picks), and -- for the clean
profile -- bit-identity of the full runtime path against ``apply_split``.

Headline artifact: ``benchmarks/out/BENCH_robustness{_smoke}.json``.

CLI: ``python -m benchmarks.robustness_bench [--smoke] [--seeds 0,1,2]``.
"""
from __future__ import annotations

import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json, time_us
from repro.core import (PAPER_ENV_J6, paper_chain, smartsplit_chain,
                        smartsplit_exhaustive)
from repro.models import cnn as cnn_lib
from repro.models.profiles import cnn_profile
from repro.runtime import (ChainRuntime, FaultSpec, FaultyLink, RetryPolicy,
                           SplitRuntime, VirtualClock, microbatch_slices)
from repro.runtime.events import CHECKSUM_FAIL

MODELS = ("alexnet", "vgg16", "mobilenetv2")
SMOKE_MODELS = ("alexnet", "mobilenetv2")
DTYPES = ("fp32", "bf16")

# Acceptance profile: 30% drops plus one outage window opening at t=0 so
# every run's first transfer provably collides with it (a transfer whose
# wire time overlaps a window dies -- see FaultyLink.outage_overlaps).
FAULT_PROFILES: dict[str, FaultSpec] = {
    "clean": FaultSpec(),
    "drop10": FaultSpec(drop_rate=0.10),
    "drop30_outage": FaultSpec(drop_rate=0.30, outages=((0.0, 1.0),)),
}

# The paper link moves ~1.25 MB/s, so boundary payloads of a few MB need
# seconds on the virtual clock -- 16s covers the largest VGG16 fp32
# boundary (12.8 MB ~ 10.2s) with slack; smoke payloads are KBs, so a 2s
# timeout keeps its retry ladders (and reported added latency) small.
POLICY = RetryPolicy(max_attempts=5, timeout_s=16.0, backoff_base_s=0.05)
POLICY_SMOKE = RetryPolicy(max_attempts=5, timeout_s=2.0,
                           backoff_base_s=0.05)


def _ideal_transfer_s(link: FaultyLink, nbytes: int) -> float:
    return link.latency_s + nbytes / link.bandwidth


def run_cell(model: str, dtype: str, profile_name: str, spec: FaultSpec,
             seeds: tuple[int, ...], in_shape: tuple, requests: int,
             params, x, policy: RetryPolicy = POLICY) -> dict:
    """One (model, dtype, fault-profile) cell across link seeds."""
    hw = PAPER_ENV_J6
    prof = cnn_profile(model, in_shape=in_shape, dtype=dtype)
    plan = smartsplit_exhaustive(prof, hw)
    layers = cnn_lib.CNN_MODELS[model]
    ref_logits, ref_boundary = cnn_lib.apply_split(
        layers, params, x, plan.split_index, dtype=dtype)
    ref_np = np.asarray(ref_logits)

    added_s: list[float] = []
    completed = 0
    total = 0
    bit_identical = True
    agg = {"recovered": 0, "fallback_device": 0, "repicks": 0,
           "proactive_resplits": 0, "attempts": 0,
           "retransmitted_bytes": 0, "wire_bytes": 0}
    for seed in seeds:
        link = FaultyLink(hw.link.bandwidth, faults=spec, seed=seed)
        rt = SplitRuntime(model, params, plan, prof, hw, link=link,
                          dtype=dtype, policy=policy, jitter_seed=seed)
        for _ in range(requests):
            total += 1
            r = rt.infer(x)
            jax.block_until_ready(r.logits)
            completed += 1
            ideal = _ideal_transfer_s(link, r.goodput_bytes) \
                if not r.on_device else 0.0
            added_s.append(max(r.link_elapsed_s - ideal, 0.0))
            agg["attempts"] += r.attempts
            agg["retransmitted_bytes"] += r.retransmitted_bytes
            agg["wire_bytes"] += r.wire_bytes
            if not r.degraded:
                bit_identical &= bool(
                    np.array_equal(np.asarray(r.logits), ref_np))
        s = rt.stats()
        for k in ("recovered", "fallback_device", "repicks",
                  "proactive_resplits"):
            agg[k] += s[k]
    return {
        "model": model, "dtype": dtype, "profile": profile_name,
        "split_index": plan.split_index,
        "boundary_bytes": int(np.asarray(ref_boundary).nbytes),
        "requests": total,
        "completed": completed,
        "success_rate": completed / total,
        "added_latency_p50_s": float(np.percentile(added_s, 50)),
        "added_latency_p99_s": float(np.percentile(added_s, 99)),
        "bit_identical_when_clean": bit_identical,
        **agg,
        "faults": {"drop_rate": spec.drop_rate,
                   "corrupt_rate": spec.corrupt_rate,
                   "delay_rate": spec.delay_rate,
                   "outages": list(spec.outages)},
        "seeds": list(seeds),
    }


# --------------------------------------------------------------------------
# Quantized-wire cells: corrupt-frame faults against int8 boundary payloads
# --------------------------------------------------------------------------

# Every third attempt (on average) delivers a flipped byte somewhere in the
# framed (scales, data) payload; the per-part crc32s must catch it, name
# the frame it hit, and the retry ladder must recover every request.
QUANT_FAULTS = FaultSpec(corrupt_rate=0.35)


def run_quant_cell(model: str, profile_name: str, spec: FaultSpec,
                   seeds: tuple[int, ...], in_shape: tuple, requests: int,
                   params, x, policy: RetryPolicy = POLICY) -> dict:
    """One int8-wire (model, corrupt-profile) cell across link seeds.

    The fault-free reference is ``apply_split(wire="int8")`` -- the same
    quantize/dequantize math the runtime codec performs -- so undegraded
    requests must match it bit-for-bit even while corrupted attempts are
    being caught and retried."""
    hw = PAPER_ENV_J6
    prof = cnn_profile(model, in_shape=in_shape)
    plan = smartsplit_exhaustive(prof, hw, wire="int8")
    layers = cnn_lib.CNN_MODELS[model]
    ref_logits, _ = cnn_lib.apply_split(layers, params, x,
                                        plan.split_index, wire="int8")
    ref_np = np.asarray(ref_logits)
    completed = total = 0
    bit_identical = True
    part_hits = {"scales": 0, "data": 0, "header": 0}
    agg = {"recovered": 0, "fallback_device": 0, "repicks": 0,
           "attempts": 0, "retransmitted_bytes": 0, "wire_bytes": 0,
           "raw_bytes": 0}
    for seed in seeds:
        link = FaultyLink(hw.link.bandwidth, faults=spec, seed=seed)
        rt = SplitRuntime(model, params, plan, prof, hw, link=link,
                          wire="int8", policy=policy, jitter_seed=seed)
        for _ in range(requests):
            total += 1
            r = rt.infer(x)
            jax.block_until_ready(r.logits)
            completed += 1
            agg["attempts"] += r.attempts
            agg["retransmitted_bytes"] += r.retransmitted_bytes
            agg["wire_bytes"] += r.wire_bytes
            if not r.degraded:
                bit_identical &= bool(
                    np.array_equal(np.asarray(r.logits), ref_np))
        for e in rt.log.events:
            if e.kind == CHECKSUM_FAIL:
                part_hits[e.detail.get("part", "header")] += 1
        s = rt.stats()
        for k in ("recovered", "fallback_device", "repicks"):
            agg[k] += s[k]
        agg["raw_bytes"] += s["hops"][0]["raw_bytes"]
    goodput = agg["wire_bytes"] - agg["retransmitted_bytes"]
    return {
        "model": model, "wire": "int8", "profile": profile_name,
        "split_index": plan.split_index,
        "requests": total,
        "completed": completed,
        "success_rate": completed / total,
        "bit_identical_when_undegraded": bit_identical,
        "corrupt_frame_hits": part_hits,
        "wire_reduction_vs_raw": agg["raw_bytes"] / goodput
        if goodput else 0.0,
        **agg,
        "faults": {"corrupt_rate": spec.corrupt_rate},
        "seeds": list(seeds),
    }


def quant_sweep(*, models=MODELS, seeds=(0,),
                in_shape=cnn_lib.INPUT_SHAPE, requests: int = 6,
                policy: RetryPolicy = POLICY) -> list[dict]:
    cells = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1,) + in_shape), jnp.float32)
    for model in models:
        params = cnn_lib.init_cnn(jax.random.PRNGKey(0),
                                  cnn_lib.CNN_MODELS[model], in_shape)
        cells.append(run_quant_cell(model, "quant_corrupt35", QUANT_FAULTS,
                                    tuple(seeds), in_shape, requests,
                                    params, x, policy=policy))
    return cells


# --------------------------------------------------------------------------
# N-tier chain cells (ChainRuntime): microbatch pipelining + mid-chain outage
# --------------------------------------------------------------------------

# Each config runs two chain profiles: ``chain_clean`` (M=1 vs M=pipeline_m
# on zero-fault links -- the pipelining headline) and ``chain_midhop_outage``
# (the middle hop is dead from t=0; every request must recover via a stage
# merge or a Pareto re-pick).
CHAIN_CONFIGS_SMOKE = (
    dict(model="alexnet", num_tiers=3, in_shape=(3, 96, 96), batch=4,
         requests=3, pipeline_m=4),
)
# Full mode adds the acceptance shape: a 4-tier VGG16 chain at the paper's
# native 224px input.
CHAIN_CONFIGS = CHAIN_CONFIGS_SMOKE + (
    dict(model="vgg16", num_tiers=4, in_shape=cnn_lib.INPUT_SHAPE, batch=4,
         requests=2, pipeline_m=4),
)


def _chain_links(hw, seed: int, outage_hop: int | None = None
                 ) -> list[FaultyLink]:
    """Per-hop links on one shared virtual clock; ``outage_hop`` (if any)
    is dead from t=0 onward."""
    clock = VirtualClock()
    links = []
    for k, link in enumerate(hw.links):
        spec = FaultSpec(outages=((0.0, 1e9),)) if k == outage_hop \
            else FaultSpec()
        links.append(FaultyLink(link.bandwidth, faults=spec,
                                seed=seed + k, clock=clock))
    return links


def run_chain_cell(cfg: dict, dtype: str, profile_name: str,
                   seeds: tuple[int, ...],
                   policy: RetryPolicy = POLICY) -> dict:
    """One (chain-config, dtype, fault-profile) cell across link seeds."""
    model, num_tiers = cfg["model"], cfg["num_tiers"]
    in_shape, batch = cfg["in_shape"], cfg["batch"]
    requests, pipeline_m = cfg["requests"], cfg["pipeline_m"]
    hw = paper_chain(num_tiers)
    prof = cnn_profile(model, batch=batch, in_shape=in_shape, dtype=dtype)
    plan = smartsplit_chain(prof, hw)
    layers = cnn_lib.CNN_MODELS[model]
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), layers, in_shape)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(batch,) + in_shape), jnp.float32)

    # Single-device reference at each microbatch granularity: XLA convs
    # are not bitwise batch-size-invariant, so the M-microbatch chain is
    # compared against the whole net run on one box over the SAME slices
    # (M=1 degenerates to the plain batched reference).
    def _ref(m: int) -> np.ndarray:
        outs = [cnn_lib.apply_cnn(layers, params, x[a:b], dtype=dtype)
                for a, b in microbatch_slices(batch, m)]
        return np.asarray(jnp.concatenate(outs, axis=0))

    outage_hop = (num_tiers - 1) // 2 if profile_name == "chain_midhop_outage" \
        else None
    completed = 0
    total = 0
    bit_identical = True
    elapsed: dict[int, list[float]] = {}
    agg = {"recovered": 0, "merges": 0, "repicks": 0, "attempts": 0,
           "retransmitted_bytes": 0, "wire_bytes": 0}
    # clean cells sweep M in {1, pipeline_m} to measure the pipelining win;
    # outage cells only need the pipelined path under fire
    m_values = (1, pipeline_m) if outage_hop is None else (pipeline_m,)
    for m in m_values:
        elapsed[m] = []
        ref_np = _ref(m)
        for seed in seeds:
            rt = ChainRuntime(model, params, plan, prof, hw,
                              links=_chain_links(hw, seed, outage_hop),
                              dtype=dtype, policy=policy, microbatches=m,
                              jitter_seed=seed)
            for _ in range(requests):
                total += 1
                r = rt.infer(x)
                jax.block_until_ready(r.logits)
                completed += 1
                elapsed[m].append(r.chain_elapsed_s)
                agg["attempts"] += r.attempts
                agg["retransmitted_bytes"] += r.retransmitted_bytes
                agg["wire_bytes"] += r.wire_bytes
                bit_identical &= bool(
                    np.array_equal(np.asarray(r.logits), ref_np))
            s = rt.stats()
            agg["recovered"] += s["recovered"]
            agg["merges"] += s["merges"]
            agg["repicks"] += s["repicks"]
    lat = {m: float(np.mean(v)) for m, v in elapsed.items()}
    cell = {
        "model": model, "dtype": dtype, "profile": profile_name,
        "num_tiers": num_tiers, "cuts": list(plan.cuts),
        "tiers": list(plan.tiers), "batch": batch,
        "pipeline_m": pipeline_m,
        "requests": total, "completed": completed,
        "success_rate": completed / total,
        "bit_identical": bit_identical,
        "chain_latency_s": {str(m): lat[m] for m in lat},
        **agg,
        "outage_hop": outage_hop,
        "seeds": list(seeds),
    }
    if 1 in lat and pipeline_m in lat and lat[pipeline_m] > 0:
        cell["pipeline_speedup"] = lat[1] / lat[pipeline_m]
    return cell


def chain_sweep(*, configs=CHAIN_CONFIGS, dtypes=DTYPES,
                seeds=(0,), policy: RetryPolicy = POLICY) -> list[dict]:
    cells = []
    for cfg in configs:
        for dtype in dtypes:
            for pname in ("chain_clean", "chain_midhop_outage"):
                cells.append(run_chain_cell(cfg, dtype, pname,
                                            tuple(seeds), policy=policy))
    return cells


def chaos_sweep(*, models=MODELS, dtypes=DTYPES, profiles=None,
                seeds=(0,), in_shape=cnn_lib.INPUT_SHAPE,
                requests: int = 6,
                policy: RetryPolicy = POLICY) -> dict:
    profiles = profiles if profiles is not None else FAULT_PROFILES
    cells = []
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1,) + in_shape), jnp.float32)
    for model in models:
        params = cnn_lib.init_cnn(jax.random.PRNGKey(0),
                                  cnn_lib.CNN_MODELS[model], in_shape)
        for dtype in dtypes:
            for pname, spec in profiles.items():
                cells.append(run_cell(model, dtype, pname, spec, seeds,
                                      in_shape, requests, params, x,
                                      policy=policy))
    return {
        "bench": "robustness",
        "hardware": "paper-j6",
        "in_shape": list(in_shape),
        "requests_per_cell": requests,
        "retry_policy": {"max_attempts": policy.max_attempts,
                         "timeout_s": policy.timeout_s,
                         "backoff_base_s": policy.backoff_base_s},
        "cells": cells,
    }


def run_all(smoke: bool = False, seeds: tuple[int, ...] | None = None):
    """Bench-contract entry: returns ``(name, us, derived)`` rows and
    writes BENCH_robustness{_smoke}.json."""
    if smoke:
        seeds = seeds if seeds is not None else (0, 1, 2)
        sweep = dict(models=SMOKE_MODELS, in_shape=(3, 96, 96),
                     requests=4, seeds=tuple(seeds),
                     policy=POLICY_SMOKE)
        chain = dict(configs=CHAIN_CONFIGS_SMOKE, seeds=tuple(seeds),
                     policy=POLICY_SMOKE)
        quant = dict(models=SMOKE_MODELS, in_shape=(3, 96, 96),
                     requests=4, seeds=tuple(seeds), policy=POLICY_SMOKE)
    else:
        seeds = seeds if seeds is not None else (0,)
        sweep = dict(models=MODELS, requests=6, seeds=tuple(seeds))
        chain = dict(configs=CHAIN_CONFIGS, seeds=tuple(seeds))
        quant = dict(models=MODELS, requests=6, seeds=tuple(seeds))

    report = {}

    def build():
        report["out"] = chaos_sweep(**sweep)
        report["out"]["chain_cells"] = chain_sweep(**chain)
        report["out"]["quant_cells"] = quant_sweep(**quant)

    us = time_us(build, repeats=1, warmup=0)
    out = report["out"]
    name = "BENCH_robustness_smoke.json" if smoke \
        else "BENCH_robustness.json"
    path = save_json("", name, out)
    rows = []
    for c in out["cells"]:
        rows.append((
            f"robustness/{c['model']}.{c['dtype']}.{c['profile']}",
            round(c["added_latency_p50_s"] * 1e6, 1),
            f"success={c['success_rate']:.2f}"
            f" p99_added={c['added_latency_p99_s']:.3f}s"
            f" fallbacks={c['fallback_device']}"
            f" repicks={c['repicks']}"
            f" retx_bytes={c['retransmitted_bytes']}"))
    for c in out["chain_cells"]:
        m_hi = str(c["pipeline_m"])
        lat_hi = c["chain_latency_s"][m_hi]
        derived = (f"success={c['success_rate']:.2f}"
                   f" lat_m{m_hi}={lat_hi:.4f}s"
                   f" merges={c['merges']} repicks={c['repicks']}"
                   f" bitid={c['bit_identical']}")
        if "pipeline_speedup" in c:
            derived += (f" lat_m1={c['chain_latency_s']['1']:.4f}s"
                        f" speedup={c['pipeline_speedup']:.3f}x")
        rows.append((
            f"robustness/chain{c['num_tiers']}.{c['model']}.{c['dtype']}"
            f".{c['profile']}",
            round(lat_hi * 1e6, 1), derived))
    for c in out["quant_cells"]:
        hits = c["corrupt_frame_hits"]
        rows.append((
            f"robustness/quant.{c['model']}.{c['profile']}", None,
            f"success={c['success_rate']:.2f}"
            f" bitid={c['bit_identical_when_undegraded']}"
            f" frame_hits=scales:{hits['scales']}/data:{hits['data']}"
            f" wire_reduction={c['wire_reduction_vs_raw']:.2f}x"))
    all_cells = out["cells"] + out["chain_cells"] + out["quant_cells"]
    n_ok = sum(c["success_rate"] == 1.0 for c in all_cells)
    rows.append((f"robustness/sweep[{len(all_cells)}cells]",
                 round(us, 1),
                 f"all_complete={n_ok}/{len(all_cells)} -> {path}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated link seeds (e.g. 0,1,2)")
    args = ap.parse_args()
    seeds = tuple(int(s) for s in args.seeds.split(",")) \
        if args.seeds else None
    from benchmarks.common import emit
    emit([], header=True)
    emit(run_all(smoke=args.smoke, seeds=seeds))


if __name__ == "__main__":
    sys.exit(main())
