"""Tier-fault chaos harness: compute-side failures under the recovery
ladder (circuit breakers + standby-tier failover).

The link-side twin is ``robustness_bench``; this bench injects faults
into the *tiers* instead -- crash windows, stragglers, memory-pressure
shedding -- via seeded ``FaultyTier`` models on the shared virtual
clock, and measures what the six-rung degradation ladder (retry ->
stage merge -> cached-front re-pick -> standby-tier failover -> device
fallback -> unrecoverable) costs and whether it ever loses or silently
corrupts a request.  Per cell we record success rate, added chain
latency vs a fault-free baseline (p50/p99), failover / device-fallback
/ breaker-open counts, the NSGA-II run count across recoveries (a
standby failover must be a cached-front TOPSIS pass, never a GA
re-run), and the headline guarantee: every request is either
bit-identical to the fault-free reference or flagged ``degraded`` with
the recovery on the event log -- never a silent wrong answer.

Headline artifact: ``benchmarks/out/BENCH_tier_faults{_smoke}.json``.

CLI: ``python -m benchmarks.tier_faults_bench [--smoke] [--seeds 0,1,2]``.
"""
from __future__ import annotations

import argparse
import importlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json, time_us
from repro.core import paper_chain, smartsplit_chain
from repro.models import cnn as cnn_lib
from repro.models.profiles import cnn_profile
from repro.runtime import (ChainRuntime, FaultyLink, FaultyTier,
                           TierFaultSpec, VirtualClock, microbatch_slices)

nsga2_mod = importlib.import_module("repro.core.nsga2")

# Fault profiles, each targeting the chain's middle tier (the phone,
# tier 0, never fails: it has no failover story).  The crash window is
# permanent -- like robustness_bench's dead-hop outage -- so every
# request provably collides with it and must ride the standby spare;
# the shed budget is 1 byte for the same reason.  ``merge_fallback`` is
# disabled on the failing profiles so the ladder cannot stop at a stage
# merge: the cells exercise breaker-gated standby failover specifically.
TIER_PROFILES: dict[str, TierFaultSpec] = {
    "tier_clean": TierFaultSpec(),
    "tier_crash_window": TierFaultSpec(crash_windows=((0.0, 1e9),)),
    "tier_straggler": TierFaultSpec(slow_rate=0.6, slow_factor=8.0),
    "tier_shed": TierFaultSpec(mem_budget=1.0),
}
NO_MERGE_PROFILES = ("tier_crash_window", "tier_shed")

CONFIGS_SMOKE = (
    dict(model="alexnet", num_tiers=3, in_shape=(3, 96, 96), batch=4,
         requests=3, microbatches=2),
)
CONFIGS = CONFIGS_SMOKE + (
    dict(model="mobilenetv2", num_tiers=4, in_shape=(3, 96, 96), batch=4,
         requests=3, microbatches=2),
)


def _clean_links(hw, seed: int) -> list[FaultyLink]:
    clock = VirtualClock()
    return [FaultyLink(link.bandwidth, seed=seed + k, clock=clock)
            for k, link in enumerate(hw.links)]


def _tier_models(hw, spec: TierFaultSpec, faulty: int, seed: int,
                 clock: VirtualClock) -> list[FaultyTier]:
    return [FaultyTier(t.name,
                       faults=spec if k == faulty else TierFaultSpec(),
                       seed=seed + k, clock=clock)
            for k, t in enumerate(hw.tiers)]


def run_cell(cfg: dict, profile_name: str, spec: TierFaultSpec,
             seeds: tuple[int, ...]) -> dict:
    """One (chain-config, tier-fault-profile) cell across seeds."""
    model, num_tiers = cfg["model"], cfg["num_tiers"]
    in_shape, batch = cfg["in_shape"], cfg["batch"]
    requests, m = cfg["requests"], cfg["microbatches"]
    hw = paper_chain(num_tiers)
    prof = cnn_profile(model, batch=batch, in_shape=in_shape)
    plan = smartsplit_chain(prof, hw, microbatches=m)
    layers = cnn_lib.CNN_MODELS[model]
    params = cnn_lib.init_cnn(jax.random.PRNGKey(0), layers, in_shape)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(batch,) + in_shape), jnp.float32)
    faulty = num_tiers // 2
    merge_fallback = False if profile_name in NO_MERGE_PROFILES else None

    # Fault-free reference logits (same microbatch slices -- XLA convs
    # are not batch-size-invariant) and fault-free baseline elapsed.
    outs = [cnn_lib.apply_cnn(layers, params, x[a:b])
            for a, b in microbatch_slices(batch, m)]
    ref_np = np.asarray(jnp.concatenate(outs, axis=0))
    base_rt = ChainRuntime(layers, params, plan, prof, hw,
                           links=_clean_links(hw, 0), microbatches=m)
    baseline_s = base_rt.infer(x).chain_elapsed_s

    completed = total = 0
    bit_identical = True
    guarantee_held = True
    added_s: list[float] = []
    agg = {"failovers": 0, "fallback_device": 0, "merges": 0,
           "repicks": 0, "breaker_opens": 0, "crashes": 0, "sheds": 0,
           "slowdowns": 0}
    ga_before = nsga2_mod.RUN_COUNT
    ga_construct = 0
    for seed in seeds:
        links = _clean_links(hw, seed)
        clock = links[0]._clock
        tiers = _tier_models(hw, spec, faulty, seed, clock)
        ga0 = nsga2_mod.RUN_COUNT
        rt = ChainRuntime(layers, params, plan, prof, hw, links=links,
                          microbatches=m, tier_faults=tiers,
                          merge_fallback=merge_fallback, jitter_seed=seed)
        ga_construct += nsga2_mod.RUN_COUNT - ga0
        for _ in range(requests):
            total += 1
            r = rt.infer(x)
            jax.block_until_ready(r.logits)
            completed += 1
            added_s.append(max(r.chain_elapsed_s - baseline_s, 0.0))
            same = bool(np.array_equal(np.asarray(r.logits), ref_np))
            bit_identical &= same
            # the never-silently-wrong contract: a non-identical answer
            # must carry the degraded flag (and its recovery events)
            guarantee_held &= same or r.degraded
        s = rt.stats()
        for k in ("failovers", "fallback_device", "merges", "repicks"):
            agg[k] += s[k]
        agg["breaker_opens"] += sum(b["opens"] for b in s["breakers"])
        for t in s["tiers"]:
            agg["crashes"] += t["crashes"]
            agg["sheds"] += t["sheds"]
            agg["slowdowns"] += t["slowdowns"]
    return {
        "model": model, "profile": profile_name,
        "num_tiers": num_tiers, "faulty_tier": faulty,
        "cuts": list(plan.cuts), "batch": batch, "microbatches": m,
        "requests": total, "completed": completed,
        "success_rate": completed / total,
        "bit_identical": bit_identical,
        "guarantee_held": guarantee_held,
        "baseline_latency_s": baseline_s,
        "added_latency_p50_s": float(np.percentile(added_s, 50)),
        "added_latency_p99_s": float(np.percentile(added_s, 99)),
        # GA runs during *recovery* (standby prewarm at construction is
        # the one legitimate planning moment; failover must be cache-hit)
        "nsga2_runs_recovery":
            nsga2_mod.RUN_COUNT - ga_before - ga_construct,
        **agg,
        "faults": {"crash_windows": list(spec.crash_windows),
                   "slow_rate": spec.slow_rate,
                   "slow_factor": spec.slow_factor,
                   "mem_budget": spec.mem_budget},
        "seeds": list(seeds),
    }


def sweep(*, configs=CONFIGS, profiles=None,
          seeds=(0, 1, 2)) -> dict:
    profiles = profiles if profiles is not None else TIER_PROFILES
    cells = [run_cell(cfg, pname, spec, tuple(seeds))
             for cfg in configs for pname, spec in profiles.items()]
    return {"bench": "tier_faults", "hardware": "paper-chain",
            "cells": cells}


def run_all(smoke: bool = False, seeds: tuple[int, ...] | None = None):
    """Bench-contract entry: returns ``(name, us, derived)`` rows and
    writes BENCH_tier_faults{_smoke}.json."""
    seeds = seeds if seeds is not None else (0, 1, 2)
    configs = CONFIGS_SMOKE if smoke else CONFIGS
    report = {}

    def build():
        report["out"] = sweep(configs=configs, seeds=tuple(seeds))

    us = time_us(build, repeats=1, warmup=0)
    out = report["out"]
    name = "BENCH_tier_faults_smoke.json" if smoke \
        else "BENCH_tier_faults.json"
    path = save_json("", name, out)
    rows = []
    for c in out["cells"]:
        rows.append((
            f"tier_faults/chain{c['num_tiers']}.{c['model']}"
            f".{c['profile']}",
            round(c["added_latency_p50_s"] * 1e6, 1),
            f"success={c['success_rate']:.2f}"
            f" bitid={c['bit_identical']}"
            f" guarantee={c['guarantee_held']}"
            f" p99_added={c['added_latency_p99_s']:.3f}s"
            f" failovers={c['failovers']}"
            f" breaker_opens={c['breaker_opens']}"
            f" ga_reruns={c['nsga2_runs_recovery']}"))
    cells = out["cells"]
    n_ok = sum(c["success_rate"] == 1.0 and c["guarantee_held"]
               for c in cells)
    rows.append((f"tier_faults/sweep[{len(cells)}cells]", round(us, 1),
                 f"all_safe={n_ok}/{len(cells)} -> {path}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seeds", default=None,
                    help="comma-separated tier/link seeds (e.g. 0,1,2)")
    args = ap.parse_args()
    seeds = tuple(int(s) for s in args.seeds.split(",")) \
        if args.seeds else None
    from benchmarks.common import emit
    emit([], header=True)
    emit(run_all(smoke=args.smoke, seeds=seeds))


if __name__ == "__main__":
    sys.exit(main())
