"""Paper reproduction benchmarks: Table I, Table II, Fig 6, Figs 7-9,
Fig 10, and the pilot-study curves (Figs 1-5) -- all model-derived, on the
paper's Samsung-J6 + 10 Mbps + i5-server environment.

Each ``run_*`` returns CSV rows (name, us_per_call, derived) and persists
full JSON artefacts under benchmarks/out/paper/."""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_json, time_us
from repro.core import (ALGORITHMS, PAPER_ENV_J6, PAPER_ENV_NOTE8,
                        energy_terms, evaluate_objectives, latency_terms,
                        smartsplit, smartsplit_exhaustive)
from repro.models.profiles import cnn_profile

TABLE1_MODELS = ["alexnet", "vgg11", "vgg13", "vgg16"]
PAPER_TABLE1 = {"alexnet": 3, "vgg11": 11, "vgg13": 10, "vgg16": 10}
PAPER_TABLE2 = {"LBO": {"alexnet": 3, "vgg11": 21, "vgg13": 20, "vgg16": 25},
                "EBO": {"alexnet": 6, "vgg11": 11, "vgg13": 15, "vgg16": 17}}
# Published ImageNet top-1 (%) -- accuracy cannot be re-measured offline;
# the paper's Fig 10 claim is "split VGG16 ~10% more accurate than
# MobileNetV2 (their test set)"; on ImageNet the published gap direction
# matches for AlexNet vs both.
PUBLISHED_TOP1 = {"alexnet": 56.5, "vgg11": 69.0, "vgg13": 69.9,
                  "vgg16": 71.6, "mobilenetv2": 71.9}


def run_table1() -> list[tuple]:
    """Table I: optimal split layer per model (GA+TOPSIS), both memory
    countings, plus the GA's wall time."""
    rows = []
    art = {}
    for name in TABLE1_MODELS:
        p = cnn_profile(name)
        us = time_us(lambda p=p: smartsplit(p, PAPER_ENV_J6), repeats=3)
        plan_full = smartsplit(p, PAPER_ENV_J6, f3_mode="full")
        plan_cal = smartsplit(p, PAPER_ENV_J6, f3_mode="activations")
        rows.append((f"table1.{name}.split_calibrated", us,
                     plan_cal.split_index))
        rows.append((f"table1.{name}.split_literal", None,
                     plan_full.split_index))
        rows.append((f"table1.{name}.paper", None, PAPER_TABLE1[name]))
        rows.append((f"table1.{name}.paper_in_pareto", None,
                     int(PAPER_TABLE1[name] in plan_full.pareto_indices)))
        art[name] = {"calibrated": plan_cal.split_index,
                     "literal": plan_full.split_index,
                     "paper": PAPER_TABLE1[name],
                     "pareto": sorted(plan_full.pareto_indices)}
    save_json("paper", "table1.json", art)
    return rows


def run_table2() -> list[tuple]:
    """Table II: split index per competing algorithm."""
    rows = []
    art = {}
    rng = np.random.default_rng(0)
    for name in TABLE1_MODELS:
        p = cnn_profile(name)
        entry = {}
        for alg, fn in ALGORITHMS.items():
            idx = fn(p, PAPER_ENV_J6, rng) if alg == "RS" \
                else fn(p, PAPER_ENV_J6)
            entry[alg] = idx
            rows.append((f"table2.{name}.{alg}", None, idx))
        entry["SmartSplit"] = smartsplit_exhaustive(
            p, PAPER_ENV_J6, f3_mode="activations").split_index
        rows.append((f"table2.{name}.SmartSplit", None, entry["SmartSplit"]))
        art[name] = entry
    save_json("paper", "table2.json", art)
    return rows


def run_fig6_pareto() -> list[tuple]:
    """Fig 6: normalised (latency, energy, memory) of every Pareto-set
    solution per model."""
    art = {}
    rows = []
    for name in TABLE1_MODELS:
        p = cnn_profile(name)
        plan = smartsplit_exhaustive(p, PAPER_ENV_J6)
        F = np.asarray(plan.pareto_F, float)
        Fn = F / F.max(axis=0)
        art[name] = {"split_indices": list(plan.pareto_indices),
                     "normalised_F": Fn.tolist()}
        rows.append((f"fig6.{name}.pareto_size", None,
                     len(plan.pareto_indices)))
    save_json("paper", "fig6_pareto.json", art)
    return rows


def run_fig789_compare() -> list[tuple]:
    """Figs 7-9: latency / energy / memory achieved by each algorithm,
    averaged over 100 runs (only RS varies across runs, as in the paper)."""
    rows = []
    art = {}
    rng = np.random.default_rng(1)
    runs = 100
    for name in TABLE1_MODELS:
        p = cnn_profile(name)
        F = evaluate_objectives(p, PAPER_ENV_J6)
        splits = {"SmartSplit": smartsplit_exhaustive(
            p, PAPER_ENV_J6, f3_mode="activations").split_index}
        for alg in ("LBO", "EBO", "COS", "COC"):
            splits[alg] = ALGORITHMS[alg](p, PAPER_ENV_J6)
        art[name] = {}
        for alg, idx in splits.items():
            lat, en, mem = F[idx]
            art[name][alg] = {"split": idx, "latency_s": lat,
                              "energy_j": en, "memory_mb": mem / 2**20}
            rows.append((f"fig7.{name}.{alg}.latency_s", None,
                         round(float(lat), 4)))
            rows.append((f"fig8.{name}.{alg}.energy_j", None,
                         round(float(en), 4)))
            rows.append((f"fig9.{name}.{alg}.memory_mb", None,
                         round(float(mem) / 2**20, 3)))
        # RS: average of 100 random splits
        rs_idx = rng.integers(1, p.num_layers, runs)
        lat, en, mem = F[rs_idx].mean(axis=0)
        art[name]["RS"] = {"split": "random", "latency_s": lat,
                           "energy_j": en, "memory_mb": mem / 2**20}
        rows.append((f"fig7.{name}.RS.latency_s", None, round(float(lat), 4)))
        rows.append((f"fig8.{name}.RS.energy_j", None, round(float(en), 4)))
        rows.append((f"fig9.{name}.RS.memory_mb", None,
                     round(float(mem) / 2**20, 3)))
    save_json("paper", "fig789_compare.json", art)
    return rows


def run_fig10_mobilenet() -> list[tuple]:
    """Fig 10: SmartSplit-split models vs MobileNetV2-on-device (COS) vs
    VGG16-on-device. Accuracy = published top-1 constants (documented)."""
    rows = []
    art = {}
    for name in TABLE1_MODELS + ["mobilenetv2"]:
        p = cnn_profile(name)
        F = evaluate_objectives(p, PAPER_ENV_J6)
        if name == "mobilenetv2":
            idx = p.num_layers            # COS: all on the phone
        else:
            idx = smartsplit_exhaustive(p, PAPER_ENV_J6,
                                        f3_mode="activations").split_index
        lat, en, mem = F[idx]
        art[name] = {"mode": "COS" if name == "mobilenetv2" else "split",
                     "split": idx, "latency_s": lat, "energy_j": en,
                     "memory_mb": mem / 2**20,
                     "published_top1": PUBLISHED_TOP1[name]}
        for metric, val in (("latency_s", lat), ("energy_j", en),
                            ("memory_mb", mem / 2**20),
                            ("top1", PUBLISHED_TOP1[name])):
            rows.append((f"fig10.{name}.{metric}", None,
                         round(float(val), 4)))
    # VGG16 fully on device for the COS comparison bar
    p = cnn_profile("vgg16")
    F = evaluate_objectives(p, PAPER_ENV_J6)
    lat, en, mem = F[p.num_layers]
    art["vgg16_cos"] = {"latency_s": lat, "energy_j": en,
                        "memory_mb": mem / 2**20}
    rows.append(("fig10.vgg16_cos.latency_s", None, round(float(lat), 4)))
    save_json("paper", "fig10_mobilenet.json", art)
    return rows


def run_pilot_curves() -> list[tuple]:
    """Figs 1-5 (pilot study), model-derived: per-split latency and energy
    decompositions for both phones; persisted for plotting."""
    rows = []
    art = {}
    for env_name, env in (("j6", PAPER_ENV_J6), ("note8", PAPER_ENV_NOTE8)):
        art[env_name] = {}
        for name in TABLE1_MODELS:
            p = cnn_profile(name)
            t_c, t_u, t_s, _ = latency_terms(p, env)
            e_c, e_u, e_d = energy_terms(p, env)
            art[env_name][name] = {
                "client_latency": t_c.tolist(),
                "upload_latency": t_u.tolist(),
                "server_latency": t_s.tolist(),
                "client_energy": e_c.tolist(),
                "upload_energy": e_u.tolist(),
                "download_energy": e_d.tolist(),
            }
        # headline claims
        p = cnn_profile("vgg16")
        t_c, t_u, t_s, _ = latency_terms(p, env)
        mid = p.num_layers // 3
        rows.append((f"pilot.{env_name}.vgg16.upload_dominates_early", None,
                     int(t_u[mid] > t_c[mid] and t_u[mid] > t_s[mid])))
    save_json("paper", "pilot_curves.json", art)
    return rows


def run_all() -> list[tuple]:
    rows = []
    for fn in (run_table1, run_table2, run_fig6_pareto, run_fig789_compare,
               run_fig10_mobilenet, run_pilot_curves):
        rows += fn()
    return rows
