"""Repo-root conftest.

Keeps the repo root on sys.path so tests can import the ``benchmarks``
namespace package (shape enumerations, smoke reports) regardless of how
pytest is invoked: ``python -m pytest`` adds the cwd itself, a bare
``pytest`` does not."""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
